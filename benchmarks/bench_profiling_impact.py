"""Paper Fig. 3 / Table 5 — impact of the performance-analysis agent:
iterative+reference vs iterative+reference+profiling at fast_1.0 / fast_1.5.
Campaign-runner based; both configs share one verification cache, so only
the iterations where agent G's recommendation actually diverges from the
blind mutation search cost new verifications."""
from __future__ import annotations

from repro.campaign import VerificationCache, run_campaign
from repro.core import LoopConfig, fast_p, kernelbench
from benchmarks.common import Row, CAMPAIGN_WORKERS, campaign_finals


def run(small: bool = True):
    rows: list[Row] = []
    cache = VerificationCache()
    for cname, prof in (("ref", False), ("ref+prof", True)):
        cfg = LoopConfig(num_iterations=5, use_reference=True,
                         use_profiling=prof)
        for level in (1, 2, 3):
            result = run_campaign(kernelbench.suite(level, small=small), cfg,
                                  cache=cache, max_workers=CAMPAIGN_WORKERS)
            finals = campaign_finals(result)
            for p in (1.0, 1.5):
                rows.append((f"profiling/{cname}/L{level}/p{p}", 0.0,
                             f"{fast_p(finals, p):.3f}"))
    return rows
