"""Paper Fig. 3 / Table 5 — impact of the performance-analysis agent.

Two sections:

* ``profiling/...`` — iterative+reference vs iterative+reference+profiling
  at fast_1.0 / fast_1.5 on the offline template backend: what the single
  rule-table agent G buys over the blind mutation search.
* ``two_agent/...`` — the same profiling-on loop on the LLM backend
  (MockTransport, offline), rule-table agent G vs the LLM analyzer
  (``repro.llm.LLMAnalyzer`` over the same mock transport): measures the
  genuine agent-F/agent-G collaboration data path — prompt rendering,
  analysis-session round trips, reply parsing — not just a rule lookup.
  Emits fast_p rows plus the share of optimization recommendations that
  came from the LLM analyzer and the analysis-session token overhead.

Campaign-runner based; configs of a section share one verification cache,
so only iterations where the recommendation actually diverges cost new
verifications.
"""
from __future__ import annotations

from repro.campaign import Scheduler, VerificationCache, run_campaign
from repro.core import LoopConfig, fast_p, kernelbench
from benchmarks.common import Row, CAMPAIGN_WORKERS, campaign_finals


def run(small: bool = True):
    rows: list[Row] = []
    cache = VerificationCache()
    for cname, prof in (("ref", False), ("ref+prof", True)):
        cfg = LoopConfig(num_iterations=5, use_reference=True,
                         use_profiling=prof)
        for level in (1, 2, 3):
            result = run_campaign(kernelbench.suite(level, small=small), cfg,
                                  cache=cache, max_workers=CAMPAIGN_WORKERS)
            finals = campaign_finals(result)
            for p in (1.0, 1.5):
                rows.append((f"profiling/{cname}/L{level}/p{p}", 0.0,
                             f"{fast_p(finals, p):.3f}"))
    rows.extend(run_two_agent(small=small))
    return rows


def run_two_agent(small: bool = True):
    """LLM generation agent F with rule-table vs LLM agent G (both offline
    on MockTransport): the collaboration measurement."""
    from repro.llm import build_llm_context, MockTransport

    rows: list[Row] = []
    cache = VerificationCache()
    cfg = LoopConfig(num_iterations=3, use_profiling=True)
    workloads = kernelbench.suite(1, small=small)
    for cname, analysis in (("rule", "rule"), ("llm", "llm")):
        # explicit MockTransport: this bench must stay offline even when
        # KFORGE_LLM_ENDPOINT is exported in the environment
        ctx = build_llm_context(transport=MockTransport())
        sched = Scheduler(max_workers=CAMPAIGN_WORKERS)
        result = run_campaign(
            workloads, cfg, cache=cache, scheduler=sched,
            agent_factory=ctx.agent_factory(platform=cfg.platform,
                                            scheduler=sched),
            analyzer_factory=(ctx.analyzer_factory(platform=cfg.platform,
                                                   scheduler=sched)
                              if analysis == "llm" else None),
            usage=ctx.usage)
        finals = campaign_finals(result)
        for p in (1.0, 1.5):
            rows.append((f"two_agent/{cname}/L1/p{p}", 0.0,
                         f"{fast_p(finals, p):.3f}"))
        n_recs = n_llm = 0
        for run_ in result.runs:
            for it in (run_.outcome.logs if run_.outcome else []):
                if it.recommendation_source is not None:
                    n_recs += 1
                    n_llm += it.recommendation_source == "llm"
        usage = result.llm_usage or {}
        rows.append((f"two_agent/{cname}/llm_rec_share", 0.0,
                     f"{n_llm / n_recs:.3f}" if n_recs else "n/a"))
        rows.append((f"two_agent/{cname}/tokens", 0.0,
                     str(usage.get("total_tokens", 0))))
    return rows
