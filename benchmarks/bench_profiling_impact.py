"""Paper Fig. 3 / Table 5 — impact of the performance-analysis agent:
iterative+reference vs iterative+reference+profiling at fast_1.0 / fast_1.5."""
from __future__ import annotations

from repro.core import LoopConfig, fast_p, kernelbench, run_suite
from benchmarks.common import Row


def run(small: bool = True):
    rows: list[Row] = []
    for cname, prof in (("ref", False), ("ref+prof", True)):
        cfg = LoopConfig(num_iterations=5, use_reference=True,
                         use_profiling=prof)
        for level in (1, 2, 3):
            outs = run_suite(kernelbench.suite(level, small=small), cfg)
            finals = [o.final for o in outs]
            for p in (1.0, 1.5):
                rows.append((f"profiling/{cname}/L{level}/p{p}", 0.0,
                             f"{fast_p(finals, p):.3f}"))
    return rows
