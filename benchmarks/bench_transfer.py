"""Paper §6.2 — cross-platform transfer uplift.

Rows: transfer/<from>-><to>/L<level>/<leg>/p<threshold>, value = fast_p;
us_per_call carries the mean best model time of the leg for the level.
One `uplift` row per level gives warm-minus-cold fast_1.

Runs on the transfer sweep harness: one campaign on the source platform,
cold+warm campaigns on the target, one shared verification cache (platform
is part of the content address, so the three legs never collide).
"""
from __future__ import annotations

from benchmarks.common import CAMPAIGN_WORKERS, Row
from repro.campaign import VerificationCache, run_transfer_sweep
from repro.core import LoopConfig, kernelbench

PAIRS = (("tpu_v5e", "gpu_sim"), ("tpu_v5e", "tpu_v4"))
THRESHOLDS = (0.0, 1.0, 1.5)


def run(small: bool = True):
    rows: list[Row] = []
    cache = VerificationCache()
    wls = kernelbench.suite(small=small)
    for src, dst in PAIRS:
        sweep = run_transfer_sweep(
            wls, from_platform=src, to_platform=dst,
            loop=LoopConfig(num_iterations=5, use_profiling=True),
            cache=cache, max_workers=CAMPAIGN_WORKERS)
        rep = sweep.report(thresholds=THRESHOLDS)
        for level, stats in sorted(rep["levels"].items()):
            for leg in ("cold", "warm"):
                for p, v in stats[leg].items():
                    rows.append((f"transfer/{src}->{dst}/L{level}/{leg}/p{p}",
                                 0.0, f"{v:.3f}"))
            rows.append((f"transfer/{src}->{dst}/L{level}/uplift", 0.0,
                         f"{stats['uplift_fast1']:+.3f}"))
        rows.append((f"transfer/{src}->{dst}/total/uplift", 0.0,
                     f"{rep['total']['uplift_fast1']:+.3f}"))
    return rows
