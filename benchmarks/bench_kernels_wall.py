"""Measured CPU wall-clock of the XLA reference implementations (the only
honest wall numbers this container can produce) + interpret-mode parity
check of each Pallas kernel. TPU projections come from the roofline bench.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, wall_us
from repro.kernels import ops, ref


def run(small: bool = True):
    del small
    rng = np.random.default_rng(0)
    rows: list[Row] = []
    x = jnp.asarray(rng.standard_normal((2048, 2048)), jnp.float32)
    g = jnp.asarray(rng.standard_normal((2048,)), jnp.float32)
    rows.append(("wall/ref/swish", wall_us(jax.jit(ref.swish), x), "cpu_xla"))
    rows.append(("wall/ref/rmsnorm",
                 wall_us(jax.jit(lambda a: ref.rmsnorm(a, g)), x), "cpu_xla"))
    rows.append(("wall/ref/softmax", wall_us(jax.jit(ref.softmax), x),
                 "cpu_xla"))
    a = jnp.asarray(rng.standard_normal((512, 512)), jnp.float32)
    rows.append(("wall/ref/matmul512",
                 wall_us(jax.jit(lambda p, q: ref.matmul(p, q)), a, a),
                 "cpu_xla"))
    q = jnp.asarray(rng.standard_normal((1, 512, 8, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 512, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 512, 2, 64)), jnp.float32)
    rows.append(("wall/ref/attention",
                 wall_us(jax.jit(lambda a_, b_, c_: ref.attention(a_, b_, c_)),
                         q, k, v), "cpu_xla"))
    rows.append(("wall/xla/attention_chunked",
                 wall_us(jax.jit(lambda a_, b_, c_: ops.xla_chunked_attention(
                     a_, b_, c_, chunk=128)), q, k, v), "cpu_xla"))
    return rows
