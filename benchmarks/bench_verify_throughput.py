"""Verification fast-path throughput: candidate-verifications/sec, cold vs
warm (DESIGN.md §4).

Both arms verify the IDENTICAL candidate list per workload — a refinement
fan-out shape: the initial candidate, its best predicted mutations, and the
top mutation's own neighborhood (which overlaps the first, as real mutation
neighborhoods do).  The cold arm is the pre-fast-path pipeline: one
``verify()`` per candidate, no caches — inputs regenerated, the reference
oracle recomputed, and every candidate (duplicates included) re-lowered and
re-compiled.  The warm arm sends the same list through ``verify_batch``
with a shared :class:`WorkloadIOCache` + :class:`ExecutableCache`: inputs
and oracle once per workload, duplicates deduped by content address before
any work.

Per-phase timings (``profile["phase_s"]``) from the warm arm are aggregated
so the report shows where the remaining time goes.

Standalone CLI (from the repo root)::

  PYTHONPATH=src python -m benchmarks.bench_verify_throughput --smoke \
      --json BENCH_verify.json          # CI fast lane (level 1 subset)
  PYTHONPATH=src python -m benchmarks.bench_verify_throughput --matrix \
      --json BENCH_verify.json          # + matrix smoke wall-clock arm
  PYTHONPATH=src python -m benchmarks.bench_verify_throughput --grad \
      --smoke --json BENCH_grad.json    # fwd_bwd arm (grad verification)

``--matrix`` additionally runs the 2-platform transfer-matrix smoke twice —
shared IO cache vs caches disabled — and reports the wall-clock win and the
oracle-compute count (strictly below legs × workloads proves cross-leg
sharing).

``--grad`` switches to the training-shaped (``direction="fwd_bwd"``)
throughput arm over the differentiable suite: per-candidate verification
(no IO cache — every candidate re-draws the cotangent and recomputes the
``jax.vjp`` oracle gradients) vs one ``verify_batch`` per workload with a
shared :class:`WorkloadIOCache` (ONE cotangent draw and ONE oracle-gradient
evaluation per workload).  The report carries per-workload pass counts so
CI can surface how many gradient-checked candidates verified CORRECT.

Harness rows (``python benchmarks/run.py --only verify_throughput``):
``verify_cold`` / ``verify_warm`` with verifications/sec and the speedup in
the derived column.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

from benchmarks.common import Row

from repro.core import candidates as cand_mod
from repro.core import kernelbench
from repro.core.evalio import ExecutableCache, WorkloadIOCache
from repro.core.verification import verify, verify_batch

# verifications per workload stay modest: interpret-mode compiles dominate
# and CI boxes are small. smoke trims the workload list, not the shape.
NEIGHBORHOOD = 4        # mutations taken per generation
SEED = 1234


def candidate_list(wl, platform=None) -> List[cand_mod.Candidate]:
    """A refinement-fan-out-shaped candidate list: two overlapping mutation
    neighborhoods around the initial candidate (duplicates kept — the batch
    path is expected to dedupe them, the cold path to pay for them)."""
    init = cand_mod.initial_candidate(wl.op, use_reference=True,
                                      platform=platform)
    gen1 = list(cand_mod.mutations(init, platform).values())[:NEIGHBORHOOD]
    cands = [init] + gen1
    if gen1:
        gen2 = list(cand_mod.mutations(gen1[0], platform)
                    .values())[:NEIGHBORHOOD]
        cands += gen2               # overlaps gen1 (same single-param space)
    return cands


def _bench(workloads, platform=None) -> Dict:
    sets = {wl.name: candidate_list(wl, platform) for wl in workloads}
    n = sum(len(c) for c in sets.values())

    # untimed warmup: first-touch jax/pallas machinery must not be charged
    # to whichever arm happens to run first
    wl0 = workloads[0]
    verify(sets[wl0.name][0], wl0, seed=SEED, platform=platform)

    t0 = time.perf_counter()
    for wl in workloads:
        for cand in sets[wl.name]:
            verify(cand, wl, seed=SEED, platform=platform)
    cold_s = time.perf_counter() - t0

    io_cache, exe_cache = WorkloadIOCache(), ExecutableCache()
    phase_totals: Dict[str, float] = {}
    t0 = time.perf_counter()
    for wl in workloads:
        results = verify_batch(sets[wl.name], wl, seed=SEED,
                               platform=platform, io_cache=io_cache,
                               exe_cache=exe_cache)
        for r in results:
            for k, v in ((r.profile or {}).get("phase_s") or {}).items():
                phase_totals[k] = phase_totals.get(k, 0.0) + v
    warm_s = time.perf_counter() - t0

    return {
        "n_workloads": len(workloads),
        "n_candidates": n,
        "cold_s": round(cold_s, 3),
        "warm_s": round(warm_s, 3),
        "cold_vps": round(n / cold_s, 2),
        "warm_vps": round(n / warm_s, 2),
        "speedup": round(cold_s / warm_s, 2),
        "io_cache": io_cache.stats(),
        "exe_cache": exe_cache.stats(),
        "warm_phase_s": {k: round(v, 3)
                         for k, v in sorted(phase_totals.items())},
    }


def _bench_matrix(small: bool) -> Dict:
    """Matrix-smoke wall-clock arm: the 2-platform level-1 matrix with the
    shared IO/executable caches vs with both disabled (``max_entries=0`` —
    every lookup misses and nothing is stored)."""
    from repro.campaign.matrix import run_transfer_matrix

    workloads = kernelbench.suite(1, small=small)
    platforms = ("tpu_v5e", "metal_m2")
    arms = {}
    for arm, (io_c, exe_c) in (
            ("disabled", (WorkloadIOCache(max_entries=0),
                          ExecutableCache(max_entries=0))),
            ("shared", (WorkloadIOCache(), ExecutableCache()))):
        t0 = time.perf_counter()
        matrix = run_transfer_matrix(workloads, platforms, io_cache=io_c,
                                     exe_cache=exe_c)
        arms[arm] = {
            "wall_s": round(time.perf_counter() - t0, 2),
            "n_failed": matrix.n_failed,
            "io_cache": io_c.stats(),
            "exe_cache": exe_c.stats(),
        }
    n_legs = len(platforms) + len(platforms) * (len(platforms) - 1)
    return {
        "platforms": list(platforms),
        "n_legs": n_legs,
        "n_workloads": len(workloads),
        "oracle_budget": n_legs * len(workloads),
        "oracle_computes_shared": arms["shared"]["io_cache"][
            "oracle_computes"],
        "speedup": round(arms["disabled"]["wall_s"]
                         / arms["shared"]["wall_s"], 2),
        "arms": arms,
    }


def _bench_grad(small: bool, smoke: bool = False) -> Dict:
    """The fwd_bwd throughput arm: per-candidate verification (no shared
    caches — cotangent + oracle gradients recomputed for every candidate)
    vs one batch per workload sharing them through the IO cache."""
    workloads = kernelbench.suite(small=small, differentiable=True)
    if smoke:
        workloads = workloads[:2]
    sets = {wl.name: candidate_list(wl) for wl in workloads}
    n = sum(len(c) for c in sets.values())

    wl0 = workloads[0]
    verify(sets[wl0.name][0], wl0, seed=SEED, direction="fwd_bwd")

    t0 = time.perf_counter()
    for wl in workloads:
        for cand in sets[wl.name]:
            verify(cand, wl, seed=SEED, direction="fwd_bwd")
    per_s = time.perf_counter() - t0

    io_cache, exe_cache = WorkloadIOCache(), ExecutableCache()
    pass_counts: Dict[str, Dict[str, int]] = {}
    t0 = time.perf_counter()
    for wl in workloads:
        results = verify_batch(sets[wl.name], wl, seed=SEED,
                               io_cache=io_cache, exe_cache=exe_cache,
                               direction="fwd_bwd")
        states: Dict[str, int] = {}
        for r in results:
            states[r.state.value] = states.get(r.state.value, 0) + 1
        pass_counts[wl.name] = {
            "n": len(results),
            "correct": sum(1 for r in results if r.correct),
            "states": states,
        }
    batch_s = time.perf_counter() - t0

    return {
        "n_workloads": len(workloads),
        "workloads": [wl.name for wl in workloads],
        "n_candidates": n,
        "per_candidate_s": round(per_s, 3),
        "batch_s": round(batch_s, 3),
        "per_candidate_vps": round(n / per_s, 2),
        "batch_vps": round(n / batch_s, 2),
        "speedup": round(per_s / batch_s, 2),
        "io_cache": io_cache.stats(),
        "exe_cache": exe_cache.stats(),
        # shared-cotangent proof: one grad-oracle evaluation per workload
        "grad_oracle_computes": io_cache.stats()["grad_oracle_computes"],
        "pass_counts": pass_counts,
    }


def run(small: bool = True, smoke: bool = False, matrix: bool = False,
        grad: bool = False, json_path=None) -> List[Row]:
    if grad:
        report = _bench_grad(small, smoke=smoke)
        if json_path:
            payload = {"bench": "verify_grad_throughput",
                       "suite": "small" if small else "full",
                       "smoke": smoke, **report}
            with open(json_path, "w") as fh:
                json.dump(payload, fh, indent=2, sort_keys=True)
                fh.write("\n")
        n = report["n_candidates"]
        n_pass = sum(p["correct"] for p in report["pass_counts"].values())
        return [
            ("verify_grad_per", report["per_candidate_s"] / n * 1e6,
             f"vps={report['per_candidate_vps']};n={n}"),
            ("verify_grad_batch", report["batch_s"] / n * 1e6,
             f"vps={report['batch_vps']};speedup={report['speedup']}x;"
             f"pass={n_pass}/{n};"
             f"grad_oracles={report['grad_oracle_computes']}"),
        ]
    workloads = kernelbench.suite(1, small=small)
    if smoke:
        workloads = workloads[:3]
    report = _bench(workloads)
    if matrix:
        report["matrix"] = _bench_matrix(small)
    if json_path:
        payload = {"bench": "verify_throughput",
                   "suite": "small" if small else "full",
                   "smoke": smoke, **report}
        with open(json_path, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")
    n = report["n_candidates"]
    rows = [
        ("verify_cold", report["cold_s"] / n * 1e6,
         f"vps={report['cold_vps']};n={n}"),
        ("verify_warm", report["warm_s"] / n * 1e6,
         f"vps={report['warm_vps']};speedup={report['speedup']}x"),
    ]
    if matrix:
        m = report["matrix"]
        rows.append(("verify_matrix_smoke",
                     m["arms"]["shared"]["wall_s"] * 1e6,
                     f"speedup={m['speedup']}x;oracle="
                     f"{m['oracle_computes_shared']}/{m['oracle_budget']}"))
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(
        description="verification fast-path throughput (cold vs warm)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI fast-lane mode: first 3 level-1 workloads")
    ap.add_argument("--matrix", action="store_true",
                    help="also run the 2-platform matrix smoke with shared "
                         "caches vs disabled and report the wall-clock win")
    ap.add_argument("--grad", action="store_true",
                    help="fwd_bwd arm over the differentiable suite: "
                         "per-candidate grad verification vs shared-"
                         "cotangent batches (gate: batch >= 1.2x)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the full report as JSON (e.g. "
                         "BENCH_verify.json / BENCH_grad.json)")
    ap.add_argument("--full-size", action="store_true",
                    help="full-size workloads (slow on CPU)")
    args = ap.parse_args()
    print("name,us_per_call,derived", flush=True)
    rows = run(small=not args.full_size, smoke=args.smoke,
               matrix=args.matrix, grad=args.grad, json_path=args.json)
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}", flush=True)
    if args.grad:
        batch = next(r for r in rows if r[0] == "verify_grad_batch")
        derived = batch[2]
        speedup = float(derived.split("speedup=")[1].split(";")[0]
                        .rstrip("x"))
        n_pass = int(derived.split("pass=")[1].split("/")[0])
        # shared-cotangent batches must beat per-candidate grad checks,
        # and at least one gradient-checked candidate must verify CORRECT
        # (otherwise the arm silently measured nothing but failures)
        if speedup < 1.2:
            print(f"FAIL: grad batch/per speedup {speedup} < 1.2",
                  flush=True)
            return 1
        if n_pass == 0:
            print("FAIL: no gradient-checked candidate verified CORRECT",
                  flush=True)
            return 1
        print(f"# ok: grad batch path {speedup}x per-candidate, "
              f"{n_pass} candidates passed the gradient check", flush=True)
        return 0
    warm = next(r for r in rows if r[0] == "verify_warm")
    speedup = float(warm[2].split("speedup=")[1].rstrip("x"))
    # the fast path must actually be fast: a regression below 1.5x warm
    # throughput fails the bench (and the CI step running it)
    if speedup < 1.5:
        print(f"FAIL: warm/cold speedup {speedup} < 1.5", flush=True)
        return 1
    print(f"# ok: warm path {speedup}x cold", flush=True)
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
