"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  bench_fastp_levels       Fig. 2  (iterative refinement fast_p per level)
  bench_correctness        Table 4 (single-shot correctness ± reference)
  bench_profiling_impact   Fig. 3 / Table 5 (analysis-agent impact)
  bench_transfer           §6.2 (cross-platform transfer uplift)
  bench_transfer_matrix    DESIGN.md §2 (all-pairs uplift heat-map)
  bench_batch_sizes        Table 6 / §7.1 (batch-size generalization)
  bench_roofline           assignment §Roofline (reads experiments/dryrun)
  bench_kernels_wall       measured CPU wall-clock of reference ops
  bench_verify_throughput  DESIGN.md §4 (verification fast path, cold/warm)

Campaign runner (repro.campaign)
  The suite-sweep benches (fastp_levels, correctness, profiling_impact) run
  on the concurrent campaign runner instead of a serial loop: workloads fan
  out over a thread pool (benchmarks.common.CAMPAIGN_WORKERS) and all
  configs/levels of a bench share one content-addressed VerificationCache,
  so re-visited candidates never re-verify. For ad-hoc sweeps with JSONL
  logging, resume, and a fast_p report, use ``python -m repro.campaign``.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (bench_batch_sizes, bench_correctness,
                        bench_fastp_levels, bench_kernels_wall,
                        bench_profiling_impact, bench_roofline,
                        bench_serve_throughput, bench_transfer,
                        bench_transfer_matrix, bench_verify_throughput)
from benchmarks.common import emit

MODULES = {
    "fastp_levels": bench_fastp_levels,
    "correctness": bench_correctness,
    "profiling_impact": bench_profiling_impact,
    "transfer": bench_transfer,
    "transfer_matrix": bench_transfer_matrix,
    "batch_sizes": bench_batch_sizes,
    "roofline": bench_roofline,
    "kernels_wall": bench_kernels_wall,
    "verify_throughput": bench_verify_throughput,
    "serve_throughput": bench_serve_throughput,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benches")
    ap.add_argument("--full-size", action="store_true",
                    help="use full-size kernelbench workloads (slow on CPU)")
    args = ap.parse_args()
    names = list(MODULES) if not args.only else args.only.split(",")
    print("name,us_per_call,derived", flush=True)
    for name in names:
        t0 = time.time()
        rows = MODULES[name].run(small=not args.full_size)
        emit(rows)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
