"""All-pairs transfer matrix (DESIGN.md §2) — the cross-target headline.

Rows: matrix/<from>-><to>/uplift, value = total warm-minus-cold fast_1 of
that ordered pair; /warm_p1 and /cold_p1 carry the two absolute fast_1
values the uplift is the difference of; /delta_iters is the
iterations-to-correct delta (warm − cold; negative = the transferred
reference converged in fewer iterations — the non-saturating signal). A
failed leg emits a single matrix/<from>-><to>/error row. matrix/wall_s vs
matrix/serial_sum_s quantify the job-graph overlap (wall must beat the
serial sum of leg durations whenever >= 2 legs can run concurrently), and
matrix/peak_legs is the scheduler's concurrency high-water mark. The final
matrix/heatmap rows carry both rendered ASCII heat-maps (one row per line,
value in `derived`).

Runs on the job-graph matrix engine: all base campaigns concurrent, each
warm leg submitted the moment its two bases resolve, one shared
VerificationCache and workload-worker pool.
"""
from __future__ import annotations

from benchmarks.common import CAMPAIGN_WORKERS, Row
from repro.campaign import VerificationCache, run_transfer_matrix
from repro.campaign.cache import format_cache_stats
from repro.core import LoopConfig, kernelbench


def run(small: bool = True):
    rows: list[Row] = []
    cache = VerificationCache()
    wls = kernelbench.suite(small=small)
    matrix = run_transfer_matrix(
        wls, loop=LoopConfig(num_iterations=5, use_profiling=True),
        cache=cache, max_workers=CAMPAIGN_WORKERS)
    for (src, dst), leg in sorted(matrix.legs.items()):
        if not leg.ok:
            rows.append((f"matrix/{src}->{dst}/error", 0.0, str(leg.error)))
            continue
        rep = leg.sweep.report()
        rows.append((f"matrix/{src}->{dst}/cold_p1", 0.0,
                     f"{rep['total']['cold']['1']:.3f}"))
        rows.append((f"matrix/{src}->{dst}/warm_p1", 0.0,
                     f"{rep['total']['warm']['1']:.3f}"))
        rows.append((f"matrix/{src}->{dst}/uplift", 0.0,
                     f"{rep['total']['uplift_fast1']:+.3f}"))
        delta = rep["total"]["iters_to_correct"]["delta"]
        rows.append((f"matrix/{src}->{dst}/delta_iters", 0.0,
                     "n/a" if delta is None else f"{delta:+.2f}"))
    tele = matrix.telemetry
    rows.append(("matrix/wall_s", tele["wall_s"] * 1e6,
                 f"{tele['wall_s']:.1f}s wall"))
    rows.append(("matrix/serial_sum_s", tele["serial_sum_s"] * 1e6,
                 f"{tele['serial_sum_s']:.1f}s summed leg time"))
    rows.append(("matrix/peak_legs", 0.0,
                 f"{tele['peak_concurrent_legs']} concurrent legs "
                 f"(matrix_workers={tele['matrix_workers']}, "
                 f"leg_workers={tele['leg_workers']})"))
    rows.append(("matrix/cache", 0.0, format_cache_stats(cache.stats())))
    for metric in ("uplift_fast1", "delta_iters"):
        for i, line in enumerate(matrix.heatmap_text(metric).splitlines()):
            rows.append((f"matrix/heatmap/{metric}/{i}", 0.0, line))
    return rows
