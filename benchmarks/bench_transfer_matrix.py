"""All-pairs transfer matrix (DESIGN.md §2) — the cross-target headline.

Rows: matrix/<from>-><to>/uplift, value = total warm-minus-cold fast_1 of
that ordered pair; matrix/<from>-><to>/warm_p1 and /cold_p1 carry the two
absolute fast_1 values the uplift is the difference of. A failed leg emits
a single matrix/<from>-><to>/error row. The final matrix/heatmap rows
carry the rendered ASCII heat-map (one row per line, value in `derived`).

Runs on the matrix engine: one base campaign per platform (reused as the
source leg of every pair it feeds and the cold leg of every pair targeting
it), N·(N−1) warm legs, one shared VerificationCache and worker pool.
"""
from __future__ import annotations

from benchmarks.common import CAMPAIGN_WORKERS, Row
from repro.campaign import VerificationCache, run_transfer_matrix
from repro.campaign.cache import format_cache_stats
from repro.core import LoopConfig, kernelbench


def run(small: bool = True):
    rows: list[Row] = []
    cache = VerificationCache()
    wls = kernelbench.suite(small=small)
    matrix = run_transfer_matrix(
        wls, loop=LoopConfig(num_iterations=5, use_profiling=True),
        cache=cache, max_workers=CAMPAIGN_WORKERS)
    for (src, dst), leg in sorted(matrix.legs.items()):
        if not leg.ok:
            rows.append((f"matrix/{src}->{dst}/error", 0.0, str(leg.error)))
            continue
        rep = leg.sweep.report()
        rows.append((f"matrix/{src}->{dst}/cold_p1", 0.0,
                     f"{rep['total']['cold']['1']:.3f}"))
        rows.append((f"matrix/{src}->{dst}/warm_p1", 0.0,
                     f"{rep['total']['warm']['1']:.3f}"))
        rows.append((f"matrix/{src}->{dst}/uplift", 0.0,
                     f"{rep['total']['uplift_fast1']:+.3f}"))
    rows.append(("matrix/cache", 0.0, format_cache_stats(cache.stats())))
    for i, line in enumerate(matrix.heatmap_text().splitlines()):
        rows.append((f"matrix/heatmap/{i}", 0.0, line))
    return rows
