"""Paper Table 4 — single-shot correctness rate, Baseline vs CUDA-reference
configuration (here: XLA-oracle reference transfer)."""
from __future__ import annotations

from repro.core import LoopConfig, fast_p, kernelbench, run_suite
from benchmarks.common import Row


def run(small: bool = True):
    rows: list[Row] = []
    for cname, use_ref in (("baseline", False), ("reference", True)):
        cfg = LoopConfig(single_shot=True, use_reference=use_ref)
        for level in (1, 2, 3):
            outs = run_suite(kernelbench.suite(level, small=small), cfg)
            finals = [o.final for o in outs]
            rows.append((f"correctness/{cname}/L{level}", 0.0,
                         f"{fast_p(finals, 0.0):.3f}"))
    return rows
