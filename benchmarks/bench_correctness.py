"""Paper Table 4 — single-shot correctness rate, Baseline vs CUDA-reference
configuration (here: XLA-oracle reference transfer). Campaign-runner based;
the shared cache dedupes the workloads whose reference hints coincide with
the baseline initial candidate."""
from __future__ import annotations

from repro.campaign import VerificationCache, run_campaign
from repro.core import LoopConfig, fast_p, kernelbench
from benchmarks.common import Row, CAMPAIGN_WORKERS, campaign_finals


def run(small: bool = True):
    rows: list[Row] = []
    cache = VerificationCache()
    for cname, use_ref in (("baseline", False), ("reference", True)):
        cfg = LoopConfig(single_shot=True, use_reference=use_ref)
        for level in (1, 2, 3):
            result = run_campaign(kernelbench.suite(level, small=small), cfg,
                                  cache=cache, max_workers=CAMPAIGN_WORKERS)
            finals = campaign_finals(result)
            rows.append((f"correctness/{cname}/L{level}", 0.0,
                         f"{fast_p(finals, 0.0):.3f}"))
    return rows
