"""Paper Fig. 2 — iterative-refinement fast_p per KernelBench level.

Rows: fastp/<config>/L<level>/p<threshold>, value = fast_p fraction
(us_per_call column carries the mean best model-time in µs for the level).

Runs on the campaign runner: one verification cache is shared across both
configs and all levels, so candidates the single-shot and iterative configs
both visit (e.g. every iteration-0 initial candidate) verify exactly once.
"""
from __future__ import annotations

from repro.campaign import VerificationCache, run_campaign
from repro.core import LoopConfig, fast_p, kernelbench
from benchmarks.common import Row, CAMPAIGN_WORKERS, campaign_finals


CONFIGS = {
    "single_shot": LoopConfig(single_shot=True),
    "iterative": LoopConfig(num_iterations=5),
}
THRESHOLDS = (0.0, 1.0, 1.5, 2.0)


def run(small: bool = True):
    rows: list[Row] = []
    cache = VerificationCache()
    for cname, cfg in CONFIGS.items():
        for level in (1, 2, 3):
            wls = kernelbench.suite(level, small=small)
            result = run_campaign(wls, cfg, cache=cache,
                                  max_workers=CAMPAIGN_WORKERS)
            finals = campaign_finals(result)
            times = [r.model_time_s for r in finals
                     if r.correct and r.model_time_s]
            mean_us = (sum(times) / len(times) * 1e6) if times else 0.0
            for p in THRESHOLDS:
                rows.append((f"fastp/{cname}/L{level}/p{p}", mean_us,
                             f"{fast_p(finals, p):.3f}"))
    return rows
