"""Paper Fig. 2 — refinement fast_p per KernelBench level, now with a
population-search arm.

Rows: ``fastp/<config>/L<level>/p<threshold>`` with the fast_p fraction in
the derived column (us_per_call carries the mean best model-time in µs for
the level), plus ``iters/<config>/L<level>`` with the mean iterations (or
PBT generations) to the first correct verification.

Configs: ``single_shot`` (iteration 0 only), ``iterative`` (the default
single-lineage refinement loop), and ``pbt`` (population-based search,
K=4 × 5 generations — same per-workload verification budget class as
iterative's 5 iterations × 4-wide mutation neighborhoods).

Runs on the campaign runner: one verification cache is shared across all
configs and levels, so candidates several configs visit (e.g. every
initial candidate) verify exactly once.

Standalone CLI (from the repo root)::

  PYTHONPATH=src python -m benchmarks.bench_fastp_levels --smoke \
      --json BENCH_pbt.json             # CI fast lane (level 1, 2 gens)
  PYTHONPATH=src python -m benchmarks.bench_fastp_levels \
      --json BENCH_pbt.json             # full small suite, all levels

``--smoke`` trims to level 1 with shortened configs (iterative: 2
iterations; pbt: K=4 × 2 generations) and gates on the PBT arm matching
the iterative arm's fast_1 — the CI regression tripwire for the
population-search path.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional

from repro.campaign import VerificationCache, run_campaign
from repro.core import LoopConfig, fast_p, kernelbench
from benchmarks.common import Row, CAMPAIGN_WORKERS, campaign_finals


CONFIGS = {
    "single_shot": LoopConfig(single_shot=True),
    "iterative": LoopConfig(num_iterations=5),
    "pbt": LoopConfig(search="pbt", population=4, generations=5),
}
# CI fast-lane shapes: same search modes, budget cut to keep the lane quick.
SMOKE_CONFIGS = {
    "iterative": LoopConfig(num_iterations=2),
    "pbt": LoopConfig(search="pbt", population=4, generations=2),
}
THRESHOLDS = (0.0, 1.0, 1.5, 2.0)


def _mean(xs: List[float]) -> Optional[float]:
    return sum(xs) / len(xs) if xs else None


def run(small: bool = True, smoke: bool = False,
        json_path: Optional[str] = None) -> List[Row]:
    configs = SMOKE_CONFIGS if smoke else CONFIGS
    levels = (1,) if smoke else (1, 2, 3)
    rows: List[Row] = []
    report: Dict[str, Dict] = {}
    cache = VerificationCache()
    for cname, cfg in configs.items():
        report[cname] = {}
        for level in levels:
            wls = kernelbench.suite(level, small=small)
            result = run_campaign(wls, cfg, cache=cache,
                                  max_workers=CAMPAIGN_WORKERS)
            finals = campaign_finals(result)
            times = [r.model_time_s for r in finals
                     if r.correct and r.model_time_s]
            mean_us = (_mean(times) or 0.0) * 1e6
            iters = [r.iters_to_correct for r in result.runs
                     if r.iters_to_correct is not None]
            curve = {f"{p:g}": round(fast_p(finals, p), 3)
                     for p in THRESHOLDS}
            report[cname][f"L{level}"] = {
                "n": len(finals),
                "fast_p": curve,
                "mean_best_model_time_us": round(mean_us, 3),
                "mean_iters_to_correct": _mean(iters),
            }
            for p in THRESHOLDS:
                rows.append((f"fastp/{cname}/L{level}/p{p}", mean_us,
                             f"{fast_p(finals, p):.3f}"))
            mit = _mean(iters)
            rows.append((f"iters/{cname}/L{level}", mean_us,
                         f"{mit:.2f}" if mit is not None else "none"))
    if json_path:
        payload = {"bench": "fastp_levels",
                   "suite": "small" if small else "full",
                   "smoke": smoke,
                   "cache": cache.stats(),
                   "configs": report}
        with open(json_path, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")
    return rows


def _fast1(rows: List[Row], cname: str, level: int = 1) -> float:
    return float(next(d for n, _, d in rows
                      if n == f"fastp/{cname}/L{level}/p1.0"))


def main() -> int:
    ap = argparse.ArgumentParser(
        description="fast_p per level: single-shot vs iterative vs "
                    "population search")
    ap.add_argument("--smoke", action="store_true",
                    help="CI fast-lane mode: level 1 only, 2 iterations / "
                         "2 generations, with a pbt-vs-iterative fast_1 gate")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the full report as JSON (e.g. "
                         "BENCH_pbt.json)")
    ap.add_argument("--full-size", action="store_true",
                    help="full-size workloads (slow on CPU)")
    args = ap.parse_args()
    print("name,us_per_call,derived", flush=True)
    rows = run(small=not args.full_size, smoke=args.smoke,
               json_path=args.json)
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}", flush=True)
    if args.smoke:
        pbt, it = _fast1(rows, "pbt"), _fast1(rows, "iterative")
        # population search must not regress the single-lineage loop on the
        # smoke suite — both are deterministic, so this is a stable gate
        if pbt < it:
            print(f"FAIL: pbt fast_1 {pbt} < iterative {it}", flush=True)
            return 1
        print(f"# ok: pbt fast_1 {pbt} >= iterative {it}", flush=True)
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
