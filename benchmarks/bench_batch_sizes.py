"""Paper Table 6 / §7.1 — batch-size generalization case study.

A candidate synthesized at one batch size is re-verified and re-modeled
across batch sizes {8,16,32,64,128}: correctness must hold (robust to shape
variation, §7.1) and the modeled TPU time is reported for baseline vs the
KForge candidate. Wall-clock of the XLA reference on CPU is included as the
measured column.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, wall_us
from repro.core import LoopConfig, kernelbench, run_workload, verify
from repro.core import candidates as cand_mod
from repro.core.workload import Workload, randn
from repro.kernels import ref

BATCHES = (8, 16, 32, 64, 128)


def _attn_workload(b: int) -> Workload:
    return Workload(
        name=f"case/attn_b{b}", level=3, op="attention",
        ref_fn=lambda q, k, v: ref.attention(q, k, v, causal=True),
        input_fn=lambda rng: {"q": randn(rng, (b, 256, 8, 64), 4.0),
                              "k": randn(rng, (b, 256, 2, 64), 4.0),
                              "v": randn(rng, (b, 256, 2, 64))},
        input_shapes={"q": (b, 256, 8, 64), "k": (b, 256, 2, 64),
                      "v": (b, 256, 2, 64)})


def _mlp_workload(b: int) -> Workload:
    t = b * 64
    return Workload(
        name=f"case/swiglu_b{b}", level=3, op="swiglu",
        ref_fn=lambda gate, up: ref.swish(gate) * up,
        input_fn=lambda rng: {"gate": randn(rng, (t, 512)),
                              "up": randn(rng, (t, 512))},
        input_shapes={"gate": (t, 512), "up": (t, 512)})


def run(small: bool = True):
    del small
    rows: list[Row] = []
    for family, mk in (("attn", _attn_workload), ("swiglu", _mlp_workload)):
        # synthesize once at the generation batch size (16)
        out = run_workload(mk(16), LoopConfig(num_iterations=4,
                                              use_reference=True,
                                              use_profiling=True))
        cand = out.best_candidate
        assert cand is not None, f"{family}: synthesis failed"
        for b in BATCHES:
            wl = mk(b)
            res = verify(cand, wl, seed=b)
            shapes = {k: tuple(v) for k, v in wl.input_shapes.items()}
            base_ms = cand_mod.baseline_time(cand.op, shapes) * 1e3
            kf_ms = cand_mod.model_time(cand, shapes) * 1e3
            inputs = wl.inputs(0)
            import jax
            measured = wall_us(jax.jit(wl.ref_fn), *inputs.values(), reps=3)
            rows.append((f"case/{family}/b{b}", measured,
                         f"correct={int(res.correct)};"
                         f"baseline_ms={base_ms:.3f};kforge_ms={kf_ms:.3f}"))
    return rows
