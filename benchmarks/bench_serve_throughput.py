"""Synthesis-service throughput: cold per-request pipelines vs one warm
shared-cache daemon (DESIGN.md §12).

Both arms serve the IDENTICAL request list — a multi-tenant shape: T
tenants each asking for the same W workloads (duplicates across tenants,
exactly the traffic the daemon's dedupe layers exist for). The cold arm
is the batch-CLI cost model: every request runs its own full refinement
loop with FRESH caches (no shared IO/executable/verification state, the
way separate ``python -m repro.campaign`` processes would — minus even
the per-process jax import, so the cold arm is *flattered* if anything).
The warm arm starts one :class:`repro.service.SynthesisService` on a real
loopback socket and pushes the same requests through concurrent HTTP
clients: the first request per unique spec pays the synthesis, duplicates
coalesce onto it or hit the completed-request memo, and every response
carries its queue latency for the p50/p95 columns.

Standalone CLI (from the repo root)::

  PYTHONPATH=src python -m benchmarks.bench_serve_throughput --smoke \
      --json BENCH_serve.json           # CI fast lane: gates warm/cold
  PYTHONPATH=src python -m benchmarks.bench_serve_throughput \
      --json BENCH_serve.json           # full mix

Harness rows (``python benchmarks/run.py --only serve_throughput``):
``serve_cold`` / ``serve_warm`` with requests/sec, the warm/cold speedup,
and warm queue-latency percentiles in the derived column. ``--smoke``
exits 1 if warm/cold drops below 1.5x — the service regression gate.
"""
from __future__ import annotations

import argparse
import json
import sys
import threading
import time
from pathlib import Path
from typing import Dict, List

from benchmarks.common import Row, emit

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tools"))

TENANTS = ("alice", "bob", "carol")
WORKLOADS = ("L1/swish", "L1/softmax")
ITERS = 2


def _requests(tenants, workloads) -> List[Dict]:
    return [{"workload": wl, "iters": ITERS, "tenant": tenant}
            for tenant in tenants for wl in workloads]


def _cold_arm(requests: List[Dict]) -> float:
    """Each request pays a full refinement loop with fresh caches."""
    from repro.core import kernelbench
    from repro.core.refinement import LoopConfig, run_workload

    t0 = time.perf_counter()
    for req in requests:
        wl = kernelbench.by_name(req["workload"], small=True)
        outcome = run_workload(wl, LoopConfig(num_iterations=req["iters"]))
        assert outcome.final.correct, f"cold run failed: {req['workload']}"
    return time.perf_counter() - t0


def _warm_arm(requests: List[Dict], workers: int) -> Dict:
    """One daemon, concurrent clients, shared caches; returns wall +
    per-request queue/served_from telemetry."""
    from kforge_client import ServiceClient
    from repro.service.daemon import ServiceConfig, SynthesisService

    svc = SynthesisService(ServiceConfig(port=0, workers=workers)).start()
    try:
        responses: List[Dict] = [None] * len(requests)

        def call(i, req):
            client = ServiceClient(port=svc.port)
            responses[i] = client.synthesize(**req)

        t0 = time.perf_counter()
        threads = [threading.Thread(target=call, args=(i, r))
                   for i, r in enumerate(requests)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        assert all(r and r.get("ok") for r in responses), \
            [r.get("error") for r in responses if not r.get("ok")]
        io_stats = svc.io_cache.stats()
    finally:
        svc.stop()
    queue = sorted(r.get("queue_s") or 0.0 for r in responses)
    deduped = sum(r["served_from"] != "run" for r in responses)
    return {"wall_s": wall, "queue_s": queue, "deduped": deduped,
            "io_cache": io_stats}


def _pct(sorted_vals: List[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1,
              max(0, int(round(q * len(sorted_vals) + 0.5)) - 1))
    return sorted_vals[idx]


def bench(tenants=TENANTS, workloads=WORKLOADS, workers: int = 4) -> Dict:
    requests = _requests(tenants, workloads)
    cold_s = _cold_arm(requests)
    warm = _warm_arm(requests, workers)
    warm_s = warm["wall_s"]
    n = len(requests)
    report = {
        "bench": "serve_throughput",
        "requests": n,
        "unique": len(workloads),
        "tenants": len(tenants),
        "deduped": warm["deduped"],
        "cold_s": round(cold_s, 3),
        "warm_s": round(warm_s, 3),
        "cold_rps": round(n / cold_s, 2),
        "warm_rps": round(n / warm_s, 2),
        "speedup": round(cold_s / warm_s, 2),
        "queue_p50_ms": round(_pct(warm["queue_s"], 0.50) * 1e3, 3),
        "queue_p95_ms": round(_pct(warm["queue_s"], 0.95) * 1e3, 3),
        "io_cache": warm["io_cache"],
    }
    # the dedupe invariant the acceptance lane asserts, enforced here too:
    # a daemon serving T x W duplicate traffic must not re-run the oracle
    # per request
    assert report["io_cache"]["oracle_computes"] < n, report
    return report


def rows(report: Dict) -> List[Row]:
    n = report["requests"]
    return [
        ("serve_cold", report["cold_s"] / n * 1e6,
         f"rps={report['cold_rps']}"),
        ("serve_warm", report["warm_s"] / n * 1e6,
         f"rps={report['warm_rps']};speedup={report['speedup']}x;"
         f"p50={report['queue_p50_ms']}ms;p95={report['queue_p95_ms']}ms;"
         f"deduped={report['deduped']}/{n}"),
    ]


def run(small: bool = True, smoke: bool = False,
        json_path=None) -> List[Row]:
    """Harness entry (benchmarks/run.py) — smoke and full use the same
    T x W mix; ``small`` is accepted for harness uniformity (the service
    suite is already the small one)."""
    report = bench()
    if json_path:
        payload = dict(report)
        with open(json_path, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
    return rows(report)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI fast lane: gate warm/cold >= 1.5x, exit 1 "
                         "below it")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the full report as JSON")
    args = ap.parse_args()
    report = bench()
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=2, sort_keys=True)
    emit(rows(report))
    if args.smoke and report["speedup"] < 1.5:
        print(f"FAIL: warm/cold speedup {report['speedup']} < 1.5",
              flush=True)
        return 1
    print(f"# ok: warm daemon {report['speedup']}x cold per-request "
          f"({report['deduped']}/{report['requests']} deduped, "
          f"queue p95 {report['queue_p95_ms']}ms)", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
