"""Shared benchmark helpers: row emission per run.py's CSV contract."""
from __future__ import annotations

import time
from typing import Iterable, List, Tuple

Row = Tuple[str, float, str]  # name, us_per_call, derived

# Worker-pool width for campaign-runner benches. Modest by default: CI boxes
# are small, and interpret-mode verification only partially releases the GIL.
CAMPAIGN_WORKERS = 4


def campaign_finals(result):
    """Terminal EvalResults for a bench's campaign, failing loudly if any
    workload died in the scheduler — a crashed worker must abort the bench
    (as the old serial loop did), not silently depress its fast_p rows."""
    if result.n_failed:
        errors = "; ".join(f"{r.workload}: {r.error}"
                           for r in result.runs if r.error)
        raise RuntimeError(f"campaign workload failures: {errors}")
    return result.finals()


def emit(rows: Iterable[Row]):
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}", flush=True)


def wall_us(fn, *args, reps: int = 5) -> float:
    import jax
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6
