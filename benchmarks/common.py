"""Shared benchmark helpers: row emission per run.py's CSV contract."""
from __future__ import annotations

import time
from typing import Iterable, List, Tuple

Row = Tuple[str, float, str]  # name, us_per_call, derived


def emit(rows: Iterable[Row]):
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}", flush=True)


def wall_us(fn, *args, reps: int = 5) -> float:
    import jax
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6
