"""Assignment §Roofline — reads the dry-run records and emits the roofline
table (single-pod 16x16 baselines for every arch × shape cell).

Run ``python -m repro.launch.dryrun --all --mesh both`` first to produce
``experiments/dryrun/*.json``.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import Row

DRYRUN_DIR = Path("experiments/dryrun")


def run(small: bool = True):
    del small
    rows: list[Row] = []
    if not DRYRUN_DIR.exists():
        rows.append(("roofline/missing", 0.0,
                     "run `python -m repro.launch.dryrun --all --mesh both`"))
        return rows
    for f in sorted(DRYRUN_DIR.glob("*_single.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") != "ok":
            continue
        r = rec["roofline"]
        rows.append((
            f"roofline/{r['arch']}/{r['shape']}",
            r["step_time_s"] * 1e6,
            f"dom={r['dominant']};compute_s={r['compute_s']:.3f};"
            f"memory_s={r['memory_s']:.3f};collective_s={r['collective_s']:.3f};"
            f"useful={r['useful_flops_fraction']:.3f};"
            f"roofline={r['roofline_fraction']:.4f}"))
    return rows
