"""Platform registry + cross-platform threading: per-platform performance
model, cache-key separation, analyzer alignment rules, persistent cache,
transfer sweep, and the seedless-verify / empty-logs regression fixes."""
import itertools
import json
from pathlib import Path

import pytest

import repro.platforms as plat_mod
from repro.campaign import (Campaign, CampaignConfig, EventLog,
                            PersistentVerificationCache, VerificationCache,
                            harvest_hints, run_transfer_sweep)
from repro.core import LoopConfig, kernelbench
from repro.core import candidates as cand_mod
from repro.core import verification as verif_mod
from repro.core.analysis import RuleBasedAnalyzer
from repro.core.refinement import RefinementOutcome, run_workload
from repro.core.states import EvalResult, ExecutionState
from repro.core.synthesis import LLMBackend, TemplateSearchBackend
from repro.core.workload import Workload, randn
from repro.kernels import ref
from repro.platforms import Platform, get_platform, resolve_platform

SRC_ROOT = Path(__file__).resolve().parents[1] / "src" / "repro"


def _tiny(name="T1/softmax", op="softmax", shape=(64, 512), scale=60.0,
          level=1):
    refs = {"softmax": ref.softmax, "swish": ref.swish}
    return Workload(
        name=name, level=level, op=op,
        ref_fn=refs[op],
        input_fn=lambda rng: {"x": randn(rng, shape, scale)},
        input_shapes={"x": shape})


# ---------------------------------------------------------------------------
# Registry semantics
# ---------------------------------------------------------------------------


def test_registry_has_four_targets():
    names = plat_mod.available_platforms()
    assert {"tpu_v5e", "tpu_v4", "gpu_sim", "metal_m2"} <= set(names)


def test_resolve_accepts_none_name_and_instance():
    default = resolve_platform(None)
    assert default.name == plat_mod.DEFAULT_PLATFORM
    byname = resolve_platform("gpu_sim")
    assert byname.name == "gpu_sim"
    assert resolve_platform(byname) is byname
    with pytest.raises(KeyError):
        resolve_platform("metal_m3")


def test_v5e_hw_matches_historical_constants():
    hw = get_platform("tpu_v5e").hw
    assert hw == {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9,
                  "hbm_bytes": 16e9, "vmem_bytes": 128 * 2 ** 20}


def test_register_duplicate_raises():
    with pytest.raises(ValueError):
        plat_mod.register_platform(get_platform("tpu_v5e"))


def test_compiler_params_hook():
    tpu = get_platform("tpu_v5e").compiler_params(
        dimension_semantics=("parallel",))
    assert tpu is not None and not isinstance(tpu, dict)  # Mosaic params
    gpu = get_platform("gpu_sim").compiler_params(num_warps=4)
    assert gpu == {"num_warps": 4}                        # echo (simulated)


def test_no_module_outside_platforms_imports_hw_v5e():
    """ISSUE 2 acceptance: HW_V5E lives only in repro/platforms/."""
    offenders = []
    for path in SRC_ROOT.rglob("*.py"):
        if "platforms" in path.parts:
            continue
        if "HW_V5E" in path.read_text():
            offenders.append(str(path))
    assert offenders == []


# ---------------------------------------------------------------------------
# Candidate space + performance model per platform
# ---------------------------------------------------------------------------


def test_space_for_default_platform_is_unchanged():
    for op, space in cand_mod.SPACES.items():
        assert cand_mod.space_for(op, "tpu_v5e") == space


def test_space_for_gpu_sim_caps_tiles_but_never_empties_an_axis():
    mm = cand_mod.space_for("matmul", "gpu_sim")
    assert max(mm["block_m"]) <= 256 and max(mm["block_k"]) <= 256
    xe = cand_mod.space_for("xent", "gpu_sim")
    assert xe["block_v"] == (512,)          # fallback keeps smallest choice
    assert xe["online"] == (False, True)    # strategy axes pass through


def test_model_time_differs_across_platforms():
    shapes = {"a": (1024, 1024), "b": (1024, 1024)}
    cand = cand_mod.Candidate("matmul", {"block_m": 128, "block_n": 128,
                                         "block_k": 128})
    times = {p: cand_mod.model_time(cand, shapes, p)
             for p in ("tpu_v5e", "tpu_v4", "gpu_sim", "metal_m2")}
    assert len(set(times.values())) == 4
    assert all(t > 0 for t in times.values())
    # speedups are computed against the same platform's baseline
    for p in times:
        assert cand_mod.baseline_time("matmul", shapes, p) > 0


def test_fast_memory_legality_diverges():
    """512-wide matmul triple-tiles fit v5e VMEM but not gpu_sim smem."""
    shapes = {"a": (1024, 1024), "b": (1024, 1024)}
    big = cand_mod.Candidate("matmul", {"block_m": 512, "block_n": 512,
                                        "block_k": 512})
    assert cand_mod.model_time(big, shapes, "tpu_v5e") < float("inf")
    assert cand_mod.model_time(big, shapes, "gpu_sim") == float("inf")


def test_initial_candidate_alignment_bias_per_platform():
    # TPU: reference transfer aligns matrix tiles up to the 128-wide MXU
    tpu = cand_mod.initial_candidate("matmul", use_reference=True,
                                     platform="tpu_v5e")
    assert tpu.params["block_m"] == 128 and tpu.params["block_n"] == 128
    # GPU: 64 is already 16-aligned; no up-alignment, and naive tiles snap
    # into the capped space
    gpu = cand_mod.initial_candidate("matmul", use_reference=True,
                                     platform="gpu_sim")
    assert gpu.params["block_m"] == 64
    assert gpu.params["block_k"] <= 256
    # per-platform REFERENCE_HINTS extension (gpu_sim biases attention q)
    att = cand_mod.initial_candidate("attention", use_reference=True,
                                     platform="gpu_sim")
    assert att.params["online"] is True and att.params["block_q"] == 128


def test_mutations_stay_in_platform_space():
    cand = cand_mod.naive_candidate("matmul", "gpu_sim")
    for mut in cand_mod.mutations(cand, "gpu_sim").values():
        assert all(v <= 256 for k, v in mut.params.items()
                   if k.startswith("block_"))


# ---------------------------------------------------------------------------
# Verification: platform in the content address and the profile
# ---------------------------------------------------------------------------


def test_cache_key_differs_across_platforms():
    wl = _tiny()
    cand = cand_mod.naive_candidate("softmax")
    k_default = verif_mod.cache_key(cand, wl, 0)
    assert verif_mod.cache_key(cand, wl, 0, "tpu_v5e") == k_default
    assert verif_mod.cache_key(cand, wl, 0, "tpu_v4") != k_default
    assert verif_mod.cache_key(cand, wl, 0, "gpu_sim") != k_default


def test_verify_stamps_platform_and_caches_per_platform():
    wl = _tiny("T1/swish", op="swish", scale=1.0)
    cand = cand_mod.Candidate("swish", {"block_rows": 8, "block_lanes": 512})
    cache = VerificationCache()
    r_tpu = verif_mod.verify(cand, wl, seed=0, cache=cache)
    r_gpu = verif_mod.verify(cand, wl, seed=0, cache=cache,
                             platform="gpu_sim")
    assert r_tpu.profile["platform"] == "tpu_v5e"
    assert r_gpu.profile["platform"] == "gpu_sim"
    assert r_tpu.model_time_s != r_gpu.model_time_s
    assert len(cache) == 2                      # no collision
    assert verif_mod.verify(cand, wl, seed=0, cache=cache,
                            platform="gpu_sim") is r_gpu


def test_seedless_verify_uses_deterministic_counter(monkeypatch):
    """Regression (ISSUE 2): time_ns() seeds defeated the cache and made
    runs irreproducible; seedless calls now draw from a per-call counter."""
    wl = _tiny("T1/swish", op="swish", scale=1.0)
    cand = cand_mod.Candidate("swish", {"block_rows": 8, "block_lanes": 512})
    monkeypatch.setattr(verif_mod, "_FRESH_SEEDS", itertools.count(1))
    cache = VerificationCache()
    r1 = verif_mod.verify(cand, wl, cache=cache)
    r2 = verif_mod.verify(cand, wl, cache=cache)
    assert r1.cache_key == verif_mod.cache_key(cand, wl, 1)
    assert r2.cache_key == verif_mod.cache_key(cand, wl, 2)
    # same counter state => byte-identical key sequence on a "rerun"
    monkeypatch.setattr(verif_mod, "_FRESH_SEEDS", itertools.count(1))
    assert verif_mod.verify(cand, wl, cache=cache) is r1


def test_refinement_outcome_final_empty_logs_regression():
    out = RefinementOutcome(workload="w", best=None, best_candidate=None,
                            logs=[])
    final = out.final                           # used to IndexError
    assert final.state is ExecutionState.GENERATION_FAILURE
    wl = _tiny("T1/swish", op="swish", scale=1.0)
    zero = run_workload(wl, LoopConfig(num_iterations=0))
    assert zero.final.state is ExecutionState.GENERATION_FAILURE


# ---------------------------------------------------------------------------
# Analyzer: thresholds derive from the platform profile
# ---------------------------------------------------------------------------

_MM_PROFILE = {
    "op": "matmul",
    "params": {"block_m": 64, "block_n": 64, "block_k": 512},
    "shapes": {"a": (1024, 1024), "b": (1024, 1024)},
    "model_time_s": 1e-3, "flops": 2 * 1024 ** 3,
}


def test_analyzer_alignment_matches_platform_tile_width():
    tpu_rec = RuleBasedAnalyzer("tpu_v5e").analyze(dict(_MM_PROFILE))
    assert tpu_rec.param in ("block_m", "block_n")
    assert tpu_rec.value == 128                 # MXU width
    # 64 is already aligned for a 16-wide tensor-core fragment: rule 1 must
    # NOT fire on gpu_sim for the same profile
    gpu_rec = RuleBasedAnalyzer("gpu_sim").analyze(dict(_MM_PROFILE))
    assert not (gpu_rec.param in ("block_m", "block_n")
                and gpu_rec.value == 128)
    # a genuinely misaligned tile gets a 16-aligned target from the space
    prof = dict(_MM_PROFILE)
    prof["params"] = {"block_m": 8, "block_n": 64, "block_k": 64}
    rec = RuleBasedAnalyzer("gpu_sim").analyze(prof)
    assert rec.param == "block_m" and rec.value % 16 == 0
    assert rec.value < 128                      # not the TPU target


def test_default_analyzer_matches_seed_behaviour():
    rec = RuleBasedAnalyzer().analyze(dict(_MM_PROFILE))
    assert rec.param in ("block_m", "block_n") and rec.value == 128


# ---------------------------------------------------------------------------
# Prompts / LLM backend idiom per platform
# ---------------------------------------------------------------------------


def test_llm_prompt_uses_platform_idiom():
    wl = kernelbench.by_name("L1/softmax", small=True)
    tpu_prompt = LLMBackend(platform="tpu_v5e",
                            prompt_only=True).build_prompt(
        wl, prev=None, prev_result=None, recommendation=None,
        use_reference=False)
    assert "pallas_call" in tpu_prompt and "VMEM" in tpu_prompt
    gpu_prompt = LLMBackend(platform="gpu_sim",
                            prompt_only=True).build_prompt(
        wl, prev=None, prev_result=None, recommendation=None,
        use_reference=False)
    assert "__global__" in gpu_prompt           # CUDA one-shot example
    assert "shared-memory" in gpu_prompt and "pallas_call" not in gpu_prompt


def test_llm_prompt_harvested_reference_overrides_oracle():
    wl = kernelbench.by_name("L1/softmax", small=True)
    backend = LLMBackend(platform="gpu_sim", prompt_only=True,
                         reference_sources={
        wl.name: ("tpu_v5e", "# harvested kernel: online=True")})
    p = backend.build_prompt(wl, prev=None, prev_result=None,
                             recommendation=None, use_reference=True)
    assert "# harvested kernel: online=True" in p
    assert "tpu_v5e" in p


# ---------------------------------------------------------------------------
# Persistent verification cache (ROADMAP satellite)
# ---------------------------------------------------------------------------


def test_persistent_cache_survives_reopen(tmp_path):
    path = tmp_path / "verify.jsonl"
    wl = _tiny("T1/swish", op="swish", scale=1.0)
    cand = cand_mod.Candidate("swish", {"block_rows": 8, "block_lanes": 512})
    cache = VerificationCache.open(path)
    assert isinstance(cache, PersistentVerificationCache)
    r1 = verif_mod.verify(cand, wl, seed=0, cache=cache)
    assert r1.correct and cache.misses == 1

    reopened = VerificationCache.open(path)
    assert len(reopened) == 1
    r2 = verif_mod.verify(cand, wl, seed=0, cache=reopened)
    assert reopened.misses == 0 and reopened.hits == 1
    assert r2.state is r1.state
    assert r2.model_time_s == pytest.approx(r1.model_time_s)


def test_persistent_cache_last_write_wins_and_tolerates_torn_tail(tmp_path):
    path = tmp_path / "verify.jsonl"
    cache = VerificationCache.open(path)
    cache.put("k", EvalResult(ExecutionState.CORRECT, model_time_s=1.0))
    cache.put("k", EvalResult(ExecutionState.CORRECT, model_time_s=2.0))
    with path.open("a") as fh:
        fh.write('{"key": "torn"')              # killed mid-write
    reopened = VerificationCache.open(path)
    assert len(reopened) == 1
    assert reopened.get("k").model_time_s == 2.0


def test_persistent_cache_separates_platforms(tmp_path):
    wl = _tiny("T1/swish", op="swish", scale=1.0)
    cand = cand_mod.Candidate("swish", {"block_rows": 8, "block_lanes": 512})
    cache = VerificationCache.open(tmp_path / "v.jsonl")
    verif_mod.verify(cand, wl, seed=0, cache=cache, platform="tpu_v5e")
    verif_mod.verify(cand, wl, seed=0, cache=cache, platform="gpu_sim")
    reopened = VerificationCache.open(tmp_path / "v.jsonl")
    assert len(reopened) == 2


# ---------------------------------------------------------------------------
# Campaign + transfer sweep across platforms
# ---------------------------------------------------------------------------


def test_campaign_events_are_platform_tagged(tmp_path):
    wl = _tiny("T1/swish", op="swish", scale=1.0)
    log = tmp_path / "p.jsonl"
    cfg = CampaignConfig(loop=LoopConfig(num_iterations=2,
                                         platform="gpu_sim"),
                         max_workers=1, log_path=log)
    result = Campaign([wl], cfg).run()
    assert result.runs[0].final.correct
    events = EventLog(log).events()
    iters = [e for e in events if e["event"] == "iteration"]
    dones = [e for e in events if e["event"] == "workload_done"]
    assert iters and all(e["platform"] == "gpu_sim" for e in iters)
    assert dones and all(e["platform"] == "gpu_sim" for e in dones)
    assert all(e["loop"]["platform"] == "gpu_sim" for e in dones)


def test_resume_does_not_cross_platforms(tmp_path):
    """A workload finished on platform A must re-run for platform B even
    from the same event log (the loop config differs by platform)."""
    wl = _tiny("T1/swish", op="swish", scale=1.0)
    log = tmp_path / "x.jsonl"
    kw = dict(max_workers=1, log_path=log)
    Campaign([wl], CampaignConfig(
        loop=LoopConfig(num_iterations=2, platform="tpu_v5e"), **kw)).run()
    second = Campaign([wl], CampaignConfig(
        loop=LoopConfig(num_iterations=2, platform="gpu_sim"), **kw)).run()
    assert second.n_skipped == 0
    third = Campaign([wl], CampaignConfig(
        loop=LoopConfig(num_iterations=2, platform="gpu_sim"), **kw)).run()
    assert third.n_skipped == 1                 # same platform does resume


def test_transfer_sweep_two_platforms(tmp_path):
    """§6.2 on two tiny workloads: harvested references make the warm leg
    converge at least as fast as the cold leg, and never score worse."""
    wls = [_tiny("T1/softmax", shape=(64, 512), scale=60.0),
           _tiny("T2/softmax_wide", shape=(128, 512), scale=60.0, level=2)]
    log = tmp_path / "sweep.jsonl"
    cache = VerificationCache.open(tmp_path / "cache.jsonl")
    sweep = run_transfer_sweep(
        wls, from_platform="tpu_v5e", to_platform="gpu_sim",
        loop=LoopConfig(num_iterations=4, use_profiling=True),
        cache=cache, max_workers=2, log_path=log)

    # strategy (not tiling) was harvested from the source platform
    assert sweep.hints["T1/softmax"] == {"online": True}
    assert harvest_hints(sweep.source) == sweep.hints

    # warm >= cold at fast_1, per level and total
    rep = sweep.report()
    for stats in rep["levels"].values():
        assert stats["warm"]["1"] >= stats["cold"]["1"]
    assert rep["total"]["warm"]["1"] >= rep["total"]["cold"]["1"]
    assert "uplift" in sweep.report_text()

    # reference-injected runs reach a correct candidate in <= the cold
    # run's iterations (here: immediately, vs after a numeric repair)
    by_name_cold = {r.workload: r.outcome for r in sweep.cold.runs}
    by_name_warm = {r.workload: r.outcome for r in sweep.warm.runs}
    for name in by_name_cold:
        first_ok_cold = min(i for i, l in enumerate(by_name_cold[name].logs)
                            if l.result.correct)
        first_ok_warm = min(i for i, l in enumerate(by_name_warm[name].logs)
                            if l.result.correct)
        assert first_ok_warm <= first_ok_cold
        assert first_ok_warm == 0               # reference fixes numerics

    # both legs journal (platform-tagged) into ONE event log
    events = EventLog(log).events()
    platforms = {e.get("platform") for e in events
                 if e.get("event") == "workload_done"}
    assert platforms == {"tpu_v5e", "gpu_sim"}

    # rendered prompt references are ready for LLMBackend(reference_sources=)
    src_plat, text = sweep.references["T1/softmax"]
    assert src_plat == "tpu_v5e" and "online" in text

    # re-running the identical sweep against the same log resumes ALL
    # three legs (the interleaved multi-config log must not shadow the
    # earlier legs' terminal events)
    rerun = run_transfer_sweep(
        wls, from_platform="tpu_v5e", to_platform="gpu_sim",
        loop=LoopConfig(num_iterations=4, use_profiling=True),
        cache=cache, max_workers=2, log_path=log)
    assert rerun.source.n_skipped == len(wls)
    assert rerun.cold.n_skipped == len(wls)
    assert rerun.warm.n_skipped == len(wls)
    assert rerun.report()["total"]["warm"]["1"] == rep["total"]["warm"]["1"]
