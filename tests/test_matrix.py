"""Transfer-matrix engine (DESIGN.md §2): all-pairs enumeration, cache
sharing, heat-map rendering with failed legs, the metal_m2 target, the
same-platform transfer guard, and the --matrix CLI."""
import dataclasses
import json

import pytest

import repro.platforms as plat_mod
from repro.campaign import (Campaign, CampaignConfig, MatrixLeg, Scheduler,
                            VerificationCache, all_pairs, run_campaign,
                            run_transfer_matrix, run_transfer_sweep)
from repro.core import LoopConfig
from repro.core import candidates as cand_mod
from repro.core import verification as verif_mod
from repro.core.synthesis import LLMBackend
from repro.core.workload import Workload, randn
from repro.kernels import ref
from repro.kernels.ops import compiler_params_for


def _tiny(name="T1/softmax", op="softmax", shape=(64, 512), scale=60.0,
          level=1):
    refs = {"softmax": ref.softmax, "swish": ref.swish}
    return Workload(
        name=name, level=level, op=op,
        ref_fn=refs[op],
        input_fn=lambda rng: {"x": randn(rng, shape, scale)},
        input_shapes={"x": shape})


# ---------------------------------------------------------------------------
# All-pairs enumeration
# ---------------------------------------------------------------------------


def test_all_pairs_matches_registry_contents():
    names = plat_mod.available_platforms()
    pairs = all_pairs(names)
    assert len(pairs) == len(names) * (len(names) - 1)
    assert len(set(pairs)) == len(pairs)                 # no duplicates
    assert all(a != b for a, b in pairs)                 # no diagonal
    assert {a for a, _ in pairs} == set(names)           # every source
    assert {b for _, b in pairs} == set(names)           # every target
    # deterministic order regardless of input order
    assert all_pairs(reversed(names)) == pairs


def test_matrix_requires_two_distinct_platforms():
    wl = _tiny()
    with pytest.raises(ValueError):
        run_transfer_matrix([wl], ["tpu_v5e"])
    with pytest.raises(ValueError):
        run_transfer_matrix([wl], ["tpu_v5e", "tpu_v5e"])


def test_matrix_legs_cover_all_ordered_pairs(tmp_path):
    wls = [_tiny("T1/swish", op="swish", scale=1.0)]
    names = ["gpu_sim", "metal_m2", "tpu_v5e"]
    matrix = run_transfer_matrix(
        wls, names, loop=LoopConfig(num_iterations=2), max_workers=2)
    assert sorted(matrix.legs) == all_pairs(names)
    assert matrix.n_failed == 0
    for (src, dst), leg in matrix.legs.items():
        assert leg.ok and leg.sweep.from_platform == src
        assert leg.sweep.to_platform == dst
        # base campaigns are shared: the (A -> B) source is the (B -> A) cold
        assert leg.sweep.source is matrix.legs[(dst, src)].sweep.cold
    rep = matrix.report()
    assert rep["n_pairs"] == 6 and rep["n_failed"] == 0
    assert set(rep["pairs"]) == {f"{a}->{b}" for a, b in all_pairs(names)}


@pytest.mark.slow
def test_matrix_defaults_to_every_registered_platform():
    wls = [_tiny("T1/swish", op="swish", scale=1.0)]
    matrix = run_transfer_matrix(wls, loop=LoopConfig(num_iterations=2),
                                 max_workers=2)
    assert matrix.platforms == plat_mod.available_platforms()
    assert sorted(matrix.legs) == all_pairs(matrix.platforms)
    assert matrix.n_failed == 0


# ---------------------------------------------------------------------------
# Cache sharing across legs and reruns
# ---------------------------------------------------------------------------


def test_matrix_shares_one_cache_and_rerun_hits_100_percent(tmp_path):
    wls = [_tiny(), _tiny("T1/swish", op="swish", scale=1.0)]
    path = tmp_path / "verify.jsonl"
    names = ["metal_m2", "tpu_v5e"]
    loop = LoopConfig(num_iterations=3, use_profiling=True)

    first = run_transfer_matrix(wls, names, loop=loop,
                                cache=VerificationCache.open(path),
                                max_workers=2)
    s1 = first.cache.stats()
    assert s1["misses"] > 0
    # warm legs revisit candidates their platform's base campaign already
    # verified: the shared cache must have absorbed some of that work
    assert s1["hits"] > 0

    # a fresh process re-opening the same persistent cache re-verifies
    # nothing: 100% hit rate on the second run (ISSUE 3 acceptance)
    second = run_transfer_matrix(wls, names, loop=loop,
                                 cache=VerificationCache.open(path),
                                 max_workers=2)
    s2 = second.cache.stats()
    assert s2["misses"] == 0 and s2["hits"] > 0
    assert second.report()["pairs"] == first.report()["pairs"]


def test_warm_legs_from_different_sources_do_not_cross_resume(tmp_path):
    """transfer_from is part of the loop-config discriminator: a warm leg
    journaled for (A -> B) must not be resume-skipped by (C -> B)."""
    wl = _tiny("T1/swish", op="swish", scale=1.0)
    log = tmp_path / "warm.jsonl"
    kw = dict(max_workers=1, log_path=log)
    base = LoopConfig(num_iterations=2, platform="gpu_sim",
                      use_reference=True)
    Campaign([wl], CampaignConfig(
        loop=dataclasses.replace(base, transfer_from="tpu_v5e"), **kw)).run()
    other = Campaign([wl], CampaignConfig(
        loop=dataclasses.replace(base, transfer_from="metal_m2"), **kw)).run()
    assert other.n_skipped == 0
    again = Campaign([wl], CampaignConfig(
        loop=dataclasses.replace(base, transfer_from="tpu_v5e"), **kw)).run()
    assert again.n_skipped == 1


def test_resume_tolerates_logs_written_before_transfer_from_existed(
        tmp_path):
    """Growing LoopConfig must not orphan old event logs: a terminal event
    journaled without the transfer_from key still resume-matches a current
    config where the new field holds its default."""
    wl = _tiny("T1/swish", op="swish", scale=1.0)
    log = tmp_path / "old.jsonl"
    loop = LoopConfig(num_iterations=2)
    first = Campaign([wl], CampaignConfig(loop=loop, max_workers=1,
                                          log_path=log)).run()
    assert first.n_skipped == 0
    # age the log: strip the field this PR added, as a pre-PR log would be
    events = [json.loads(line) for line in log.read_text().splitlines()]
    for ev in events:
        if isinstance(ev.get("loop"), dict):
            ev["loop"].pop("transfer_from")
    log.write_text("\n".join(json.dumps(ev) for ev in events) + "\n")
    second = Campaign([wl], CampaignConfig(loop=loop, max_workers=1,
                                           log_path=log)).run()
    assert second.n_skipped == 1


def test_cli_rejects_platform_with_matrix(capsys):
    from repro.campaign.__main__ import main
    with pytest.raises(SystemExit) as exc:
        main(["--matrix", "--platform", "metal_m2"])
    assert exc.value.code == 2
    assert "--platforms" in capsys.readouterr().err


def test_campaign_accepts_injected_scheduler():
    wl = _tiny("T1/swish", op="swish", scale=1.0)
    sched = Scheduler(max_workers=2)
    r1 = run_campaign([wl], LoopConfig(num_iterations=2), scheduler=sched)
    r2 = run_campaign([wl], LoopConfig(num_iterations=2,
                                       platform="metal_m2"),
                      scheduler=sched)
    assert r1.runs[0].final.correct and r2.runs[0].final.correct


# ---------------------------------------------------------------------------
# Heat-map rendering (incl. failed legs)
# ---------------------------------------------------------------------------


def _matrix_with_failure():
    wls = [_tiny("T1/swish", op="swish", scale=1.0)]
    names = ["metal_m2", "tpu_v5e"]
    matrix = run_transfer_matrix(wls, names,
                                 loop=LoopConfig(num_iterations=2),
                                 max_workers=1)
    # knock one leg out after the fact: rendering must survive the hole
    matrix.legs[("tpu_v5e", "metal_m2")] = MatrixLeg(
        "tpu_v5e", "metal_m2", error="RuntimeError: leg exploded")
    return matrix


def test_heatmap_renders_failed_leg_without_crashing():
    matrix = _matrix_with_failure()
    text = matrix.heatmap_text()
    assert "ERR" in text and "·" in text
    assert "1 failed" in text.splitlines()[0]
    md = matrix.heatmap_markdown()
    assert "ERR" in md and "| **tpu_v5e** |" in md
    rep = matrix.report()
    assert rep["n_failed"] == 1
    assert rep["pairs"]["tpu_v5e->metal_m2"] == {
        "error": "RuntimeError: leg exploded"}
    assert matrix.uplift("tpu_v5e", "metal_m2") is None
    assert matrix.uplift("metal_m2", "tpu_v5e") is not None


def test_matrix_isolates_unknown_platform_into_leg_errors():
    """A platform that fails to resolve poisons exactly its own legs."""
    wls = [_tiny("T1/swish", op="swish", scale=1.0)]
    matrix = run_transfer_matrix(
        wls, ["tpu_v5e", "metal_m2", "metal_m9"],
        loop=LoopConfig(num_iterations=2), max_workers=1)
    assert matrix.n_failed == 4                  # every pair touching m9
    for (src, dst), leg in matrix.legs.items():
        if "metal_m9" in (src, dst):
            assert not leg.ok and "metal_m9" in leg.error
        else:
            assert leg.ok
    assert "ERR" in matrix.heatmap_text()


# ---------------------------------------------------------------------------
# metal_m2 target
# ---------------------------------------------------------------------------


def test_metal_m2_registered_with_metal_idiom():
    assert "metal_m2" in plat_mod.available_platforms()
    m = plat_mod.get_platform("metal_m2")
    assert m.matrix_align == 8 and m.vector_align == 32
    assert "[[thread_position_in_grid]]" in m.oneshot_example
    assert "threadgroup" in m.constraints_note
    # unified memory: fast-mem budget is KiB-scale, not the TPUs' 128 MiB
    assert m.fast_mem_bytes < 2 ** 20
    assert "KiB" in m.describe()


def test_metal_m2_space_and_model_diverge_from_tpu():
    mm = cand_mod.space_for("matmul", "metal_m2")
    assert max(mm["block_m"]) <= 128             # 128-capped tiles
    # strategy axes pass through untouched
    assert cand_mod.space_for("softmax", "metal_m2")["online"] == \
        (False, True)
    shapes = {"a": (1024, 1024), "b": (1024, 1024)}
    c = cand_mod.Candidate("matmul", {"block_m": 128, "block_n": 128,
                                      "block_k": 128})
    t_metal = cand_mod.model_time(c, shapes, "metal_m2")
    assert 0 < t_metal < float("inf")
    assert t_metal > cand_mod.model_time(c, shapes, "tpu_v5e")
    # elements-per-thread reference hint (paper §7.2) lands on block_rows
    sw = cand_mod.initial_candidate("swish", use_reference=True,
                                    platform="metal_m2")
    assert sw.params["block_rows"] == 8


def test_metal_m2_gets_no_tpu_compiler_params():
    assert compiler_params_for("metal_m2", dimension_semantics=("parallel",)) \
        is None
    assert compiler_params_for("gpu_sim") is None
    assert compiler_params_for("tpu_v5e",
                               dimension_semantics=("parallel",)) is not None


def test_metal_m2_prompt_and_verification():
    wl = _tiny()
    prompt = LLMBackend(platform="metal_m2").build_prompt(
        wl, prev=None, prev_result=None, recommendation=None,
        use_reference=False)
    assert "[[thread_position_in_grid]]" in prompt
    assert "threadgroup" in prompt and "pallas_call" not in prompt
    cand = cand_mod.Candidate("softmax", {"block_rows": 64, "online": True})
    cache = VerificationCache()
    r = verif_mod.verify(cand, wl, seed=0, cache=cache, platform="metal_m2")
    assert r.correct and r.profile["platform"] == "metal_m2"
    assert verif_mod.cache_key(cand, wl, 0, "metal_m2") != \
        verif_mod.cache_key(cand, wl, 0, "tpu_v5e")


# ---------------------------------------------------------------------------
# Same-platform transfer guard + CLI
# ---------------------------------------------------------------------------


def test_same_platform_transfer_sweep_raises():
    wl = _tiny("T1/swish", op="swish", scale=1.0)
    with pytest.raises(ValueError, match="distinct platforms"):
        run_transfer_sweep([wl], from_platform="gpu_sim",
                           to_platform="gpu_sim")


def test_cli_rejects_same_platform_transfer(capsys):
    from repro.campaign.__main__ import main
    with pytest.raises(SystemExit) as exc:
        main(["--transfer-from", "gpu_sim", "--platform", "gpu_sim"])
    assert exc.value.code == 2
    assert "must differ" in capsys.readouterr().err


def test_cli_rejects_matrix_with_transfer_from(capsys):
    from repro.campaign.__main__ import main
    with pytest.raises(SystemExit) as exc:
        main(["--matrix", "--transfer-from", "tpu_v5e"])
    assert exc.value.code == 2


def test_cli_matrix_smoke(tmp_path, capsys, monkeypatch):
    """--matrix end to end on a stubbed two-workload suite: heat-map +
    cache stats printed, exit 0, and a rerun against the same persistent
    cache reports zero misses."""
    from repro.campaign import __main__ as cli
    wls = [_tiny(), _tiny("T1/swish", op="swish", scale=1.0)]
    monkeypatch.setattr(cli.kernelbench, "suite",
                        lambda level, small=True: wls)
    cache = str(tmp_path / "cli-cache.jsonl")
    argv = ["--matrix", "--platforms", "tpu_v5e", "metal_m2",
            "--iters", "2", "--cache-path", cache]
    assert cli.main(argv) == 0
    out = capsys.readouterr().out
    assert "transfer matrix" in out and "fast_1 uplift" in out
    assert "metal_m2" in out and "hit rate" in out

    assert cli.main(argv) == 0
    assert "/ 0 misses" in capsys.readouterr().out


@pytest.mark.slow
def test_cli_matrix_over_full_registry_level1(tmp_path, capsys):
    """The acceptance-shaped invocation, shrunk to level 1: every
    registered platform, persistent cache, rerun -> 100% hits."""
    from repro.campaign.__main__ import main
    cache = str(tmp_path / "c.jsonl")
    argv = ["--matrix", "--level", "1", "--iters", "2",
            "--cache-path", cache]
    assert main(argv) == 0
    out = capsys.readouterr().out
    for name in plat_mod.available_platforms():
        assert name in out
    assert main(argv) == 0
    assert "100.0% hit rate" in capsys.readouterr().out
