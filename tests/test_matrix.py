"""Transfer-matrix engine (DESIGN.md §2): all-pairs enumeration, the
dependency-aware job graph (base overlap, warm-leg ordering, per-leg
factory binding, attributed base failures, resume, process isolation),
cache sharing, heat-map rendering with failed legs, the metal_m2 target,
the same-platform transfer guard, and the --matrix CLI."""
import dataclasses
import json
import os
import threading
import time
from pathlib import Path
from types import SimpleNamespace

import pytest

import repro.platforms as plat_mod
from repro.campaign import (Campaign, CampaignConfig, MatrixLeg, Scheduler,
                            VerificationCache, all_pairs, run_campaign,
                            run_transfer_matrix, run_transfer_sweep)
from repro.core import LoopConfig
from repro.core import candidates as cand_mod
from repro.core import verification as verif_mod
from repro.core.synthesis import LLMBackend
from repro.core.workload import Workload, randn
from repro.kernels import ref
from repro.kernels.ops import compiler_params_for


def _tiny(name="T1/softmax", op="softmax", shape=(64, 512), scale=60.0,
          level=1):
    refs = {"softmax": ref.softmax, "swish": ref.swish}
    return Workload(
        name=name, level=level, op=op,
        ref_fn=refs[op],
        input_fn=lambda rng: {"x": randn(rng, shape, scale)},
        input_shapes={"x": shape})


# ---------------------------------------------------------------------------
# All-pairs enumeration
# ---------------------------------------------------------------------------


def test_all_pairs_matches_registry_contents():
    names = plat_mod.available_platforms()
    pairs = all_pairs(names)
    assert len(pairs) == len(names) * (len(names) - 1)
    assert len(set(pairs)) == len(pairs)                 # no duplicates
    assert all(a != b for a, b in pairs)                 # no diagonal
    assert {a for a, _ in pairs} == set(names)           # every source
    assert {b for _, b in pairs} == set(names)           # every target
    # deterministic order regardless of input order
    assert all_pairs(reversed(names)) == pairs


def test_matrix_requires_two_distinct_platforms():
    wl = _tiny()
    with pytest.raises(ValueError):
        run_transfer_matrix([wl], ["tpu_v5e"])
    with pytest.raises(ValueError):
        run_transfer_matrix([wl], ["tpu_v5e", "tpu_v5e"])


def test_matrix_legs_cover_all_ordered_pairs(tmp_path):
    wls = [_tiny("T1/swish", op="swish", scale=1.0)]
    names = ["gpu_sim", "metal_m2", "tpu_v5e"]
    matrix = run_transfer_matrix(
        wls, names, loop=LoopConfig(num_iterations=2), max_workers=2)
    assert sorted(matrix.legs) == all_pairs(names)
    assert matrix.n_failed == 0
    for (src, dst), leg in matrix.legs.items():
        assert leg.ok and leg.sweep.from_platform == src
        assert leg.sweep.to_platform == dst
        # base campaigns are shared: the (A -> B) source is the (B -> A) cold
        assert leg.sweep.source is matrix.legs[(dst, src)].sweep.cold
    rep = matrix.report()
    assert rep["n_pairs"] == 6 and rep["n_failed"] == 0
    assert set(rep["pairs"]) == {f"{a}->{b}" for a, b in all_pairs(names)}


@pytest.mark.slow
def test_matrix_defaults_to_every_registered_platform():
    wls = [_tiny("T1/swish", op="swish", scale=1.0)]
    matrix = run_transfer_matrix(wls, loop=LoopConfig(num_iterations=2),
                                 max_workers=2)
    assert matrix.platforms == plat_mod.available_platforms()
    assert sorted(matrix.legs) == all_pairs(matrix.platforms)
    assert matrix.n_failed == 0


# ---------------------------------------------------------------------------
# Cache sharing across legs and reruns
# ---------------------------------------------------------------------------


def test_matrix_shares_one_cache_and_rerun_hits_100_percent(tmp_path):
    wls = [_tiny(), _tiny("T1/swish", op="swish", scale=1.0)]
    path = tmp_path / "verify.jsonl"
    names = ["metal_m2", "tpu_v5e"]
    loop = LoopConfig(num_iterations=3, use_profiling=True)

    first = run_transfer_matrix(wls, names, loop=loop,
                                cache=VerificationCache.open(path),
                                max_workers=2)
    s1 = first.cache.stats()
    assert s1["misses"] > 0
    # warm legs revisit candidates their platform's base campaign already
    # verified: the shared cache must have absorbed some of that work
    assert s1["hits"] > 0

    # a fresh process re-opening the same persistent cache re-verifies
    # nothing: 100% hit rate on the second run (ISSUE 3 acceptance)
    second = run_transfer_matrix(wls, names, loop=loop,
                                 cache=VerificationCache.open(path),
                                 max_workers=2)
    s2 = second.cache.stats()
    assert s2["misses"] == 0 and s2["hits"] > 0
    assert second.report()["pairs"] == first.report()["pairs"]


def test_warm_legs_from_different_sources_do_not_cross_resume(tmp_path):
    """transfer_from is part of the loop-config discriminator: a warm leg
    journaled for (A -> B) must not be resume-skipped by (C -> B)."""
    wl = _tiny("T1/swish", op="swish", scale=1.0)
    log = tmp_path / "warm.jsonl"
    kw = dict(max_workers=1, log_path=log)
    base = LoopConfig(num_iterations=2, platform="gpu_sim",
                      use_reference=True)
    Campaign([wl], CampaignConfig(
        loop=dataclasses.replace(base, transfer_from="tpu_v5e"), **kw)).run()
    other = Campaign([wl], CampaignConfig(
        loop=dataclasses.replace(base, transfer_from="metal_m2"), **kw)).run()
    assert other.n_skipped == 0
    again = Campaign([wl], CampaignConfig(
        loop=dataclasses.replace(base, transfer_from="tpu_v5e"), **kw)).run()
    assert again.n_skipped == 1


def test_resume_tolerates_logs_written_before_transfer_from_existed(
        tmp_path):
    """Growing LoopConfig must not orphan old event logs: a terminal event
    journaled without the transfer_from key still resume-matches a current
    config where the new field holds its default."""
    wl = _tiny("T1/swish", op="swish", scale=1.0)
    log = tmp_path / "old.jsonl"
    loop = LoopConfig(num_iterations=2)
    first = Campaign([wl], CampaignConfig(loop=loop, max_workers=1,
                                          log_path=log)).run()
    assert first.n_skipped == 0
    # age the log: strip the field this PR added, as a pre-PR log would be
    events = [json.loads(line) for line in log.read_text().splitlines()]
    for ev in events:
        if isinstance(ev.get("loop"), dict):
            ev["loop"].pop("transfer_from")
    log.write_text("\n".join(json.dumps(ev) for ev in events) + "\n")
    second = Campaign([wl], CampaignConfig(loop=loop, max_workers=1,
                                           log_path=log)).run()
    assert second.n_skipped == 1


def test_cli_rejects_platform_with_matrix(capsys):
    from repro.campaign.__main__ import main
    with pytest.raises(SystemExit) as exc:
        main(["--matrix", "--platform", "metal_m2"])
    assert exc.value.code == 2
    assert "--platforms" in capsys.readouterr().err


def test_campaign_accepts_injected_scheduler():
    wl = _tiny("T1/swish", op="swish", scale=1.0)
    sched = Scheduler(max_workers=2)
    r1 = run_campaign([wl], LoopConfig(num_iterations=2), scheduler=sched)
    r2 = run_campaign([wl], LoopConfig(num_iterations=2,
                                       platform="metal_m2"),
                      scheduler=sched)
    assert r1.runs[0].final.correct and r2.runs[0].final.correct


# ---------------------------------------------------------------------------
# Job graph: overlap, ordering, per-leg binding, attribution, resume
# ---------------------------------------------------------------------------


def test_matrix_overlaps_bases_and_orders_warm_legs():
    """Acceptance: on a 4-worker pool the base campaigns demonstrably run
    concurrently (telemetry peak >= 2, overlapping intervals, wall-clock
    below the serial sum of leg durations), and no warm leg starts before
    both of its base campaigns finished."""
    wls = [_tiny(), _tiny("T1/swish", op="swish", scale=1.0)]
    names = ["gpu_sim", "tpu_v5e"]
    matrix = run_transfer_matrix(wls, names,
                                 loop=LoopConfig(num_iterations=3),
                                 max_workers=4)
    assert matrix.n_failed == 0
    tele = matrix.telemetry
    assert tele["peak_concurrent_legs"] >= 2
    jobs = tele["jobs"]
    b1, b2 = jobs["base[gpu_sim]"], jobs["base[tpu_v5e]"]
    assert max(b1["started_at"], b2["started_at"]) \
        < min(b1["finished_at"], b2["finished_at"])
    for src, dst in all_pairs(names):
        warm = jobs[f"warm[{src}->{dst}]"]
        assert warm["started_at"] >= jobs[f"base[{src}]"]["finished_at"]
        assert warm["started_at"] >= jobs[f"base[{dst}]"]["finished_at"]
    assert tele["wall_s"] < tele["serial_sum_s"]


def test_warm_leg_starts_before_unrelated_slow_base_finishes(monkeypatch):
    """Warm legs are gated on THEIR two bases only: with a straggler third
    base, the pair of fast bases' warm legs complete while it still runs."""
    import repro.campaign.matrix as matrix_mod

    def fake_run_campaign(workloads, loop, **kw):
        time.sleep(1.0 if loop.platform == "tpu_v4" else 0.05)
        return SimpleNamespace(runs=[])

    monkeypatch.setattr(matrix_mod, "run_campaign", fake_run_campaign)
    monkeypatch.setattr(matrix_mod, "harvest_hints", lambda result: {})
    monkeypatch.setattr(matrix_mod, "reference_sources",
                        lambda result, name: {})
    matrix = matrix_mod.run_transfer_matrix(
        [_tiny()], ["gpu_sim", "metal_m2", "tpu_v4"], max_workers=8)
    jobs = matrix.telemetry["jobs"]
    slow_end = jobs["base[tpu_v4]"]["finished_at"]
    for pair in (("gpu_sim", "metal_m2"), ("metal_m2", "gpu_sim")):
        fast_warm = jobs[f"warm[{pair[0]}->{pair[1]}]"]
        assert fast_warm["finished_at"] < slow_end


def test_warm_leg_factories_bind_their_own_platform_and_hints(monkeypatch):
    """Regression for the loop-variable capture bug: with legs running
    concurrently, every warm leg's backend must be constructed for ITS
    target platform with ITS source's hints — a by-reference closure handed
    several legs the last iteration's platform."""
    import repro.campaign.matrix as matrix_mod
    created = []
    lock = threading.Lock()
    real_backend = matrix_mod.TemplateSearchBackend
    real_harvest = matrix_mod.harvest_hints

    class Recorder(real_backend):
        def __init__(self, platform=None, reference_hints=None):
            with lock:
                created.append((plat_mod.resolve_platform(platform).name,
                                (reference_hints or {}).get("__src__")))
            super().__init__(platform=platform,
                             reference_hints=reference_hints)

    def tagged_harvest(result):
        hints = real_harvest(result)
        # stamp which platform's base produced these hints ("__src__" never
        # matches a workload name, so the backend ignores it)
        hints["__src__"] = result.runs[0].final.profile["platform"]
        return hints

    monkeypatch.setattr(matrix_mod, "TemplateSearchBackend", Recorder)
    monkeypatch.setattr(matrix_mod, "harvest_hints", tagged_harvest)
    names = ["gpu_sim", "metal_m2", "tpu_v5e"]
    matrix = run_transfer_matrix(
        [_tiny("T1/swish", op="swish", scale=1.0)], names,
        loop=LoopConfig(num_iterations=2), max_workers=4)
    assert matrix.n_failed == 0
    assert matrix.telemetry["peak_concurrent_legs"] >= 2
    # each backend was built for (target platform, source hints) of exactly
    # one ordered pair, and every pair is covered
    assert {(src, dst) for dst, src in created} == set(all_pairs(names))


def test_base_failure_attributed_to_failing_platform_names():
    """A warm leg whose base campaign(s) died must say WHICH platform's
    base failed — and name both when both did."""
    wls = [_tiny("T1/swish", op="swish", scale=1.0)]
    matrix = run_transfer_matrix(
        wls, ["tpu_v5e", "zz_bogus_a", "zz_bogus_b"],
        loop=LoopConfig(num_iterations=2), max_workers=2)
    both = matrix.legs[("zz_bogus_a", "zz_bogus_b")].error
    assert "base campaign [zz_bogus_a] failed" in both
    assert "base campaign [zz_bogus_b] failed" in both
    one = matrix.legs[("zz_bogus_a", "tpu_v5e")].error
    assert "base campaign [zz_bogus_a] failed" in one
    assert "base campaign [tpu_v5e]" not in one
    assert matrix.legs[("tpu_v5e", "zz_bogus_b")].error.startswith(
        "RuntimeError: base campaign [zz_bogus_b] failed")


def test_matrix_resume_with_half_prefilled_log(tmp_path):
    """Per-leg resume survives the job-graph rewrite: a log holding only
    the legs that ran ON one platform (its base + every warm leg targeting
    it) resumes exactly those, re-runs the rest, and reproduces the
    uninterrupted matrix."""
    wls = [_tiny(), _tiny("T1/swish", op="swish", scale=1.0)]
    names = ["metal_m2", "tpu_v5e"]
    loop = LoopConfig(num_iterations=2)
    full_log = tmp_path / "full.jsonl"
    first = run_transfer_matrix(wls, names, loop=loop, max_workers=2,
                                log_path=full_log)
    assert first.n_failed == 0
    events = [json.loads(line)
              for line in full_log.read_text().splitlines()]
    half = [ev for ev in events
            if (ev.get("loop") or {}).get("platform") == "metal_m2"]
    assert any(ev.get("event") == "workload_done" for ev in half)
    half_log = tmp_path / "half.jsonl"
    half_log.write_text("\n".join(json.dumps(ev) for ev in half) + "\n")

    second = run_transfer_matrix(wls, names, loop=loop, max_workers=2,
                                 log_path=half_log)
    assert second.n_failed == 0
    onto_metal = second.legs[("tpu_v5e", "metal_m2")]
    assert onto_metal.sweep.cold.n_skipped == len(wls)   # base[metal_m2]
    assert onto_metal.sweep.warm.n_skipped == len(wls)   # warm tpu->metal
    onto_tpu = second.legs[("metal_m2", "tpu_v5e")]
    assert onto_tpu.sweep.cold.n_skipped == 0            # base[tpu_v5e]
    assert onto_tpu.sweep.warm.n_skipped == 0
    # resumed legs report identically to the uninterrupted run — including
    # iters_to_correct, which must be restored from the log, not lost
    assert second.report()["pairs"] == first.report()["pairs"]


@pytest.mark.slow
def test_matrix_process_isolation_end_to_end(tmp_path):
    """--isolate mode: every leg in a forked child, results pickled back,
    child cache snapshots folded into the parent's telemetry.

    Runs in a fresh interpreter: forking is only safe before the parent
    has executed jax computations (the XLA runtime's threads/locks do not
    survive a fork) — which holds for the real ``--isolate`` CLI path,
    where all verification happens inside the leg children, but not for
    this pytest process after earlier tests ran jax.
    """
    import subprocess
    import sys
    path = tmp_path / "v.jsonl"
    code = (
        "from repro.campaign import VerificationCache, run_transfer_matrix\n"
        "from repro.core import LoopConfig\n"
        "from repro.core.workload import Workload, randn\n"
        "from repro.kernels import ref\n"
        "wl = Workload(name='T1/swish', level=1, op='swish',\n"
        "              ref_fn=ref.swish,\n"
        "              input_fn=lambda rng: {'x': randn(rng, (64, 512),\n"
        "                                               1.0)},\n"
        "              input_shapes={'x': (64, 512)})\n"
        f"cache = VerificationCache.open({str(path)!r})\n"
        "m = run_transfer_matrix([wl], ['metal_m2', 'tpu_v5e'],\n"
        "                        loop=LoopConfig(num_iterations=2),\n"
        "                        max_workers=2, isolation='process',\n"
        "                        cache=cache)\n"
        "assert m.n_failed == 0, m.report()\n"
        "assert m.telemetry['isolation'] == 'process'\n"
        "for leg in m.legs.values():\n"
        "    assert leg.sweep.warm.runs[0].final.correct\n"
        "stats = m.cache.stats()\n"
        "assert stats['entries'] > 0, stats\n"
        "print('PROCESS_MATRIX_OK', stats['entries'])\n")
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=300, cwd=str(Path(__file__).resolve().parents[1]),
        env={**os.environ,
             "PYTHONPATH": "src" + os.pathsep + str(
                 Path(__file__).resolve().parents[1])})
    assert proc.returncode == 0, proc.stderr
    assert "PROCESS_MATRIX_OK" in proc.stdout
    # the persistent file is the cross-process medium: this process sees
    # every entry the leg children appended
    assert len(VerificationCache.open(path)) > 0


def test_matrix_leg_timeout_resolves_hung_thread_mode_legs(monkeypatch):
    """``leg_timeout_s``: a hung leg in THREAD mode resolves as a timeout
    error at the deadline (the graph scheduler's per-job watchdog) instead
    of wedging a graph slot — and the matrix completes around the holes."""
    import repro.campaign.matrix as matrix_mod
    release = threading.Event()

    def hang(*args, **kwargs):
        release.wait(10.0)
        raise RuntimeError("leg finished after abandonment")

    monkeypatch.setattr(matrix_mod, "run_campaign", hang)
    try:
        # 4 workers so the warm legs get slots even while the abandoned
        # base threads still hold theirs
        matrix = run_transfer_matrix(
            [_tiny()], ["metal_m2", "tpu_v5e"],
            loop=LoopConfig(num_iterations=1),
            max_workers=4, leg_timeout_s=0.3)
    finally:
        release.set()
    assert matrix.n_failed == len(matrix.legs) == 2
    for leg in matrix.legs.values():
        # warm legs either timed out themselves or report their base's
        # timeout — both surface the deadline, never a hang
        assert "timeout" in leg.error
    assert matrix.telemetry["leg_timeout_s"] == 0.3
    base_errors = [j["error"] for name, j in matrix.telemetry["jobs"].items()
                   if name.startswith("base[")]
    assert all(e and e.startswith("timeout") and "abandoned" in e
               for e in base_errors)


def test_matrix_leg_timeout_selects_the_graph_deadline_per_mode(monkeypatch):
    """The graph scheduler's per-job deadline is ``leg_timeout_s`` in
    thread mode but ``timeout_s`` under --isolate (there the child-killing
    workload timeout already bounds each leg; ``leg_timeout_s`` must not
    arm a second, thread-style deadline)."""
    import repro.campaign.matrix as matrix_mod
    graph_timeouts = []

    class Abort(Exception):
        pass

    def spy_scheduler(*args, **kwargs):
        # the graph scheduler is the first one constructed; capture its
        # deadline and abort before any leg (or fork) happens
        graph_timeouts.append(kwargs.get("timeout_s"))
        raise Abort

    monkeypatch.setattr(matrix_mod, "Scheduler", spy_scheduler)
    for isolation, expected in (("thread", 0.5), ("process", 60.0)):
        with pytest.raises(Abort):
            matrix_mod.run_transfer_matrix(
                [], ["metal_m2", "tpu_v5e"], isolation=isolation,
                timeout_s=60.0, leg_timeout_s=0.5)
    assert graph_timeouts == [0.5, 60.0]


def test_iters_delta_is_paired_over_workloads_correct_in_both_legs():
    """A workload only the warm leg rescued must not drag the warm mean up
    and flip the delta's sign: the delta pairs workloads correct in BOTH
    legs."""
    from repro.campaign.runner import WorkloadRun
    from repro.campaign.transfer import TransferSweepResult
    from repro.core.states import EvalResult, ExecutionState

    def fake_result(iters):        # workload -> iters_to_correct (or None)
        runs = [WorkloadRun(workload=name, level=1, iters_to_correct=it)
                for name, it in iters.items()]
        return SimpleNamespace(runs=runs, finals=lambda: [
            EvalResult(ExecutionState.CORRECT if r.iters_to_correct
                       else ExecutionState.GENERATION_FAILURE)
            for r in runs])

    sweep = TransferSweepResult(
        from_platform="a", to_platform="b",
        source=fake_result({}),
        # W1 correct in both (warm faster); W2 rescued by warm only
        cold=fake_result({"W1": 2, "W2": None}),
        warm=fake_result({"W1": 1, "W2": 4}),
        hints={})
    it = sweep.report()["total"]["iters_to_correct"]
    assert it["cold"] == 2.0
    assert it["warm"] == 2.5          # leg means still cover each leg
    assert it["n_paired"] == 1
    assert it["delta"] == -1.0        # paired: W1 only — transfer helped


def test_matrix_reports_iteration_delta_metric():
    """The softmax workload needs refinement iterations cold (numerically
    naive candidates fail on large-magnitude inputs) but lands correct
    earlier warm via the transferred online-softmax hint: the
    iterations-to-correct delta is negative where fast_1 uplift saturates
    at zero."""
    wls = [_tiny()]                       # softmax, scale=60
    names = ["metal_m2", "tpu_v5e"]
    matrix = run_transfer_matrix(wls, names,
                                 loop=LoopConfig(num_iterations=4),
                                 max_workers=2)
    assert matrix.n_failed == 0
    for pair in all_pairs(names):
        leg = matrix.legs[pair]
        it = leg.sweep.report()["total"]["iters_to_correct"]
        assert it["cold"] is not None and it["warm"] is not None
        assert leg.delta_iters == it["delta"] == it["warm"] - it["cold"]
        assert leg.delta_iters < 0
    text = matrix.heatmap_text(metric="delta_iters")
    assert "iterations-to-correct delta" in text
    md = matrix.heatmap_markdown(metric="delta_iters")
    assert "| **metal_m2** |" in md
    with pytest.raises(ValueError, match="metric"):
        matrix.heatmap_text(metric="bogus")


# ---------------------------------------------------------------------------
# Heat-map rendering (incl. failed legs)
# ---------------------------------------------------------------------------


def _matrix_with_failure():
    wls = [_tiny("T1/swish", op="swish", scale=1.0)]
    names = ["metal_m2", "tpu_v5e"]
    matrix = run_transfer_matrix(wls, names,
                                 loop=LoopConfig(num_iterations=2),
                                 max_workers=1)
    # knock one leg out after the fact: rendering must survive the hole
    matrix.legs[("tpu_v5e", "metal_m2")] = MatrixLeg(
        "tpu_v5e", "metal_m2", error="RuntimeError: leg exploded")
    return matrix


def test_heatmap_renders_failed_leg_without_crashing():
    matrix = _matrix_with_failure()
    text = matrix.heatmap_text()
    assert "ERR" in text and "·" in text
    assert "1 failed" in text.splitlines()[0]
    md = matrix.heatmap_markdown()
    assert "ERR" in md and "| **tpu_v5e** |" in md
    rep = matrix.report()
    assert rep["n_failed"] == 1
    assert rep["pairs"]["tpu_v5e->metal_m2"] == {
        "error": "RuntimeError: leg exploded"}
    assert matrix.uplift("tpu_v5e", "metal_m2") is None
    assert matrix.uplift("metal_m2", "tpu_v5e") is not None


def test_matrix_isolates_unknown_platform_into_leg_errors():
    """A platform that fails to resolve poisons exactly its own legs."""
    wls = [_tiny("T1/swish", op="swish", scale=1.0)]
    matrix = run_transfer_matrix(
        wls, ["tpu_v5e", "metal_m2", "metal_m9"],
        loop=LoopConfig(num_iterations=2), max_workers=1)
    assert matrix.n_failed == 4                  # every pair touching m9
    for (src, dst), leg in matrix.legs.items():
        if "metal_m9" in (src, dst):
            assert not leg.ok and "metal_m9" in leg.error
        else:
            assert leg.ok
    assert "ERR" in matrix.heatmap_text()


# ---------------------------------------------------------------------------
# metal_m2 target
# ---------------------------------------------------------------------------


def test_metal_m2_registered_with_metal_idiom():
    assert "metal_m2" in plat_mod.available_platforms()
    m = plat_mod.get_platform("metal_m2")
    assert m.matrix_align == 8 and m.vector_align == 32
    assert "[[thread_position_in_grid]]" in m.oneshot_example
    assert "threadgroup" in m.constraints_note
    # unified memory: fast-mem budget is KiB-scale, not the TPUs' 128 MiB
    assert m.fast_mem_bytes < 2 ** 20
    assert "KiB" in m.describe()


def test_metal_m2_space_and_model_diverge_from_tpu():
    mm = cand_mod.space_for("matmul", "metal_m2")
    assert max(mm["block_m"]) <= 128             # 128-capped tiles
    # strategy axes pass through untouched
    assert cand_mod.space_for("softmax", "metal_m2")["online"] == \
        (False, True)
    shapes = {"a": (1024, 1024), "b": (1024, 1024)}
    c = cand_mod.Candidate("matmul", {"block_m": 128, "block_n": 128,
                                      "block_k": 128})
    t_metal = cand_mod.model_time(c, shapes, "metal_m2")
    assert 0 < t_metal < float("inf")
    assert t_metal > cand_mod.model_time(c, shapes, "tpu_v5e")
    # elements-per-thread reference hint (paper §7.2) lands on block_rows
    sw = cand_mod.initial_candidate("swish", use_reference=True,
                                    platform="metal_m2")
    assert sw.params["block_rows"] == 8


def test_metal_m2_gets_no_tpu_compiler_params():
    assert compiler_params_for("metal_m2", dimension_semantics=("parallel",)) \
        is None
    assert compiler_params_for("gpu_sim") is None
    assert compiler_params_for("tpu_v5e",
                               dimension_semantics=("parallel",)) is not None


def test_metal_m2_prompt_and_verification():
    wl = _tiny()
    prompt = LLMBackend(platform="metal_m2", prompt_only=True).build_prompt(
        wl, prev=None, prev_result=None, recommendation=None,
        use_reference=False)
    assert "[[thread_position_in_grid]]" in prompt
    assert "threadgroup" in prompt and "pallas_call" not in prompt
    cand = cand_mod.Candidate("softmax", {"block_rows": 64, "online": True})
    cache = VerificationCache()
    r = verif_mod.verify(cand, wl, seed=0, cache=cache, platform="metal_m2")
    assert r.correct and r.profile["platform"] == "metal_m2"
    assert verif_mod.cache_key(cand, wl, 0, "metal_m2") != \
        verif_mod.cache_key(cand, wl, 0, "tpu_v5e")


# ---------------------------------------------------------------------------
# Same-platform transfer guard + CLI
# ---------------------------------------------------------------------------


def test_same_platform_transfer_sweep_raises():
    wl = _tiny("T1/swish", op="swish", scale=1.0)
    with pytest.raises(ValueError, match="distinct platforms"):
        run_transfer_sweep([wl], from_platform="gpu_sim",
                           to_platform="gpu_sim")


def test_cli_rejects_same_platform_transfer(capsys):
    from repro.campaign.__main__ import main
    with pytest.raises(SystemExit) as exc:
        main(["--transfer-from", "gpu_sim", "--platform", "gpu_sim"])
    assert exc.value.code == 2
    assert "must differ" in capsys.readouterr().err


def test_cli_rejects_matrix_with_transfer_from(capsys):
    from repro.campaign.__main__ import main
    with pytest.raises(SystemExit) as exc:
        main(["--matrix", "--transfer-from", "tpu_v5e"])
    assert exc.value.code == 2


def test_cli_matrix_smoke(tmp_path, capsys, monkeypatch):
    """--matrix end to end on a stubbed two-workload suite: heat-map +
    cache stats printed, exit 0, and a rerun against the same persistent
    cache reports zero misses."""
    from repro.campaign import __main__ as cli
    wls = [_tiny(), _tiny("T1/swish", op="swish", scale=1.0)]
    monkeypatch.setattr(cli.kernelbench, "suite",
                        lambda level, small=True: wls)
    cache = str(tmp_path / "cli-cache.jsonl")
    argv = ["--matrix", "--platforms", "tpu_v5e", "metal_m2",
            "--iters", "2", "--cache-path", cache]
    assert cli.main(argv) == 0
    out = capsys.readouterr().out
    assert "transfer matrix" in out and "fast_1 uplift" in out
    assert "metal_m2" in out and "hit rate" in out

    assert cli.main(argv) == 0
    assert "/ 0 misses" in capsys.readouterr().out


@pytest.mark.slow
def test_cli_matrix_over_full_registry_level1(tmp_path, capsys):
    """The acceptance-shaped invocation, shrunk to level 1: every
    registered platform, persistent cache, rerun -> 100% hits."""
    from repro.campaign.__main__ import main
    cache = str(tmp_path / "c.jsonl")
    argv = ["--matrix", "--level", "1", "--iters", "2",
            "--cache-path", cache]
    assert main(argv) == 0
    out = capsys.readouterr().out
    for name in plat_mod.available_platforms():
        assert name in out
    assert main(argv) == 0
    assert "100.0% hit rate" in capsys.readouterr().out
