"""Elastic checkpoint restore: save under one layout, restore with explicit
shardings of the live mesh (the down/up-scale path after a node failure)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.launch.mesh import make_debug_mesh
from repro.models import build_model
from repro.sharding import make_rules, spec_tree
from repro.train import restore_checkpoint, save_checkpoint


def test_restore_with_mesh_shardings(tmp_path):
    cfg = reduced(get_config("starcoder2-7b"))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    save_checkpoint(tmp_path, 3, {"params": params})

    # restore onto the live mesh with explicit NamedShardings (this is what
    # the trainer does after an elastic re-layout)
    mesh = make_debug_mesh(1, 1)
    rules = make_rules(mesh)
    shardings = {"params": spec_tree(m.logical_specs(), rules, params)}
    restored = restore_checkpoint(tmp_path, 3, {"params": params},
                                  shardings=shardings)
    for a, b in zip(jax.tree.leaves(params),
                    jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # restored leaves carry the requested sharding
    leaf = jax.tree.leaves(restored["params"])[0]
    assert leaf.sharding.mesh.shape == mesh.shape


@pytest.mark.slow
def test_trainer_state_survives_relayout(tmp_path):
    """Save from a trainer, restore into a fresh trainer, losses continue."""
    from repro.train import Trainer, TrainConfig
    cfg = reduced(get_config("starcoder2-7b"))
    m = build_model(cfg)

    def batch(i):
        t = (np.arange(17)[None] + i) % 64
        return {"tokens": np.tile(t[:, :-1], (2, 1)).astype(np.int32),
                "labels": np.tile(t[:, 1:], (2, 1)).astype(np.int32)}

    tc = TrainConfig(peak_lr=5e-3, warmup_steps=1, total_steps=20,
                     ckpt_dir=str(tmp_path), ckpt_every=4)
    t1 = Trainer(m, tc)
    for i in range(4):
        t1.train_step(batch(i))
    loss_before = t1.train_step(batch(4))["loss"]

    t2 = Trainer(m, tc)  # "new fleet" after failure
    assert t2.restore_if_available()
    assert t2.step_num == 4
    loss_after = t2.train_step(batch(4))["loss"]
    assert abs(loss_before - loss_after) < 1e-4
