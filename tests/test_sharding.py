"""Sharding rules + tiny-mesh integration: the logical-axis system resolves
correctly, constraints are no-ops outside a rules context, and a sharded
train step on a debug mesh matches the unsharded one."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as PS

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.sharding import (ShardingRules, constrain, make_rules,
                            resolve_axes, set_rules, spec_tree)
from repro.launch.mesh import make_debug_mesh, mesh_desc


def test_resolve_axes_basic():
    mesh = make_debug_mesh(1, 1)
    rules = make_rules(mesh)
    spec = resolve_axes(("fsdp", "tp"), rules, (16, 16))
    assert spec == PS(("data",), "model")


def test_resolve_axes_divisibility_fallback():
    mesh = make_debug_mesh(1, 1)
    rules = ShardingRules(mesh=mesh, logical={"tp": "model"})
    # fake a model axis of size 16 by overriding axis_size
    class R(ShardingRules):
        def axis_size(self, physical):
            return 16 if physical else 1
    r = R(mesh=mesh, logical={"tp": "model"})
    spec = resolve_axes(("tp",), r, (60,))  # 60 % 16 != 0 -> replicate
    assert spec == PS(None)
    spec2 = resolve_axes(("tp",), r, (64,))
    assert spec2 == PS("model")


def test_constrain_noop_outside_context():
    x = jnp.ones((4, 4))
    y = constrain(x, ("batch", None))
    assert y is x


def test_constrain_inside_context_applies():
    mesh = make_debug_mesh(1, 1)
    with set_rules(make_rules(mesh)):
        y = jax.jit(lambda x: constrain(x, ("batch", None)))(jnp.ones((4, 4)))
    np.testing.assert_array_equal(y, np.ones((4, 4)))


def test_spec_tree_matches_structure():
    mesh = make_debug_mesh(1, 1)
    rules = make_rules(mesh)
    cfg = reduced(get_config("starcoder2-7b"))
    m = build_model(cfg)
    abs_p = m.abstract_params()
    tree = spec_tree(m.logical_specs(), rules, abs_p)
    assert jax.tree.structure(tree) == jax.tree.structure(abs_p)


def test_sharded_step_matches_unsharded():
    """Loss under a (1,1) mesh with full constraint machinery == plain loss."""
    cfg = reduced(get_config("starcoder2-7b"))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    from repro.configs.base import ShapeConfig
    batch = m.make_batch(jax.random.PRNGKey(1),
                         ShapeConfig("s", 32, 2, "train"))
    plain, _ = jax.jit(lambda p, b: m.loss_fn(p, b))(params, batch)
    mesh = make_debug_mesh(1, 1)
    rules = make_rules(mesh)
    with set_rules(rules):
        sharded, _ = jax.jit(lambda p, b: m.loss_fn(p, b))(params, batch)
    assert float(plain) == pytest.approx(float(sharded), rel=1e-5)


def test_multi_pod_rules_extend_batch_axes():
    import numpy as np_
    devs = np_.asarray(jax.devices()[:1]).reshape(1, 1, 1)
    mesh = jax.sharding.Mesh(devs, ("pod", "data", "model"))
    rules = make_rules(mesh)
    assert rules.logical["batch"] == ("pod", "data")
    assert rules.logical["fsdp"] == ("pod", "data")
    assert mesh_desc(mesh) == "pod=1xdata=1xmodel=1"
