"""LLM performance-analysis agent G (DESIGN.md §9, paper §3.2): the mock
analysis oracle, the three-line reply contract (parse, re-prompt,
fallback), rule-table edge cases, stale-recommendation clearing in the
refinement loop, the two-agent matrix/CLI surface, and the acceptance
flow — a full two-agent campaign recorded then replayed offline."""
import pytest

from repro.campaign import EventLog, run_transfer_matrix
from repro.core import LoopConfig
from repro.core.analysis import Recommendation, RuleBasedAnalyzer
from repro.core.candidates import space_for
from repro.core.prompts import is_analysis_prompt, render_analysis
from repro.core.refinement import run_workload
from repro.core.synthesis import LLMBackend
from repro.core.workload import Workload, randn
from repro.kernels import ref
from repro.llm import (ANALYSIS_REPROMPT, LLMAnalyzer, LLMSession,
                       MockTransport, TransportError, UsageMeter,
                       analysis_reply_reason, build_llm_context,
                       default_mock_analysis_reply, default_mock_reply,
                       parse_recommendation)
from repro.platforms import resolve_platform


def _tiny(name="T1/swish", op="swish", rows=8, lanes=512):
    refs = {"swish": ref.swish, "softmax": ref.softmax}
    return Workload(
        name=name, level=1, op=op,
        ref_fn=refs[op],
        input_fn=lambda rng: {"x": randn(rng, (rows, lanes),
                                         60.0 if op == "softmax" else 1.0)},
        input_shapes={"x": (rows, lanes)})


# One matmul profile the TPU alignment rule (Rule 1) fires on: block_m=64
# underfills the 128x128 MXU, so the rule table recommends block_m=128.
def _profile(platform="tpu_v5e"):
    return {"op": "matmul", "platform": platform,
            "params": {"block_m": 64, "block_n": 128, "block_k": 512},
            "shapes": [[512, 512], [512, 512]],
            "model_time_s": 1.0e-4, "baseline_time_s": 2.0e-4,
            "flops": 2.68e8}


def _analysis_prompt(platform="tpu_v5e"):
    plat = resolve_platform(platform)
    return render_analysis(plat.descriptor, _profile(platform),
                           space_for("matmul", plat))


# ---------------------------------------------------------------------------
# MockTransport analysis oracle
# ---------------------------------------------------------------------------


def test_mock_analysis_oracle_round_trips_the_rule_table():
    prompt = _analysis_prompt()
    reply = default_mock_analysis_reply(prompt)
    lines = reply.splitlines()
    assert lines[0].startswith("RECOMMENDATION: ")
    assert lines[1] == "PARAM: block_m"
    assert lines[2] == "VALUE: 128"
    rec = parse_recommendation(reply, op="matmul", platform="tpu_v5e")
    assert (rec.param, rec.value, rec.source) == ("block_m", 128, "llm")
    # same rule, same profile, different platform -> different verdict:
    # the oracle answers from the profile's OWN platform
    expected = RuleBasedAnalyzer(platform="metal_m2").analyze(
        _profile("metal_m2"))
    assert expected.text in default_mock_analysis_reply(
        _analysis_prompt("metal_m2"))


def test_mock_analysis_oracle_degrades_on_unreadable_profile():
    torn = _analysis_prompt().replace("```json\n", "```json\n{{{garbage ")
    reply = default_mock_analysis_reply(torn)
    assert "could not be read" in reply
    # still satisfies the reply contract — the session must not re-prompt
    # a degraded oracle forever
    assert analysis_reply_reason(reply) is None
    rec = parse_recommendation(reply)
    assert rec.param is None and rec.value is None


def test_default_mock_reply_routes_analysis_prompts_to_the_oracle():
    analysis = default_mock_reply(_analysis_prompt())
    assert analysis.startswith("RECOMMENDATION:")
    assert "```python" not in analysis
    # a synthesis prompt still gets the oracle-echo code block
    synthesis = default_mock_reply("Optimize the workload named T1/swish.")
    assert "```python" in synthesis and "RECOMMENDATION:" not in synthesis
    assert is_analysis_prompt(_analysis_prompt())
    assert not is_analysis_prompt(synthesis)


def test_mock_faults_break_the_analysis_contract_not_fences():
    prompt = _analysis_prompt()
    malformed = MockTransport(malformed_every=1).complete(prompt).text
    assert "RECOMMENDATION:" not in malformed and "VERDICT:" in malformed
    assert analysis_reply_reason(malformed) is not None
    truncated = MockTransport(truncate_every=1).complete(prompt).text
    assert truncated.endswith("RECOMMENDA")
    assert analysis_reply_reason(truncated) is not None


# ---------------------------------------------------------------------------
# Reply parsing (the three-line contract)
# ---------------------------------------------------------------------------


def test_parse_recommendation_contract():
    assert parse_recommendation("no contract lines at all") is None
    rec = parse_recommendation(
        "RECOMMENDATION: keep the tiling.\nPARAM: none\nVALUE: none")
    assert rec.param is None and rec.value is None and rec.source == "llm"
    # legal param + JSON-literal value decode and survive validation
    rec = parse_recommendation(
        "RECOMMENDATION: widen block_m.\nPARAM: block_m\nVALUE: 256",
        op="matmul", platform="tpu_v5e")
    assert rec.param == "block_m" and rec.value == 256


def test_parse_recommendation_strips_illegal_actions_to_text_only():
    # unknown parameter for the op's platform-legal space
    rec = parse_recommendation(
        "RECOMMENDATION: raise warp occupancy.\nPARAM: warp_count\nVALUE: 4",
        op="matmul", platform="tpu_v5e")
    assert rec.param is None and rec.value is None
    assert "warp occupancy" in rec.text
    # legal parameter, value outside its choices
    rec = parse_recommendation(
        "RECOMMENDATION: widen block_m.\nPARAM: block_m\nVALUE: 999",
        op="matmul", platform="tpu_v5e")
    assert rec.param is None
    # PARAM line without any VALUE line -> no structured action
    rec = parse_recommendation(
        "RECOMMENDATION: widen block_m.\nPARAM: block_m",
        op="matmul", platform="tpu_v5e")
    assert rec.param is None


def test_analysis_reply_reason_names_the_missing_line():
    assert analysis_reply_reason("RECOMMENDATION: fine.\nPARAM: none") is None
    reason = analysis_reply_reason("VERDICT: looks great")
    assert "RECOMMENDATION" in reason


# ---------------------------------------------------------------------------
# LLMAnalyzer: session contract, re-prompt, fallback
# ---------------------------------------------------------------------------


def test_analysis_session_reprompts_with_the_analysis_contract():
    calls = []

    def flaky(prompt):
        calls.append(prompt)
        return ("VERDICT: looks fine" if len(calls) == 1 else
                "RECOMMENDATION: keep the tiling.\nPARAM: none\nVALUE: none")

    usage = UsageMeter()
    session = LLMSession(MockTransport(completion_fn=flaky), usage=usage,
                         reply_check=analysis_reply_reason,
                         reprompt_instruction=ANALYSIS_REPROMPT)
    text = session.complete("Analysis prompt.")
    assert text.startswith("RECOMMENDATION:")
    assert usage.reprompts == 1 and usage.requests == 2
    # the re-prompt names the defect and restates agent G's contract, not
    # the generation agent's code-block contract
    assert "no `RECOMMENDATION:` line" in calls[1]
    assert "exactly three lines" in calls[1]
    assert "fenced" not in calls[1]


def test_llm_analyzer_falls_back_to_rule_table_when_replies_never_parse():
    usage = UsageMeter()
    session = LLMSession(
        MockTransport(completion_fn=lambda p: "no contract here"),
        usage=usage, max_attempts=2, reply_check=analysis_reply_reason,
        reprompt_instruction=ANALYSIS_REPROMPT)
    analyzer = LLMAnalyzer(session=session, platform="tpu_v5e")
    rec = analyzer.analyze(_profile())
    assert rec.source == "rule"
    assert (rec.param, rec.value) == ("block_m", 128)
    assert usage.requests == 2 and usage.failures == 1


def test_llm_analyzer_survives_dead_transport():
    def dead(prompt):
        raise TransportError("wire cut")

    analyzer = LLMAnalyzer(session=dead, platform="tpu_v5e")
    rec = analyzer.analyze(_profile())
    assert rec.source == "rule" and rec.param == "block_m"


def test_llm_analyzer_prompt_embeds_profile_and_legal_space():
    analyzer = LLMAnalyzer(session=lambda p: "", platform="tpu_v5e")
    prompt = analyzer.build_prompt(_profile())
    assert is_analysis_prompt(prompt)
    assert '"block_m": 64' in prompt            # the profile json fence
    assert "256" in prompt                      # a legal block_m choice
    assert resolve_platform("tpu_v5e").descriptor in prompt


def test_analyzer_factory_meters_into_the_shared_usage():
    ctx = build_llm_context(transport=MockTransport())
    analyzer = ctx.analyzer_factory(platform="tpu_v5e")()
    rec = analyzer.analyze(_profile())
    assert rec.source == "llm" and rec.param == "block_m"
    snap = ctx.usage.snapshot()
    assert snap["requests"] == 1 and snap["total_tokens"] > 0


# ---------------------------------------------------------------------------
# Rule table: foreign-space regression (Rule 4 guard)
# ---------------------------------------------------------------------------


def test_attention_profile_with_foreign_space_falls_through_to_roofline(
        monkeypatch):
    """Regression: an attention profile whose platform-legal space carries
    no block_k axis used to KeyError inside Rule 4 (params were guarded,
    the space was not); it must fall through to the roofline verdict."""
    import repro.core.analysis as analysis_mod
    monkeypatch.setattr(analysis_mod, "space_for", lambda op, plat: {})
    profile = {"op": "attention",
               "params": {"block_q": 128, "block_k": 128},
               "shapes": [[4, 1024, 64]],
               "model_time_s": 1.0e-4, "flops": 1.0e6}
    rec = analysis_mod.RuleBasedAnalyzer().analyze(profile)
    assert rec.param is None and "roofline" in rec.text


# ---------------------------------------------------------------------------
# Refinement loop: stale recommendations + journaled source
# ---------------------------------------------------------------------------

_GOOD_REPLY = ("mirroring the oracle\n\n```python\n"
               "from repro.kernels import ref as _ref\n\n\n"
               "def candidate(*inputs):\n    return _ref.swish(*inputs)\n"
               "```\n")
_BAD_REPLY = ("regressed\n\n```python\n"
              "def candidate(*inputs):\n    return inputs[0] * 0.0\n```\n")


class _MagicAnalyzer:
    """Stub agent G with an unmistakable token, so prompts can be asserted
    to carry — or to have dropped — its advice."""

    def analyze(self, profile):
        return Recommendation(text="MAGIC_REC_TOKEN raise block_lanes.",
                              source="llm")


def test_regression_clears_stale_recommendation_from_the_next_prompt():
    replies = [_GOOD_REPLY, _BAD_REPLY, _GOOD_REPLY]
    prompts = []

    def complete(prompt):
        prompts.append(prompt)
        return replies.pop(0)

    out = run_workload(_tiny(),
                       LoopConfig(num_iterations=3, use_profiling=True),
                       agent=LLMBackend(complete=complete,
                                        platform="tpu_v5e"),
                       analyzer=_MagicAnalyzer())
    assert [log.phase for log in out.logs] == \
        ["functional", "optimization", "functional"]
    # iteration 0 was CORRECT -> its recommendation reaches prompt 1 ...
    assert "MAGIC_REC_TOKEN" in prompts[1]
    # ... but the regression in iteration 1 clears it: the functional
    # retry prompt carries the failure feedback, not stale tuning advice
    assert "MAGIC_REC_TOKEN" not in prompts[2]
    assert [log.recommendation_source for log in out.logs] == \
        ["llm", None, "llm"]


# ---------------------------------------------------------------------------
# Matrix: two-agent legs + analysis validation
# ---------------------------------------------------------------------------


def test_matrix_rejects_llm_analysis_without_llm_backend():
    with pytest.raises(ValueError, match="analysis='llm' requires"):
        run_transfer_matrix([_tiny()], ["metal_m2", "tpu_v5e"],
                            analysis="llm")
    with pytest.raises(ValueError, match="analysis must be"):
        run_transfer_matrix([_tiny()], ["metal_m2", "tpu_v5e"],
                            backend="llm", analysis="vibes")


def test_matrix_two_agent_legs_meter_analysis_calls():
    matrix = run_transfer_matrix(
        [_tiny()], ["metal_m2", "tpu_v5e"],
        loop=LoopConfig(num_iterations=2, use_profiling=True),
        max_workers=4, backend="llm", analysis="llm")
    assert matrix.n_failed == 0
    tele = matrix.telemetry
    assert tele["analysis"] == "llm"
    # 4 legs x 2 generation iterations = 8 generation requests; agent G's
    # analysis sessions bill on top of that through the same fleet meter
    assert tele["llm_usage"]["requests"] > 8


# ---------------------------------------------------------------------------
# CLI: flags + the two-agent acceptance flow
# ---------------------------------------------------------------------------


def test_cli_analysis_llm_requires_llm_backend(capsys):
    from repro.campaign.__main__ import main
    with pytest.raises(SystemExit):
        main(["--analysis", "llm"])
    assert "--backend llm" in capsys.readouterr().err


def test_cli_leg_timeout_only_with_thread_mode_matrix(capsys):
    from repro.campaign.__main__ import main
    with pytest.raises(SystemExit):
        main(["--leg-timeout", "10"])
    assert "--matrix" in capsys.readouterr().err
    with pytest.raises(SystemExit):
        main(["--matrix", "--leg-timeout", "10", "--isolate"])
    assert "--isolate" in capsys.readouterr().err or \
        "thread-mode" in capsys.readouterr().err


def test_cli_use_profiling_is_an_alias_of_profiling():
    from repro.campaign.__main__ import build_parser
    parser = build_parser()
    assert parser.parse_args(["--use-profiling"]).profiling
    assert parser.parse_args(["--profiling"]).profiling


def test_cli_two_agent_record_then_replay(tmp_path, capsys, monkeypatch):
    """The ISSUE acceptance flow: record a full two-agent campaign offline,
    then ``--backend llm --analysis llm --use-profiling --replay SESSION``
    reruns it with zero live calls, analysis tokens journaled in
    ``campaign_done.llm_usage`` and at least one optimization-pass
    iteration whose recommendation came from the LLM analyzer."""
    from repro.campaign import __main__ as cli
    wls = [_tiny()]
    monkeypatch.setattr(cli.kernelbench, "suite",
                        lambda level, small=True: wls)
    session = tmp_path / "session.jsonl"
    rec_log, rep_log = tmp_path / "rec.jsonl", tmp_path / "rep.jsonl"
    base = ["--backend", "llm", "--analysis", "llm",
            "--platform", "tpu_v5e", "--iters", "3"]
    assert cli.main(base + ["--profiling", "--record", str(session),
                            "--log", str(rec_log)]) == 0
    out_rec = capsys.readouterr().out
    assert "llm usage:" in out_rec

    events = EventLog(rec_log).events()
    iters = [e for e in events if e.get("event") == "iteration"]
    assert any(e.get("phase") == "optimization" and
               e.get("recommendation_source") == "llm" for e in iters)
    done = [e for e in events if e.get("event") == "campaign_done"]
    # generation alone is 3 requests; the analysis sessions bill on top
    assert done and done[-1]["llm_usage"]["requests"] > 3

    recorded = session.read_bytes()
    assert cli.main(base + ["--use-profiling", "--replay", str(session),
                            "--log", str(rep_log)]) == 0
    out_rep = capsys.readouterr().out
    assert "correct=1" in out_rep
    # replay mode never writes: an unchanged session file is the proof no
    # live call was made and captured
    assert session.read_bytes() == recorded
    rep_iters = [e for e in EventLog(rep_log).events()
                 if e.get("event") == "iteration"]
    assert any(e.get("recommendation_source") == "llm" for e in rep_iters)
    assert out_rec.split("campaign report")[1] == \
        out_rep.split("campaign report")[1]
