"""Property-based tests (hypothesis) on the per-tenant fairness limiter
(repro.service.fairness.TenantFairLimiter), registered alongside the
CI-enforced non-skip hypothesis lane from the population-property tests.

The two service-level invariants the daemon's admission control rests on:

1. **Fleet budget is a hard ceiling** — under ANY interleaving of
   reserves across any set of tenants, the number of reserves whose
   pacing delay permits issue inside a window can never exceed the
   burst allowance plus the window's refill. The token-bucket algebra
   behind it: with a frozen clock and budget R rpm, the bucket starts at
   R and each reserve debits 1, so the k-th reserve (0-indexed) sees a
   deficit of ``max(0, k + 1 - R)`` and must pace ``deficit * 60 / R``
   seconds — whoever the tenants are.

2. **A starved tenant's delay is bounded by the fleet deficit alone** —
   per-tenant buckets only ever ADD delay for the tenant that spent its
   own slice (max composition); a fresh tenant's bucket is full, so the
   hot tenant's backlog never leaks into the fresh tenant's pacing.
"""
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not vendored; property tests skipped")
from hypothesis import given, settings, strategies as st

from repro.service.fairness import TenantFairLimiter

# an interleaving: each entry is (tenant index, token cost)
_INTERLEAVINGS = st.lists(
    st.tuples(st.integers(0, 4), st.integers(0, 200)),
    min_size=1, max_size=120)


def _frozen():
    t = {"now": 0.0}
    return t, (lambda: t["now"])


@settings(max_examples=80, deadline=None)
@given(_INTERLEAVINGS, st.integers(1, 50))
def test_fleet_budget_never_exceeded_under_any_interleaving(seq, rpm):
    """Invariant 1: reserves that may issue within any horizon T obey
    ``burst + refill``: issue_time(k) >= (k + 1 - rpm) * 60 / rpm, so at
    most ``rpm + T * rpm / 60`` calls can have issue times <= T."""
    t, clock = _frozen()
    fair = TenantFairLimiter(rpm=rpm, clock=clock)
    delays = [fair.reserve(f"t{ti}") for ti, _ in seq]
    for k, delay in enumerate(delays):
        expected = max(0.0, (k + 1 - rpm) * 60.0 / rpm)
        assert delay == pytest.approx(expected), \
            f"reserve {k}: delay {delay} != {expected} (rpm={rpm})"
    # the window form of the same bound, for a few horizons
    for horizon in (0.0, 30.0, 60.0, 120.0):
        issued = sum(d <= horizon for d in delays)
        assert issued <= rpm + horizon * rpm / 60.0 + 1e-9


@settings(max_examples=80, deadline=None)
@given(_INTERLEAVINGS, st.integers(60, 6000))
def test_fleet_token_budget_never_exceeded(seq, tpm):
    """Invariant 1 for the token bucket: cumulative tokens issuable by
    time T never exceed burst (tpm) + refill (T * tpm / 60)."""
    t, clock = _frozen()
    fair = TenantFairLimiter(tpm=tpm, clock=clock)
    spent = 0
    for i, (tenant, tokens) in enumerate(seq):
        delay = fair.reserve(f"t{tenant}", tokens=tokens)
        spent += tokens
        deficit = spent - tpm
        expected = max(0.0, deficit * 60.0 / tpm)
        assert delay == pytest.approx(expected)


@settings(max_examples=80, deadline=None)
@given(_INTERLEAVINGS, st.integers(2, 50), st.integers(1, 20))
def test_per_tenant_buckets_only_add_delay_for_the_spender(seq, rpm,
                                                          tenant_rpm):
    """Per-tenant pacing is the max of the two layers: every delay is >=
    the fleet-only delay (same interleaving, no tenant buckets), and any
    EXTRA delay is explained entirely by that tenant's own spend."""
    t1, clock1 = _frozen()
    fleet_only = TenantFairLimiter(rpm=rpm, clock=clock1)
    t2, clock2 = _frozen()
    fair = TenantFairLimiter(rpm=rpm, tenant_rpm=tenant_rpm, clock=clock2)

    per_tenant_count = {}
    for tenant_idx, _ in seq:
        tenant = f"t{tenant_idx}"
        base = fleet_only.reserve(tenant)
        combined = fair.reserve(tenant)
        k_t = per_tenant_count.get(tenant, 0)
        per_tenant_count[tenant] = k_t + 1
        own = max(0.0, (k_t + 1 - tenant_rpm) * 60.0 / tenant_rpm)
        assert combined == pytest.approx(max(base, own))
        assert combined >= base - 1e-12


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 200), st.integers(2, 50), st.integers(1, 20))
def test_fresh_tenant_delay_bounded_by_fleet_deficit(hot_reserves, rpm,
                                                     tenant_rpm):
    """Invariant 2: after a hot tenant issues any number of reserves, a
    fresh tenant's first delay equals the pure fleet deficit — the hot
    tenant's per-tenant backlog does not leak."""
    t, clock = _frozen()
    fair = TenantFairLimiter(rpm=rpm, tenant_rpm=tenant_rpm, clock=clock)
    for _ in range(hot_reserves):
        fair.reserve("hot")
    fleet_deficit = max(0.0, (hot_reserves + 1 - rpm) * 60.0 / rpm)
    assert fair.reserve("fresh") == pytest.approx(fleet_deficit)


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 100), st.integers(2, 60))
def test_refill_restores_burst_headroom(n, rpm):
    """Advancing the frozen clock refills the bucket at rpm/60 per second
    (capped at the burst allowance): after a full minute idle, a drained
    fleet bucket admits a full burst again."""
    t, clock = _frozen()
    fair = TenantFairLimiter(rpm=rpm, clock=clock)
    for _ in range(n):
        fair.reserve("a")
    # idle one minute past the backlog (+1 s of float-rounding margin)
    t["now"] += 61.0 + (max(0, n - rpm) * 60.0 / rpm)
    delays = [fair.reserve("b") for _ in range(rpm)]
    assert delays == [0.0] * rpm
