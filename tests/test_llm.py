"""LLM-backend subsystem (DESIGN.md §9): transport protocol semantics
(mock faults, record/replay sessions, env-stub HTTP), rate-limiter pacing,
session retry/re-prompt/accounting, scheduler slot-yield while throttled,
campaign usage journaling, and the LLM legs of the transfer matrix/CLI."""
import http.server
import json
import threading
import time

import pytest

from repro.campaign import (EventLog, Scheduler, run_campaign,
                            run_transfer_matrix)
from repro.campaign.report import format_report, report_from_events
from repro.core import LoopConfig
from repro.core.synthesis import LLMBackend
from repro.core.workload import Workload, randn
from repro.kernels import ref
from repro.llm import (Completion, HTTPTransport, LLMSession, MockTransport,
                       RateLimitError, RateLimiter, ReplayMissError,
                       ReplayTransport, TransportError, UsageMeter,
                       build_llm_context, default_mock_reply, estimate_tokens,
                       prompt_key)


def _tiny(name="T1/swish", op="swish", rows=8, lanes=512):
    refs = {"swish": ref.swish, "softmax": ref.softmax}
    return Workload(
        name=name, level=1, op=op,
        ref_fn=refs[op],
        input_fn=lambda rng: {"x": randn(rng, (rows, lanes),
                                         60.0 if op == "softmax" else 1.0)},
        input_shapes={"x": (rows, lanes)})


# ---------------------------------------------------------------------------
# MockTransport: determinism + fault injection
# ---------------------------------------------------------------------------


def test_default_mock_reply_echoes_the_right_oracle():
    p = "... Optimize the workload named L2/attention_gqa with a custom ..."
    assert "_ref.attention(*inputs)" in default_mock_reply(p)
    p = "... Optimize the workload named L2/xent_moonshot with a custom ..."
    assert "_ref.softmax_xent(*inputs)" in default_mock_reply(p)
    # unknown op family: a deterministic wrong candidate (feedback path)
    p = "... Optimize the workload named L9/mystery with a custom ..."
    assert "return inputs[0]" in default_mock_reply(p)


def test_default_mock_reply_resolves_l3_ops_via_registry():
    """L3 block names embed no op substring; the registry lookup must
    still find the op so L3 LLM campaigns can verify CORRECT."""
    for name, marker in (("L3/qwen_lm_head", "_ref.softmax_xent"),
                         ("L3/yi_mlp_block", "_ref.swish(inputs[0])"),
                         ("L3/starcoder2_attn_block", "_ref.attention"),
                         ("L3/phi3_gemm_stack", "_ref.matmul")):
        p = f"... Optimize the workload named {name} with a custom ..."
        assert marker in default_mock_reply(p), name


def test_mock_transport_is_deterministic():
    prompt = "Optimize the workload named T1/swish now"
    a = MockTransport().complete(prompt)
    b = MockTransport().complete(prompt)
    assert a == b
    assert a.prompt_tokens == estimate_tokens(prompt)


def test_mock_transport_fault_schedule():
    t = MockTransport(rate_limit_every=3, malformed_every=2,
                      retry_after_s=0.7)
    prompt = "Optimize the workload named T1/swish now"
    ok = t.complete(prompt)                       # call 1: clean
    assert "```python" in ok.text
    bad = t.complete(prompt)                      # call 2: malformed
    assert "```" not in bad.text
    with pytest.raises(RateLimitError) as exc:    # call 3: throttled
        t.complete(prompt)
    assert exc.value.retry_after_s == 0.7
    assert t.calls == 3


def test_mock_transport_truncation_leaves_fence_unclosed():
    t = MockTransport(truncate_every=1)
    text = t.complete("Optimize the workload named T1/swish now").text
    assert text.count("```") == 1                 # opened, never closed


def test_mock_transport_latency_uses_injected_sleep():
    naps = []
    t = MockTransport(latency_s=0.25, sleep=naps.append)
    t.complete("x")
    assert naps == [0.25]


# ---------------------------------------------------------------------------
# ReplayTransport: record / replay JSONL sessions
# ---------------------------------------------------------------------------


def test_record_then_replay_round_trips_byte_for_byte(tmp_path):
    path = tmp_path / "session.jsonl"
    inner = MockTransport()
    rec = ReplayTransport.record(path, inner)
    prompts = ["Optimize the workload named T1/swish now",
               "Optimize the workload named T1/softmax now"]
    recorded = [rec.complete(p) for p in prompts]
    assert inner.calls == 2 and len(rec) == 2

    rep = ReplayTransport.replay(path)
    assert rep.inner is None                      # zero live calls possible
    for p, comp in zip(reversed(prompts), reversed(recorded)):
        assert rep.complete(p) == comp            # order-independent keys


def test_replay_miss_names_the_session_file(tmp_path):
    path = tmp_path / "session.jsonl"
    ReplayTransport.record(path, MockTransport()).complete("known prompt")
    rep = ReplayTransport.replay(path)
    with pytest.raises(ReplayMissError, match="session.jsonl"):
        rep.complete("never recorded")


def test_replay_of_missing_file_fails_fast(tmp_path):
    with pytest.raises(TransportError, match="record one first"):
        ReplayTransport.replay(tmp_path / "nope.jsonl")


def test_replay_repeated_identical_prompts_fifo_then_repeat(tmp_path):
    """Identical prompts stack per-key FIFO; an exhausted key repeats its
    last completion so resumed replays stay deterministic."""
    path = tmp_path / "s.jsonl"
    replies = iter(["first reply ```python\npass```",
                    "second reply ```python\npass```"])
    inner = MockTransport(completion_fn=lambda p: next(replies))
    rec = ReplayTransport.record(path, inner)
    rec.complete("same")
    # drain the recorded queue so the second live call really happens
    assert ReplayTransport.replay(path).complete("same").text.startswith(
        "first")
    rec2 = ReplayTransport.record(path, inner)    # resume: key on disk
    assert rec2.complete("same").text.startswith("first")
    assert inner.calls == 1                       # no live call re-spent
    rec2.complete("same")                         # queue exhausted -> live
    assert inner.calls == 2

    rep = ReplayTransport.replay(path)
    assert rep.complete("same").text.startswith("first")
    assert rep.complete("same").text.startswith("second")
    assert rep.complete("same").text.startswith("second")   # repeat last


def test_replay_tolerates_torn_tail_line(tmp_path):
    path = tmp_path / "s.jsonl"
    ReplayTransport.record(path, MockTransport()).complete("p1")
    with path.open("a") as fh:
        fh.write('{"key": "torn')                 # killed mid-write
    rep = ReplayTransport.replay(path)
    assert len(rep) == 1


def test_http_transport_requires_env(monkeypatch):
    monkeypatch.delenv(HTTPTransport.ENV_ENDPOINT, raising=False)
    assert not HTTPTransport.configured()
    with pytest.raises(TransportError, match="KFORGE_LLM_ENDPOINT"):
        HTTPTransport.from_env()


def test_http_retry_after_parses_defensively():
    """RFC 7231 allows Retry-After as an HTTP-date; a non-numeric header
    must degrade to None (session backoff) — never raise out of the 429
    handler as an unretryable error."""
    assert HTTPTransport._parse_retry_after("2.5") == 2.5
    assert HTTPTransport._parse_retry_after(
        "Wed, 21 Oct 2026 07:28:00 GMT") is None
    assert HTTPTransport._parse_retry_after(None) is None
    assert HTTPTransport._parse_retry_after("") is None


def test_http_transport_payload_extraction():
    assert HTTPTransport._extract_text({"text": "a"}) == "a"
    assert HTTPTransport._extract_text({"choices": [{"text": "b"}]}) == "b"
    assert HTTPTransport._extract_text(
        {"choices": [{"message": {"content": "c"}}]}) == "c"
    with pytest.raises(TransportError, match="payload shape"):
        HTTPTransport._extract_text({"weird": 1})


# ---------------------------------------------------------------------------
# HTTPTransport against a real (local, stdlib) HTTP server
# ---------------------------------------------------------------------------


class _ScriptedHTTPHandler(http.server.BaseHTTPRequestHandler):
    """Pops one scripted behavior per POST from ``server.script`` and
    records what the client actually sent in ``server.requests``."""

    def do_POST(self):  # noqa: N802 — BaseHTTPRequestHandler API
        length = int(self.headers.get("content-length", 0))
        self.server.requests.append(
            {"payload": json.loads(self.rfile.read(length)),
             "authorization": self.headers.get("authorization")})
        kind, *args = self.server.script.pop(0)
        if kind == "ok":
            body = json.dumps(
                {"text": args[0],
                 "usage": {"prompt_tokens": 7,
                           "completion_tokens": 3}}).encode()
        elif kind == "429":
            self.send_response(429)
            self.send_header("retry-after", str(args[0]))
            self.send_header("content-length", "0")
            self.end_headers()
            return
        elif kind == "cut":
            # correct Content-Length, body cut mid-JSON: the stream reads
            # cleanly but never parses
            body = b'{"text": "trunc'
        else:                           # "boom" — server-side failure
            self.send_response(500, "kaput")
            self.send_header("content-length", "0")
            self.end_headers()
            return
        self.send_response(200)
        self.send_header("content-type", "application/json")
        self.send_header("content-length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, *args):       # keep pytest output clean
        pass


@pytest.fixture
def http_endpoint():
    server = http.server.ThreadingHTTPServer(("127.0.0.1", 0),
                                             _ScriptedHTTPHandler)
    server.script = []
    server.requests = []
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    try:
        yield server
    finally:
        server.shutdown()
        server.server_close()


def _endpoint_url(server) -> str:
    host, port = server.server_address
    return f"http://{host}:{port}/v1/completions"


def test_http_transport_round_trip_against_local_server(http_endpoint):
    http_endpoint.script.append(("ok", "the completion"))
    transport = HTTPTransport(_endpoint_url(http_endpoint),
                              api_key="sk-test", model="m1")
    comp = transport.complete("the prompt")
    assert comp.text == "the completion"
    # real usage counts from the payload, not estimates
    assert (comp.prompt_tokens, comp.completion_tokens) == (7, 3)
    sent = http_endpoint.requests[0]
    assert sent["payload"]["prompt"] == "the prompt"
    assert sent["payload"]["model"] == "m1"
    assert sent["payload"]["max_tokens"] == transport.max_output_tokens
    assert sent["authorization"] == "Bearer sk-test"


def test_http_transport_maps_429_with_retry_after(http_endpoint):
    http_endpoint.script.append(("429", "1.5"))
    transport = HTTPTransport(_endpoint_url(http_endpoint))
    with pytest.raises(RateLimitError) as exc:
        transport.complete("p")
    assert exc.value.retry_after_s == 1.5


def test_http_transport_session_retries_real_429_then_succeeds(http_endpoint):
    """The whole wire path: a genuine HTTP 429 absorbed by the session's
    backoff, then the next request lands."""
    reply = "```python\ndef candidate(*inputs):\n    return inputs[0]\n```"
    http_endpoint.script.extend([("429", "0.01"), ("ok", reply)])
    usage = UsageMeter()
    session = LLMSession(HTTPTransport(_endpoint_url(http_endpoint)),
                         usage=usage, sleep=lambda s: None)
    assert session.complete("p") == reply
    assert usage.rate_limit_hits == 1 and usage.requests == 1


def test_http_transport_truncated_body_is_transport_error(http_endpoint):
    http_endpoint.script.append(("cut",))
    transport = HTTPTransport(_endpoint_url(http_endpoint))
    with pytest.raises(TransportError, match="endpoint unreachable"):
        transport.complete("p")


def test_http_transport_500_is_a_plain_transport_error(http_endpoint):
    http_endpoint.script.append(("boom",))
    transport = HTTPTransport(_endpoint_url(http_endpoint))
    with pytest.raises(TransportError, match="HTTP 500") as exc:
        transport.complete("p")
    assert not isinstance(exc.value, RateLimitError)


# ---------------------------------------------------------------------------
# RateLimiter pacing (deterministic fake clock)
# ---------------------------------------------------------------------------


class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_limiter_unlimited_never_waits():
    lim = RateLimiter()
    assert lim.reserve(10_000) == 0.0
    assert lim.stats()["reserved_tokens"] == 10_000


def test_limiter_rpm_burst_then_even_pacing():
    clock = _Clock()
    lim = RateLimiter(rpm=60, clock=clock)        # 1 request/second steady
    assert lim.reserve() == 0.0                   # burst: 60 free... first
    for _ in range(59):
        lim.reserve()
    # bucket empty: each further request owes 1s more than the last
    assert lim.reserve() == pytest.approx(1.0)
    assert lim.reserve() == pytest.approx(2.0)
    clock.t += 2.0                                # refill 2 requests
    assert lim.reserve() == pytest.approx(1.0)


def test_limiter_tpm_paces_on_tokens():
    clock = _Clock()
    lim = RateLimiter(tpm=6000, clock=clock)      # 100 tokens/second
    assert lim.reserve(6000) == 0.0               # burst minute spent
    assert lim.reserve(100) == pytest.approx(1.0)
    clock.t += 61.0                               # refill caps at tpm
    assert lim.reserve(6000) == 0.0
    assert lim.reserve(50) == pytest.approx(0.5)


def test_limiter_rejects_nonpositive_budgets():
    with pytest.raises(ValueError):
        RateLimiter(rpm=0)
    with pytest.raises(ValueError):
        RateLimiter(tpm=-5)


# ---------------------------------------------------------------------------
# LLMSession: retry, re-prompt, accounting
# ---------------------------------------------------------------------------


def test_session_retries_rate_limit_with_retry_after():
    naps = []
    t = MockTransport(rate_limit_every=1, retry_after_s=0.4)
    # every call rate-limited on the modulo schedule -> flip to clean after
    # the first: emulate by wrapping complete
    calls = {"n": 0}

    class Flaky:
        def complete(self, prompt):
            calls["n"] += 1
            if calls["n"] == 1:
                raise RateLimitError("busy", retry_after_s=0.4)
            return Completion("ok ```python\npass\n```", 1, 1)

    usage = UsageMeter()
    s = LLMSession(Flaky(), usage=usage, sleep=naps.append)
    assert "pass" in s.complete("p")
    assert naps == [0.4]                          # honored the server hint
    snap = usage.snapshot()
    assert snap["rate_limit_hits"] == 1 and snap["requests"] == 1
    assert t.calls == 0                           # unrelated transport


def test_session_gives_up_after_max_attempts_of_rate_limits():
    t = MockTransport(rate_limit_every=1)         # always throttled
    usage = UsageMeter()
    s = LLMSession(t, usage=usage, max_attempts=3, sleep=lambda _s: None)
    with pytest.raises(TransportError, match="3 rate-limited attempts"):
        s.complete("p")
    assert t.calls == 3
    assert usage.snapshot()["failures"] == 1


def test_session_reprompts_malformed_completion_with_feedback():
    seen = []

    class OnceMalformed:
        def complete(self, prompt):
            seen.append(prompt)
            if len(seen) == 1:
                return Completion("no code here, sorry", 1, 1)
            return Completion("```python\npass\n```", 1, 1)

    usage = UsageMeter()
    s = LLMSession(OnceMalformed(), usage=usage)
    assert "pass" in s.complete("original task")
    assert len(seen) == 2
    # the re-prompt carries the task, the defect, and the bad reply
    assert "original task" in seen[1]
    assert "no fenced code block" in seen[1]
    assert "no code here, sorry" in seen[1]
    assert usage.snapshot()["reprompts"] == 1


def test_session_flags_truncated_fence_distinctly():
    seen = []

    class Truncated:
        def complete(self, prompt):
            seen.append(prompt)
            return Completion("```python\ndef candidate(*inp", 1, 1)

    s = LLMSession(Truncated(), max_attempts=2)
    text = s.complete("task")                     # still malformed at the end
    assert "```python" in text and len(seen) == 2
    assert "truncated" in seen[1]
    # the backend then names the generation failure precisely
    backend = LLMBackend(complete=lambda p: text)
    gen = backend.generate(_tiny())
    assert gen.failure == "reply contains no code block"


def test_session_throttle_pause_yields_scheduler_slot():
    """The rate-limit acceptance property: a throttled session releases its
    worker slot for the pacing sleep, so on a 1-slot pool another job runs
    TO COMPLETION while the throttled one is still pacing."""
    sched = Scheduler(max_workers=1)

    class SlowLimiter:
        def reserve(self, tokens=0):
            return 0.6

    done = []
    session = LLMSession(MockTransport(), limiter=SlowLimiter(),
                         scheduler=sched)

    def throttled():
        out = session.complete("Optimize the workload named T1/swish now")
        done.append("throttled")
        return out

    def quick():
        done.append("quick")

    a = sched.submit("throttled", throttled)
    time.sleep(0.15)                              # a is inside its pause
    b = sched.submit("quick", quick)
    results = sched.wait([a, b])
    assert all(r.ok for r in results)
    assert done == ["quick", "throttled"]         # b ran during a's pause
    assert sched.telemetry()["peak_concurrent"] == 2
    assert session.usage.snapshot()["throttle_waits"] == 1


def test_yielding_is_noop_off_pool():
    sched = Scheduler(max_workers=2)
    with sched.yielding():                        # coordinator thread
        pass
    assert sched.telemetry()["running"] == 0


# ---------------------------------------------------------------------------
# LLMBackend over a session: candidates, PARAMS, failures
# ---------------------------------------------------------------------------


def test_llm_backend_executes_mock_session_and_verifies(tmp_path):
    from repro.core.candidates import Candidate
    from repro.core.verification import verify
    wl = _tiny()
    backend = LLMBackend(complete=LLMSession(MockTransport()))
    gen = backend.generate(wl)
    assert gen.failure is None and gen.callable_fn is not None
    res = verify(gen.candidate or Candidate(wl.op, {}), wl, seed=0,
                 fn=gen.callable_fn)
    assert res.correct
    # param-less callable scores as the naive implementation, not a crash
    assert res.model_time_s is not None and res.speedup == pytest.approx(1.0)


def test_verify_survives_malformed_declared_params():
    """PARAMS is untrusted model output: wrong-typed or zero tile values
    must not crash verification after correctness is established — the
    candidate scores via the naive fallback instead."""
    from repro.core.candidates import Candidate
    from repro.core.verification import verify
    wl = _tiny()
    for params in ({"block_rows": "eight"}, {"block_rows": 0},
                   {"block_lanes": None}):
        res = verify(Candidate(wl.op, params), wl, seed=0,
                     fn=lambda x: ref.swish(x))
        assert res.correct, params
        assert res.speedup == pytest.approx(1.0)


def test_session_reserves_prompt_plus_completion_tokens():
    """The tpm budget covers the reply too: the reservation must exceed
    the prompt estimate by the session's completion estimate."""
    reserved = []

    class Capture:
        def reserve(self, tokens=0):
            reserved.append(tokens)
            return 0.0

    prompt = "Optimize the workload named T1/swish now"
    s = LLMSession(MockTransport(), limiter=Capture(),
                   completion_tokens_estimate=512)
    s.complete(prompt)
    assert reserved == [estimate_tokens(prompt) + 512]


def test_llm_backend_adopts_declared_params():
    reply = ("```python\n"
             "import jax.numpy as jnp\n"
             "PARAMS = {'block_rows': 8, 'block_lanes': 512}\n"
             "def candidate(x):\n"
             "    return x * jnp.asarray(1.0) / (1 + jnp.exp(-x)) * "
             "(1 + jnp.exp(-x)) / (1 + jnp.exp(-x))\n"
             "```")
    backend = LLMBackend(complete=lambda p: reply)
    gen = backend.generate(_tiny())
    assert gen.candidate is not None
    assert gen.candidate.params == {"block_rows": 8, "block_lanes": 512}


def test_llm_backend_surfaces_transport_error_as_generation_failure():
    dead = LLMSession(MockTransport(rate_limit_every=1), max_attempts=1,
                      sleep=lambda _s: None)
    backend = LLMBackend(complete=dead)
    gen = backend.generate(_tiny())
    assert gen.failure is not None and "model call failed" in gen.failure


# ---------------------------------------------------------------------------
# Campaigns on the LLM backend: e2e, usage journaling, record/replay
# ---------------------------------------------------------------------------


def test_llm_campaign_end_to_end_with_usage_journal(tmp_path):
    log = tmp_path / "llm.jsonl"
    ctx = build_llm_context()
    res = run_campaign([_tiny()], LoopConfig(num_iterations=2),
                       agent_factory=ctx.agent_factory(platform="tpu_v5e"),
                       usage=ctx.usage, log_path=log)
    assert [r.state.value for r in res.finals()] == ["correct"]
    assert res.llm_usage["requests"] == 2
    events = EventLog(log).events()
    done = [ev for ev in events if ev.get("event") == "campaign_done"]
    assert done and done[-1]["llm_usage"]["requests"] == 2
    report = report_from_events(events)
    assert report["llm_usage"]["requests"] == 2
    assert "llm: 2 requests" in format_report(report)


def test_usage_journal_sums_deltas_across_campaigns(tmp_path):
    """campaign_done journals each campaign's usage DELTA: two campaigns
    sharing one meter (sweep legs) — or a resumed log's two processes —
    must sum to the true total, not double- or under-count."""
    log = tmp_path / "shared.jsonl"
    ctx = build_llm_context()
    for wl in (_tiny(), _tiny("T1/softmax", op="softmax")):
        run_campaign([wl], LoopConfig(num_iterations=2),
                     agent_factory=ctx.agent_factory(), usage=ctx.usage,
                     log_path=log)
    events = EventLog(log).events()
    deltas = [ev["llm_usage"]["requests"] for ev in events
              if ev.get("event") == "campaign_done"]
    assert deltas == [2, 2]                        # per-campaign, not cumulative
    total = ctx.usage.snapshot()["requests"]
    assert report_from_events(events)["llm_usage"]["requests"] == total == 4


def test_session_and_backend_share_one_fence_pattern():
    from repro.core import synthesis
    import repro.llm.session as session_mod
    assert session_mod.CODE_BLOCK_RE is synthesis._CODE_RE


def test_llm_campaign_record_replay_round_trip(tmp_path):
    session_path = tmp_path / "session.jsonl"
    wls = [_tiny(), _tiny("T1/softmax", op="softmax")]
    loop = LoopConfig(num_iterations=2)

    rec_ctx = build_llm_context(record=str(session_path))
    recorded = run_campaign(wls, loop,
                            agent_factory=rec_ctx.agent_factory(),
                            usage=rec_ctx.usage)
    live_calls = rec_ctx.transport.inner.calls
    assert live_calls > 0

    rep_ctx = build_llm_context(replay=str(session_path))
    replayed = run_campaign(wls, loop,
                            agent_factory=rep_ctx.agent_factory(),
                            usage=rep_ctx.usage)
    assert rep_ctx.transport.inner is None            # 0 live calls
    assert rep_ctx.transport.served_from_file == live_calls
    assert [r.state.value for r in recorded.finals()] == \
        [r.state.value for r in replayed.finals()] == ["correct", "correct"]


def test_llm_campaign_replay_miss_degrades_to_generation_failure(tmp_path):
    session_path = tmp_path / "session.jsonl"
    ReplayTransport.record(session_path, MockTransport()).complete("other")
    ctx = build_llm_context(replay=str(session_path))
    res = run_campaign([_tiny()], LoopConfig(num_iterations=2),
                       agent_factory=ctx.agent_factory(), usage=ctx.usage)
    final = res.finals()[0]
    assert final.state.value == "generation_failure"
    assert "never recorded" in (final.error or "")


def test_build_llm_context_validation(tmp_path):
    with pytest.raises(ValueError, match="mutually exclusive"):
        build_llm_context(record="a.jsonl", replay="b.jsonl")
    with pytest.raises(ValueError, match="not both"):
        build_llm_context(transport=MockTransport(), replay="b.jsonl")
    ctx = build_llm_context(rpm=10, tpm=1000)
    assert ctx.limiter is not None and ctx.limiter.rpm == 10
    # zero budgets reach the limiter's validation, never silently dropped
    with pytest.raises(ValueError, match="rpm must be positive"):
        build_llm_context(rpm=0)
    with pytest.raises(ValueError, match="tpm must be positive"):
        build_llm_context(rpm=10, tpm=0)


# ---------------------------------------------------------------------------
# Matrix LLM legs: per-leg reference binding + budget telemetry
# ---------------------------------------------------------------------------


def test_matrix_llm_warm_legs_bind_their_own_references(monkeypatch):
    """Mirror of the PR-4 default-arg regression test for the LLM path:
    every warm leg's LLMBackend must receive the rendered references of
    ITS source base, bound for ITS target platform, under concurrency."""
    import repro.campaign.matrix as matrix_mod
    import repro.llm.session as session_mod
    import repro.platforms as plat_mod

    created = []
    lock = threading.Lock()
    real_backend = session_mod.LLMBackend
    real_refs = matrix_mod.reference_sources

    class Recorder(real_backend):
        def __init__(self, complete=None, platform=None,
                     reference_sources=None, **kw):
            refs = reference_sources or {}
            with lock:
                created.append((plat_mod.resolve_platform(platform).name,
                                refs.get("__src__")))
            super().__init__(complete=complete, platform=platform,
                             reference_sources=refs, **kw)

    def tagged_refs(result, from_platform):
        refs = real_refs(result, from_platform)
        refs["__src__"] = from_platform      # never matches a workload name
        return refs

    monkeypatch.setattr(session_mod, "LLMBackend", Recorder)
    monkeypatch.setattr(matrix_mod, "reference_sources", tagged_refs)
    names = ["gpu_sim", "metal_m2", "tpu_v5e"]
    matrix = run_transfer_matrix(
        [_tiny()], names, loop=LoopConfig(num_iterations=2),
        max_workers=4, backend="llm")
    assert matrix.n_failed == 0
    from repro.campaign import all_pairs
    warm = {(src, dst) for dst, src in created if src is not None}
    assert warm == set(all_pairs(names))


def test_matrix_llm_keeps_scheduler_budget_while_throttled():
    """Slot-yield under the shared leg scheduler: with a limiter pacing
    every completion, the matrix still renders both heat-maps and the job
    graph's peak concurrency stays within the same budget the template
    backend gets (throttled legs yield, they don't wedge workers)."""
    ctx = build_llm_context(rpm=100_000)          # generous: tiny waits only
    matrix = run_transfer_matrix(
        [_tiny()], ["metal_m2", "tpu_v5e"],
        loop=LoopConfig(num_iterations=2),
        max_workers=2, matrix_workers=2, backend="llm", llm=ctx)
    assert matrix.n_failed == 0
    assert matrix.telemetry["backend"] == "llm"
    assert matrix.telemetry["peak_concurrent_legs"] <= 2
    assert matrix.telemetry["llm_usage"]["requests"] > 0
    assert "fast_1 uplift" in matrix.heatmap_text()
    assert "iterations-to-correct" in \
        matrix.heatmap_text(metric="delta_iters")


def test_matrix_llm_per_leg_usage_deltas_sum_to_fleet_total(tmp_path):
    """Concurrent legs journal per-leg meters (parented on the fleet
    meter), so summing every campaign_done delta equals the fleet total —
    a single shared meter's wall-clock deltas would let overlapping legs
    absorb each other's spend and the report would over-count."""
    log = tmp_path / "matrix.jsonl"
    ctx = build_llm_context()
    matrix = run_transfer_matrix(
        [_tiny()], ["metal_m2", "tpu_v5e"],
        loop=LoopConfig(num_iterations=2),
        max_workers=4, matrix_workers=4, backend="llm", llm=ctx,
        log_path=log)
    assert matrix.n_failed == 0
    fleet = ctx.usage.snapshot()["requests"]
    events = EventLog(log).events()
    deltas = [ev["llm_usage"]["requests"] for ev in events
              if ev.get("event") == "campaign_done"]
    assert len(deltas) == 4                       # 2 bases + 2 warm legs
    assert sum(deltas) == fleet > 0
    assert report_from_events(events)["llm_usage"]["requests"] == fleet


def test_matrix_llm_rejects_process_isolation():
    with pytest.raises(ValueError, match="isolation='process'"):
        run_transfer_matrix([_tiny()], ["metal_m2", "tpu_v5e"],
                            backend="llm", isolation="process")


def test_matrix_rejects_unknown_backend():
    with pytest.raises(ValueError, match="backend"):
        run_transfer_matrix([_tiny()], ["metal_m2", "tpu_v5e"],
                            backend="quantum")


# ---------------------------------------------------------------------------
# CLI: flag validation + replay round trip
# ---------------------------------------------------------------------------


def test_cli_llm_flags_require_llm_backend(capsys):
    from repro.campaign.__main__ import main
    for argv in (["--record", "s.jsonl"], ["--replay", "s.jsonl"],
                 ["--rpm", "10"], ["--tpm", "100"]):
        with pytest.raises(SystemExit):
            main(argv)
        assert "--backend llm" in capsys.readouterr().err


def test_cli_zero_rate_budget_is_a_usage_error(capsys):
    from repro.campaign.__main__ import main
    with pytest.raises(SystemExit):
        main(["--backend", "llm", "--rpm", "0"])
    assert "rpm must be positive" in capsys.readouterr().err


def test_cli_rejects_record_with_replay(capsys):
    from repro.campaign.__main__ import main
    with pytest.raises(SystemExit):
        main(["--backend", "llm", "--record", "a.jsonl",
              "--replay", "b.jsonl"])
    assert "mutually exclusive" in capsys.readouterr().err


def test_cli_rejects_llm_with_isolate(capsys):
    from repro.campaign.__main__ import main
    with pytest.raises(SystemExit):
        main(["--backend", "llm", "--matrix", "--isolate"])
    assert "--isolate" in capsys.readouterr().err


def test_cli_replay_of_missing_session_fails_with_usage_error(capsys,
                                                              tmp_path):
    from repro.campaign.__main__ import main
    with pytest.raises(SystemExit):
        main(["--backend", "llm", "--replay", str(tmp_path / "no.jsonl")])
    assert "record one first" in capsys.readouterr().err


def test_cli_llm_record_then_replay(tmp_path, capsys, monkeypatch):
    """The acceptance flow in miniature: --record a session, then --replay
    it deterministically with zero live calls."""
    from repro.campaign import __main__ as cli
    wls = [_tiny()]
    monkeypatch.setattr(cli.kernelbench, "suite",
                        lambda level, small=True: wls)
    session = str(tmp_path / "session.jsonl")
    base = ["--backend", "llm", "--platform", "metal_m2", "--iters", "2"]
    assert cli.main(base + ["--record", session,
                            "--log", str(tmp_path / "rec.jsonl")]) == 0
    out_rec = capsys.readouterr().out
    assert "llm usage:" in out_rec and "llm:" in out_rec

    assert cli.main(base + ["--replay", session,
                            "--log", str(tmp_path / "rep.jsonl")]) == 0
    out_rep = capsys.readouterr().out
    assert "correct=1" in out_rep
    # identical fast_p tail -> deterministic replay
    assert out_rec.split("campaign report")[1] == \
        out_rep.split("campaign report")[1]


@pytest.mark.slow
def test_cli_llm_matrix_smoke(tmp_path, capsys, monkeypatch):
    """--matrix --backend llm renders both heat-maps from LLM legs with the
    same concurrency budget telemetry as the template backend."""
    from repro.campaign import __main__ as cli
    wls = [_tiny(), _tiny("T1/softmax", op="softmax")]
    monkeypatch.setattr(cli.kernelbench, "suite",
                        lambda level, small=True: wls)
    argv = ["--matrix", "--backend", "llm",
            "--platforms", "tpu_v5e", "metal_m2", "--iters", "2",
            "--rpm", "100000"]
    assert cli.main(argv) == 0
    out = capsys.readouterr().out
    assert "(llm backend)" in out and "llm usage:" in out
    assert "fast_1 uplift" in out and "iterations-to-correct" in out
    assert "peak 2 concurrent legs" in out
