"""Direction-aware verification (fwd vs fwd_bwd): cache-key compatibility,
gradient-oracle checks, GRAD_MISMATCH semantics, the two-section profile,
and the campaign plumbing that journals/resumes the direction axis.

The load-bearing regressions here:

* forward-only keys are BYTE-IDENTICAL to the pre-direction scheme, so
  persistent caches written by older runs stay valid;
* a forward result is never served for a fwd_bwd request (direction
  collision), while a fwd_bwd rerun against the same persistent cache is
  100% hits;
* a candidate whose forward output matches but whose backward is wrong
  scores GRAD_MISMATCH naming the worst-offending gradient — not CORRECT.
"""
import hashlib
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.campaign.cache import VerificationCache
from repro.campaign.events import normalize_loop
from repro.campaign.runner import run_campaign
from repro.core import candidates as cand_mod
from repro.core import kernelbench
from repro.core.candidates import Candidate
from repro.core.evalio import ExecutableCache, WorkloadIOCache
from repro.core.refinement import LoopConfig
from repro.core.states import ExecutionState as ES
from repro.core.synthesis import TemplateSearchBackend
from repro.core.verification import (cache_key, executable_key, io_signature,
                                     verify, verify_batch)
from repro.core.workload import Workload, randn
from repro.kernels import ref

FIXTURES = Path(__file__).parent / "fixtures"


def _diff_wl(name="T1/softmax_bwd", shape=(64, 128), tol=1e-5):
    """A tiny differentiable workload for fast fwd_bwd tests."""
    return Workload(
        name=name, level=1, op="softmax", ref_fn=ref.softmax,
        input_fn=lambda rng: {"x": randn(rng, shape, 3.0)},
        input_shapes={"x": shape}, tol=tol, differentiable=True)


def _fwd_wl(name="T1/softmax_fwd", shape=(64, 128)):
    return Workload(
        name=name, level=1, op="softmax", ref_fn=ref.softmax,
        input_fn=lambda rng: {"x": randn(rng, shape, 3.0)},
        input_shapes={"x": shape})


# ---------------------------------------------------------------------------
# Cache keys: fwd byte-identity, direction separation
# ---------------------------------------------------------------------------

def _legacy_cache_key(cand, wl, seed, platform_name):
    """The EXACT pre-direction key derivation, frozen here as a regression
    oracle: if fwd keys ever drift from this, every persistent cache and
    CI cache-hit gate breaks silently."""
    sig = {
        "workload": wl.name,
        "op": cand.op,
        "params": sorted((k, repr(v)) for k, v in cand.params.items()),
        "io": io_signature(wl),
        "tol": wl.tol,
        "seed": int(seed),
        "platform": platform_name,
    }
    blob = json.dumps(sig, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def _legacy_executable_key(cand, wl, platform_name):
    sig = {
        "op": cand.op,
        "params": sorted((k, repr(v)) for k, v in cand.params.items()),
        "io": io_signature(wl),
        "platform": platform_name,
    }
    blob = json.dumps(sig, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def test_fwd_cache_key_byte_identical_to_pre_direction_scheme():
    wl = _diff_wl()
    cand = Candidate("softmax", {"online": True, "block_rows": 8})
    legacy = _legacy_cache_key(cand, wl, 3, "tpu_v5e")
    assert cache_key(cand, wl, 3) == legacy
    assert cache_key(cand, wl, 3, direction="fwd") == legacy
    legacy_exe = _legacy_executable_key(cand, wl, "tpu_v5e")
    assert executable_key(cand, wl) == legacy_exe
    assert executable_key(cand, wl, direction="fwd") == legacy_exe


def test_direction_folds_into_cache_and_executable_keys():
    wl = _diff_wl()
    cand = Candidate("softmax", {"online": True, "block_rows": 8})
    assert cache_key(cand, wl, 0) != \
        cache_key(cand, wl, 0, direction="fwd_bwd")
    assert executable_key(cand, wl) != \
        executable_key(cand, wl, direction="fwd_bwd")


def test_unknown_direction_rejected():
    wl = _diff_wl()
    with pytest.raises(ValueError, match="unknown direction"):
        verify(Candidate("softmax", {"online": True, "block_rows": 8}),
               wl, seed=0, direction="bwd")


def test_fwd_bwd_requires_differentiable_workload():
    wl = _fwd_wl()
    with pytest.raises(ValueError, match="differentiable"):
        verify(Candidate("softmax", {"online": True, "block_rows": 8}),
               wl, seed=0, direction="fwd_bwd")


# ---------------------------------------------------------------------------
# Gradient oracle: cotangent determinism, vjp reference
# ---------------------------------------------------------------------------

def test_cotangent_deterministic_and_seed_derived():
    wl = _diff_wl()
    inputs = wl.inputs(0)
    c0a = wl.cotangent(inputs, seed=0)
    c0b = wl.cotangent(inputs, seed=0)
    c1 = wl.cotangent(inputs, seed=1)
    np.testing.assert_array_equal(np.asarray(c0a), np.asarray(c0b))
    assert not np.array_equal(np.asarray(c0a), np.asarray(c1))
    assert c0a.shape == jax.eval_shape(lambda x: ref.softmax(x),
                                       inputs["x"]).shape


def test_grad_reference_matches_manual_vjp():
    wl = _diff_wl()
    inputs = wl.inputs(0)
    cot = wl.cotangent(inputs, seed=0)
    grads = wl.grad_reference(inputs, cot)
    assert set(grads) == {"x"}
    _, vjp = jax.vjp(ref.softmax, inputs["x"])
    (expect,) = vjp(cot)
    np.testing.assert_allclose(np.asarray(grads["x"]), np.asarray(expect),
                               rtol=1e-6)


def test_grad_input_names_excludes_integer_inputs():
    wl = kernelbench.by_name("L1/rope")
    inputs = wl.inputs(0)
    assert wl.grad_input_names(inputs) == ("x",)


# ---------------------------------------------------------------------------
# fwd_bwd verification: CORRECT profile, GRAD_MISMATCH, caching
# ---------------------------------------------------------------------------

def test_fwd_bwd_correct_profile_has_two_sections():
    wl = _diff_wl()
    cand = Candidate("softmax", {"online": True, "block_rows": 8})
    r = verify(cand, wl, seed=0, direction="fwd_bwd")
    assert r.state is ES.CORRECT
    prof = r.profile
    assert prof["direction"] == "fwd_bwd"
    assert set(prof["fwd"]) >= {"model_time_s", "baseline_time_s", "flops"}
    assert set(prof["bwd"]) >= {"model_time_s", "baseline_time_s", "flops",
                                "max_rel_err"}
    factor = cand_mod.bwd_cost_factor("softmax")
    assert prof["bwd"]["flops"] == pytest.approx(
        prof["fwd"]["flops"] * factor)
    assert prof["model_time_s"] == pytest.approx(
        prof["fwd"]["model_time_s"] + prof["bwd"]["model_time_s"])
    # the gradient phases were actually measured
    assert {"grad_compile", "grad_run", "grad_check"} <= set(prof["phase_s"])


def test_fwd_profile_unchanged_by_direction_axis():
    wl = _diff_wl()
    cand = Candidate("softmax", {"online": True, "block_rows": 8})
    r = verify(cand, wl, seed=0)
    assert r.state is ES.CORRECT
    assert "direction" not in r.profile
    assert "fwd" not in r.profile and "bwd" not in r.profile


def test_fwd_correct_but_bwd_wrong_scores_grad_mismatch():
    """The acceptance scenario: a candidate with a perfect forward and a
    broken backward must NOT score CORRECT, and the feedback must name
    the worst-offending gradient."""
    wl = _diff_wl()

    @jax.custom_vjp
    def broken(x):
        return ref.softmax(x)

    def fwd(x):
        return ref.softmax(x), x

    def bwd(x, g):
        _, vjp = jax.vjp(ref.softmax, x)
        return (vjp(g)[0] * 2.0,)          # fwd-correct, gradients doubled

    broken.defvjp(fwd, bwd)
    r = verify(Candidate("softmax", {"online": True, "block_rows": 8}),
               wl, seed=0, fn=broken, direction="fwd_bwd")
    assert r.state is ES.GRAD_MISMATCH
    assert "gradient wrt 'x'" in r.error
    assert not r.correct
    assert "grad_mismatch" in r.feedback()


def test_naive_attention_grad_mismatch_names_gradient():
    """The registered L2 workload behaves the same way: the naive
    (non-online) attention candidate passes forward tolerance but its
    -1e30 masking poisons the gradients."""
    wl = kernelbench.by_name("L2/attention_bwd", small=True)
    naive = Candidate("attention", dict(
        cand_mod.NAIVE_DEFAULTS["attention"]))
    assert not naive.params["online"]
    r = verify(naive, wl, seed=0, direction="fwd_bwd")
    assert r.state is ES.GRAD_MISMATCH
    assert "gradient wrt '" in r.error and "max rel err" in r.error


def test_fwd_result_never_served_for_fwd_bwd_and_rerun_hits(tmp_path):
    """Direction-collision regression + the 100%-hit rerun acceptance
    check, against one persistent cache file."""
    wl = _diff_wl()
    cands = [Candidate("softmax", {"online": True, "block_rows": br})
             for br in (8, 16)]
    path = tmp_path / "verify.jsonl"

    cache = VerificationCache.open(path)
    fwd = verify_batch(cands, wl, seed=0, cache=cache)
    assert all(r.state is ES.CORRECT for r in fwd)
    assert cache.hits == 0

    # same candidates, fwd_bwd: the fwd results must NOT satisfy these
    cache2 = VerificationCache.open(path)
    bwd = verify_batch(cands, wl, seed=0, cache=cache2,
                       direction="fwd_bwd")
    assert cache2.hits == 0 and cache2.misses == len(cands)
    assert all(r.profile["direction"] == "fwd_bwd" for r in bwd)

    # fwd_bwd rerun against the same cache path: 100% hits
    cache3 = VerificationCache.open(path)
    again = verify_batch(cands, wl, seed=0, cache=cache3,
                         direction="fwd_bwd")
    assert cache3.hits == len(cands) and cache3.misses == 0
    for a, b in zip(bwd, again):
        assert a.state is b.state
        assert a.profile["bwd"]["max_rel_err"] == \
            b.profile["bwd"]["max_rel_err"]


def test_fwd_bwd_shares_io_entry_and_grad_oracle_across_batch():
    wl = _diff_wl()
    io_cache = WorkloadIOCache()
    cands = [Candidate("softmax", {"online": True, "block_rows": br})
             for br in (8, 16, 32)]
    rs = verify_batch(cands, wl, seed=0, io_cache=io_cache,
                      direction="fwd_bwd")
    assert all(r.state is ES.CORRECT for r in rs)
    s = io_cache.stats()
    assert s["oracle_computes"] == 1
    assert s["grad_oracle_computes"] == 1      # shared across the batch


def test_grad_executable_cached_across_seeds():
    wl = _diff_wl()
    exe_cache = ExecutableCache()
    cand = Candidate("softmax", {"online": True, "block_rows": 8})
    verify(cand, wl, seed=0, exe_cache=exe_cache, direction="fwd_bwd")
    assert exe_cache.hits == 0
    verify(cand, wl, seed=1, exe_cache=exe_cache, direction="fwd_bwd")
    # fresh seed: both the forward and the gradient executable are reused
    assert exe_cache.hits == 2


# ---------------------------------------------------------------------------
# Registered differentiable workloads + the rope satellite
# ---------------------------------------------------------------------------

def test_suite_differentiable_filter():
    diff = kernelbench.suite(differentiable=True)
    names = {w.name for w in diff}
    assert {"L1/rope", "L2/attention_bwd", "L2/swiglu_bwd",
            "L3/mamba2_ssd_bwd"} <= names
    assert all(w.differentiable for w in diff)
    fwd_only = kernelbench.suite(differentiable=False)
    assert not any(w.differentiable for w in fwd_only)
    assert len(diff) + len(fwd_only) == len(kernelbench.suite())


def test_rope_workload_reachable_and_correct():
    wl = kernelbench.by_name("L1/rope", small=True)
    naive = Candidate("rope", dict(cand_mod.NAIVE_DEFAULTS["rope"]))
    r = verify(naive, wl, seed=0)
    assert r.state is ES.CORRECT, r.error
    r2 = verify(naive, wl, seed=0, direction="fwd_bwd")
    assert r2.state is ES.CORRECT, r2.error


def test_rope_reference_hints_are_in_space():
    from repro.platforms import get_platform
    for name in ("metal_m2", "gpu_sim"):
        plat = get_platform(name)
        hint = plat.reference_hints.get("rope")
        assert hint, f"{name} has no rope reference hint"
        space = cand_mod.space_for("rope", plat)
        for k, v in hint.items():
            assert v in space[k], (name, k, v)


# ---------------------------------------------------------------------------
# Template backend: GRAD_MISMATCH repair
# ---------------------------------------------------------------------------

def test_template_backend_repairs_grad_mismatch_by_going_online():
    from repro.core.states import EvalResult
    from repro.core.synthesis import Generation
    wl = kernelbench.by_name("L2/attention_bwd", small=True)
    agent = TemplateSearchBackend()
    naive = Candidate("attention", dict(
        cand_mod.NAIVE_DEFAULTS["attention"]))
    prev = Generation(candidate=naive, source=naive.describe())
    bad = EvalResult(ES.GRAD_MISMATCH,
                     error="gradient wrt 'q': max rel err 4e+01 > tol 5e-03")
    gen = agent.generate(wl, prev=prev, prev_result=bad)
    assert gen.candidate is not None
    assert gen.candidate.params["online"] is True


# ---------------------------------------------------------------------------
# io_signature fallback accounting (satellite: silent-except bugfix)
# ---------------------------------------------------------------------------

def test_io_sig_fallback_counted_and_surfaced():
    before = WorkloadIOCache.io_sig_fallbacks()

    def exotic_input_fn(rng):
        x = rng.standard_normal((8, 8))
        # data-dependent guard: ShapeOnlyRng's constant fill trips it, a
        # real generator does not — exactly the exotic-input_fn class the
        # concrete fallback exists for
        assert float(np.abs(np.asarray(x)).max()) > 0
        return {"x": x}

    wl = Workload(name="T1/exotic", level=1, op="swish", ref_fn=ref.swish,
                  input_fn=exotic_input_fn, input_shapes={"x": (8, 8)})
    sig = io_signature(wl)
    assert sig == [("x", [8, 8], "float64")]
    assert WorkloadIOCache.io_sig_fallbacks() == before + 1
    assert WorkloadIOCache().stats()["io_sig_fallbacks"] == before + 1

    # ...and the campaign report renders the warning
    from repro.campaign.report import format_report, report_from_events
    events = [{"event": "campaign_done", "cache": {},
               "io_cache": {"entries": 1, "hits": 0, "misses": 1,
                            "oracle_computes": 1, "grad_oracle_computes": 2,
                            "input_computes": 1, "io_sig_fallbacks": 3}}]
    text = format_report(report_from_events(events))
    assert "WARNING: 3 io-signature concrete fallbacks" in text
    assert "2 grad oracle computes" in text


# ---------------------------------------------------------------------------
# Campaign plumbing: journaling, mixed-direction resume, old-format logs
# ---------------------------------------------------------------------------

def test_workload_done_journals_direction(tmp_path):
    wl = kernelbench.by_name("L1/rope", small=True)
    log = tmp_path / "c.jsonl"
    run_campaign([wl], LoopConfig(num_iterations=1, direction="fwd_bwd"),
                 log_path=log, max_workers=1)
    events = [json.loads(ln) for ln in log.read_text().splitlines()]
    done = [e for e in events if e.get("event") == "workload_done"]
    assert done and all(e["direction"] == "fwd_bwd" for e in done)
    assert all(e["loop"]["direction"] == "fwd_bwd" for e in done)


def test_resume_mixed_direction_log_keeps_directions_apart(tmp_path):
    """One log interleaving fwd and fwd_bwd runs of the same workload:
    each direction resumes only its own terminal events."""
    wl = kernelbench.by_name("L1/rope", small=True)
    log = tmp_path / "mixed.jsonl"
    fwd_cfg = LoopConfig(num_iterations=1)
    bwd_cfg = LoopConfig(num_iterations=1, direction="fwd_bwd")
    first = run_campaign([wl], fwd_cfg, log_path=log, max_workers=1)
    assert first.n_skipped == 0
    second = run_campaign([wl], bwd_cfg, log_path=log, max_workers=1)
    assert second.n_skipped == 0          # fwd terminal must not satisfy it
    # now both directions are terminal: each rerun skips its own only
    assert run_campaign([wl], fwd_cfg, log_path=log,
                        max_workers=1).n_skipped == 1
    assert run_campaign([wl], bwd_cfg, log_path=log,
                        max_workers=1).n_skipped == 1


def test_resume_tolerates_pre_direction_log_format(tmp_path):
    """Satellite regression: a committed log written BEFORE the direction
    field existed must keep resuming — normalize_loop fills the missing
    field with its default, so old fwd logs read as direction='fwd'."""
    fixture = FIXTURES / "pre_direction_campaign.jsonl"
    events = [json.loads(ln) for ln in fixture.read_text().splitlines()]
    for ev in events:     # guard: the fixture must stay old-format
        assert "direction" not in ev
        assert "direction" not in (ev.get("loop") or {})
    log = tmp_path / "old.jsonl"
    shutil.copy(fixture, log)
    wl = kernelbench.by_name("L1/swish", small=True)
    res = run_campaign([wl], LoopConfig(num_iterations=2), log_path=log,
                       max_workers=1)
    assert res.n_skipped == 1             # resumed, zero re-verification
    # ...but a fwd_bwd run of the same name must NOT be satisfied by it
    assert normalize_loop({"num_iterations": 2})["direction"] == "fwd"
    assert normalize_loop({"num_iterations": 2, "direction": "fwd_bwd"}) \
        != normalize_loop({"num_iterations": 2})


def test_generation_event_journals_direction():
    from repro.campaign.population import run_workload_pbt
    wl = kernelbench.by_name("L2/swiglu_bwd", small=True)
    cfg = LoopConfig(search="pbt", population=2, generations=1,
                     direction="fwd_bwd")
    out = run_workload_pbt(wl, cfg)
    assert out.generations
    for ev in out.generations:
        assert ev["direction"] == "fwd_bwd"
        assert ev["loop"]["direction"] == "fwd_bwd"
    assert out.final.state is ES.CORRECT


def test_cli_direction_fwd_bwd_runs_differentiable_suite(tmp_path, capsys):
    from repro.campaign.__main__ import main
    log = tmp_path / "cli.jsonl"
    rc = main(["--suite", "small", "--level", "1", "--iters", "1",
               "--workers", "1", "--direction", "fwd_bwd",
               "--log", str(log)])
    out = capsys.readouterr().out
    assert rc == 0
    assert "fast_0=" in out
    events = [json.loads(ln) for ln in log.read_text().splitlines()]
    done = [e for e in events if e.get("event") == "workload_done"]
    # level-1 differentiable = L1/rope only
    assert [e["workload"] for e in done] == ["L1/rope"]
    assert done[0]["direction"] == "fwd_bwd"


def test_cli_direction_fwd_bwd_errors_on_empty_selection(monkeypatch):
    from repro.campaign import __main__ as cli
    monkeypatch.setattr(cli.kernelbench, "suite",
                        lambda *a, **kw: [])
    with pytest.raises(SystemExit) as exc:
        cli.main(["--suite", "small", "--direction", "fwd_bwd"])
    assert exc.value.code == 2
