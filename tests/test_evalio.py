"""Verification fast path (DESIGN.md §4): abstract io signatures, the
shared input/oracle cache, the compiled-executable cache, batched candidate
verification, and the §7.3 anti-cheating properties they must preserve."""
import numpy as np
import pytest

from repro.campaign import (EventLog, format_report, report_from_events,
                            run_campaign, run_transfer_matrix)
from repro.core import (Candidate, LoopConfig, kernelbench, run_workload,
                        verify)
from repro.core.evalio import ExecutableCache, ShapeOnlyRng, WorkloadIOCache
from repro.core.states import ExecutionState as ES
from repro.core.verification import (cache_key, executable_key, io_signature,
                                     verify_batch)
from repro.core.workload import Workload, randn
from repro.kernels import ref


def _tiny(name="T1/softmax", op="softmax", shape=(64, 512), scale=60.0,
          ref_fn=None):
    refs = {"softmax": ref.softmax, "swish": ref.swish}
    return Workload(
        name=name, level=1, op=op,
        ref_fn=ref_fn or refs[op],
        input_fn=lambda rng: {"x": randn(rng, shape, scale)},
        input_shapes={"x": shape})


def _concrete_signature(wl):
    """The io signature computed the pre-fast-path way: materialize real
    inputs and run the kernel-input transform concretely."""
    from repro.core import kernelbench as kb
    kernel = kb.workload_for_candidate_inputs(wl, wl.inputs(0))
    return sorted((k, [int(d) for d in v.shape], str(v.dtype))
                  for k, v in kernel.items())


# ---------------------------------------------------------------------------
# io_signature: abstract == concrete, memoized, fallback-safe
# ---------------------------------------------------------------------------

def test_io_signature_matches_concrete_small_suite():
    for wl in kernelbench.suite(small=True):
        if getattr(wl, "_io_sig", None) is not None:
            del wl._io_sig          # defeat memoization from earlier tests
        assert io_signature(wl) == _concrete_signature(wl), wl.name


@pytest.mark.slow
def test_io_signature_matches_concrete_full_suite():
    for wl in kernelbench.suite(small=False):
        if getattr(wl, "_io_sig", None) is not None:
            del wl._io_sig
        assert io_signature(wl) == _concrete_signature(wl), wl.name


def test_io_signature_memoized_without_rerunning_input_fn():
    calls = {"n": 0}

    def input_fn(rng):
        calls["n"] += 1
        return {"x": randn(rng, (16, 128), 1.0)}

    wl = Workload(name="T1/sig", level=1, op="swish", ref_fn=ref.swish,
                  input_fn=input_fn, input_shapes={"x": (16, 128)})
    first = io_signature(wl)
    n_after_first = calls["n"]
    assert io_signature(wl) == first
    assert calls["n"] == n_after_first   # second read served from the memo


def test_io_signature_exotic_rng_falls_back_to_real_generator():
    # rng.normal is not one of ShapeOnlyRng's shape-only draws — it must
    # fall through to a real generator and still yield the right signature
    wl = Workload(
        name="T1/exotic", level=1, op="softmax", ref_fn=ref.softmax,
        input_fn=lambda rng: {
            "x": rng.normal(size=(8, 128)).astype(np.float32)},
        input_shapes={"x": (8, 128)})
    assert io_signature(wl) == _concrete_signature(wl)


def test_shape_only_rng_draws_are_cheap_and_shaped():
    rng = ShapeOnlyRng()
    assert rng.standard_normal((3, 4), dtype=np.float32).shape == (3, 4)
    assert rng.uniform(2.0, 5.0, size=(2,)).tolist() == [2.0, 2.0]
    assert rng.integers(7, 9, size=(2,)).tolist() == [7, 7]


# ---------------------------------------------------------------------------
# WorkloadIOCache: hit/miss/eviction, laziness, seed isolation (§7.3)
# ---------------------------------------------------------------------------

def test_io_cache_hit_and_lazy_oracle():
    cache = WorkloadIOCache()
    wl = _tiny()
    e1 = cache.entry(wl, seed=0)
    assert cache.stats()["oracle_computes"] == 0   # oracle not touched yet
    e2 = cache.entry(wl, seed=0)
    assert e1 is e2
    assert cache.stats() == {"entries": 1, "hits": 1, "misses": 1,
                             "oracle_computes": 0,
                             "grad_oracle_computes": 0,
                             "input_computes": 1,
                             "io_sig_fallbacks":
                                 WorkloadIOCache.io_sig_fallbacks()}
    out1 = e1.expected()
    out2 = e2.expected()
    assert out1 is out2
    assert cache.stats()["oracle_computes"] == 1   # computed exactly once


def test_io_cache_two_seeds_never_share_inputs_or_oracle():
    """§7.3: the freshness defense requires each seed its own entry."""
    cache = WorkloadIOCache()
    wl = _tiny()
    e0, e1 = cache.entry(wl, seed=0), cache.entry(wl, seed=1)
    assert e0 is not e1
    assert cache.stats()["hits"] == 0 and cache.stats()["misses"] == 2
    assert not np.array_equal(e0.inputs["x"], e1.inputs["x"])
    e0.expected(), e1.expected()
    assert cache.stats()["oracle_computes"] == 2


def test_io_cache_lru_eviction_bound():
    cache = WorkloadIOCache(max_entries=1)
    wl = _tiny()
    cache.entry(wl, seed=0)
    cache.entry(wl, seed=1)          # evicts seed 0
    assert len(cache) == 1
    cache.entry(wl, seed=0)          # must rebuild: miss, not hit
    assert cache.stats()["misses"] == 3 and cache.stats()["hits"] == 0


def test_io_cache_disabled_with_zero_entries():
    cache = WorkloadIOCache(max_entries=0)
    wl = _tiny()
    a, b = cache.entry(wl, seed=0), cache.entry(wl, seed=0)
    assert a is not b
    assert len(cache) == 0
    assert cache.stats()["hits"] == 0 and cache.stats()["misses"] == 2


def test_io_cache_key_separates_small_and_full_suite_shapes():
    small = _tiny(shape=(64, 512))
    full = _tiny(shape=(2048, 2048), scale=1.0)
    cache = WorkloadIOCache()
    cache.entry(small, seed=0)
    cache.entry(full, seed=0)        # same name+seed, different shapes
    assert cache.stats()["misses"] == 2 and len(cache) == 2


# ---------------------------------------------------------------------------
# Anti-cheating with a shared IO cache (§7.3)
# ---------------------------------------------------------------------------

def test_constant_output_cheat_still_flagged_under_shared_io_cache():
    import jax.numpy as jnp
    wl = kernelbench.by_name("L1/swish")
    cand = Candidate("swish", {"block_rows": 8, "block_lanes": 512})
    cheat = lambda x: jnp.zeros_like(x)  # noqa: E731
    io_cache = WorkloadIOCache()
    for seed in (123, 124):              # the refinement loop's seed ladder
        res = verify(cand, wl, seed=seed, fn=cheat, io_cache=io_cache)
        assert res.state is ES.NUMERIC_MISMATCH
    # two fresh seeds -> two independent entries, two oracle evaluations
    s = io_cache.stats()
    assert s["entries"] == 2 and s["oracle_computes"] == 2


# ---------------------------------------------------------------------------
# ExecutableCache + executable_key
# ---------------------------------------------------------------------------

def test_executable_key_is_seed_and_tol_independent():
    wl = _tiny()
    cand = Candidate("softmax", {"block_rows": 8, "online": True})
    assert cache_key(cand, wl, 0) != cache_key(cand, wl, 1)
    assert executable_key(cand, wl) == executable_key(cand, wl)
    other = Candidate("softmax", {"block_rows": 16, "online": True})
    assert executable_key(cand, wl) != executable_key(other, wl)


def test_exe_cache_reuses_compiled_program_across_seeds():
    wl = kernelbench.by_name("L1/swish")
    cand = Candidate("swish", {"block_rows": 8, "block_lanes": 512})
    exe = ExecutableCache()
    r0 = verify(cand, wl, seed=0, exe_cache=exe)
    r1 = verify(cand, wl, seed=1, exe_cache=exe)
    assert r0.state is ES.CORRECT and r1.state is ES.CORRECT
    s = exe.stats()
    assert s == {"entries": 1, "hits": 1, "misses": 1}
    # and the fresh seed still produced a fresh numeric check
    assert r0.max_abs_err != r1.max_abs_err or r0.max_abs_err == 0.0


def test_exe_cache_lru_bound_and_disabled_mode():
    exe = ExecutableCache(max_entries=1)
    exe.put("a", object())
    exe.put("b", object())
    assert len(exe) == 1 and exe.get("a") is None
    off = ExecutableCache(max_entries=0)
    off.put("a", object())
    assert len(off) == 0 and off.get("a") is None


def test_compile_failure_error_keeps_exception_type_prefix():
    """The collapsed compile except-branch must preserve the old
    'ExcType: message' error format the analyzer prompts rely on."""
    wl = kernelbench.by_name("L1/swish")
    cand = Candidate("swish", {"block_rows": 8, "block_lanes": 2048 + 512})
    res = verify(cand, wl, seed=0)
    assert res.state is ES.COMPILATION_FAILURE
    head = res.error.split(":")[0]
    assert head.isidentifier(), res.error


# ---------------------------------------------------------------------------
# verify_batch: order, dedup, shared inputs, single oracle
# ---------------------------------------------------------------------------

def test_verify_batch_order_dedup_and_mixed_states():
    wl = kernelbench.by_name("L1/swish")
    good = Candidate("swish", {"block_rows": 8, "block_lanes": 512})
    bad = Candidate("swish", {"block_rows": 8, "block_lanes": 2048 + 512})
    rs = verify_batch([good, bad, good], wl, seed=0)
    assert [r.state for r in rs] == [ES.CORRECT, ES.COMPILATION_FAILURE,
                                     ES.CORRECT]
    assert rs[0] is rs[2]            # duplicate shares the result object


def test_verify_batch_computes_oracle_once():
    oracle_calls = {"n": 0}

    def counting_ref(x):
        oracle_calls["n"] += 1
        return ref.swish(x)

    wl = _tiny("T1/swish", op="swish", scale=1.0, ref_fn=counting_ref)
    cands = [Candidate("swish", {"block_rows": r, "block_lanes": 512})
             for r in (8, 16, 32)]
    rs = verify_batch(cands, wl, seed=0, io_cache=WorkloadIOCache())
    assert all(r.state is ES.CORRECT for r in rs)
    assert oracle_calls["n"] == 1


def test_verify_batch_served_from_cache_never_builds_inputs():
    from repro.campaign import VerificationCache
    wl = _tiny("T1/swish", op="swish", scale=1.0)
    cands = [Candidate("swish", {"block_rows": r, "block_lanes": 512})
             for r in (8, 16)]
    cache = VerificationCache()
    verify_batch(cands, wl, seed=0, cache=cache)   # populate
    io_cache = WorkloadIOCache()
    rs = verify_batch(cands, wl, seed=0, cache=cache, io_cache=io_cache)
    assert all(r.state is ES.CORRECT for r in rs)
    # fully cache-served: the io cache was never consulted
    assert io_cache.stats()["misses"] == 0
    assert io_cache.stats()["input_computes"] == 0


def test_analysis_prompt_strips_volatile_phase_timings():
    """phase_s values differ on every run; a prompt embedding them would
    never hit a record/replay session twice."""
    from repro.core.prompts import render_analysis
    p1 = {"op": "swish", "phase_s": {"compile": 0.1}}
    p2 = {"op": "swish", "phase_s": {"compile": 0.9}}
    assert render_analysis("ACC", p1) == render_analysis("ACC", p2)
    assert "phase_s" not in render_analysis("ACC", p1)


def test_verify_batch_results_carry_phase_timings():
    wl = _tiny("T1/swish", op="swish", scale=1.0)
    [r] = verify_batch(
        [Candidate("swish", {"block_rows": 8, "block_lanes": 512})],
        wl, seed=0)
    phases = r.profile["phase_s"]
    assert set(phases) == {"input_gen", "compile", "run", "check", "model"}
    assert all(v >= 0 for v in phases.values())


# ---------------------------------------------------------------------------
# Fan-out refinement (LoopConfig.fanout)
# ---------------------------------------------------------------------------

def test_fanout_rejected_below_one_by_cli(capsys):
    from repro.campaign.__main__ import main
    with pytest.raises(SystemExit) as exc:
        main(["--fanout", "0"])
    assert exc.value.code != 0
    assert "--fanout must be >= 1" in capsys.readouterr().err


def test_fanout_converges_and_shares_batch_inputs():
    wl = kernelbench.by_name("L1/softmax")
    io_cache, exe_cache = WorkloadIOCache(), ExecutableCache()
    plain = run_workload(wl, LoopConfig(num_iterations=4))
    fan = run_workload(wl, LoopConfig(num_iterations=4, fanout=3),
                       io_cache=io_cache, exe_cache=exe_cache)
    assert fan.final.correct
    # batched iterations verified >1 candidate against ONE entry per seed:
    # more compile-level lookups (one per verification) than input sets
    s, e = io_cache.stats(), exe_cache.stats()
    assert s["misses"] >= 1
    assert e["hits"] + e["misses"] > s["misses"]
    # exploring the proposal's neighborhood can only improve the best
    # model time at equal iteration budget (deterministic backend)
    assert (fan.best.model_time_s or 1e9) <= \
        (plain.best.model_time_s or 1e9) + 1e-12


# ---------------------------------------------------------------------------
# Campaign / matrix / report integration
# ---------------------------------------------------------------------------

def test_campaign_done_journals_fastpath_cache_stats(tmp_path):
    wl = _tiny("T1/swish", op="swish", scale=1.0)
    log = tmp_path / "ev.jsonl"
    run_campaign([wl], LoopConfig(num_iterations=2), log_path=log,
                 max_workers=1)
    done = [e for e in EventLog(log).events()
            if e.get("event") == "campaign_done"]
    assert done
    assert {"entries", "hits", "misses", "oracle_computes",
            "input_computes"} <= set(done[-1]["io_cache"])
    assert {"entries", "hits", "misses"} <= set(done[-1]["exe_cache"])


def test_matrix_thread_mode_shares_oracles_across_legs():
    """Acceptance: a matrix run computes strictly fewer reference oracles
    than legs x workloads — cross-leg sharing is real, not per-leg."""
    wls = [_tiny("T1/swish", op="swish", scale=1.0),
           _tiny("T1/softmax", op="softmax")]
    platforms = ["tpu_v5e", "metal_m2"]
    matrix = run_transfer_matrix(wls, platforms,
                                 loop=LoopConfig(num_iterations=2),
                                 max_workers=2)
    assert matrix.n_failed == 0
    n_legs = len(platforms) + len(platforms) * (len(platforms) - 1)
    s = matrix.io_cache.stats()
    assert s["oracle_computes"] < n_legs * len(wls)
    assert s["hits"] > 0
    assert matrix.report()["io_cache"] == s


def test_report_formats_fastpath_cache_lines():
    events = [{"event": "campaign_done",
               "cache": {"entries": 1, "hits": 2, "misses": 3},
               "io_cache": {"entries": 4, "hits": 5, "misses": 6,
                            "oracle_computes": 7, "input_computes": 8},
               "exe_cache": {"entries": 9, "hits": 10, "misses": 11}}]
    report = report_from_events(events)
    assert report["io_cache"]["oracle_computes"] == 7
    text = format_report(report)
    assert "io cache: 5 hits / 6 misses (7 oracle computes)" in text
    assert "exe cache: 10 hits / 11 misses (9 compiled)" in text


def test_report_tolerates_logs_without_fastpath_stats():
    events = [{"event": "campaign_done",
               "cache": {"entries": 0, "hits": 0, "misses": 0}}]
    report = report_from_events(events)
    assert report["io_cache"] is None and report["exe_cache"] is None
    assert "io cache" not in format_report(report)
