"""Campaign runner: cache semantics, scheduler isolation, JSONL resume,
refinement convergence, and the twice-run 100%-hit acceptance property."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.campaign import (Campaign, CampaignConfig, EventLog, JobResult,
                            Scheduler, VerificationCache, result_from_dict,
                            result_to_dict, run_campaign, warm_cache)
from repro.campaign.report import format_report, report_from_events
from repro.core import LoopConfig, kernelbench
from repro.core import candidates as cand_mod
from repro.core import verification as verif_mod
from repro.core.refinement import run_workload
from repro.core.states import EvalResult, ExecutionState
from repro.core.synthesis import Generation
from repro.core.workload import Workload, randn


def _tiny_workload(name="T1/swish", op="swish", rows=8, lanes=512):
    from repro.kernels import ref
    return Workload(
        name=name, level=1, op=op,
        ref_fn=lambda x: ref.swish(x),
        input_fn=lambda rng: {"x": randn(rng, (rows, lanes))},
        input_shapes={"x": (rows, lanes)})


# ---------------------------------------------------------------------------
# VerificationCache semantics
# ---------------------------------------------------------------------------


def test_cache_hit_returns_same_result_without_reverifying(monkeypatch):
    wl = _tiny_workload()
    cand = cand_mod.initial_candidate("swish", use_reference=False)
    cache = VerificationCache()

    calls = {"n": 0}
    real_materialize = cand_mod.materialize

    def counting_materialize(c, **kw):
        calls["n"] += 1
        return real_materialize(c, **kw)

    monkeypatch.setattr(cand_mod, "materialize", counting_materialize)
    r1 = verif_mod.verify(cand, wl, seed=0, cache=cache)
    r2 = verif_mod.verify(cand, wl, seed=0, cache=cache)
    assert r1.correct
    assert r2 is r1                     # memoized object, not a re-run
    assert calls["n"] == 1              # same candidate+seed verified once
    assert cache.stats() == {"entries": 1, "hits": 1, "misses": 1}


def test_cache_key_separates_seed_params_and_workload():
    wl_a = _tiny_workload()
    wl_b = _tiny_workload(name="T1/swish-wide", lanes=2048)
    c1 = cand_mod.Candidate("swish", {"block_rows": 1, "block_lanes": 128})
    c2 = cand_mod.Candidate("swish", {"block_rows": 8, "block_lanes": 128})
    base = verif_mod.cache_key(c1, wl_a, 0)
    assert verif_mod.cache_key(c1, wl_a, 0) == base          # deterministic
    assert verif_mod.cache_key(c1, wl_a, 1) != base          # seed
    assert verif_mod.cache_key(c2, wl_a, 0) != base          # params
    assert verif_mod.cache_key(c1, wl_b, 0) != base          # workload io


def test_llm_callable_candidates_bypass_cache():
    wl = _tiny_workload()
    cand = cand_mod.initial_candidate("swish", use_reference=False)
    cache = VerificationCache()
    r = verif_mod.verify(cand, wl, seed=0, cache=cache,
                         fn=lambda x: jnp.asarray(x) * 0)
    assert r.cache_key is None
    assert len(cache) == 0
    assert cache.hits == 0 and cache.misses == 0


# ---------------------------------------------------------------------------
# Refinement convergence (previously untested)
# ---------------------------------------------------------------------------


class _StubbornAgent:
    """Always proposes the same legal candidate."""

    def __init__(self, cand):
        self.cand = cand

    def generate(self, wl, **kw):
        return Generation(candidate=self.cand, source=self.cand.describe())


def test_run_workload_converges_on_duplicate_candidate():
    wl = _tiny_workload()
    cand = cand_mod.Candidate("swish", {"block_rows": 8, "block_lanes": 512})
    out = run_workload(wl, LoopConfig(num_iterations=5),
                       agent=_StubbornAgent(cand))
    # iteration 0 verifies; iteration 1 sees the duplicate, logs convergence
    # and stops early instead of burning the remaining budget.
    assert len(out.logs) == 2
    assert out.logs[-1].recommendation == "converged"
    assert out.logs[-1].result is out.logs[0].result
    assert out.best is not None and out.best.correct


def test_converged_iteration_reuses_result_not_verify(monkeypatch):
    wl = _tiny_workload()
    cand = cand_mod.Candidate("swish", {"block_rows": 8, "block_lanes": 512})
    calls = {"n": 0}
    real_verify = verif_mod.verify

    def counting_verify(*a, **kw):
        calls["n"] += 1
        return real_verify(*a, **kw)

    import repro.core.refinement as refinement_mod
    monkeypatch.setattr(refinement_mod, "verify", counting_verify)
    run_workload(wl, LoopConfig(num_iterations=5), agent=_StubbornAgent(cand))
    assert calls["n"] == 1


# ---------------------------------------------------------------------------
# Scheduler: failure isolation and timeout
# ---------------------------------------------------------------------------


def test_scheduler_isolates_exploding_job():
    def boom():
        raise RuntimeError("kernel exploded")

    results = Scheduler(max_workers=2).run([
        ("ok-1", lambda: 41), ("boom", boom), ("ok-2", lambda: 42)])
    by_name = {r.name: r for r in results}
    assert by_name["ok-1"].ok and by_name["ok-1"].value == 41
    assert by_name["ok-2"].ok and by_name["ok-2"].value == 42
    assert not by_name["boom"].ok
    assert "RuntimeError: kernel exploded" in by_name["boom"].error


def test_scheduler_timeout_marks_job_and_campaign_continues():
    import threading
    release = threading.Event()

    def hang():
        release.wait(10.0)
        return "late"

    results = Scheduler(max_workers=2, timeout_s=0.2).run([
        ("hang", hang), ("ok", lambda: 1)])
    release.set()
    by_name = {r.name: r for r in results}
    assert not by_name["hang"].ok and "timeout" in by_name["hang"].error
    assert by_name["ok"].ok


def test_campaign_isolates_exploding_workload(tmp_path):
    good = _tiny_workload("T1/good")
    bad = _tiny_workload("T1/bad")

    class ExplodingAgent:
        def generate(self, wl, **kw):
            if wl.name == "T1/bad":
                raise RuntimeError("agent crashed")
            return Generation(
                candidate=cand_mod.initial_candidate("swish",
                                                     use_reference=False))

    log = tmp_path / "c.jsonl"
    cfg = CampaignConfig(loop=LoopConfig(num_iterations=2), max_workers=2,
                         log_path=log)
    result = Campaign([good, bad], cfg,
                      agent_factory=ExplodingAgent).run()
    by_name = {r.workload: r for r in result.runs}
    assert by_name["T1/good"].error is None
    assert by_name["T1/good"].final.correct
    assert "agent crashed" in by_name["T1/bad"].error
    # the error is journaled, and fast_p still counts the failed problem
    events = EventLog(log).events()
    assert any(e["event"] == "workload_error" and e["workload"] == "T1/bad"
               for e in events)
    finals = result.finals()
    assert len(finals) == 2
    assert sum(1 for f in finals if f.correct) == 1


# ---------------------------------------------------------------------------
# JSONL events: round-trip, resume, pre-warm
# ---------------------------------------------------------------------------


def test_eval_result_event_roundtrip():
    r = EvalResult(ExecutionState.CORRECT, model_time_s=1.5e-6,
                   baseline_model_time_s=3e-6, max_abs_err=1e-5,
                   profile={"op": "swish"}, cache_key="abc")
    back = result_from_dict(json.loads(json.dumps(result_to_dict(r))))
    assert back.state is ExecutionState.CORRECT
    assert back.speedup == pytest.approx(2.0)
    assert back.cache_key == "abc"
    assert back.profile == {"op": "swish"}


def test_resume_skips_completed_workloads(tmp_path):
    wls = [_tiny_workload("T1/a"), _tiny_workload("T1/b")]
    log = tmp_path / "resume.jsonl"
    cfg = CampaignConfig(loop=LoopConfig(num_iterations=3), max_workers=2,
                         log_path=log)
    first = Campaign(wls, cfg).run()
    assert first.n_skipped == 0 and first.n_failed == 0

    class MustNotRun:
        def generate(self, wl, **kw):  # pragma: no cover - the assertion
            raise AssertionError("resumed campaign re-ran a done workload")

    second = Campaign(wls, cfg, agent_factory=MustNotRun).run()
    assert second.n_skipped == 2
    assert all(r.final is not None and r.final.correct for r in second.runs)
    # the resumed result is report-ready without re-running anything
    report = report_from_events(EventLog(log).events())
    assert report["levels"][1]["n"] >= 2


def test_resume_prewarms_cache_for_unfinished_workloads(tmp_path):
    wl = _tiny_workload("T1/warm")
    log = tmp_path / "warm.jsonl"
    cfg = CampaignConfig(loop=LoopConfig(num_iterations=3), max_workers=1,
                         log_path=log)
    Campaign([wl], cfg).run()

    # strip the terminal event: simulates a campaign killed mid-workload
    events = EventLog(log).events()
    iter_events = [e for e in events if e["event"] == "iteration"]
    assert iter_events
    truncated = tmp_path / "truncated.jsonl"
    with truncated.open("w") as fh:
        for ev in iter_events:
            fh.write(json.dumps(ev) + "\n")

    cache = VerificationCache()
    n = warm_cache(cache, EventLog(truncated).events())
    assert n == len([e for e in iter_events
                     if e["result"].get("cache_key")])
    cfg2 = CampaignConfig(loop=LoopConfig(num_iterations=3), max_workers=1,
                          log_path=truncated)
    result = Campaign([wl], cfg2, cache=cache).run()
    assert result.n_skipped == 0          # not terminal -> re-run ...
    assert cache.misses == 0              # ... entirely from cache
    assert result.runs[0].final.correct


def test_event_log_tolerates_torn_tail(tmp_path):
    log = tmp_path / "torn.jsonl"
    elog = EventLog(log)
    elog.append({"event": "campaign_start"})
    with log.open("a") as fh:
        fh.write('{"event": "iteration", "trunc')   # killed mid-write
    assert [e["event"] for e in elog.events()] == ["campaign_start"]


# ---------------------------------------------------------------------------
# Acceptance: small-suite campaign twice -> second run is 100% cache hits
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_small_suite_campaign_second_run_all_cache_hits(tmp_path):
    wls = kernelbench.suite(small=True)
    cache = VerificationCache()
    first = run_campaign(wls, LoopConfig(num_iterations=5), cache=cache,
                         max_workers=4, log_path=tmp_path / "r1.jsonl")
    assert first.n_failed == 0
    assert cache.misses > 0 and cache.hits == 0

    misses_before, hits_before = cache.misses, cache.hits
    second = run_campaign(wls, LoopConfig(num_iterations=5), cache=cache,
                          max_workers=4, log_path=tmp_path / "r2.jsonl")
    assert second.n_failed == 0
    assert cache.misses == misses_before          # 100% verification hits
    assert cache.hits > hits_before
    # both runs converge on identical terminal results
    for a, b in zip(first.finals(), second.finals()):
        assert a.state is b.state
        assert a.model_time_s == b.model_time_s


# ---------------------------------------------------------------------------
# CLI + report
# ---------------------------------------------------------------------------


def test_cli_emits_fastp_report_from_jsonl(tmp_path, capsys):
    from repro.campaign.__main__ import main
    log = tmp_path / "cli.jsonl"
    rc = main(["--suite", "small", "--level", "1", "--iters", "2",
               "--workers", "2", "--log", str(log)])
    out = capsys.readouterr().out
    assert rc == 0
    assert log.exists()
    assert "fast_0=" in out and "fast_1.5=" in out
    assert "verification cache:" in out

    # --report-only aggregates the same log without re-running
    rc = main(["--report-only", "--log", str(log)])
    out2 = capsys.readouterr().out
    assert rc == 0
    assert "campaign report" in out2


def test_report_counts_errors_in_denominator():
    events = [
        {"event": "workload_done", "workload": "L1/a", "level": 1,
         "final": result_to_dict(EvalResult(
             ExecutionState.CORRECT, model_time_s=1e-6,
             baseline_model_time_s=4e-6))},
        {"event": "workload_error", "workload": "L1/b", "level": 1,
         "error": "timeout"},
        {"event": "campaign_done", "cache": {"hits": 3, "misses": 1,
                                             "entries": 1}},
    ]
    report = report_from_events(events)
    assert report["levels"][1]["n"] == 2
    assert report["levels"][1]["fast_p"]["0"] == pytest.approx(0.5)
    assert report["total"]["fast_p"]["2"] == pytest.approx(0.5)
    text = format_report(report)
    assert "generation_failure=1" in text
    assert "cache: 3 hits / 1 misses" in text


@pytest.mark.slow
def test_hung_job_does_not_block_process_exit():
    """Daemon workers: a wedged job must not stall interpreter shutdown
    after its timeout fires (ThreadPoolExecutor would join it at exit)."""
    import subprocess
    import sys
    import time as _time
    code = (
        "import time\n"
        "from repro.campaign.scheduler import Scheduler\n"
        "rs = Scheduler(max_workers=2, timeout_s=0.5).run([\n"
        "    ('hang', lambda: time.sleep(120)), ('ok', lambda: 1)])\n"
        "print([r.error is None for r in rs])\n")
    t0 = _time.monotonic()
    proc = subprocess.run([sys.executable, "-c", code], timeout=60,
                          capture_output=True, text=True)
    elapsed = _time.monotonic() - t0
    assert proc.returncode == 0, proc.stderr
    assert "[False, True]" in proc.stdout
    assert elapsed < 45          # exited despite the 120s-hung worker


def test_report_separates_interleaved_loop_configs():
    from repro.campaign import distinct_loop_configs
    loop_a = {"num_iterations": 1, "single_shot": True}
    loop_b = {"num_iterations": 5, "single_shot": False}
    ok = result_to_dict(EvalResult(ExecutionState.CORRECT, model_time_s=1e-6,
                                   baseline_model_time_s=4e-6))
    bad = result_to_dict(EvalResult(ExecutionState.NUMERIC_MISMATCH,
                                    error="err"))
    events = [
        {"event": "workload_done", "workload": "L1/a", "level": 1,
         "loop": loop_a, "final": bad},
        {"event": "workload_done", "workload": "L1/b", "level": 1,
         "loop": loop_a, "final": bad},
        {"event": "workload_done", "workload": "L1/a", "level": 1,
         "loop": loop_b, "final": ok},
    ]
    assert len(distinct_loop_configs(events)) == 2
    rep_a = report_from_events(events, loop=loop_a)
    rep_b = report_from_events(events, loop=loop_b)
    assert rep_a["total"]["n"] == 2
    assert rep_a["total"]["fast_p"]["0"] == pytest.approx(0.0)
    assert rep_b["total"]["n"] == 1
    assert rep_b["total"]["fast_p"]["0"] == pytest.approx(1.0)
    # unfiltered, latest-per-workload blends configs — the CLI avoids this
    # by reporting per distinct config
    assert report_from_events(events)["total"]["n"] == 2


def test_scheduler_jobresult_ok_property():
    assert JobResult("x", value=1).ok
    assert not JobResult("x", error="boom").ok


def test_report_latest_terminal_event_wins():
    done = {"event": "workload_done", "workload": "L1/a", "level": 1,
            "final": result_to_dict(EvalResult(ExecutionState.CORRECT,
                                               model_time_s=1e-6,
                                               baseline_model_time_s=4e-6))}
    err = {"event": "workload_error", "workload": "L1/a", "level": 1,
           "error": "timeout"}
    # error then retried-to-done: the retry wins and n stays 1
    report = report_from_events([err, done])
    assert report["levels"][1]["n"] == 1
    assert report["levels"][1]["fast_p"]["0"] == pytest.approx(1.0)
    # duplicate done events (--no-resume rerun on one log) don't double-count
    report = report_from_events([done, done])
    assert report["total"]["n"] == 1


def test_scheduler_starved_jobs_cancelled_not_marked_timeout():
    import threading
    release = threading.Event()

    def hang():
        release.wait(10.0)
        return "late"

    ran = {"n": 0}

    def queued():
        ran["n"] += 1
        return "ran"

    # one worker: 'hang' occupies it, 'queued' never gets a slot
    results = Scheduler(max_workers=1, timeout_s=0.2).run([
        ("hang", hang), ("queued", queued)])
    release.set()
    by_name = {r.name: r for r in results}
    assert "timeout" in by_name["hang"].error
    assert "never started" in by_name["queued"].error
    assert ran["n"] == 0        # cancelled, not left to run after return


def test_resume_ignores_log_from_different_loop_config(tmp_path):
    wl = _tiny_workload("T1/cfg")
    log = tmp_path / "cfg.jsonl"
    Campaign([wl], CampaignConfig(loop=LoopConfig(num_iterations=2),
                                  max_workers=1, log_path=log)).run()
    # same log, different loop config: nothing may be skipped ...
    cache = VerificationCache()
    result = Campaign([wl], CampaignConfig(
        loop=LoopConfig(num_iterations=3, use_profiling=True),
        max_workers=1, log_path=log), cache=cache).run()
    assert result.n_skipped == 0
    assert result.runs[0].final.correct
    # ... but the config-independent cache is still pre-warmed
    assert cache.hits > 0


def test_resume_rejects_same_name_different_shapes(tmp_path):
    """Small and full suites share workload names; a log written for one
    shape must not be replayed as finished work for another."""
    log = tmp_path / "shapes.jsonl"
    cfg_kw = dict(loop=LoopConfig(num_iterations=2), max_workers=1,
                  log_path=log)
    Campaign([_tiny_workload("T1/shared", lanes=512)],
             CampaignConfig(**cfg_kw)).run()
    result = Campaign([_tiny_workload("T1/shared", lanes=2048)],
                      CampaignConfig(**cfg_kw)).run()
    assert result.n_skipped == 0          # io signature differs -> re-run
    assert result.runs[0].final.correct


def test_iterations_journaled_before_workload_finishes(tmp_path):
    """A workload that dies mid-loop still leaves its completed iterations
    in the log (that is what resume pre-warms the cache from)."""
    wl = _tiny_workload("T1/dies")

    class DiesOnThird:
        def __init__(self):
            self.calls = 0

        def generate(self, w, **kw):
            self.calls += 1
            if self.calls >= 3:
                raise RuntimeError("backend died mid-workload")
            p = {"block_rows": self.calls, "block_lanes": 128}
            return Generation(candidate=cand_mod.Candidate("swish", p))

    log = tmp_path / "dies.jsonl"
    cfg = CampaignConfig(loop=LoopConfig(num_iterations=5), max_workers=1,
                         log_path=log)
    result = Campaign([wl], cfg, agent_factory=DiesOnThird).run()
    assert "backend died" in result.runs[0].error
    events = EventLog(log).events()
    iters = [e for e in events if e["event"] == "iteration"]
    assert len(iters) == 2                # both completed iterations persist
    assert all(e["result"]["cache_key"] for e in iters)


def test_resume_honours_per_event_config_in_interleaved_log(tmp_path):
    """A log holding runs of two configs: resume must skip only the
    terminal events written under the *current* config, even when the last
    campaign_start belongs to it."""
    wl_a, wl_b = _tiny_workload("T1/ia"), _tiny_workload("T1/ib")
    log = tmp_path / "mixed.jsonl"
    loop3, loop5 = LoopConfig(num_iterations=3), LoopConfig(num_iterations=5)
    # run A (iters=3) finishes both workloads
    Campaign([wl_a, wl_b], CampaignConfig(loop=loop3, max_workers=1,
                                          log_path=log)).run()
    # run B (iters=5) finishes only wl_a (simulating a kill before wl_b)
    Campaign([wl_a], CampaignConfig(loop=loop5, max_workers=1,
                                    log_path=log, resume=False)).run()
    # run C (iters=5): wl_a resumes from run B; wl_b must NOT resume from
    # run A's iters=3 result just because run B's campaign_start is last.
    ran = []

    class Tracking:
        def generate(self, w, **kw):
            ran.append(w.name)
            return Generation(candidate=cand_mod.initial_candidate(
                "swish", use_reference=False))

    result = Campaign([wl_a, wl_b],
                      CampaignConfig(loop=loop5, max_workers=1,
                                     log_path=log),
                      agent_factory=Tracking).run()
    assert result.n_skipped == 1
    skipped = {r.workload for r in result.runs if r.skipped}
    assert skipped == {"T1/ia"}
    assert "T1/ib" in ran and "T1/ia" not in ran


def test_measure_wall_not_satisfied_by_wall_less_cache_hit():
    wl = _tiny_workload("T1/wall")
    cand = cand_mod.initial_candidate("swish", use_reference=False)
    cache = VerificationCache()
    r1 = verif_mod.verify(cand, wl, seed=0, cache=cache)
    assert r1.wall_time_s is None
    r2 = verif_mod.verify(cand, wl, seed=0, cache=cache, measure_wall=True)
    assert r2.wall_time_s is not None       # re-verified, not the stale hit
    # the upgraded entry now serves measure_wall requests from cache
    r3 = verif_mod.verify(cand, wl, seed=0, cache=cache, measure_wall=True)
    assert r3 is r2
