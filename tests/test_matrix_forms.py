"""Chunk-parallel matrix forms vs. token-recurrence oracles (§Perf B/D)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _mk(rng, *shape, lo=None, hi=None):
    if lo is not None:
        return jnp.asarray(rng.uniform(lo, hi, shape), jnp.float32)
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


@pytest.mark.parametrize("chunk", [16, 32, 128])
def test_ssd_matrix_exact(rng, chunk):
    B, T, H, P, N = 2, 128, 3, 16, 8
    x = _mk(rng, B, T, H, P)
    a = _mk(rng, B, T, H, lo=0.3, hi=0.999)
    b = _mk(rng, B, T, H, N)
    c = _mk(rng, B, T, H, N)
    y, s = ops.ssd_matrix(x, a, b, c, chunk=chunk)
    y_ref, s_ref = ref.ssd(x, a, b, c)
    np.testing.assert_allclose(y, y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s, s_ref, rtol=2e-4, atol=2e-4)


def test_ssd_matrix_shared_bc(rng):
    """(B,T,N) shared-head B/C == explicit broadcast."""
    B, T, H, P, N = 2, 64, 4, 8, 8
    x = _mk(rng, B, T, H, P)
    a = _mk(rng, B, T, H, lo=0.5, hi=0.99)
    b2 = _mk(rng, B, T, N)
    c2 = _mk(rng, B, T, N)
    bb = jnp.broadcast_to(b2[:, :, None], (B, T, H, N))
    cb = jnp.broadcast_to(c2[:, :, None], (B, T, H, N))
    y1, s1 = ops.ssd_matrix(x, a, b2, c2, chunk=16)
    y2, s2 = ops.ssd_matrix(x, a, bb, cb, chunk=16)
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(s1, s2, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("chunk", [16, 64])
def test_wkv6_matrix_exact(rng, chunk):
    B, T, H, D = 2, 128, 2, 16
    r = _mk(rng, B, T, H, D)
    k = _mk(rng, B, T, H, D)
    v = _mk(rng, B, T, H, D)
    w = _mk(rng, B, T, H, D, lo=0.05, hi=0.999)
    u = _mk(rng, H, D)
    out, s = ops.wkv6_matrix(r, k, v, w, u, chunk=chunk)
    out_ref, s_ref = ref.wkv6(r, k, v, w, u)
    np.testing.assert_allclose(out, out_ref, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(s, s_ref, rtol=3e-4, atol=3e-4)


def test_wkv6_matrix_initial_state(rng):
    B, T, H, D = 1, 64, 2, 8
    r = _mk(rng, B, T, H, D)
    k = _mk(rng, B, T, H, D)
    v = _mk(rng, B, T, H, D)
    w = _mk(rng, B, T, H, D, lo=0.2, hi=0.99)
    u = _mk(rng, H, D)
    s0 = _mk(rng, B, H, D, D) * 0.1
    out, s = ops.wkv6_matrix(r, k, v, w, u, chunk=16, state=s0)
    out_ref, s_ref = ref.wkv6(r, k, v, w, u, s0)
    np.testing.assert_allclose(out, out_ref, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(s, s_ref, rtol=3e-4, atol=3e-4)


def test_matrix_forms_differentiable(rng):
    """Backward through the matrix forms is finite and matches the oracle."""
    B, T, H, D = 1, 32, 1, 8
    r = _mk(rng, B, T, H, D)
    k = _mk(rng, B, T, H, D)
    v = _mk(rng, B, T, H, D)
    w = _mk(rng, B, T, H, D, lo=0.2, hi=0.99)
    u = _mk(rng, H, D)
    g1 = jax.grad(lambda r_: jnp.sum(
        ops.wkv6_matrix(r_, k, v, w, u, chunk=8)[0] ** 2))(r)
    g2 = jax.grad(lambda r_: jnp.sum(ref.wkv6(r_, k, v, w, u)[0] ** 2))(r)
    np.testing.assert_allclose(g1, g2, rtol=2e-3, atol=2e-3)


def test_wkv6_matrix_stability_extreme_decay():
    """Strong decay (w→0) must not overflow — the 1/decay factorization
    would; the difference form stays bounded. Guarded so the module still
    collects (and the tests above still run) without hypothesis vendored."""
    pytest.importorskip(
        "hypothesis", reason="hypothesis not vendored; property test skipped")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.sampled_from([8, 16, 32]))
    def check(seed, chunk):
        rng = np.random.default_rng(seed)
        B, T, H, D = 1, 64, 1, 8
        r = _mk(rng, B, T, H, D)
        k = _mk(rng, B, T, H, D)
        v = _mk(rng, B, T, H, D)
        w = _mk(rng, B, T, H, D, lo=1e-4, hi=0.5)   # aggressive decay
        u = _mk(rng, H, D)
        out, s = ops.wkv6_matrix(r, k, v, w, u, chunk=chunk)
        assert np.isfinite(np.asarray(out)).all()
        assert np.isfinite(np.asarray(s)).all()
        out_ref, _ = ref.wkv6(r, k, v, w, u)
        np.testing.assert_allclose(out, out_ref, rtol=1e-3, atol=1e-3)

    check()
