"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not vendored; property tests skipped")
from hypothesis import given, settings, strategies as st

from repro.core.candidates import (SPACES, Candidate, baseline_time,
                                   model_time, mutations)
from repro.core.metrics import fast_p
from repro.core.states import EvalResult, ExecutionState
from repro.kernels import ref
from repro.optim import compress_int8, decompress_int8
from repro.roofline.analysis import collective_bytes
from repro.roofline import hlo_cost

F32 = st.floats(-100, 100, allow_nan=False, width=32)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.lists(F32, min_size=4, max_size=4), min_size=2,
                max_size=8))
def test_softmax_rows_sum_to_one(rows):
    x = jnp.asarray(np.array(rows, np.float32))
    s = ref.softmax(x)
    np.testing.assert_allclose(np.sum(np.asarray(s), -1), 1.0, rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_attention_probabilities_convex(seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((1, 8, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 8, 2, 8)), jnp.float32)
    vmax = rng.standard_normal((1, 8, 2, 8)).astype(np.float32)
    v = jnp.asarray(vmax)
    out = np.asarray(ref.attention(q, k, v, causal=True))
    # attention output is a convex combination of values
    assert out.max() <= vmax.max() + 1e-4
    assert out.min() >= vmax.min() - 1e-4


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(1e-3, 1e3))
def test_int8_compression_bounded_error(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(64) * scale, jnp.float32)
    q, s = compress_int8(x)
    back = decompress_int8(q, s)
    # error bounded by half a quantization step
    assert float(jnp.max(jnp.abs(back - x))) <= float(s) * 0.5 + 1e-9


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_error_feedback_accumulates_unbiased(seed):
    """Sum of (transmitted + residual) equals sum of true gradients."""
    from repro.optim import CompressionState, ef_compress_grads
    rng = np.random.default_rng(seed)
    grads = {"w": jnp.asarray(rng.standard_normal(32), jnp.float32)}
    state = CompressionState(error={"w": jnp.zeros(32)})
    sent_total = jnp.zeros(32)
    true_total = jnp.zeros(32)
    for _ in range(4):
        g = {"w": jnp.asarray(rng.standard_normal(32), jnp.float32)}
        true_total = true_total + g["w"]
        sent, state = ef_compress_grads(g, state)
        sent_total = sent_total + sent["w"]
    # residual closes the gap exactly
    np.testing.assert_allclose(np.asarray(sent_total + state.error["w"]),
                               np.asarray(true_total), rtol=1e-4, atol=1e-4)


_OPS = sorted(SPACES)


@settings(max_examples=40, deadline=None)
@given(st.sampled_from(_OPS), st.integers(0, 10 ** 9))
def test_model_time_positive_and_mutation_closed(op, seed):
    rng = np.random.default_rng(seed)
    params = {k: rng.choice(v).item() for k, v in SPACES[op].items()}
    cand = Candidate(op, params)
    shapes = {
        "swish": {"x": (2048, 2048)},
        "softmax": {"x": (1024, 4096)},
        "rmsnorm": {"x": (2048, 4096)},
        "matmul": {"a": (1024, 1024), "b": (1024, 1024)},
        "swiglu": {"gate": (4096, 2048), "up": (4096, 2048)},
        "attention": {"q": (2, 1024, 8, 64), "k": (2, 1024, 2, 64),
                      "v": (2, 1024, 2, 64)},
        "xent": {"logits": (512, 32768), "labels": (512,)},
        "ssd": {"x": (2, 1024, 4, 64), "a": (2, 1024, 4),
                "b": (2, 1024, 4, 16), "c": (2, 1024, 4, 16)},
    }[op]
    t = model_time(cand, shapes)
    assert t > 0
    for mut in mutations(cand).values():
        assert mut.op == op
        assert set(mut.params) == set(params)
        assert model_time(mut, shapes) > 0
    # baseline is a fixed member of the space
    assert baseline_time(op, shapes) > 0


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.floats(0.1, 10)), min_size=1,
                max_size=20), st.floats(0, 3))
def test_fast_p_monotone_in_p(items, p):
    results = [EvalResult(ExecutionState.CORRECT if ok
                          else ExecutionState.NUMERIC_MISMATCH,
                          model_time_s=1.0, baseline_model_time_s=sp)
               for ok, sp in items]
    assert 0.0 <= fast_p(results, p + 0.5) <= fast_p(results, p) <= 1.0


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6), st.integers(1, 64), st.integers(1, 64),
       st.sampled_from(["f32", "bf16", "s32"]))
def test_collective_bytes_parser(n_ops, d0, d1, dtype):
    bytes_per = {"f32": 4, "bf16": 2, "s32": 4}[dtype] * d0 * d1
    lines = ["ENTRY %main () -> f32[] {"]
    for i in range(n_ops):
        lines.append(f"  %ar.{i} = {dtype}[{d0},{d1}]{{1,0}} "
                     f"all-reduce(%x.{i}), replica_groups={{}}")
    lines.append("}")
    total, breakdown = collective_bytes("\n".join(lines))
    assert total == n_ops * bytes_per
    assert breakdown == {"all-reduce": n_ops * bytes_per}


def test_hlo_cost_while_multiplier():
    """Loop-aware analyzer multiplies body cost by known trip count."""
    hlo = """
%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %g = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %d = f32[8,8]{1,0} dot(%g, %g), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %c = s32[] constant(1)
  %i = s32[] get-tuple-element(%p), index=0
  %t = (s32[], f32[8,8]) tuple(%i, %d)
}
%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(7)
  %lt = pred[] compare(%i, %n), direction=LT
}
ENTRY %main (x: f32[8,8]) -> f32[8,8] {
  %x = f32[8,8]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %tu = (s32[], f32[8,8]) tuple(%c0, %x)
  %w = (s32[], f32[8,8]) while(%tu), condition=%cond, body=%body
  %r = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""
    res = hlo_cost.analyze(hlo)
    assert res.flops == 7 * 2 * 8 * 8 * 8
