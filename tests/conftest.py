import numpy as np
import pytest

# NOTE: deliberately NO xla_force_host_platform_device_count here — smoke
# tests and benchmarks must see the single real device; only
# launch/dryrun.py forces 512 placeholder devices.


@pytest.fixture
def rng():
    return np.random.default_rng(0)
