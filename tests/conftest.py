import numpy as np
import pytest

# NOTE: deliberately NO xla_force_host_platform_device_count here — smoke
# tests and benchmarks must see the single real device; only
# launch/dryrun.py forces 512 placeholder devices.

# Two lanes (documented in ROADMAP.md):
#   fast lane:  python -m pytest -x -q -m "not slow"   (~seconds)
#   full lane:  python -m pytest -x -q                 (everything)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: heavyweight parametrization or end-to-end campaign; "
        "excluded from the fast lane (-m \"not slow\")")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
