"""KForge core behaviour: five states, refinement dynamics, reference
transfer, analysis agent, fast_p metric, anti-cheat verification."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Candidate, EvalResult, ExecutionState, LLMBackend,
                        LoopConfig, Recommendation, RuleBasedAnalyzer,
                        TemplateSearchBackend, fast_p, fast_p_curve,
                        initial_candidate, kernelbench, run_workload,
                        state_histogram, verify)
from repro.core.oneshot import VECTOR_ADD_PALLAS
from repro.core.states import ExecutionState as ES


# ---------------------------------------------------------------------------
# Verification: five execution states
# ---------------------------------------------------------------------------

def test_state_correct():
    wl = kernelbench.by_name("L1/swish")
    cand = Candidate("swish", {"block_rows": 8, "block_lanes": 512})
    res = verify(cand, wl, seed=0)
    assert res.state is ES.CORRECT
    assert res.speedup is not None and res.speedup > 0


def test_state_compilation_failure_on_misaligned_blocks():
    wl = kernelbench.by_name("L1/swish")  # 2048x2048 input
    cand = Candidate("swish", {"block_rows": 8, "block_lanes": 2048 + 512})
    res = verify(cand, wl, seed=0)
    assert res.state is ES.COMPILATION_FAILURE


def test_state_numeric_mismatch_on_naive_softmax():
    wl = kernelbench.by_name("L1/softmax")  # +-60 magnitude rows
    cand = Candidate("softmax", {"block_rows": 8, "online": False})
    res = verify(cand, wl, seed=0)
    assert res.state is ES.NUMERIC_MISMATCH


def test_state_runtime_error():
    wl = kernelbench.by_name("L1/swish")

    def exploding(x):
        raise RuntimeError("device abort")

    # bypass trace-time detection by raising from a callback-free wrapper
    cand = Candidate("swish", {"block_rows": 8, "block_lanes": 512})
    res = verify(cand, wl, seed=0, fn=exploding)
    assert res.state in (ES.COMPILATION_FAILURE, ES.RUNTIME_ERROR)


def test_llm_backend_without_completion_rejected_at_construction():
    """A backend with no completion channel used to fail LATE — one opaque
    GENERATION_FAILURE per workload, deep in the refinement loop. The
    misconfiguration is now a clear ValueError at construction."""
    with pytest.raises(ValueError, match="completion channel"):
        LLMBackend(complete=None)
    with pytest.raises(ValueError, match="completion channel"):
        LLMBackend()
    # prompt inspection stays possible, but generation refuses clearly
    backend = LLMBackend(prompt_only=True)
    wl = kernelbench.by_name("L1/swish")
    assert "kernel" in backend.build_prompt(
        wl, prev=None, prev_result=None, recommendation=None,
        use_reference=False)
    with pytest.raises(RuntimeError, match="prompt_only"):
        backend.generate(wl)


def test_anti_cheat_constant_output_flagged():
    """Paper §7.3: constant-output programs must not verify as correct."""
    wl = kernelbench.by_name("L1/swish")
    cand = Candidate("swish", {"block_rows": 8, "block_lanes": 512})
    cheat = lambda x: jnp.zeros_like(x)
    res = verify(cand, wl, seed=123, fn=cheat)
    assert res.state is ES.NUMERIC_MISMATCH


# ---------------------------------------------------------------------------
# Refinement dynamics (paper Fig. 1 / Tables 4-5 qualitative behaviour)
# ---------------------------------------------------------------------------

def test_iterative_fixes_numerics():
    wl = kernelbench.by_name("L1/softmax")
    single = run_workload(wl, LoopConfig(single_shot=True)).final
    iterative = run_workload(wl, LoopConfig(num_iterations=3)).final
    assert single.state is ES.NUMERIC_MISMATCH
    assert iterative.state is ES.CORRECT


def test_reference_improves_single_shot():
    wl = kernelbench.by_name("L1/softmax")
    base = run_workload(wl, LoopConfig(single_shot=True)).final
    with_ref = run_workload(
        wl, LoopConfig(single_shot=True, use_reference=True)).final
    assert not base.correct and with_ref.correct


@pytest.mark.slow
def test_profiling_does_not_hurt_and_logs_recommendations():
    wl = kernelbench.by_name("L1/rmsnorm")
    plain = run_workload(wl, LoopConfig(num_iterations=4))
    prof = run_workload(wl, LoopConfig(num_iterations=4, use_profiling=True))
    assert prof.final.correct
    assert prof.final.model_time_s <= plain.final.model_time_s * 1.05
    assert any(l.recommendation for l in prof.logs)


def test_convergence_breaks_early():
    wl = kernelbench.by_name("L1/swish", small=True)
    out = run_workload(wl, LoopConfig(num_iterations=5, use_profiling=True))
    assert len(out.logs) <= 5
    assert out.final.correct


# ---------------------------------------------------------------------------
# Agents
# ---------------------------------------------------------------------------

def test_analyzer_recommends_mxu_alignment():
    an = RuleBasedAnalyzer()
    rec = an.analyze({
        "op": "matmul", "params": {"block_m": 64, "block_n": 64,
                                   "block_k": 512},
        "shapes": {"a": (1024, 1024), "b": (1024, 1024)},
        "model_time_s": 1e-3, "flops": 2 * 1024 ** 3})
    assert rec.param in ("block_m", "block_n")
    assert rec.value == 128


def test_recommendation_apply_respects_space():
    cand = initial_candidate("matmul", use_reference=False)
    rec = Recommendation(text="x", param="nonexistent", value=1)
    assert rec.apply(cand).params == cand.params


def test_reference_hints_transfer_strategy():
    naive = initial_candidate("attention", use_reference=False)
    ref = initial_candidate("attention", use_reference=True)
    assert not naive.params["online"] and ref.params["online"]


def test_llm_backend_prompt_contains_paper_fields():
    backend = LLMBackend(prompt_only=True)
    wl = kernelbench.by_name("L2/attention_gqa")
    p = backend.build_prompt(wl, prev=None, prev_result=None,
                             recommendation=None, use_reference=True)
    assert "pallas_call" in p and wl.name in p
    assert "reference" in p.lower()


def test_llm_backend_executes_canned_completion():
    reply = f"```python\n{VECTOR_ADD_PALLAS}\n```"
    backend = LLMBackend(complete=lambda prompt: reply)
    wl = kernelbench.by_name("L1/swish")
    gen = backend.generate(wl)
    assert gen.callable_fn is not None and gen.failure is None


# ---------------------------------------------------------------------------
# Metric
# ---------------------------------------------------------------------------

def _mk(state, speedup=None):
    return EvalResult(state, model_time_s=1.0,
                      baseline_model_time_s=speedup if speedup else None)


def test_fast_p():
    results = [_mk(ES.CORRECT, 2.0), _mk(ES.CORRECT, 0.5),
               _mk(ES.NUMERIC_MISMATCH), _mk(ES.COMPILATION_FAILURE)]
    assert fast_p(results, 0.0) == 0.5
    assert fast_p(results, 1.0) == 0.25
    assert fast_p(results, 3.0) == 0.0
    curve = fast_p_curve(results)
    assert curve[0.0] >= curve[1.0] >= curve[2.0]


def test_state_histogram():
    results = [_mk(ES.CORRECT, 2.0), _mk(ES.NUMERIC_MISMATCH)]
    h = state_histogram(results)
    assert h == {"correct": 1, "numeric_mismatch": 1}


def test_agent_discovers_ssd_matrix_form():
    """The optimization pass must rediscover the recurrence->matrix
    transformation that §Perf iteration B1 applied by hand (L2/ssd_scan)."""
    wl = kernelbench.by_name("L2/ssd_scan", small=True)
    out = run_workload(wl, LoopConfig(num_iterations=5, use_profiling=True))
    assert out.final.correct
    assert out.best_candidate.params["form"] == "matrix"
    assert out.final.speedup > 10
