"""Population-based search: selection/exploit/explore semantics, journal
determinism, EventLog resume with zero re-verification, fault injection
(a member that raises inside verify_batch), shared-cache-key lineage
attribution, and the CLI/engine validation surface."""
import json

import pytest

from repro.campaign import (Campaign, CampaignConfig, EventLog, Scheduler,
                            VerificationCache)
from repro.campaign import events as ev_mod
from repro.campaign import population as pop
from repro.campaign.__main__ import main
from repro.campaign.matrix import run_transfer_matrix
from repro.campaign.transfer import run_transfer_sweep
from repro.core import LoopConfig
from repro.core import candidates as cand_mod
from repro.core.analysis import Recommendation
from repro.core.refinement import run_workload
from repro.core.states import EvalResult, ExecutionState
from repro.core.workload import Workload, randn


def _tiny_workload(name="T1/swish", op="swish", rows=8, lanes=512):
    from repro.kernels import ref
    return Workload(
        name=name, level=1, op=op,
        ref_fn=lambda x: ref.swish(x),
        input_fn=lambda rng: {"x": randn(rng, (rows, lanes))},
        input_shapes={"x": (rows, lanes)})


def _res(speedup=None, correct=True, t=1.0):
    """Fabricated EvalResult: ``speedup`` x faster than baseline when
    correct, NUMERIC_MISMATCH otherwise."""
    if not correct:
        return EvalResult(ExecutionState.NUMERIC_MISMATCH, error="mismatch")
    return EvalResult(ExecutionState.CORRECT, model_time_s=t,
                      baseline_model_time_s=(speedup or 1.0) * t)


def _members(params_list, op="swish"):
    return [pop.Member(f"m{i}", cand_mod.Candidate(op, dict(p)))
            for i, p in enumerate(params_list)]


def _strip_volatile(ev):
    """A generation event with wall-clock noise removed: everything left
    is deterministic under a fixed seed."""
    ev = json.loads(json.dumps(ev))
    for m in ev["members"]:
        m["result"].pop("wall_time_s", None)
        (m["result"].get("profile") or {}).pop("phase_s", None)
    return ev


# ---------------------------------------------------------------------------
# Selection: member_score and truncation_split
# ---------------------------------------------------------------------------


def test_member_score_tiers_and_tie_break():
    assert pop.member_score(_res(speedup=2.0)) == (0, 1.0)
    assert pop.member_score(_res(speedup=1.2)) == (1, 1.0)
    assert pop.member_score(_res(speedup=0.8)) == (2, 1.0)
    assert pop.member_score(_res(correct=False)) == \
        (pop.FAILED_TIER, float("inf"))
    # inside a tier, faster modeled time wins
    assert pop.member_score(_res(speedup=2.0, t=0.5)) < \
        pop.member_score(_res(speedup=2.0, t=1.0))
    # a failed member never outranks any correct one
    assert pop.member_score(_res(speedup=0.1)) < \
        pop.member_score(_res(correct=False))


def test_truncation_split_monotone_and_disjoint():
    scores = [pop.member_score(r) for r in
              (_res(speedup=2.0), _res(speedup=1.2), _res(speedup=0.9),
               _res(correct=False))]
    winners, losers = pop.truncation_split(scores)
    assert winners == [0]
    assert 3 in losers                      # failed member is always a loser
    assert not set(winners) & set(losers)
    for w in winners:
        for l in losers:
            assert scores[w] <= scores[l]   # selection is monotone


def test_truncation_split_all_failed_and_degenerate():
    failed = [pop.member_score(_res(correct=False))] * 4
    winners, losers = pop.truncation_split(failed)
    assert winners == []
    assert sorted(losers) == [0, 1, 2, 3]   # everyone is up for explore
    assert pop.truncation_split([]) == ([], [])
    assert pop.truncation_split([(0, 1.0)]) == ([], [])


def test_failed_members_are_losers_even_outside_bottom_quarter():
    # 1 winner, 3 failed, K=8: the bottom-quarter cut alone (2) would
    # leave one failing member surviving untouched forever
    scores = [pop.member_score(r) for r in
              (_res(speedup=2.0), _res(correct=False), _res(correct=False),
               _res(correct=False), _res(speedup=1.2), _res(speedup=1.1),
               _res(speedup=1.05), _res(speedup=0.9))]
    winners, losers = pop.truncation_split(scores)
    assert winners == [0, 4]                 # n=8 -> cut=2, best two
    assert {1, 2, 3} <= set(losers)


# ---------------------------------------------------------------------------
# Exploit/explore: copy_tiling, in_space, evolve
# ---------------------------------------------------------------------------


def test_copy_tiling_copies_tiles_snaps_and_keeps_strategy():
    dst = cand_mod.Candidate("softmax", {"block_rows": 1, "online": False})
    src = cand_mod.Candidate("softmax", {"block_rows": 64, "online": True})
    out = cand_mod.copy_tiling(dst, src)
    assert out.params["block_rows"] == 64      # tile copied
    assert out.params["online"] is False       # strategy axis stays dst's
    assert cand_mod.in_space(out)


def test_in_space_rejects_unknown_axes_and_illegal_values():
    assert cand_mod.in_space(
        cand_mod.Candidate("swish", {"block_rows": 8, "block_lanes": 128}))
    assert not cand_mod.in_space(
        cand_mod.Candidate("swish", {"block_rows": 7}))
    assert not cand_mod.in_space(
        cand_mod.Candidate("swish", {"bogus_axis": 1}))


def test_evolve_losers_exploit_winners_and_explore():
    members = _members([
        {"block_rows": 64, "block_lanes": 2048},    # winner
        {"block_rows": 8, "block_lanes": 512},
        {"block_rows": 8, "block_lanes": 128},
        {"block_rows": 1, "block_lanes": 128},      # failed -> loser
    ])
    results = [_res(speedup=2.0), _res(speedup=1.2, t=1.0),
               _res(speedup=1.1, t=2.0), _res(correct=False)]
    nxt = pop.evolve(members, results, seed=3, generation=0)
    assert len(nxt) == len(members)
    assert [m.lineage for m in nxt] == ["m0", "m1", "m2", "m3"]
    # survivors keep their params
    for i in (0, 1, 2):
        assert nxt[i].origin == "survivor"
        assert nxt[i].candidate.params == members[i].candidate.params
    # the loser exploited the winner (tiling copied) then explored
    loser = nxt[3]
    assert loser.origin == "exploit"
    assert loser.exploited_from == "m0"
    assert loser.explored is not None
    assert cand_mod.in_space(loser.candidate)
    # one mutation away from the winner's tiling: exactly one param of the
    # exploited copy differs
    base = cand_mod.copy_tiling(members[3].candidate, members[0].candidate)
    diff = [k for k in loser.candidate.params
            if loser.candidate.params[k] != base.params.get(k)]
    assert len(diff) == 1


def test_evolve_is_deterministic_per_seed():
    members = _members([{"block_rows": 64, "block_lanes": 2048},
                        {"block_rows": 1, "block_lanes": 128}])
    results = [_res(speedup=2.0), _res(correct=False)]
    a = pop.evolve(members, results, seed=11, generation=2)
    b = pop.evolve(members, results, seed=11, generation=2)
    assert a == b


def test_evolve_all_failed_explores_every_member():
    members = _members([{"block_rows": 1, "block_lanes": 128},
                        {"block_rows": 8, "block_lanes": 128},
                        {"block_rows": 8, "block_lanes": 512}])
    results = [_res(correct=False)] * 3
    nxt = pop.evolve(members, results, seed=0, generation=1)
    for before, after in zip(members, nxt):
        assert after.origin == "explore"
        assert after.exploited_from is None
        assert after.explored is not None
        assert after.candidate.params != before.candidate.params
        assert cand_mod.in_space(after.candidate)


def test_evolve_propagates_winner_recommendation():
    members = _members([{"block_rows": 64, "block_lanes": 2048},
                        {"block_rows": 1, "block_lanes": 128}])
    results = [_res(speedup=2.0), _res(correct=False)]
    rec = Recommendation(text="shrink lanes", param="block_lanes",
                         value=512, source="rule")
    nxt = pop.evolve(members, results, seed=0, generation=0,
                     recommendations={"m0": rec})
    loser = nxt[1]
    assert loser.origin == "exploit" and loser.exploited_from == "m0"
    assert loser.explored == "block_lanes->512"
    assert loser.recommendation_source == "rule"
    assert loser.candidate.params["block_lanes"] == 512
    assert loser.candidate.params["block_rows"] == 64   # exploited tiling


def test_evolve_ignores_recommendation_outside_space():
    members = _members([{"block_rows": 64, "block_lanes": 2048},
                        {"block_rows": 1, "block_lanes": 128}])
    results = [_res(speedup=2.0), _res(correct=False)]
    rec = Recommendation(text="bogus", param="block_lanes", value=7,
                         source="llm")
    nxt = pop.evolve(members, results, seed=0, generation=0,
                     recommendations={"m0": rec})
    assert nxt[1].recommendation_source is None   # fell back to mutation
    assert cand_mod.in_space(nxt[1].candidate)


# ---------------------------------------------------------------------------
# run_workload dispatch + end-to-end search
# ---------------------------------------------------------------------------


def test_run_workload_dispatches_on_search():
    wl = _tiny_workload()
    out = run_workload(wl, LoopConfig(search="pbt", population=2,
                                      generations=1))
    assert isinstance(out, pop.PBTOutcome)
    with pytest.raises(ValueError, match="unknown search"):
        run_workload(wl, LoopConfig(search="genetic"))
    with pytest.raises(ValueError, match="population"):
        run_workload(wl, LoopConfig(search="pbt", population=1))
    with pytest.raises(ValueError, match="generations"):
        run_workload(wl, LoopConfig(search="pbt", generations=0))


def test_pbt_search_end_to_end():
    wl = _tiny_workload()
    events = []
    out = pop.run_workload_pbt(
        wl, LoopConfig(search="pbt", population=3, generations=2),
        on_generation=events.append)
    assert out.best is not None and out.best.correct
    assert [ev["generation"] for ev in events] == [0, 1]
    assert out.generations == events
    # one IterationLog per generation keeps iterations_to_correct and the
    # campaign report working unchanged
    assert [log.iteration for log in out.logs] == [0, 1]
    assert all(log.phase == "pbt" for log in out.logs)
    for ev in events:
        assert ev["population"] == 3
        assert sorted(m["lineage"] for m in ev["members"]) == \
            ["m0", "m1", "m2"]
        for m in ev["members"]:
            assert cand_mod.in_space(cand_mod.Candidate(wl.op, m["params"]))
        assert set(ev["winners"]) | set(ev["losers"]) <= \
            {m["lineage"] for m in ev["members"]}
        assert not set(ev["winners"]) & set(ev["losers"])


def test_pbt_journal_deterministic_across_runs():
    wl = _tiny_workload()
    cfg = LoopConfig(search="pbt", population=3, generations=3, seed=7)
    evs1, evs2 = [], []
    pop.run_workload_pbt(wl, cfg, on_generation=evs1.append)
    pop.run_workload_pbt(wl, cfg, on_generation=evs2.append)
    assert [_strip_volatile(e) for e in evs1] == \
        [_strip_volatile(e) for e in evs2]


def test_pbt_generations_fan_across_scheduler():
    wl = _tiny_workload(rows=64, lanes=2048)
    sched = Scheduler(max_workers=3)
    out = pop.run_workload_pbt(
        wl, LoopConfig(search="pbt", population=4, generations=2),
        scheduler=sched)
    assert out.best is not None and out.best.correct
    tele = sched.telemetry()
    assert tele["running"] == 0                  # every slot reclaimed
    assert tele["completed"] >= 2                # shards actually ran


# ---------------------------------------------------------------------------
# Shared cache_key after exploit-copying: lineage attribution stays distinct
# ---------------------------------------------------------------------------


def test_shared_cache_key_keeps_lineage_attribution(monkeypatch):
    # tiny swish (8x512) has only 2 workload-legal mutations of the initial
    # candidate, so K=5 necessarily holds duplicate members — the same
    # dedupe that exploit-copying produces mid-search
    wl = _tiny_workload()
    calls = []                     # list.append is atomic across threads
    real = cand_mod.materialize

    def counting(c, **kw):
        calls.append(1)
        return real(c, **kw)

    monkeypatch.setattr(cand_mod, "materialize", counting)
    events = []
    pop.run_workload_pbt(
        wl, LoopConfig(search="pbt", population=5, generations=1),
        on_generation=events.append)
    members = events[0]["members"]
    assert [m["lineage"] for m in members] == \
        ["m0", "m1", "m2", "m3", "m4"]           # every member journaled
    keys = [m["result"]["cache_key"] for m in members]
    unique_params = {json.dumps(m["params"], sort_keys=True)
                     for m in members}
    assert len(unique_params) < len(members)      # duplicates exist...
    assert len(set(keys)) == len(unique_params)   # ...and share cache keys
    assert len(calls) == len(unique_params)       # verified once per unique
    # duplicate members share the result but keep their own attribution
    by_key = {}
    for m in members:
        by_key.setdefault(m["result"]["cache_key"], []).append(m)
    shared = [ms for ms in by_key.values() if len(ms) > 1]
    assert shared
    for ms in shared:
        assert len({m["lineage"] for m in ms}) == len(ms)
        assert len({json.dumps(m["result"], sort_keys=True)
                    for m in ms}) == 1


# ---------------------------------------------------------------------------
# EventLog: warm_cache + generation_events helpers
# ---------------------------------------------------------------------------


def _fake_generation(workload, g, keys, loop=None, io=None):
    return {"event": "generation_done", "workload": workload,
            "generation": g, "seed": g, "loop": loop, "io": io,
            "winners": [], "losers": [],
            "members": [{"lineage": f"m{i}", "params": {},
                         "result": {"state": "correct", "cache_key": k}}
                        for i, k in enumerate(keys)]}


def test_warm_cache_loads_generation_members():
    cache = VerificationCache()
    n = ev_mod.warm_cache(cache, [_fake_generation("W", 0, ["k1", "k2"]),
                                  _fake_generation("W", 1, ["k3"])])
    assert n == 3
    assert cache.get("k2") is not None and cache.get("k2").correct


def test_generation_events_latest_complete_prefix():
    evs = [_fake_generation("W", 0, ["a"]), _fake_generation("W", 1, ["b"]),
           _fake_generation("W", 2, ["c"]),
           # a second (retried) run of the same workload, killed after g1
           _fake_generation("W", 0, ["d"]), _fake_generation("W", 1, ["e"]),
           # noise: another workload, and a non-generation event
           _fake_generation("X", 0, ["f"]), {"event": "workload_done"}]
    prefix = ev_mod.generation_events(evs, "W")
    assert [e["generation"] for e in prefix] == [0, 1]
    assert prefix[0]["members"][0]["result"]["cache_key"] == "d"
    # a torn log whose head is gone (no generation 0) is not resumable
    assert ev_mod.generation_events(
        [_fake_generation("W", 1, ["x"])], "W") == []


def test_generation_events_filters_loop_and_io():
    loop_a = {"search": "pbt", "population": 4}
    loop_b = {"search": "pbt", "population": 6}
    evs = [_fake_generation("W", 0, ["a"], loop=loop_a, io=[["x", [8], "f32"]]),
           _fake_generation("W", 0, ["b"], loop=loop_b, io=[["x", [8], "f32"]])]
    got = ev_mod.generation_events(evs, "W", loop=loop_a,
                                   io=[["x", [8], "f32"]])
    assert len(got) == 1
    assert got[0]["members"][0]["result"]["cache_key"] == "a"
    assert ev_mod.generation_events(evs, "W", loop=loop_a,
                                    io=[["x", [16], "f32"]]) == []


def test_normalize_loop_backfills_search_fields():
    old = {"num_iterations": 5, "platform": "tpu_v5e"}
    n = ev_mod.normalize_loop(old)
    assert n["search"] == "lineage"
    assert n["population"] == 4 and n["generations"] == 4


# ---------------------------------------------------------------------------
# Campaign resume: restored generations re-verify NOTHING
# ---------------------------------------------------------------------------


def _replay_log(tmp_path, events, name="replayed.jsonl"):
    log = EventLog(tmp_path / name)
    for ev in events:
        log.append(ev)
    return log.path


def test_pbt_campaign_resumes_with_zero_reverification(tmp_path,
                                                       monkeypatch):
    wl = _tiny_workload()
    loop = LoopConfig(search="pbt", population=3, generations=2)
    log = tmp_path / "c.jsonl"
    res1 = Campaign([wl], CampaignConfig(loop=loop, log_path=log)).run()
    assert res1.runs[0].final.correct
    events = EventLog(log).events()
    gens = [e for e in events if e["event"] == "generation_done"]
    assert len(gens) == 2

    # simulate a campaign killed after its last generation but before the
    # terminal workload_done event was written
    kept = [e for e in events
            if e["event"] not in ("workload_done", "campaign_done")]
    log2 = _replay_log(tmp_path, kept)
    calls = []
    real = cand_mod.materialize

    def counting(c, **kw):
        calls.append(1)
        return real(c, **kw)

    monkeypatch.setattr(cand_mod, "materialize", counting)
    res2 = Campaign([wl], CampaignConfig(loop=loop, log_path=log2)).run()
    run2 = res2.runs[0]
    assert calls == []                            # ZERO re-verification
    assert res2.cache.misses == 0                 # 100% cache hits
    assert not run2.skipped and run2.final.correct
    assert run2.final.cache_key == res1.runs[0].final.cache_key
    assert run2.iters_to_correct == res1.runs[0].iters_to_correct
    # generation index, member lineages, and scores all restored
    assert run2.outcome.generations == gens


def test_pbt_campaign_resumes_mid_generation(tmp_path, monkeypatch):
    wl = _tiny_workload()
    loop = LoopConfig(search="pbt", population=3, generations=3)
    log = tmp_path / "c.jsonl"
    Campaign([wl], CampaignConfig(loop=loop, log_path=log)).run()
    gens = [e for e in EventLog(log).events()
            if e["event"] == "generation_done"]
    assert [e["generation"] for e in gens] == [0, 1, 2]

    # kill mid-generation: the in-flight generation 2 never hit the log
    log2 = _replay_log(tmp_path, gens[:2])
    calls = []
    real = cand_mod.materialize

    def counting(c, **kw):
        calls.append(1)
        return real(c, **kw)

    monkeypatch.setattr(cand_mod, "materialize", counting)
    res2 = Campaign([wl], CampaignConfig(loop=loop, log_path=log2)).run()
    gens2 = [e for e in EventLog(log2).events()
             if e["event"] == "generation_done"]
    assert [e["generation"] for e in gens2] == [0, 1, 2]
    # the continuation is exactly the generation the killed run would have
    # produced (deterministic evolve from the restored prefix)...
    assert _strip_volatile(gens2[-1]) == _strip_volatile(gens[-1])
    # ...and only that generation's unique members were verified
    unique_last = {json.dumps(m["params"], sort_keys=True)
                   for m in gens[-1]["members"]}
    assert 0 < len(calls) <= len(unique_last)
    assert res2.runs[0].final.correct


# ---------------------------------------------------------------------------
# Fault injection: a member that raises inside verify_batch
# ---------------------------------------------------------------------------


def test_faulty_member_is_isolated_scored_failed_and_excluded(monkeypatch):
    wl = _tiny_workload()
    cfg = LoopConfig(search="pbt", population=3, generations=2)
    # a clean run pins down generation 0's members (the search is
    # deterministic); poison the m1 member's candidate for the real run
    clean = []
    pop.run_workload_pbt(wl, cfg, on_generation=clean.append)
    poison = dict(clean[0]["members"][1]["params"])
    assert poison != clean[0]["members"][0]["params"]

    real_vb, real_v = pop.verify_batch, pop.verify

    def poisoned_vb(cands, *a, **kw):
        if any(c.params == poison for c in cands):
            raise RuntimeError("injected batch fault")
        return real_vb(cands, *a, **kw)

    def poisoned_v(c, *a, **kw):
        if c.params == poison:
            raise RuntimeError("injected single fault")
        return real_v(c, *a, **kw)

    monkeypatch.setattr(pop, "verify_batch", poisoned_vb)
    monkeypatch.setattr(pop, "verify", poisoned_v)

    sched = Scheduler(max_workers=3)
    events = []
    out = pop.run_workload_pbt(wl, cfg, scheduler=sched,
                               on_generation=events.append)

    # the generation completed with a full population
    ev = events[0]
    assert len(ev["members"]) == 3
    bad = [m for m in ev["members"] if m["params"] == poison]
    assert len(bad) == 1
    # the faulty member is scored failed and excluded from selection
    assert bad[0]["state"] == "runtime_error"
    assert bad[0]["score"]["tier"] == pop.FAILED_TIER
    assert "verification raised" in bad[0]["result"]["error"]
    assert bad[0]["lineage"] not in ev["winners"]
    assert bad[0]["lineage"] in ev["losers"]
    # the other members verified normally and the search still converged
    good = [m for m in ev["members"] if m["params"] != poison]
    assert all(m["state"] == "correct" for m in good)
    assert out.best is not None and out.best.correct
    # the scheduler slot the failing shard held was reclaimed
    assert sched.telemetry()["running"] == 0


# ---------------------------------------------------------------------------
# CLI + transfer-engine validation surface
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("argv", [
    ["--population", "4"],
    ["--generations", "2"],
    ["--search", "pbt", "--backend", "llm"],
    ["--search", "pbt", "--single-shot"],
    ["--search", "pbt", "--fanout", "2"],
    ["--search", "pbt", "--population", "1"],
    ["--search", "pbt", "--generations", "0"],
])
def test_cli_rejects_invalid_pbt_combinations(argv):
    with pytest.raises(SystemExit) as exc:
        main(argv)
    assert exc.value.code == 2


def test_transfer_engines_reject_pbt_with_llm_backend():
    loop = LoopConfig(search="pbt")
    with pytest.raises(ValueError, match="pbt"):
        run_transfer_sweep([], from_platform="tpu_v5e",
                           to_platform="metal_m2", loop=loop, backend="llm")
    with pytest.raises(ValueError, match="pbt"):
        run_transfer_matrix([], ["tpu_v5e", "metal_m2"], loop=loop,
                            backend="llm")


@pytest.mark.slow
def test_cli_pbt_campaign_end_to_end(tmp_path, capsys):
    rc = main(["--search", "pbt", "--level", "1", "--population", "3",
               "--generations", "2", "--workers", "2",
               "--log", str(tmp_path / "pbt.jsonl")])
    assert rc == 0
    out = capsys.readouterr().out
    assert "campaign[" in out and "fast_1" in out
