"""Per-arch smoke tests: reduced config of the same family, one forward/train
step on CPU, asserting output shapes + no NaNs; plus prefill/decode
consistency.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduced
from repro.configs.base import ShapeConfig
from repro.models import build_model

SMOKE_TRAIN = ShapeConfig("smoke_train", 32, 2, "train")

ARCHS = list_archs()


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = reduced(get_config(arch))
            m = build_model(cfg)
            params = m.init(jax.random.PRNGKey(0))
            cache[arch] = (cfg, m, params)
        return cache[arch]
    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite(built, arch):
    cfg, m, params = built(arch)
    batch = m.make_batch(jax.random.PRNGKey(1), SMOKE_TRAIN)
    loss, metrics = jax.jit(lambda p, b: m.loss_fn(p, b))(params, batch)
    assert np.isfinite(float(loss))
    # roughly ln(vocab) at init
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < \
        2.0 * np.log(cfg.vocab_size)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_grads_nonzero_finite(built, arch):
    cfg, m, params = built(arch)
    batch = m.make_batch(jax.random.PRNGKey(2), SMOKE_TRAIN)
    grads = jax.grad(lambda p: m.loss_fn(p, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    total = sum(float(jnp.sum(jnp.abs(g))) for g in flat)
    assert np.isfinite(total) and total > 0


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(built, arch):
    """Greedy decode after prefill == teacher-forced next-token argmax."""
    cfg, m, params = built(arch)
    b, plen, cache_len = 2, 12, 32
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, plen + 1)),
                         jnp.int32)
    kw = {}
    if cfg.family == "encdec":
        kw["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.encoder.num_positions,
                                 cfg.encoder.d_model)), jnp.float32)
    if cfg.family == "vlm":
        kw["vision"] = jnp.asarray(
            rng.standard_normal((b, cfg.encoder.num_positions, cfg.d_model)),
            jnp.float32)

    logits_a, cache_a, lengths = m.prefill_fn(params, tokens[:, :plen], **kw)
    logits_b, _, _ = m.prefill_fn(params, tokens[:, :plen + 1], **kw)

    # grow cache to cache_len and take one decode step with token plen
    full_cache, _ = m.init_cache(b, cache_len, jnp.float32)

    def graft(dst, src):
        if dst.shape == src.shape:
            return src.astype(dst.dtype)
        sl = tuple(slice(0, s) for s in src.shape)
        return dst.at[sl].set(src.astype(dst.dtype))

    cache = jax.tree.map(graft, full_cache, cache_a)
    step_logits, _ = m.decode_fn(params, cache, tokens[:, plen:plen + 1],
                                 lengths)
    # decode-step logits must match the teacher-forced logits for the same
    # position (prefill over plen+1 tokens, last position)
    np.testing.assert_allclose(
        np.asarray(step_logits), np.asarray(logits_b), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_cover_params(built, arch):
    cfg, m, params = built(arch)
    specs = m.logical_specs()
    p_leaves = jax.tree.leaves(params)
    s_leaves = jax.tree.leaves(specs, is_leaf=lambda t: isinstance(t, tuple))
    assert len(p_leaves) == len(s_leaves)
    for p, s in zip(p_leaves, s_leaves):
        assert len(s) == p.ndim, (arch, p.shape, s)


def test_vlm_masks_vision_positions(built):
    cfg, m, params = built("internvl2-2b")
    batch = m.make_batch(jax.random.PRNGKey(4), SMOKE_TRAIN)
    loss, _ = m.loss_fn(params, batch)
    # loss is over text positions only; still ~ln(V)
    assert 0.5 * np.log(cfg.vocab_size) < float(loss)


def test_moe_router_balance_loss(built):
    cfg, m, params = built("qwen2-moe-a2.7b")
    batch = m.make_batch(jax.random.PRNGKey(5), SMOKE_TRAIN)
    _, metrics = m.loss_fn(params, batch)
    assert float(metrics["aux"]) > 0.0


def test_rwkv_decode_matches_train_forward(built):
    """State-based decode must track the parallel forward exactly."""
    cfg, m, params = built("rwkv6-7b")
    rng = np.random.default_rng(7)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 9)), jnp.int32)
    logits_pref, cache, lengths = m.prefill_fn(params, toks[:, :8])
    logits_full, _, _ = m.prefill_fn(params, toks)
    step_logits, _ = m.decode_fn(params, cache, toks[:, 8:9], lengths)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(logits_full), rtol=2e-3, atol=2e-3)
