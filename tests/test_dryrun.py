"""Dry-run machinery integration (debug mesh — the 512-device production
meshes are exercised by ``python -m repro.launch.dryrun``, which must own
the XLA device-count flag)."""
import jax
import pytest

from repro.launch.cells import lower_cell, model_flops_total
from repro.launch.mesh import make_debug_mesh
from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.roofline import roofline_report


@pytest.mark.parametrize("shape", [
    pytest.param("train_4k", marks=pytest.mark.slow), "decode_32k"])
def test_lower_cell_whisper_debug_mesh(shape):
    mesh = make_debug_mesh(1, 1)
    compiled, lowered, aux = lower_cell("whisper-base", shape, mesh)
    mem = compiled.memory_analysis()
    assert mem.temp_size_in_bytes >= 0
    cfg = get_config("whisper-base")
    rep = roofline_report(
        arch="whisper-base", shape=shape, mesh_desc="debug", chips=1,
        cost=compiled.cost_analysis(), hlo_text=compiled.as_text(),
        model_flops_total=model_flops_total(cfg, SHAPES[shape]))
    assert rep.compute_s > 0 and rep.hlo_bytes_per_device > 0
    assert rep.dominant in ("compute", "memory", "collective")
    assert 0 < rep.useful_flops_fraction


def test_mesh_requires_device_count():
    from repro.launch.mesh import make_production_mesh
    with pytest.raises(RuntimeError):
        make_production_mesh()  # only 1 real device in tests
