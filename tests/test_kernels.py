"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode).

Every Pallas kernel is swept over shapes, dtypes, and block sizes and
asserted against kernels/ref.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba2 import ssd
from repro.kernels.matmul import matmul
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.rope import rope
from repro.kernels.rwkv6 import wkv6
from repro.kernels.softmax import softmax
from repro.kernels.swiglu import swiglu_act
from repro.kernels.swish import swish
from repro.kernels.xent import softmax_xent


def _arr(rng, shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


TOL = dict(rtol=2e-4, atol=2e-4)
TOL_BF16 = dict(rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("shape,blocks", [
    ((256, 256, 256), (128, 128, 128)),
    ((512, 128, 384), (128, 128, 128)),
    ((256, 512, 256), (64, 256, 128)),
])
def test_matmul_shapes(rng, shape, blocks):
    m, k, n = shape
    bm, bn, bk = blocks
    a, b = _arr(rng, (m, k), scale=0.1), _arr(rng, (k, n), scale=0.1)
    out = matmul(a, b, block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(out, ref.matmul(a, b), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(rng, dtype):
    a = _arr(rng, (256, 256), dtype, 0.1)
    b = _arr(rng, (256, 256), dtype, 0.1)
    out = matmul(a, b)
    tol = TOL_BF16 if dtype == jnp.bfloat16 else TOL
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref.matmul(a, b), np.float32), **tol)


@pytest.mark.parametrize("rows,d,block", [
    (256, 512, 256), (512, 128, 256),
    pytest.param(1024, 1024, 256, marks=pytest.mark.slow)])
def test_rmsnorm(rng, rows, d, block):
    x, g = _arr(rng, (rows, d)), _arr(rng, (d,), scale=0.5)
    np.testing.assert_allclose(rmsnorm(x, g, block_rows=block),
                               ref.rmsnorm(x, g), **TOL)


@pytest.mark.parametrize("shape", [(8, 512), (64, 1024), (256, 4096)])
def test_swish(rng, shape):
    x = _arr(rng, shape, scale=3.0)
    np.testing.assert_allclose(swish(x, block_rows=8, block_lanes=512),
                               ref.swish(x), **TOL)


@pytest.mark.parametrize("scale", [1.0, 60.0])
def test_softmax_stability(rng, scale):
    x = _arr(rng, (256, 512), scale=scale)
    np.testing.assert_allclose(softmax(x, block_rows=128), ref.softmax(x),
                               **TOL)


def test_swiglu(rng):
    g, u = _arr(rng, (256, 1024)), _arr(rng, (256, 1024))
    np.testing.assert_allclose(swiglu_act(g, u, block_rows=128,
                                          block_cols=512),
                               ref.swish(g) * u, **TOL)


@pytest.mark.parametrize("sq,sk,h,kv,d,causal", [
    pytest.param(256, 256, 4, 4, 64, True, marks=pytest.mark.slow),
    pytest.param(256, 256, 8, 2, 64, True,    # GQA
                 marks=pytest.mark.slow),
    (128, 256, 4, 2, 32, True),    # cross-length causal
    (256, 256, 4, 2, 64, False),
])
def test_flash_attention(rng, sq, sk, h, kv, d, causal):
    q = _arr(rng, (2, sq, h, d))
    k = _arr(rng, (2, sk, kv, d))
    v = _arr(rng, (2, sk, kv, d))
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    np.testing.assert_allclose(out, ref.attention(q, k, v, causal=causal),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtype(rng, dtype):
    q = _arr(rng, (1, 128, 4, 32), dtype)
    k = _arr(rng, (1, 128, 2, 32), dtype)
    v = _arr(rng, (1, 128, 2, 32), dtype)
    out = flash_attention(q, k, v, block_q=64, block_k=64)
    tol = TOL_BF16 if dtype == jnp.bfloat16 else dict(rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref.attention(q, k, v), np.float32),
                               **tol)


@pytest.mark.parametrize("s,kv,g,lengths", [
    (512, 2, 2, (300, 512)),
    pytest.param(512, 1, 8, (512, 100), marks=pytest.mark.slow),
    pytest.param(1024, 4, 1, (1, 1024), marks=pytest.mark.slow),
])
def test_decode_attention(rng, s, kv, g, lengths):
    h = kv * g
    q = _arr(rng, (2, 1, h, 64))
    kc = _arr(rng, (2, s, kv, 64))
    vc = _arr(rng, (2, s, kv, 64))
    lens = jnp.asarray(lengths, jnp.int32)
    out = decode_attention(q, kc, vc, lens, block_k=128)
    np.testing.assert_allclose(out, ref.decode_attention(q, kc, vc, lens),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("t,h,d,chunk", [
    (64, 2, 16, 16),
    pytest.param(128, 1, 32, 64, marks=pytest.mark.slow),
    (64, 4, 8, 64)])
def test_wkv6(rng, t, h, d, chunk):
    r = _arr(rng, (2, t, h, d))
    k = _arr(rng, (2, t, h, d))
    v = _arr(rng, (2, t, h, d))
    w = jnp.asarray(rng.uniform(0.2, 0.99, (2, t, h, d)), jnp.float32)
    u = _arr(rng, (h, d))
    out = wkv6(r, k, v, w, u, chunk=chunk)
    exp, _ = ref.wkv6(r, k, v, w, u)
    np.testing.assert_allclose(out, exp, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("t,h,p,n,chunk", [(64, 2, 16, 8, 16),
                                           (128, 1, 32, 16, 32)])
def test_ssd(rng, t, h, p, n, chunk):
    x = _arr(rng, (2, t, h, p))
    a = jnp.asarray(rng.uniform(0.5, 0.99, (2, t, h)), jnp.float32)
    b = _arr(rng, (2, t, h, n))
    c = _arr(rng, (2, t, h, n))
    out = ssd(x, a, b, c, chunk=chunk)
    exp, _ = ref.ssd(x, a, b, c)
    np.testing.assert_allclose(out, exp, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("s,d,theta", [
    (256, 64, 1e4),
    pytest.param(512, 128, 5e5, marks=pytest.mark.slow)])
def test_rope(rng, s, d, theta):
    x = _arr(rng, (2, s, 4, d))
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (2, s))
    np.testing.assert_allclose(rope(x, pos, theta=theta, block_s=128),
                               ref.rope(x, pos, theta), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("t,v,scale", [
    (128, 2048, 1.0),
    pytest.param(256, 8192, 50.0, marks=pytest.mark.slow)])
def test_xent(rng, t, v, scale):
    logits = _arr(rng, (t, v), scale=scale)
    labels = jnp.asarray(rng.integers(0, v, (t,)), jnp.int32)
    out = softmax_xent(logits, labels, block_t=64, block_v=512)
    np.testing.assert_allclose(out, ref.softmax_xent(logits, labels),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.slow
def test_attention_grad_matches_reference(rng):
    """Pallas forward + recompute-backward == oracle gradients."""
    from repro.kernels import ops
    q = _arr(rng, (1, 128, 4, 32))
    k = _arr(rng, (1, 128, 2, 32))
    v = _arr(rng, (1, 128, 2, 32))
    gp = jax.grad(lambda q: jnp.sum(
        ops.attention(q, k, v, impl="pallas") ** 2))(q)
    gr = jax.grad(lambda q: jnp.sum(ref.attention(q, k, v) ** 2))(q)
    np.testing.assert_allclose(gp, gr, rtol=5e-3, atol=5e-3)
