"""Substrate tests: optimizer, schedules, data pipeline, checkpointing,
trainer (learning + restart), fault tolerance, serving engine."""
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data import DataConfig, TokenPipeline
from repro.models import build_model
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         cosine_schedule, linear_warmup)
from repro.serve import Engine, ServeConfig
from repro.serve.engine import Request
from repro.train import (Trainer, TrainConfig, latest_step,
                         restore_checkpoint, save_checkpoint)
from repro.train.fault import (RetryPolicy, StepWatchdog, WatchdogConfig,
                               run_with_retry)


def _patterned(step, batch=4, seq=32, vocab=64):
    t = (np.arange(seq + 1)[None] + step) % vocab
    return {"tokens": np.tile(t[:, :-1], (batch, 1)).astype(np.int32),
            "labels": np.tile(t[:, 1:], (batch, 1)).astype(np.int32)}


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced(get_config("starcoder2-7b"))
    return cfg, build_model(cfg)


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def test_adamw_decreases_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    cfg = AdamWConfig(weight_decay=0.0)
    state = adamw_init(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, grads, state, cfg,
                                        jnp.asarray(0.05))
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.2


def test_grad_clipping_caps_norm():
    params = {"w": jnp.zeros(4)}
    cfg = AdamWConfig(clip_norm=1.0, weight_decay=0.0)
    state = adamw_init(params, cfg)
    _, _, metrics = adamw_update(params, {"w": jnp.full(4, 100.0)}, state,
                                 cfg, jnp.asarray(1e-3))
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_schedules():
    s = jnp.asarray(0)
    assert float(linear_warmup(s, 1.0, 10)) == pytest.approx(0.1)
    end = float(cosine_schedule(jnp.asarray(999), 1.0, 10, 1000))
    assert 0.09 < end < 0.12  # decays to ~min_ratio


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_resumable():
    dc = DataConfig(seq_len=16, global_batch=4, vocab_size=100, seed=7)
    p1 = TokenPipeline(dc)
    batches = [next(p1) for _ in range(5)]
    p2 = TokenPipeline(dc)
    p2.load_state_dict({"step": 3})
    np.testing.assert_array_equal(next(p2)["tokens"], batches[3]["tokens"])


def test_pipeline_host_sharding_partitions_batch():
    full = TokenPipeline(DataConfig(seq_len=8, global_batch=4, vocab_size=50,
                                    num_hosts=1, host_id=0)).batch_at(0)
    parts = [TokenPipeline(DataConfig(seq_len=8, global_batch=4,
                                      vocab_size=50, num_hosts=2, host_id=h)
                           ).batch_at(0) for h in range(2)]
    assert parts[0]["tokens"].shape == (2, 8)
    assert full["tokens"].shape == (4, 8)
    # different hosts generate different examples
    assert not np.array_equal(parts[0]["tokens"], parts[1]["tokens"])


def test_pipeline_memmap_source(tmp_path):
    tokens = (np.arange(1000) % 97).astype(np.uint16)
    f = tmp_path / "toks.bin"
    tokens.tofile(f)
    p = TokenPipeline(DataConfig(seq_len=8, global_batch=2, vocab_size=97,
                                 source="memmap", path=str(f)))
    b = next(p)
    assert b["tokens"].shape == (2, 8)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "step": jnp.asarray(9)}
    save_checkpoint(tmp_path, 9, state)
    assert latest_step(tmp_path) == 9
    restored = restore_checkpoint(tmp_path, 9, state)
    np.testing.assert_array_equal(restored["params"]["w"],
                                  state["params"]["w"])
    assert not list(Path(tmp_path).glob(".tmp*"))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_checkpoint(tmp_path, 1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        restore_checkpoint(tmp_path, 1, {"w": jnp.zeros((3, 3))})


# ---------------------------------------------------------------------------
# Trainer
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_trainer_learns_and_restores(small_model, tmp_path):
    cfg, model = small_model
    tc = TrainConfig(peak_lr=1e-2, warmup_steps=2, total_steps=40,
                     microbatches=2, ckpt_dir=str(tmp_path), ckpt_every=5)
    tr = Trainer(model, tc)
    losses = [tr.train_step(_patterned(i, vocab=cfg.vocab_size))["loss"]
              for i in range(10)]
    assert losses[-1] < losses[0] * 0.8
    tr2 = Trainer(model, tc)
    assert tr2.restore_if_available()
    assert tr2.step_num == 10
    m = tr2.train_step(_patterned(10, vocab=cfg.vocab_size))
    assert m["loss"] < losses[0]


@pytest.mark.slow
def test_trainer_grad_compression_still_learns(small_model):
    cfg, model = small_model
    tc = TrainConfig(peak_lr=1e-2, warmup_steps=2, total_steps=40,
                     grad_compression=True)
    tr = Trainer(model, tc)
    losses = [tr.train_step(_patterned(i, vocab=cfg.vocab_size))["loss"]
              for i in range(8)]
    assert losses[-1] < losses[0]


@pytest.mark.slow
def test_microbatch_equivalence(small_model):
    """ga=2 over 2x batch == single step over the same concatenated batch."""
    cfg, model = small_model
    from repro.train.trainer import make_train_step
    from repro.optim import adamw_init
    batch = _patterned(0, batch=4, vocab=cfg.vocab_size)
    params = model.init(jax.random.PRNGKey(0))
    tc1 = TrainConfig(microbatches=1)
    tc2 = TrainConfig(microbatches=2)
    s1 = make_train_step(model, tc1)
    s2 = make_train_step(model, tc2)
    p1, _, m1 = jax.jit(s1)(params, adamw_init(params, tc1.adamw), batch)
    p2, _, m2 = jax.jit(s2)(params, adamw_init(params, tc2.adamw), batch)
    # same data -> same loss and nearly identical update
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 5e-5


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------

def test_watchdog_flags_stragglers():
    wd = StepWatchdog(WatchdogConfig(straggler_factor=2.0, window=16,
                                     trigger=3))
    for _ in range(10):
        assert wd.record(1.0) is None
    assert wd.record(5.0) == "straggler"
    assert wd.record(5.0) == "straggler"
    assert wd.record(5.0) == "relayout"


def test_run_with_retry_restores():
    calls = {"n": 0}

    def failing_step():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("node lost")
        return "ok"

    def restore():
        return failing_step

    out = run_with_retry(failing_step, restore,
                         RetryPolicy(max_retries=5, backoff_s=0.0))
    assert out == "ok" and calls["n"] == 3


def test_run_with_retry_exhausts():
    def always_fail():
        raise RuntimeError("dead")

    with pytest.raises(RuntimeError):
        run_with_retry(always_fail, lambda: always_fail,
                       RetryPolicy(max_retries=2, backoff_s=0.0))


# ---------------------------------------------------------------------------
# Serving engine
# ---------------------------------------------------------------------------

def test_engine_continuous_batching(small_model):
    cfg, model = small_model
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, ServeConfig(max_batch=2, max_seq=64))
    rng = np.random.default_rng(0)
    for rid in range(4):  # more requests than slots
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab_size, 5),
                           max_new_tokens=3 + rid))
    out = eng.run()
    assert set(out) == {0, 1, 2, 3}
    for rid in out:
        assert len(out[rid]) == 3 + rid


def test_engine_greedy_matches_prefill(small_model):
    """First generated token == argmax of prefill logits."""
    cfg, model = small_model
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.arange(6)
    logits, _, _ = model.prefill_fn(params,
                                    jnp.asarray(prompt, jnp.int32)[None])
    expected = int(jnp.argmax(logits[0]))
    eng = Engine(model, params, ServeConfig(max_batch=1, max_seq=32))
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=2))
    out = eng.run()
    assert out[0][0] == expected


def test_async_checkpoint(small_model, tmp_path):
    cfg, model = small_model
    tc = TrainConfig(peak_lr=1e-3, warmup_steps=1, total_steps=10,
                     ckpt_dir=str(tmp_path), ckpt_every=2, async_ckpt=True)
    tr = Trainer(model, tc)
    for i in range(4):
        tr.train_step(_patterned(i, vocab=cfg.vocab_size))
    tr.wait_for_checkpoint()
    assert latest_step(tmp_path) == 4
    tr2 = Trainer(model, tc)
    assert tr2.restore_if_available()
    assert tr2.step_num == 4
