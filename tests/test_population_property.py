"""Property-based tests (hypothesis) on population-search invariants:
size/lineage preservation, monotone truncation selection, space-legality
of every exploited/explored member, and journal determinism under a
fixed seed (what makes PBT record/replay and resume work)."""
import json

import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not vendored; property tests skipped")
from hypothesis import given, settings, strategies as st

from repro.campaign import population as pop
from repro.core import LoopConfig
from repro.core import candidates as cand_mod
from repro.core.states import EvalResult, ExecutionState
from repro.core.workload import Workload, randn
from repro.platforms import available_platforms

_OPS = sorted(cand_mod.SPACES)
_PLATFORMS = available_platforms()


@st.composite
def _population(draw, min_size=2, max_size=8):
    """(op, platform, members, results): K members with params drawn from
    the platform-legal space and fabricated evaluation results."""
    op = draw(st.sampled_from(_OPS))
    platform = draw(st.sampled_from(_PLATFORMS))
    space = cand_mod.space_for(op, platform)
    k = draw(st.integers(min_size, max_size))
    members, results = [], []
    for i in range(k):
        params = {key: draw(st.sampled_from(choices))
                  for key, choices in space.items()}
        members.append(pop.Member(f"m{i}", cand_mod.Candidate(op, params)))
        correct = draw(st.booleans())
        if correct:
            t = draw(st.floats(1e-6, 10.0, allow_nan=False))
            speedup = draw(st.floats(0.1, 5.0, allow_nan=False))
            results.append(EvalResult(ExecutionState.CORRECT,
                                      model_time_s=t,
                                      baseline_model_time_s=speedup * t))
        else:
            results.append(EvalResult(ExecutionState.NUMERIC_MISMATCH,
                                      error="mismatch"))
    return op, platform, members, results


@settings(max_examples=60, deadline=None)
@given(_population(), st.integers(0, 2 ** 31 - 1), st.integers(0, 16))
def test_evolve_preserves_population_size_and_lineages(drawn, seed, gen):
    op, platform, members, results = drawn
    nxt = pop.evolve(members, results, platform=platform, seed=seed,
                     generation=gen)
    assert len(nxt) == len(members)
    assert [m.lineage for m in nxt] == [m.lineage for m in members]


@settings(max_examples=60, deadline=None)
@given(_population(), st.integers(0, 2 ** 31 - 1), st.integers(0, 16))
def test_evolved_members_stay_space_legal(drawn, seed, gen):
    op, platform, members, results = drawn
    for m in pop.evolve(members, results, platform=platform, seed=seed,
                        generation=gen):
        assert cand_mod.in_space(m.candidate, platform)


@settings(max_examples=60, deadline=None)
@given(_population(), st.integers(0, 2 ** 31 - 1), st.integers(0, 16))
def test_evolve_is_deterministic_in_seed_and_generation(drawn, seed, gen):
    op, platform, members, results = drawn
    a = pop.evolve(members, results, platform=platform, seed=seed,
                   generation=gen)
    b = pop.evolve(members, results, platform=platform, seed=seed,
                   generation=gen)
    assert a == b


@settings(max_examples=100, deadline=None)
@given(st.lists(
    st.one_of(
        st.tuples(st.just(True), st.floats(0.1, 5.0, allow_nan=False),
                  st.floats(1e-6, 10.0, allow_nan=False)),
        st.tuples(st.just(False), st.just(0.0), st.just(0.0))),
    min_size=0, max_size=16))
def test_truncation_selection_is_monotone_and_disjoint(items):
    results = [EvalResult(ExecutionState.CORRECT, model_time_s=t,
                          baseline_model_time_s=sp * t) if ok
               else EvalResult(ExecutionState.NUMERIC_MISMATCH, error="x")
               for ok, sp, t in items]
    scores = [pop.member_score(r) for r in results]
    winners, losers = pop.truncation_split(scores)
    assert not set(winners) & set(losers)
    assert set(winners) | set(losers) <= set(range(len(scores)))
    for w in winners:
        assert scores[w][0] < pop.FAILED_TIER    # failures never win
        for l in losers:
            assert scores[w] <= scores[l]        # monotone in score
    # every failed member is a loser (nothing worth keeping)
    for i, s in enumerate(scores):
        if len(scores) >= 2 and s[0] >= pop.FAILED_TIER and i not in winners:
            assert i in losers


def _tiny_workload():
    from repro.kernels import ref
    return Workload(
        name="P1/swish", level=1, op="swish",
        ref_fn=lambda x: ref.swish(x),
        input_fn=lambda rng: {"x": randn(rng, (8, 512))},
        input_shapes={"x": (8, 512)})


def _strip_volatile(ev):
    ev = json.loads(json.dumps(ev))
    for m in ev["members"]:
        m["result"].pop("wall_time_s", None)
        (m["result"].get("profile") or {}).pop("phase_s", None)
    return ev


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2 ** 16), st.integers(2, 4))
def test_identical_seeds_produce_identical_generation_journals(seed, k):
    wl = _tiny_workload()
    cfg = LoopConfig(search="pbt", population=k, generations=2, seed=seed)
    evs1, evs2 = [], []
    pop.run_workload_pbt(wl, cfg, on_generation=evs1.append)
    pop.run_workload_pbt(wl, cfg, on_generation=evs2.append)
    assert [_strip_volatile(e) for e in evs1] == \
        [_strip_volatile(e) for e in evs2]
    for ev in evs1:
        assert ev["population"] == k
        assert [m["lineage"] for m in ev["members"]] == \
            [f"m{i}" for i in range(k)]
