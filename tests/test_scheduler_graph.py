"""Dependency-aware scheduler (DESIGN.md §2): submit/wait handles, after=
edges, the re-entrant global worker budget, cancellation stamping, and the
process-isolation mode (timeout actually kills a hung job)."""
import os
import threading
import time

import pytest

from repro.campaign.scheduler import Scheduler


# ---------------------------------------------------------------------------
# submit / wait / after
# ---------------------------------------------------------------------------


def test_submit_wait_returns_values_and_isolates_errors():
    sched = Scheduler(max_workers=2)
    ok = sched.submit("ok", lambda: 41)
    boom = sched.submit("boom", lambda: (_ for _ in ()).throw(
        RuntimeError("exploded")))
    results = {r.name: r for r in sched.wait([ok, boom])}
    assert results["ok"].ok and results["ok"].value == 41
    assert not results["boom"].ok
    assert "RuntimeError: exploded" in results["boom"].error
    assert results["ok"].started_at is not None
    assert results["ok"].finished_at >= results["ok"].started_at


def test_dependent_job_starts_only_after_all_dependencies():
    sched = Scheduler(max_workers=4)
    a = sched.submit("a", lambda: time.sleep(0.15) or "a")
    b = sched.submit("b", lambda: time.sleep(0.05) or "b")
    c = sched.submit("c", lambda: "c", after=(a, b))
    sched.wait([a, b, c])
    assert c.started_at >= a.finished_at
    assert c.started_at >= b.finished_at


def test_dependent_starts_as_soon_as_its_deps_resolve_not_after_all_jobs():
    """The matrix requirement: a warm leg gated on two fast bases must run
    while an unrelated slow base is still executing."""
    sched = Scheduler(max_workers=4)
    slow_gate = threading.Event()
    fast_a = sched.submit("fast_a", lambda: "a")
    fast_b = sched.submit("fast_b", lambda: "b")
    slow = sched.submit("slow", lambda: slow_gate.wait(10.0))
    dep_done = threading.Event()
    dep = sched.submit("dep", dep_done.set, after=(fast_a, fast_b))
    # the dependent must complete while 'slow' is still running
    assert dep_done.wait(5.0)
    assert not slow.done.is_set()
    slow_gate.set()
    sched.wait([fast_a, fast_b, slow, dep])


def test_dependency_failure_is_visible_to_dependent_not_fatal():
    """after= edges are ordering only: the dependent runs and reads the
    dependency's error off the handle (how the matrix attributes failed
    bases)."""
    sched = Scheduler(max_workers=2)
    bad = sched.submit("bad", lambda: (_ for _ in ()).throw(
        ValueError("base died")))
    seen = {}

    def dependent():
        seen["dep_error"] = bad.error
        return "ran"

    dep = sched.submit("dep", dependent, after=(bad,))
    results = {r.name: r for r in sched.wait([bad, dep])}
    assert not results["bad"].ok
    assert results["dep"].ok and results["dep"].value == "ran"
    assert "base died" in seen["dep_error"]


def test_hung_dependency_does_not_strand_dependents():
    """Regression: in thread mode a timed-out dependency's done event used
    to never fire, so a job gated on it (and any wait() over the graph)
    deadlocked. The dependency must resolve as a timeout failure that the
    dependent can observe and react to."""
    sched = Scheduler(max_workers=2, timeout_s=0.3)
    gate = threading.Event()
    hung = sched.submit("hung", lambda: gate.wait(60.0))
    seen = {}

    def dependent():
        seen["dep_error"] = hung.error
        return "ran"

    dep = sched.submit("dep", dependent, after=(hung,))
    t0 = time.time()
    results = {r.name: r for r in sched.wait([hung, dep])}
    gate.set()
    assert time.time() - t0 < 10.0          # resolved, not deadlocked
    assert not results["hung"].ok and "timeout" in results["hung"].error
    assert results["dep"].ok and results["dep"].value == "ran"
    assert "timeout" in seen["dep_error"]


def test_run_returns_results_in_submission_order():
    sched = Scheduler(max_workers=4)
    results = sched.run([(f"j{i}", (lambda i=i: i)) for i in range(8)])
    assert [r.name for r in results] == [f"j{i}" for i in range(8)]
    assert [r.value for r in results] == list(range(8))


# ---------------------------------------------------------------------------
# Global worker budget + re-entrancy
# ---------------------------------------------------------------------------


def test_nested_fanout_is_bounded_and_deadlock_free():
    """Jobs that fan sub-jobs onto their own scheduler: leaf concurrency
    never exceeds max_workers (the budget is global, and waiting parents
    yield their slot) and everything completes."""
    sched = Scheduler(max_workers=2)
    lock = threading.Lock()
    state = {"running": 0, "peak": 0}

    def leaf():
        with lock:
            state["running"] += 1
            state["peak"] = max(state["peak"], state["running"])
        time.sleep(0.03)
        with lock:
            state["running"] -= 1
        return 1

    def outer():
        return sum(r.value
                   for r in sched.run([(f"leaf", leaf) for _ in range(3)]))

    results = sched.run([(f"outer{i}", outer) for i in range(4)])
    assert [r.value for r in results] == [3, 3, 3, 3]
    assert state["peak"] <= 2
    assert sched.telemetry()["completed"] == 16


def test_concurrent_run_calls_share_one_budget():
    """Two threads driving the same scheduler get max_workers slots total,
    not max_workers each — the matrix's shared workload pool contract."""
    sched = Scheduler(max_workers=2)
    lock = threading.Lock()
    state = {"running": 0, "peak": 0}

    def leaf():
        with lock:
            state["running"] += 1
            state["peak"] = max(state["peak"], state["running"])
        time.sleep(0.03)
        with lock:
            state["running"] -= 1

    threads = [threading.Thread(
        target=lambda: sched.run([("l", leaf) for _ in range(4)]))
        for _ in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(30.0)
    assert state["peak"] <= 2
    assert sched.telemetry()["completed"] == 12


def test_telemetry_tracks_peak_concurrency():
    sched = Scheduler(max_workers=3)
    gate = threading.Event()
    jobs = [sched.submit(f"j{i}", lambda: gate.wait(5.0)) for i in range(3)]
    deadline = time.time() + 5.0
    while sched.telemetry()["running"] < 3 and time.time() < deadline:
        time.sleep(0.01)
    assert sched.telemetry()["running"] == 3
    gate.set()
    sched.wait(jobs)
    tele = sched.telemetry()
    assert tele["peak_concurrent"] == 3 and tele["completed"] == 3


# ---------------------------------------------------------------------------
# Cancellation stamping (every resolution path agrees)
# ---------------------------------------------------------------------------


def test_try_cancel_stamps_error_on_generic_wait_path():
    """A job cancelled while queued must resolve ok=False through the
    plain done.wait() path too — without the stamp it came back as
    ok=True, value=None."""
    sched = Scheduler(max_workers=1)          # no timeout: generic path
    gate = threading.Event()
    blocker = sched.submit("blocker", lambda: gate.wait(10.0))
    queued = sched.submit("queued", lambda: 99)
    time.sleep(0.05)
    assert queued.try_cancel()
    res = sched.wait([queued])[0]
    assert not res.ok
    assert res.error == "cancelled"
    assert res.value is None
    gate.set()
    assert sched.wait([blocker])[0].ok
    # ... and a cancelled job never runs, even once a slot frees up
    time.sleep(0.3)
    assert queued.value is None


def test_try_cancel_refuses_started_job():
    sched = Scheduler(max_workers=1)
    gate = threading.Event()
    running = sched.submit("running", lambda: gate.wait(10.0) and "done")
    deadline = time.time() + 5.0
    while running.started_at is None and time.time() < deadline:
        time.sleep(0.01)
    assert not running.try_cancel()
    gate.set()
    assert sched.wait([running])[0].ok


# ---------------------------------------------------------------------------
# Thread-mode watchdog deadline
# ---------------------------------------------------------------------------


def test_thread_mode_watchdog_resolves_unobserved_hang():
    """The per-job watchdog stamps the timeout even when NOBODY awaits the
    job: a fire-and-wait-later pattern (LLM matrix legs) must see the job
    resolve at the deadline, not whenever a waiter happens to look."""
    sched = Scheduler(max_workers=1, timeout_s=0.3)
    release = threading.Event()
    job = sched.submit("hang", lambda: release.wait(10.0))
    try:
        # plain done.wait(), never sched.wait()/_await — only the watchdog
        # can fire here
        assert job.done.wait(timeout=5.0)
        assert job.error is not None and job.error.startswith("timeout")
        assert "abandoned" in job.error
    finally:
        release.set()


def test_late_finish_does_not_resurrect_timed_out_job():
    sched = Scheduler(max_workers=1, timeout_s=0.2)
    release = threading.Event()
    job = sched.submit("hang", lambda: release.wait(10.0) and "late value")
    assert job.done.wait(timeout=5.0)
    release.set()                       # let the abandoned worker finish
    time.sleep(0.3)
    res = sched.wait([job])[0]
    assert not res.ok and "timeout" in res.error
    # ... and the freed slot serves the next job normally
    assert sched.wait([sched.submit("next", lambda: 7)])[0].value == 7


# ---------------------------------------------------------------------------
# Process isolation
# ---------------------------------------------------------------------------


def test_process_isolation_returns_values_and_isolates_errors():
    sched = Scheduler(max_workers=2, isolation="process")
    results = {r.name: r for r in sched.run([
        ("ok", lambda: {"answer": 42}),
        ("boom", lambda: (_ for _ in ()).throw(ValueError("child died"))),
    ])}
    assert results["ok"].ok and results["ok"].value == {"answer": 42}
    assert not results["boom"].ok
    assert "ValueError: child died" in results["boom"].error


def test_process_isolation_timeout_kills_hung_job(tmp_path):
    """The point of process mode: a timed-out job is SIGKILL-ed, not
    abandoned — the hung worker is genuinely gone afterwards."""
    pid_file = tmp_path / "hung.pid"

    def hang():
        pid_file.write_text(str(os.getpid()))
        time.sleep(120)

    sched = Scheduler(max_workers=2, timeout_s=1.0, isolation="process")
    results = {r.name: r for r in sched.run([
        ("hang", hang), ("ok", lambda: 1)])}
    assert results["ok"].ok
    assert not results["hang"].ok
    assert "killed" in results["hang"].error
    pid = int(pid_file.read_text())
    deadline = time.time() + 10.0
    while time.time() < deadline:
        try:
            os.kill(pid, 0)
        except ProcessLookupError:
            break
        time.sleep(0.1)
    else:
        pytest.fail(f"hung child pid={pid} still alive after timeout kill")


def test_process_isolation_slot_freed_after_kill():
    """Unlike an abandoned thread, a killed child gives its slot back: a
    1-wide pool survives a hung job and still runs the next one."""
    sched = Scheduler(max_workers=1, timeout_s=0.5, isolation="process")
    results = sched.run([("hang", lambda: time.sleep(60)),
                         ("next", lambda: "ran")])
    assert not results[0].ok and "killed" in results[0].error
    assert results[1].ok and results[1].value == "ran"


def test_process_isolation_unpicklable_result_reported():
    sched = Scheduler(max_workers=1, isolation="process")
    res = sched.run([("lock", lambda: threading.Lock())])[0]
    assert not res.ok
    assert "not picklable" in res.error


def test_invalid_isolation_mode_rejected():
    with pytest.raises(ValueError, match="isolation"):
        Scheduler(isolation="fiber")
