"""Acceptance lane for the synthesis-as-a-service daemon (DESIGN.md §12).

Every daemon test here runs over a REAL loopback socket — the daemon is
started on an ephemeral port and spoken to through
``tools/kforge_client.py`` (or a raw socket, for the fault-injection
cases that need to send garbage). Structure:

* acceptance: health, synthesis round-trip, memo dedupe, concurrent
  multi-tenant dedupe with per-tenant attribution, resume-safe journal,
  graceful-shutdown drain;
* fault injection: malformed JSON, unknown fields/workloads, client
  disconnect mid-request, worker death mid-job (slot reclaimed, daemon
  stays up), deadline-exceeded;
* units: PreforkPool and TenantFairLimiter in isolation (the hypothesis
  property lane for the limiter lives in test_service_property.py);
* the ROADMAP bugfix regression: LLM-backed requests in thread-mode
  workers with per-tenant ``llm_usage`` attribution under record→replay.
"""
import json
import os
import signal
import socket
import sys
import threading
import time
from pathlib import Path

import pytest

from repro.service import PreforkPool, TenantFairLimiter
from repro.service.daemon import (ServiceConfig, ServiceError,
                                  SynthesisService)

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tools"))
from kforge_client import ServiceClient  # noqa: E402


@pytest.fixture
def daemon(tmp_path):
    """A running daemon on an ephemeral loopback port + bound client;
    always stopped (drained) at teardown."""
    started = []

    def start(**cfg_kwargs):
        pool = cfg_kwargs.pop("pool", None)
        cfg_kwargs.setdefault("port", 0)
        cfg_kwargs.setdefault("workers", 4)
        cfg_kwargs.setdefault("log_path", tmp_path / "service.jsonl")
        svc = SynthesisService(ServiceConfig(**cfg_kwargs), pool=pool)
        svc.start()
        started.append(svc)
        return svc, ServiceClient(port=svc.port)

    yield start
    for svc in started:
        svc.stop()


# ---------------------------------------------------------------------------
# acceptance: round-trip, dedupe, attribution
# ---------------------------------------------------------------------------

def test_health_then_synthesize_then_memo(daemon):
    svc, client = daemon()
    h = client.health()
    assert h["ok"] and h["accepting"]
    assert h["requests"]["total"] == 0

    r = client.synthesize("L1/swish", iters=2, tenant="alice")
    assert r["ok"] and r["state"] == "correct"
    assert r["served_from"] == "run"
    assert r["tenant"] == "alice"
    assert r["workload"] == "L1/swish"

    # identical spec from another tenant: answered from the memo with no
    # new oracle work — the sub-ms cache-hit path (allow generous margin
    # for the HTTP round-trip itself)
    oracle_before = svc.io_cache.stats()["oracle_computes"]
    t0 = time.perf_counter()
    r2 = client.synthesize("L1/swish", iters=2, tenant="bob")
    wall = time.perf_counter() - t0
    assert r2["ok"] and r2["served_from"] == "memo"
    assert svc.io_cache.stats()["oracle_computes"] == oracle_before
    assert wall < 0.25, f"memo hit took {wall:.3f}s"

    h = client.health()
    assert h["requests"]["total"] == 2
    assert h["requests"]["deduped"] == 1
    assert h["tenants"]["alice"]["requests"] == 1
    assert h["tenants"]["bob"]["deduped"] == 1


@pytest.mark.slow
def test_concurrent_tenants_dedupe_and_attribution(daemon, tmp_path):
    """N tenants × overlapping workloads over one socket: the oracle runs
    once per unique workload, never once per request."""
    svc, client = daemon()
    tenants = ["alice", "bob", "carol"]
    workloads = ["L1/swish", "L1/softmax"]
    results = {}

    def tenant_thread(tenant):
        c = ServiceClient(port=svc.port)
        for wl in workloads:
            results[(tenant, wl)] = c.synthesize(wl, iters=2, tenant=tenant)

    threads = [threading.Thread(target=tenant_thread, args=(t,))
               for t in tenants]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    assert len(results) == len(tenants) * len(workloads)
    assert all(r["ok"] for r in results.values())
    # dedupe: 6 requests but only 2 unique specs — the oracle ran for one
    # synthesis per unique workload (a run touches a couple of seeds),
    # never once per request
    stats = svc.io_cache.stats()
    assert stats["oracle_computes"] < len(tenants) * len(workloads)
    h = client.health()
    assert h["requests"]["total"] == 6
    assert h["requests"]["deduped"] >= 4
    for tenant in tenants:
        assert h["tenants"][tenant]["requests"] == len(workloads)

    # the journal attributes every request to its tenant
    events = svc.log.events()
    done = [e for e in events if e.get("event") == "request_done"]
    assert len(done) == 6
    assert {e["tenant"] for e in done} == set(tenants)
    assert all(e["ok"] for e in done)
    # dedupe visible per-event: served_from run/coalesced/memo
    assert sum(e["served_from"] != "run" for e in done) >= 4


def test_resume_safe_journal_warms_cache(daemon, tmp_path):
    log = tmp_path / "service.jsonl"
    svc, client = daemon(log_path=log)
    r = client.synthesize("L1/swish", iters=2, tenant="alice")
    assert r["ok"]
    svc.stop()

    # a restarted daemon over the same journal pre-warms its verification
    # cache: the same request re-verifies nothing
    svc2, client2 = daemon(log_path=log)
    h = client2.health()
    assert h["warmed_cache_entries"] > 0
    hits_before = svc2.cache.stats()["hits"]
    r2 = client2.synthesize("L1/swish", iters=2, tenant="alice")
    assert r2["ok"] and r2["served_from"] == "run"  # fresh memo, warm cache
    assert svc2.cache.stats()["hits"] > hits_before


def test_graceful_shutdown_drains_inflight(daemon, tmp_path):
    svc, client = daemon()
    responses = {}

    def submit():
        responses["r"] = client.synthesize("L1/softmax", iters=3,
                                           tenant="alice")

    t = threading.Thread(target=submit)
    t.start()
    # wait until the request is actually in flight
    deadline = time.time() + 10
    while not svc._inflight and time.time() < deadline:
        time.sleep(0.01)
    assert svc._inflight, "request never became in-flight"

    out = ServiceClient(port=svc.port).shutdown()
    assert out["ok"] and out["draining"] >= 1
    t.join(timeout=120)
    assert not t.is_alive()
    # the drained request still got its full answer
    assert responses["r"]["ok"] and responses["r"]["state"] == "correct"
    svc.wait()  # stop() completes
    events = svc.log.events()
    stop_ev = [e for e in events if e.get("event") == "service_stop"]
    assert len(stop_ev) == 1 and stop_ev[0]["drained"] >= 1
    # every accepted request has a matching terminal journal entry
    n_recv = sum(e.get("event") == "request_received" for e in events)
    n_done = sum(e.get("event") == "request_done" for e in events)
    assert n_recv == n_done


def test_rejects_new_requests_while_draining(daemon):
    svc, client = daemon()
    svc.begin_shutdown()
    r = client.synthesize("L1/swish", iters=2, tenant="alice")
    assert not r["ok"] and r["error"]["kind"] == "shutting_down"


def test_report_renders_from_service_journal(daemon):
    svc, client = daemon()
    assert client.synthesize("L1/swish", iters=2, tenant="alice")["ok"]
    out = client.report()
    assert out["ok"]
    assert "level 1" in out["report"]   # the synthesis result landed
    assert "service" in out["report"]
    assert "tenant alice" in out["report"]


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------

def _raw_post(port, payload: bytes, path=b"/synthesize") -> bytes:
    with socket.create_connection(("127.0.0.1", port), timeout=10) as s:
        s.sendall(b"POST " + path + b" HTTP/1.1\r\n"
                  b"Host: localhost\r\nContent-Type: application/json\r\n"
                  b"Content-Length: " + str(len(payload)).encode()
                  + b"\r\nConnection: close\r\n\r\n" + payload)
        chunks = []
        while True:
            chunk = s.recv(65536)
            if not chunk:
                break
            chunks.append(chunk)
    return b"".join(chunks)


def test_malformed_json_is_structured_400(daemon):
    svc, client = daemon()
    raw = _raw_post(svc.port, b'{"workload": "L1/swish", INVALID')
    assert b"400" in raw.split(b"\r\n", 1)[0]
    body = json.loads(raw.split(b"\r\n\r\n", 1)[1])
    assert body["error"]["kind"] == "bad_json"
    # daemon unharmed
    assert client.health()["ok"]
    assert any(e.get("kind") == "bad_json" for e in svc.log.events()
               if e.get("event") == "request_error")


@pytest.mark.parametrize("spec,expect", [
    ({"workload": "L9/nope"}, "unknown workload"),
    ({"workload": "L1/swish", "platfrom": "tpu_v5e"}, "unknown request"),
    ({"workload": "L1/swish", "deadline_s": -1}, "deadline_s"),
    ({"workload": "L1/swish", "backend": "gpt"}, "backend"),
    ({"workload": "L1/swish", "isolate": True}, "no pre-forked"),
    ({"workload": "L1/swish", "backend": "llm", "search": "pbt"}, "pbt"),
    ({}, "required"),
])
def test_bad_requests_are_structured(daemon, spec, expect):
    svc, _ = daemon()
    raw = _raw_post(svc.port, json.dumps(spec).encode())
    body = json.loads(raw.split(b"\r\n\r\n", 1)[1])
    assert not body["ok"]
    assert body["error"]["kind"] == "bad_request"
    assert expect in body["error"]["message"]


def test_client_disconnect_mid_request_daemon_stays_up(daemon):
    svc, client = daemon()
    # declare a body, send half of it, vanish
    with socket.create_connection(("127.0.0.1", svc.port), timeout=10) as s:
        s.sendall(b"POST /synthesize HTTP/1.1\r\nHost: x\r\n"
                  b"Content-Type: application/json\r\n"
                  b"Content-Length: 500\r\n\r\n" + b'{"workload": "L1')
        # abortive close: RST instead of FIN, the rudest disconnect
        s.setsockopt(socket.SOL_SOCKET, socket.SO_LINGER,
                     b"\x01\x00\x00\x00\x00\x00\x00\x00")
    deadline = time.time() + 10
    while time.time() < deadline:
        events = [e for e in svc.log.events()
                  if e.get("event") == "request_error"]
        if events:
            break
        time.sleep(0.05)
    assert events, "disconnect was never journaled"
    # the daemon keeps serving
    assert client.health()["ok"]
    assert client.synthesize("L1/swish", iters=2, tenant="alice")["ok"]


def test_deadline_exceeded_is_structured_504(daemon):
    svc, client = daemon()
    r = client.synthesize("L1/softmax", iters=4, tenant="alice",
                          deadline_s=0.05)
    assert not r["ok"]
    assert r["error"]["kind"] == "deadline"
    assert "deadline" in r["error"]["message"]
    # daemon unharmed; the abandoned job finishes in the background and
    # its result lands in the memo for the next caller
    assert client.health()["ok"]
    deadline = time.time() + 120
    while svc._inflight and time.time() < deadline:
        time.sleep(0.05)
    r2 = client.synthesize("L1/softmax", iters=4, tenant="alice")
    assert r2["ok"] and r2["served_from"] == "memo"


def test_worker_death_mid_job_reclaims_slot(daemon):
    """Kill a pre-forked worker mid-job: the caller gets a structured
    ``worker_died`` error, the slot is respawned, and the daemon keeps
    serving isolate requests."""
    def handler(spec):
        if spec["loop"]["seed"] == 999:     # the doomed request
            time.sleep(120)
        return {"ok": True, "workload": spec["workload"],
                "state": "correct", "correct": True, "speedup": 1.0,
                "model_time_s": 0.001, "iterations": 1,
                "iters_to_correct": 1, "level": 1,
                "result": {"state": "correct"}, "io": []}

    pool = PreforkPool(1, handler=handler)
    svc, client = daemon(pool=pool)
    pids_before = pool.pids

    def doomed():
        return client.synthesize("L1/swish", iters=1, seed=999,
                                 isolate=True, tenant="alice")

    holder = {}
    t = threading.Thread(target=lambda: holder.update(r=doomed()))
    t.start()
    # wait for the job to reach the worker, then kill it
    deadline = time.time() + 10
    while pool.jobs == 0 and time.time() < deadline:
        time.sleep(0.01)
    time.sleep(0.2)
    os.kill(pids_before[0], signal.SIGKILL)
    t.join(timeout=30)
    assert not t.is_alive()
    r = holder["r"]
    assert not r["ok"] and r["error"]["kind"] == "worker_died"
    assert "respawned" in r["error"]["message"]

    # slot reclaimed: a fresh worker serves the next isolate request
    assert pool.respawns == 1
    assert pool.pids != pids_before
    r2 = client.synthesize("L1/swish", iters=1, isolate=True,
                           tenant="alice")
    assert r2["ok"] and r2["isolated"]
    assert client.health()["ok"]


def test_prefork_deadline_kills_worker(daemon):
    def handler(spec):
        time.sleep(120)

    pool = PreforkPool(1, handler=handler)
    svc, client = daemon(pool=pool)
    r = client.synthesize("L1/swish", iters=1, isolate=True,
                          deadline_s=0.3, tenant="alice")
    assert not r["ok"] and r["error"]["kind"] == "deadline"
    # the pool-side kill + respawn completes just after the handler's own
    # deadline response goes out; give it a moment
    deadline = time.time() + 10
    while pool.respawns == 0 and time.time() < deadline:
        time.sleep(0.05)
    assert pool.respawns == 1          # killed worker replaced
    assert client.health()["ok"]


@pytest.mark.slow
def test_prefork_isolate_e2e_real_synthesis(tmp_path):
    """The real lane, end to end through ``python -m repro.service``: the
    daemon subprocess forks its worker pool BEFORE importing jax (the
    pre-fork rule — forking from this jax-loaded pytest process instead
    would be exactly the hazard the ordering avoids), then a pre-forked
    worker imports jax inside the child and runs a real refinement loop."""
    import subprocess
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.service", "--port", "0",
         "--isolate-workers", "1", "--workers", "2",
         "--log", str(tmp_path / "svc.jsonl")],
        cwd=str(Path(__file__).resolve().parent.parent),
        env=env, stdout=subprocess.PIPE, text=True)
    try:
        line = proc.stdout.readline()       # "kforge service on http://..."
        port = int(line.split("http://")[1].split()[0].rsplit(":", 1)[1])
        client = ServiceClient(port=port)
        r = client.synthesize("L1/swish", iters=2, isolate=True,
                              tenant="alice")
        assert r["ok"] and r["state"] == "correct" and r["isolated"]
        out = client.shutdown()
        assert out["ok"]
        assert proc.wait(timeout=60) == 0   # graceful exit after drain
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=30)


# ---------------------------------------------------------------------------
# ROADMAP bugfix regression: LLM-backed requests in thread-mode workers
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_llm_tenants_get_attributed_usage_record_then_replay(daemon,
                                                             tmp_path):
    rec = str(tmp_path / "llm_session.jsonl")

    def run_pair(**cfg):
        svc, client = daemon(**cfg)
        ra = client.synthesize("L1/swish", iters=2, backend="llm",
                               tenant="alice")
        rb = client.synthesize("L1/softmax", iters=2, backend="llm",
                               tenant="bob")
        h = client.health()
        svc.stop()
        return svc, ra, rb, h

    # record leg: MockTransport behind a recorder
    _, ra, rb, h = run_pair(llm_record=rec,
                            log_path=tmp_path / "rec.jsonl")
    assert ra["ok"] and rb["ok"]
    assert ra["llm_usage"]["requests"] > 0
    assert rb["llm_usage"]["requests"] > 0

    # replay leg: zero live calls, same attribution story
    svc2, ra2, rb2, h2 = run_pair(llm_replay=rec,
                                  log_path=tmp_path / "rep.jsonl")
    assert ra2["ok"] and rb2["ok"]
    # per-tenant deltas: each tenant's spend is its own, not the fleet's
    assert ra2["llm_usage"]["requests"] > 0
    assert rb2["llm_usage"]["requests"] > 0
    ta = h2["tenants"]["alice"]["llm_usage"]
    tb = h2["tenants"]["bob"]["llm_usage"]
    assert ta["requests"] == ra2["llm_usage"]["requests"]
    assert tb["requests"] == rb2["llm_usage"]["requests"]
    # fleet meter totals both tenants
    assert h2["llm_usage"]["requests"] == \
        ta["requests"] + tb["requests"]
    # the journal carries the per-request deltas too
    done = [e for e in svc2.log.events() if
            e.get("event") == "request_done" and e.get("llm_usage")]
    assert {e["tenant"] for e in done} == {"alice", "bob"}


# ---------------------------------------------------------------------------
# PreforkPool units (no daemon)
# ---------------------------------------------------------------------------

def test_pool_roundtrip_and_close():
    pool = PreforkPool(2, handler=lambda spec: {"ok": True,
                                                "echo": spec["x"]})
    try:
        assert pool.submit({"x": 1})["echo"] == 1
        assert pool.submit({"x": 2})["echo"] == 2
        assert pool.stats()["jobs"] == 2
        assert pool.stats()["respawns"] == 0
    finally:
        pool.close()
    assert pool.submit({"x": 3})["error"]["kind"] == "pool_closed"


def test_pool_handler_exception_is_isolated():
    def handler(spec):
        raise ValueError("boom")

    pool = PreforkPool(1, handler=handler)
    try:
        r = pool.submit({})
        assert not r["ok"]
        assert r["error"]["kind"] == "worker_error"
        assert "boom" in r["error"]["message"]
        # the worker survived the exception — same pid serves again
        assert pool.respawns == 0
    finally:
        pool.close()


def test_pool_worker_death_detected_and_respawned():
    def handler(spec):
        os.kill(os.getpid(), signal.SIGKILL)

    pool = PreforkPool(1, handler=handler)
    try:
        r = pool.submit({})
        assert not r["ok"] and r["error"]["kind"] == "worker_died"
        assert pool.respawns == 1
        # reclaimed slot works (fresh worker, fresh handler state)
        pool2_pid = pool.pids[0]
        assert pool2_pid is not None
    finally:
        pool.close()


# ---------------------------------------------------------------------------
# TenantFairLimiter units (property lane in test_service_property.py)
# ---------------------------------------------------------------------------

def test_fair_limiter_unlimited_is_free():
    fair = TenantFairLimiter()
    assert fair.reserve("a") == 0.0
    assert fair.reserve("b", tokens=10_000) == 0.0


def test_fair_limiter_fleet_budget_paces_everyone():
    t = {"now": 0.0}
    fair = TenantFairLimiter(rpm=60, clock=lambda: t["now"])
    # burst allowance: the first 60 reserves are free, then 1/s pacing
    delays = [fair.reserve("a") for _ in range(61)]
    assert delays[:60] == [0.0] * 60
    assert delays[60] == pytest.approx(1.0)


def test_fair_limiter_fresh_tenant_not_starved_by_hot_one():
    t = {"now": 0.0}
    fair = TenantFairLimiter(rpm=1000, tenant_rpm=60,
                             clock=lambda: t["now"])
    # hot tenant burns far past its per-tenant slice
    hot_delay = 0.0
    for _ in range(120):
        hot_delay = fair.reserve("hot")
    assert hot_delay > 0          # the hot tenant is paying its backlog
    # a fresh tenant's bucket is full and the fleet bucket still has
    # burst room: it pays nothing, not the hot tenant's deficit
    assert fair.reserve("fresh") == 0.0


def test_fair_limiter_for_tenant_duck_type():
    t = {"now": 0.0}
    fair = TenantFairLimiter(rpm=60, clock=lambda: t["now"])
    bound = fair.for_tenant("alice")
    for _ in range(60):
        bound.reserve()
    assert bound.reserve(tokens=5) == pytest.approx(1.0)
    assert fair.stats()["fleet"]["reserved_requests"] == 61


def test_fair_limiter_stats_shape():
    fair = TenantFairLimiter(rpm=10, tenant_rpm=5)
    fair.reserve("a")
    fair.reserve("b")
    s = fair.stats()
    assert set(s["tenants"]) == {"a", "b"}
    assert s["tenant_rpm"] == 5
