"""Prompt-snapshot (golden) tests: the §3.2 synthesis AND analysis prompts,
rendered for every registered platform, are diffed against
``tests/goldens/`` so any prompt drift — template edits, platform
descriptor/example/constraint changes — shows up as a reviewable
full-prompt diff instead of silently shifting what production LLM sessions
(generation agent F or analysis agent G) are asked.

Regenerate intentionally with::

    UPDATE_GOLDENS=1 PYTHONPATH=src python -m pytest tests/test_prompts_golden.py
"""
import os
from pathlib import Path

import pytest

from repro.core import prompts
from repro.core.candidates import space_for
from repro.platforms import available_platforms, resolve_platform

GOLDEN_DIR = Path(__file__).parent / "goldens"

# Fixed, platform-independent prompt inputs: only the platform-owned fields
# (descriptor, one-shot example, constraints note) may vary across goldens.
WORKLOAD_NAME = "L1/swish"
WORKLOAD_SRC = (
    "def swish(x):\n"
    '    """Reference oracle (pure jax.numpy)."""\n'
    "    return x * jax.nn.sigmoid(x)\n")
REF_SRC = "# harvested reference kernel\n# strategy: online=True\n"
REF_PLATFORM = "gpu_sim"
PREV_SRC = "def candidate(*inputs):\n    return inputs[0]\n"
PREV_RESULT = "numeric_mismatch: max rel err 1.00e+00 > tol 1e-05"
RECOMMENDATION = "Increase block_lanes to 512 to fill the vector unit."


def render(platform_name: str) -> str:
    plat = resolve_platform(platform_name)
    return prompts.render_synthesis(
        plat.descriptor, plat.oneshot_example, WORKLOAD_SRC, WORKLOAD_NAME,
        ref_src=REF_SRC, ref_platform=REF_PLATFORM,
        prev_src=PREV_SRC, prev_result=PREV_RESULT,
        recommendation=RECOMMENDATION, constraints=plat.constraints_note)


@pytest.mark.parametrize("platform", available_platforms())
def test_synthesis_prompt_matches_golden(platform):
    golden = GOLDEN_DIR / f"synthesis_prompt_{platform}.txt"
    rendered = render(platform)
    if os.environ.get("UPDATE_GOLDENS"):
        GOLDEN_DIR.mkdir(exist_ok=True)
        golden.write_text(rendered)
    assert golden.exists(), (
        f"missing golden {golden}; generate with UPDATE_GOLDENS=1")
    assert rendered == golden.read_text(), (
        f"synthesis prompt for {platform} drifted from {golden.name}; "
        "if intentional, regenerate with UPDATE_GOLDENS=1 so review sees "
        "the diff")


# Fixed analysis-prompt inputs: one verification profile (the shape
# ``verify`` stamps on CORRECT results); only the platform descriptor and
# the platform-legal space may vary across the analysis goldens.
def analysis_profile(platform_name: str) -> dict:
    return {"op": "matmul", "platform": platform_name,
            "params": {"block_m": 64, "block_n": 128, "block_k": 512},
            "shapes": [[512, 512], [512, 512]],
            "model_time_s": 1.0e-4, "baseline_time_s": 2.0e-4,
            "flops": 2.68e8}


def render_analysis(platform_name: str) -> str:
    plat = resolve_platform(platform_name)
    return prompts.render_analysis(plat.descriptor,
                                   analysis_profile(platform_name),
                                   space_for("matmul", plat))


@pytest.mark.parametrize("platform", available_platforms())
def test_analysis_prompt_matches_golden(platform):
    golden = GOLDEN_DIR / f"analysis_prompt_{platform}.txt"
    rendered = render_analysis(platform)
    if os.environ.get("UPDATE_GOLDENS"):
        GOLDEN_DIR.mkdir(exist_ok=True)
        golden.write_text(rendered)
    assert golden.exists(), (
        f"missing golden {golden}; generate with UPDATE_GOLDENS=1")
    assert rendered == golden.read_text(), (
        f"analysis prompt for {platform} drifted from {golden.name}; "
        "if intentional, regenerate with UPDATE_GOLDENS=1 so review sees "
        "the diff")


def test_analysis_prompt_contract_fields_render_for_every_platform():
    """Agent G's prompt contract: the marker transports route on, the
    profile embedded as a recoverable json fence, the platform-legal
    space, and the three-line reply contract."""
    for name in available_platforms():
        p = render_analysis(name)
        assert prompts.is_analysis_prompt(p)
        assert resolve_platform(name).descriptor in p
        assert '"block_m": 64' in p                    # profile json fence
        assert "```json" in p
        for label in ("RECOMMENDATION:", "PARAM:", "VALUE:"):
            assert label in p                          # reply contract


# Training-shaped (fwd_bwd) analysis profile: the two-section roofline
# ``verify`` stamps under direction="fwd_bwd". Named so it does NOT match
# the ``analysis_prompt_*`` coverage glob below — that glob maps stems to
# platforms one-to-one.
def fwd_bwd_profile() -> dict:
    prof = analysis_profile("tpu_v5e")
    prof.update({
        "direction": "fwd_bwd",
        "fwd": {"model_time_s": 1.0e-4, "baseline_time_s": 2.0e-4,
                "flops": 2.68e8},
        "bwd": {"model_time_s": 3.0e-4, "baseline_time_s": 6.0e-4,
                "flops": 8.05e8, "max_rel_err": 1.2e-6},
        "model_time_s": 4.0e-4, "baseline_time_s": 8.0e-4,
    })
    return prof


def test_fwd_bwd_analysis_prompt_matches_golden():
    """The fwd_bwd analysis prompt renders BOTH rooflines (fwd and bwd
    sections in the profile fence) plus the training-shaped guidance note
    — and only then: the forward goldens above prove fwd prompts stayed
    byte-identical."""
    golden = GOLDEN_DIR / "fwd_bwd_analysis_prompt_tpu_v5e.txt"
    plat = resolve_platform("tpu_v5e")
    rendered = prompts.render_analysis(plat.descriptor, fwd_bwd_profile(),
                                       space_for("matmul", plat))
    if os.environ.get("UPDATE_GOLDENS"):
        GOLDEN_DIR.mkdir(exist_ok=True)
        golden.write_text(rendered)
    assert golden.exists(), (
        f"missing golden {golden}; generate with UPDATE_GOLDENS=1")
    assert rendered == golden.read_text(), (
        "fwd_bwd analysis prompt drifted; if intentional, regenerate "
        "with UPDATE_GOLDENS=1 so review sees the diff")
    assert prompts.ANALYSIS_FWD_BWD_NOTE in rendered
    assert '"fwd"' in rendered and '"bwd"' in rendered


def test_goldens_cover_exactly_the_registered_platforms():
    """A platform added without a golden (or a golden for a dropped
    platform) fails here, keeping snapshots and registry in lock-step.
    Defined LAST so a fresh UPDATE_GOLDENS=1 bless run writes every
    parametrized golden before coverage is judged."""
    for kind in ("synthesis_prompt", "analysis_prompt"):
        have = {p.stem.replace(f"{kind}_", "")
                for p in GOLDEN_DIR.glob(f"{kind}_*.txt")}
        assert have == set(available_platforms()), kind


def test_prompt_contract_fields_render_for_every_platform():
    """The per-platform contract (prompts module docstring): descriptor in
    the instruction lines, the one-shot example body, the constraints note,
    and both optional blocks."""
    for name in available_platforms():
        plat = resolve_platform(name)
        p = render(name)
        assert plat.descriptor in p
        assert plat.oneshot_example.strip() in p
        assert plat.constraints_note in p
        assert REF_SRC in p and REF_PLATFORM in p      # reference block
        assert PREV_RESULT in p and RECOMMENDATION in p  # feedback block
        assert "candidate(*inputs)" in p               # reply contract
