"""Prompt-snapshot (golden) tests: the §3.2 synthesis prompt, rendered for
every registered platform, is diffed against ``tests/goldens/`` so any
prompt drift — template edits, platform descriptor/example/constraint
changes — shows up as a reviewable full-prompt diff instead of silently
shifting what production LLM sessions are asked.

Regenerate intentionally with::

    UPDATE_GOLDENS=1 PYTHONPATH=src python -m pytest tests/test_prompts_golden.py
"""
import os
from pathlib import Path

import pytest

from repro.core import prompts
from repro.platforms import available_platforms, resolve_platform

GOLDEN_DIR = Path(__file__).parent / "goldens"

# Fixed, platform-independent prompt inputs: only the platform-owned fields
# (descriptor, one-shot example, constraints note) may vary across goldens.
WORKLOAD_NAME = "L1/swish"
WORKLOAD_SRC = (
    "def swish(x):\n"
    '    """Reference oracle (pure jax.numpy)."""\n'
    "    return x * jax.nn.sigmoid(x)\n")
REF_SRC = "# harvested reference kernel\n# strategy: online=True\n"
REF_PLATFORM = "gpu_sim"
PREV_SRC = "def candidate(*inputs):\n    return inputs[0]\n"
PREV_RESULT = "numeric_mismatch: max rel err 1.00e+00 > tol 1e-05"
RECOMMENDATION = "Increase block_lanes to 512 to fill the vector unit."


def render(platform_name: str) -> str:
    plat = resolve_platform(platform_name)
    return prompts.render_synthesis(
        plat.descriptor, plat.oneshot_example, WORKLOAD_SRC, WORKLOAD_NAME,
        ref_src=REF_SRC, ref_platform=REF_PLATFORM,
        prev_src=PREV_SRC, prev_result=PREV_RESULT,
        recommendation=RECOMMENDATION, constraints=plat.constraints_note)


@pytest.mark.parametrize("platform", available_platforms())
def test_synthesis_prompt_matches_golden(platform):
    golden = GOLDEN_DIR / f"synthesis_prompt_{platform}.txt"
    rendered = render(platform)
    if os.environ.get("UPDATE_GOLDENS"):
        GOLDEN_DIR.mkdir(exist_ok=True)
        golden.write_text(rendered)
    assert golden.exists(), (
        f"missing golden {golden}; generate with UPDATE_GOLDENS=1")
    assert rendered == golden.read_text(), (
        f"synthesis prompt for {platform} drifted from {golden.name}; "
        "if intentional, regenerate with UPDATE_GOLDENS=1 so review sees "
        "the diff")


def test_goldens_cover_exactly_the_registered_platforms():
    """A platform added without a golden (or a golden for a dropped
    platform) fails here, keeping snapshots and registry in lock-step."""
    have = {p.stem.replace("synthesis_prompt_", "")
            for p in GOLDEN_DIR.glob("synthesis_prompt_*.txt")}
    assert have == set(available_platforms())


def test_prompt_contract_fields_render_for_every_platform():
    """The per-platform contract (prompts module docstring): descriptor in
    the instruction lines, the one-shot example body, the constraints note,
    and both optional blocks."""
    for name in available_platforms():
        plat = resolve_platform(name)
        p = render(name)
        assert plat.descriptor in p
        assert plat.oneshot_example.strip() in p
        assert plat.constraints_note in p
        assert REF_SRC in p and REF_PLATFORM in p      # reference block
        assert PREV_RESULT in p and RECOMMENDATION in p  # feedback block
        assert "candidate(*inputs)" in p               # reply contract
