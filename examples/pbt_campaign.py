"""Run a population-based (PBT-style) synthesis campaign.

``LoopConfig(search="pbt")`` replaces the single-lineage refinement loop
with K candidate lineages per workload (DESIGN.md §10): each generation
evaluates all members through one batched verification, truncation-selects
by speedup tier, exploit-copies winners' tiling params into losers, and
explores via model-ranked platform-legal mutations. Every generation is
journaled to the event log, so the search is deterministic under a fixed
seed and resumable mid-generation — kill this script halfway and run it
again: completed generations replay from the log and their verifications
are 100% cache hits.

Usage::

  PYTHONPATH=src python examples/pbt_campaign.py [log.jsonl]
"""
from __future__ import annotations

import json
import sys

from repro.campaign import Campaign, CampaignConfig, VerificationCache
from repro.core import LoopConfig, kernelbench


def main() -> None:
    log_path = sys.argv[1] if len(sys.argv) > 1 else "pbt-example.jsonl"
    workloads = kernelbench.suite(1, small=True)

    cfg = CampaignConfig(
        loop=LoopConfig(search="pbt", population=4, generations=3, seed=7),
        max_workers=4,
        log_path=log_path,
        resume=True,
    )
    campaign = Campaign(workloads, cfg, cache=VerificationCache())
    result = campaign.run()

    print(f"{len(result.runs)} workloads: "
          f"{result.n_skipped} resumed from {log_path}, "
          f"{result.n_failed} failed")
    print(f"cache: {result.cache.stats()}")
    print()
    print(campaign.report_text())

    # What the journal recorded: per-generation winners and the
    # exploit/explore decisions their losers made.
    print("\ngeneration journal (first workload):")
    with open(log_path) as fh:
        events = [json.loads(line) for line in fh]
    gens = [ev for ev in events if ev.get("event") == "generation_done"
            and ev["workload"] == workloads[0].name]
    for ev in gens:
        moves = [f"{m['lineage']}<-{m['exploited_from']}"
                 f"({m['explored'] or 'copy'})"
                 for m in ev["members"] if m["origin"] == "exploit"]
        print(f"  gen {ev['generation']}: winners={ev['winners']} "
              f"moves={moves or '(none)'}")

    # Re-run the identical campaign against the same cache: every member
    # of every generation is a verification-cache hit.
    before = result.cache.misses
    Campaign(workloads, CampaignConfig(loop=cfg.loop, max_workers=4),
             cache=result.cache).run()
    print(f"\nre-run new verifications: {result.cache.misses - before} "
          "(the whole search replayed from cache)")


if __name__ == "__main__":
    main()
