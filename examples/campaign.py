"""Run a concurrent, cached, resumable synthesis campaign.

The campaign runner is how KForge evaluates fleets of workloads (paper §5):
every workload's refinement loop fans out over a worker pool, every
verification is memoized in a content-addressed cache, and every iteration
is journaled to a JSONL event log. Kill this script halfway and run it
again: finished workloads are skipped and the cache is pre-warmed from the
log, so only the unfinished work — and only its unseen candidates — runs.

Usage::

  PYTHONPATH=src python examples/campaign.py [log.jsonl]
"""
from __future__ import annotations

import sys

from repro.campaign import Campaign, CampaignConfig, VerificationCache
from repro.core import LoopConfig, kernelbench


def main() -> None:
    log_path = sys.argv[1] if len(sys.argv) > 1 else "campaign-example.jsonl"
    workloads = kernelbench.suite(small=True)

    cfg = CampaignConfig(
        loop=LoopConfig(num_iterations=5, use_profiling=True),
        max_workers=4,
        timeout_s=300.0,          # one hung workload cannot stall the fleet
        log_path=log_path,
        resume=True,
    )
    campaign = Campaign(workloads, cfg, cache=VerificationCache())
    result = campaign.run()

    print(f"{len(result.runs)} workloads: "
          f"{result.n_skipped} resumed from {log_path}, "
          f"{result.n_failed} failed")
    print(f"cache: {result.cache.stats()}")
    print()
    print(campaign.report_text())

    # Run the identical campaign again against the same cache: zero new
    # verifications (every candidate+seed is a cache hit).
    before = result.cache.misses
    Campaign(workloads, CampaignConfig(loop=cfg.loop, max_workers=4),
             cache=result.cache).run()
    print(f"\nre-run new verifications: {result.cache.misses - before} "
          "(the whole campaign replayed from cache)")


if __name__ == "__main__":
    main()
