"""Batched serving demo: continuous batching over more requests than slots,
on a reduced qwen2-MoE config (router + shared experts on the decode path).

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serve import Engine, ServeConfig
from repro.serve.engine import Request

cfg = reduced(get_config("qwen2-moe-a2.7b"))
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
engine = Engine(model, params, ServeConfig(max_batch=4, max_seq=96))

rng = np.random.default_rng(0)
n_requests = 10
for rid in range(n_requests):
    plen = int(rng.integers(4, 24))
    engine.submit(Request(rid=rid,
                          prompt=rng.integers(0, cfg.vocab_size, plen),
                          max_new_tokens=int(rng.integers(4, 12))))

t0 = time.monotonic()
done = engine.run()
wall = time.monotonic() - t0
total = sum(len(v) for v in done.values())
print(f"served {len(done)} requests / {total} tokens in {wall:.2f}s "
      f"({total / wall:.1f} tok/s) with max_batch=4 slots")
for rid in sorted(done):
    print(f"  request {rid:2d}: {len(done[rid])} tokens {done[rid][:8]}...")
