"""Run an LLM-backed synthesis campaign end to end — offline.

The full production data path (prompt -> transport -> completion -> exec ->
callable verification -> feedback), driven by the deterministic
MockTransport so it runs anywhere with zero network: each completion echoes
the workload's reference oracle as a fenced code block, exactly what the
session layer, rate limiter, and usage accounting see in production. The
CI fast lane executes this script as the LLM smoke test.

Usage::

  PYTHONPATH=src python examples/llm_campaign.py [runs-dir]

The first run records the prompt->completion session to
``<runs-dir>/llm-session.jsonl``; the second half of the script replays it
byte-for-byte with ZERO live transport calls — the same
``--record``/``--replay`` workflow the campaign CLI exposes
(``python -m repro.campaign --backend llm --replay ...``).
"""
from __future__ import annotations

import sys
from pathlib import Path

from repro.campaign import Scheduler, run_campaign
from repro.core import LoopConfig, kernelbench
from repro.llm import MockTransport, build_llm_context, format_usage


def main() -> None:
    runs = Path(sys.argv[1] if len(sys.argv) > 1 else "runs-llm")
    runs.mkdir(parents=True, exist_ok=True)
    session = runs / "llm-session.jsonl"
    workloads = kernelbench.suite(1, small=True)
    loop = LoopConfig(num_iterations=2, platform="tpu_v5e")

    # -- leg 1: record — MockTransport completions captured to JSONL --------
    # transport pinned explicitly: this script promises zero network, so a
    # stray KFORGE_LLM_ENDPOINT in the environment must not flip it (or
    # CI) onto a live billed endpoint
    ctx = build_llm_context(transport=MockTransport(), record=str(session),
                            rpm=100_000, tpm=10_000_000)
    sched = Scheduler(max_workers=4)     # sessions yield slots while pacing
    result = run_campaign(
        workloads, loop, scheduler=sched,
        agent_factory=ctx.agent_factory(platform=loop.platform,
                                        scheduler=sched),
        usage=ctx.usage, log_path=runs / "llm-campaign.jsonl")
    states = [r.state.value for r in result.finals()]
    print(f"recorded campaign: {len(result.runs)} workloads -> "
          f"{states.count('correct')} correct")
    print(f"llm usage: {format_usage(result.llm_usage)}")
    live_calls = ctx.transport.inner.calls
    print(f"session: {len(ctx.transport)} prompts recorded to {session} "
          f"({live_calls} live transport calls)")

    # -- leg 2: replay — byte-for-byte, zero live calls ---------------------
    replay_ctx = build_llm_context(replay=str(session))
    replayed = run_campaign(
        workloads, loop,
        agent_factory=replay_ctx.agent_factory(platform=loop.platform),
        usage=replay_ctx.usage)
    rep_states = [r.state.value for r in replayed.finals()]
    assert rep_states == states, (rep_states, states)
    assert replay_ctx.transport.inner is None          # no live channel at all
    print(f"replayed campaign: identical results, "
          f"{replay_ctx.transport.served_from_file} completions served "
          "from the session file, 0 live calls")


if __name__ == "__main__":
    main()
