"""Quickstart: run the KForge loop on one KernelBench-JAX workload.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import LoopConfig, kernelbench, run_workload

wl = kernelbench.by_name("L1/softmax", small=True)
print(f"workload: {wl.name} — {wl.description}\n")

for label, cfg in [
    ("single-shot (no reference)",
     LoopConfig(single_shot=True)),
    ("iterative refinement",
     LoopConfig(num_iterations=5)),
    ("iterative + reference + profiling agent",
     LoopConfig(num_iterations=5, use_reference=True, use_profiling=True)),
]:
    out = run_workload(wl, cfg)
    print(f"== {label}")
    for log in out.logs:
        line = f"  iter {log.iteration} [{log.phase}] {log.candidate_desc}"
        line += f" -> {log.result.state.value}"
        if log.result.correct and log.result.speedup:
            line += f" ({log.result.speedup:.2f}x modeled speedup)"
        if log.recommendation:
            line += f"\n      G: {log.recommendation}"
        print(line)
    final = out.final
    if final.correct:
        print(f"  best: {out.best_candidate.describe()} "
              f"speedup={final.speedup:.2f}x\n")
    else:
        print(f"  failed: {final.error}\n")
