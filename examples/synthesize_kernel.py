"""Full synthesis walkthrough (paper §7.2 analogue): synthesize a fused
attention kernel for the starcoder2 block, show the prompt the LLM backend
would receive, the deterministic backend's refinement trace, and the final
Pallas candidate.

    PYTHONPATH=src python examples/synthesize_kernel.py
"""
from repro.core import LLMBackend, LoopConfig, kernelbench, run_workload
from repro.core.verification import verify

wl = kernelbench.by_name("L3/starcoder2_attn_block", small=True)

print("=" * 70)
print("1. The synthesis prompt (what a production LLM backend receives):")
print("=" * 70)
backend = LLMBackend(prompt_only=True)
prompt = backend.build_prompt(wl, prev=None, prev_result=None,
                              recommendation=None, use_reference=True)
print(prompt[:2200], "\n[... truncated ...]\n")

print("=" * 70)
print("2. Offline deterministic agent: functional pass + optimization pass")
print("=" * 70)
out = run_workload(wl, LoopConfig(num_iterations=5, use_reference=True,
                                  use_profiling=True))
for log in out.logs:
    print(f"iter {log.iteration} [{log.phase:12s}] {log.candidate_desc} "
          f"-> {log.result.state.value}")
    if log.recommendation:
        print(f"    analysis agent G: {log.recommendation}")

print()
best = out.best_candidate
res = out.final
print(f"final candidate : {best.describe()}")
print(f"modeled TPU time: {res.model_time_s * 1e6:.1f} us "
      f"(baseline {res.baseline_model_time_s * 1e6:.1f} us, "
      f"{res.speedup:.2f}x)")

print()
print("3. Re-verify on fresh random inputs (anti-cheating, paper §7.3):")
check = verify(best, wl, seed=20260712)
print(f"   state={check.state.value} max_rel_err={check.max_abs_err:.2e}")
