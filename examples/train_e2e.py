"""End-to-end training driver: a ~100M-parameter starcoder2-family model on
the synthetic pipeline with checkpointing and restart.

Default runs 30 quick steps on CPU; pass --steps 300 for the full run:

    PYTHONPATH=src python examples/train_e2e.py --steps 300
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.data import DataConfig, TokenPipeline
from repro.models import build_model
from repro.train import Trainer, TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    # ~100M params: starcoder2 family at width 512 / 20 layers / 24k vocab
    cfg = dataclasses.replace(
        get_config("starcoder2-7b"), num_layers=20, d_model=512, num_heads=8,
        num_kv_heads=2, head_dim=64, d_ff=2048, vocab_size=24576)
    model = build_model(cfg)
    n = cfg.param_count()
    print(f"model: {cfg.name}-e2e  params={n/1e6:.1f}M")

    pipe = TokenPipeline(DataConfig(seq_len=args.seq_len,
                                    global_batch=args.global_batch,
                                    vocab_size=cfg.vocab_size))
    tc = TrainConfig(peak_lr=3e-4, warmup_steps=max(2, args.steps // 10),
                     total_steps=args.steps, microbatches=2,
                     ckpt_dir=args.ckpt_dir, ckpt_every=max(10, args.steps // 4))
    trainer = Trainer(model, tc, rng=jax.random.PRNGKey(0))
    if trainer.restore_if_available(pipe):
        print(f"resumed from checkpoint at step {trainer.step_num}")

    for metrics in trainer.fit(pipe, args.steps):
        if trainer.step_num % 5 == 0:
            tok_s = args.global_batch * args.seq_len / metrics["step_time_s"]
            print(f"step {trainer.step_num:4d}  loss={metrics['loss']:.4f}  "
                  f"gnorm={metrics['grad_norm']:.2f}  tok/s={tok_s:,.0f}")
    path = trainer.save()
    print(f"final checkpoint: {path}")


if __name__ == "__main__":
    main()
