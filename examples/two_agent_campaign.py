"""Run the paper's TWO-AGENT loop end to end — offline.

Agent F (generation, ``LLMBackend``) and agent G (performance analysis,
``repro.llm.LLMAnalyzer``) collaborating through one shared MockTransport:
F's correct candidates are profiled, G's analysis sessions turn each
profile into a single structured recommendation, and the next optimization
iteration's prompt carries it (paper §3.2's functional → optimization
loop). MockTransport answers both agents deterministically — synthesis
prompts with oracle-echo code blocks, analysis prompts from the rule-table
oracle — so the whole collaboration runs anywhere with zero network. The
CI fast lane executes this script as the two-agent smoke test.

Usage::

  PYTHONPATH=src python examples/two_agent_campaign.py [runs-dir]

The first run records BOTH agents' prompt->completion traffic to one
session file; the second replays it with ZERO live transport calls — the
CLI equivalent is ``python -m repro.campaign --backend llm --analysis llm
--use-profiling --replay SESSION``.
"""
from __future__ import annotations

import sys
from pathlib import Path

from repro.campaign import EventLog, Scheduler, run_campaign
from repro.core import LoopConfig, kernelbench
from repro.llm import MockTransport, build_llm_context, format_usage


def run_two_agent(ctx, workloads, loop, log_path=None):
    sched = Scheduler(max_workers=4)     # sessions yield slots while pacing
    return run_campaign(
        workloads, loop, scheduler=sched,
        agent_factory=ctx.agent_factory(platform=loop.platform,
                                        scheduler=sched),
        analyzer_factory=ctx.analyzer_factory(platform=loop.platform,
                                              scheduler=sched),
        usage=ctx.usage, log_path=log_path)


def main() -> None:
    runs = Path(sys.argv[1] if len(sys.argv) > 1 else "runs-two-agent")
    runs.mkdir(parents=True, exist_ok=True)
    session = runs / "two-agent-session.jsonl"
    log = runs / "two-agent-campaign.jsonl"
    workloads = kernelbench.suite(1, small=True)
    # use_profiling=True is what invokes agent G at all (§5.2)
    loop = LoopConfig(num_iterations=3, use_profiling=True,
                      platform="tpu_v5e")

    # -- leg 1: record — both agents' traffic captured to one JSONL ---------
    # transport pinned explicitly: this script promises zero network, so a
    # stray KFORGE_LLM_ENDPOINT in the environment must not flip it onto a
    # live billed endpoint
    ctx = build_llm_context(transport=MockTransport(), record=str(session))
    result = run_two_agent(ctx, workloads, loop, log_path=log)
    states = [r.state.value for r in result.finals()]
    print(f"recorded two-agent campaign: {len(result.runs)} workloads -> "
          f"{states.count('correct')} correct")
    print(f"llm usage (generation + analysis): "
          f"{format_usage(result.llm_usage)}")

    # the event log is the collaboration audit trail: every recommendation
    # carries the analyzer that produced it
    iters = [e for e in EventLog(log).events()
             if e.get("event") == "iteration"]
    llm_recs = [e for e in iters if e.get("recommendation_source") == "llm"]
    opt = [e for e in iters if e.get("phase") == "optimization"]
    assert llm_recs, "no recommendation came from the LLM analyzer"
    assert opt, "no optimization-phase iteration ran"
    print(f"event log: {len(iters)} iterations, {len(opt)} optimization "
          f"phase, {len(llm_recs)} LLM-analyzer recommendations")

    # -- leg 2: replay — byte-for-byte, zero live calls ---------------------
    replay_ctx = build_llm_context(replay=str(session))
    replayed = run_two_agent(replay_ctx, workloads, loop)
    rep_states = [r.state.value for r in replayed.finals()]
    assert rep_states == states, (rep_states, states)
    assert replay_ctx.transport.inner is None      # no live channel at all
    print(f"replayed two-agent campaign: identical results, "
          f"{replay_ctx.transport.served_from_file} completions served "
          "from the session file, 0 live calls")


if __name__ == "__main__":
    main()
