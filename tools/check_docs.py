#!/usr/bin/env python
"""Docs-consistency check (CI): the documentation must track the code.

Fails (exit 1, one line per problem) when:

* a registered platform is missing from README.md's platform table, the
  campaign CLI docs, or DESIGN.md;
* a public name exported by ``repro.campaign`` is missing from docs/api.md;
* a ``python -m repro.campaign`` CLI flag (introspected from the live
  argument parser, so new flags are covered automatically) is missing from
  README.md or docs/api.md.

Run as ``PYTHONPATH=src python tools/check_docs.py`` from the repo root.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def platform_table_rows(readme: str) -> set:
    """Platform names (`...` in the first cell) of README's table rows."""
    names = set()
    for line in readme.splitlines():
        m = re.match(r"\|\s*`([a-z0-9_]+)`\s*\|", line)
        if m:
            names.add(m.group(1))
    return names


def main() -> int:
    from repro import campaign
    from repro.platforms import available_platforms

    problems = []
    readme = (ROOT / "README.md").read_text()
    design = (ROOT / "DESIGN.md").read_text()
    api = (ROOT / "docs" / "api.md").read_text()

    table = platform_table_rows(readme)
    for name in available_platforms():
        if name not in table:
            problems.append(
                f"README.md: platform {name!r} missing from the platform "
                "table (| `name` | ... | row)")
        if name not in design:
            problems.append(f"DESIGN.md: platform {name!r} never mentioned")

    from repro.campaign.__main__ import build_parser
    flags = sorted({opt for action in build_parser()._actions
                    for opt in action.option_strings
                    if opt.startswith("--") and opt != "--help"})
    for flag in flags:
        # word-boundary match: documenting --matrix-workers must not count
        # as documenting --workers (or --matrix)
        pattern = re.compile(re.escape(flag) + r"(?![\w-])")
        for doc_name, text in (("README.md", readme), ("docs/api.md", api)):
            if not pattern.search(text):
                problems.append(
                    f"{doc_name}: campaign CLI flag {flag} undocumented")

    public = [n for n in vars(campaign)
              if (not n.startswith("_") and n[0].isupper())
              or n in ("run_campaign", "run_transfer_sweep",
                       "run_transfer_matrix", "harvest_hints",
                       "reference_sources", "all_pairs")]
    for name in sorted(set(public)):
        if name not in api:
            problems.append(f"docs/api.md: repro.campaign.{name} "
                            "undocumented")

    for p in problems:
        print(f"docs-consistency: {p}", file=sys.stderr)
    if not problems:
        n = len(available_platforms())
        print(f"docs-consistency: OK ({n} platforms, "
              f"{len(set(public))} campaign exports, "
              f"{len(flags)} CLI flags)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
