#!/usr/bin/env python
"""Docs-consistency check (CI): the documentation must track the code.

Fails (exit 1, one line per problem) when:

* a registered platform is missing from README.md's platform table, the
  campaign CLI docs, or DESIGN.md;
* a public name exported by ``repro.campaign`` or ``repro.llm`` is missing
  from docs/api.md;
* a ``python -m repro.campaign`` CLI flag (introspected from the live
  argument parser, so new flags are covered automatically — aliases like
  ``--use-profiling`` included) is missing from README.md or docs/api.md;
* a ``python -m repro.service`` daemon CLI flag, or a name exported by
  ``repro.service`` (``__all__``), is missing from README.md or
  docs/api.md — the service surface must stay documented too;
* an LLM-subsystem CLI flag (one whose parser help text mentions
  ``--backend llm`` or ``LLM``) is additionally missing from
  docs/llm_backends.md — the LLM guide must cover its own surface;
* a fenced ``python`` block in docs/api.md or docs/llm_backends.md does
  not parse, or imports a module/name that no longer resolves against
  ``src/`` (the stale-docs guard: example code must track the API).

Run as ``PYTHONPATH=src python tools/check_docs.py`` from the repo root.
"""
from __future__ import annotations

import ast
import importlib
import importlib.util
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

PY_BLOCK_RE = re.compile(r"```python\n(.*?)```", re.S)


def check_python_blocks(doc_name: str, text: str, problems: list) -> int:
    """Parse every fenced python block and resolve its imports against the
    live tree: ``import x`` / ``from x import y`` must find module ``x``,
    and for first-party (``repro``) modules every imported name must still
    exist. Returns the number of blocks checked."""
    blocks = PY_BLOCK_RE.findall(text)
    for i, block in enumerate(blocks, 1):
        try:
            tree = ast.parse(block)
        except SyntaxError as exc:
            problems.append(f"{doc_name}: python block #{i} does not "
                            f"parse: {exc}")
            continue
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    _check_import(doc_name, i, alias.name, None, problems)
            elif isinstance(node, ast.ImportFrom) and node.level == 0 \
                    and node.module:
                names = [a.name for a in node.names if a.name != "*"]
                _check_import(doc_name, i, node.module, names, problems)
    return len(blocks)


def _check_import(doc_name: str, block: int, module: str,
                  names, problems: list) -> None:
    try:
        spec = importlib.util.find_spec(module)
    except (ImportError, ValueError):
        spec = None
    if spec is None:
        problems.append(f"{doc_name}: python block #{block} imports "
                        f"{module!r}, which does not resolve")
        return
    if not names or module.split(".")[0] != "repro":
        return                          # attribute-check first-party only
    mod = importlib.import_module(module)
    for name in names:
        if not hasattr(mod, name):
            problems.append(
                f"{doc_name}: python block #{block} imports {name!r} from "
                f"{module}, which no longer exports it")


def platform_table_rows(readme: str) -> set:
    """Platform names (`...` in the first cell) of README's table rows."""
    names = set()
    for line in readme.splitlines():
        m = re.match(r"\|\s*`([a-z0-9_]+)`\s*\|", line)
        if m:
            names.add(m.group(1))
    return names


def main() -> int:
    from repro import campaign
    from repro import llm as llm_mod
    from repro.platforms import available_platforms

    problems = []
    readme = (ROOT / "README.md").read_text()
    design = (ROOT / "DESIGN.md").read_text()
    api = (ROOT / "docs" / "api.md").read_text()
    llm_doc = (ROOT / "docs" / "llm_backends.md").read_text()

    table = platform_table_rows(readme)
    for name in available_platforms():
        if name not in table:
            problems.append(
                f"README.md: platform {name!r} missing from the platform "
                "table (| `name` | ... | row)")
        if name not in design:
            problems.append(f"DESIGN.md: platform {name!r} never mentioned")

    from repro.campaign.__main__ import build_parser
    actions = [a for a in build_parser()._actions
               if any(o.startswith("--") and o != "--help"
                      for o in a.option_strings)]
    flags = sorted({opt for action in actions
                    for opt in action.option_strings
                    if opt.startswith("--")})
    # flags whose help text names the LLM subsystem must ALSO appear in
    # docs/llm_backends.md — the LLM guide owns that surface
    llm_flags = sorted({opt for action in actions
                        for opt in action.option_strings
                        if opt.startswith("--")
                        and re.search(r"--backend llm|\bLLM\b",
                                      action.help or "")})
    for flag in flags:
        # word-boundary match: documenting --matrix-workers must not count
        # as documenting --workers (or --matrix)
        pattern = re.compile(re.escape(flag) + r"(?![\w-])")
        for doc_name, text in (("README.md", readme), ("docs/api.md", api)):
            if not pattern.search(text):
                problems.append(
                    f"{doc_name}: campaign CLI flag {flag} undocumented")
        if flag in llm_flags and not pattern.search(llm_doc):
            problems.append(
                f"docs/llm_backends.md: LLM-subsystem CLI flag {flag} "
                "undocumented (its --help names the LLM backend)")

    # service daemon: CLI flags (live parser, stdlib-only import) + the
    # package's __all__ exports must appear in README.md and docs/api.md
    from repro.service.__main__ import build_parser as build_service_parser
    service_flags = sorted({
        opt for action in build_service_parser()._actions
        for opt in action.option_strings
        if opt.startswith("--") and opt != "--help"})
    for flag in service_flags:
        pattern = re.compile(re.escape(flag) + r"(?![\w-])")
        for doc_name, text in (("README.md", readme), ("docs/api.md", api)):
            if not pattern.search(text):
                problems.append(
                    f"{doc_name}: service daemon CLI flag {flag} "
                    "undocumented")

    import repro.service as service_mod
    service_public = sorted(service_mod.__all__)
    for name in service_public:
        if name not in api:
            problems.append(f"docs/api.md: repro.service.{name} "
                            "undocumented")

    public = [n for n in vars(campaign)
              if (not n.startswith("_") and n[0].isupper())
              or n in ("run_campaign", "run_transfer_sweep",
                       "run_transfer_matrix", "harvest_hints",
                       "reference_sources", "all_pairs")]
    for name in sorted(set(public)):
        if name not in api:
            problems.append(f"docs/api.md: repro.campaign.{name} "
                            "undocumented")

    llm_public = [n for n in vars(llm_mod)
                  if (not n.startswith("_") and n[0].isupper())
                  or n in ("build_llm_context", "format_usage",
                           "estimate_tokens", "prompt_key",
                           "parse_recommendation", "analysis_reply_reason",
                           "default_mock_reply",
                           "default_mock_analysis_reply")]
    for name in sorted(set(llm_public)):
        if name not in api and name not in llm_doc:
            problems.append(f"docs: repro.llm.{name} undocumented in both "
                            "docs/api.md and docs/llm_backends.md")

    n_blocks = 0
    for doc_name, text in (("docs/api.md", api),
                           ("docs/llm_backends.md", llm_doc)):
        n_blocks += check_python_blocks(doc_name, text, problems)

    for p in problems:
        print(f"docs-consistency: {p}", file=sys.stderr)
    if not problems:
        n = len(available_platforms())
        print(f"docs-consistency: OK ({n} platforms, "
              f"{len(set(public))} campaign exports, "
              f"{len(set(llm_public))} llm exports, "
              f"{len(service_public)} service exports, "
              f"{len(flags)} CLI flags ({len(llm_flags)} llm-gated), "
              f"{len(service_flags)} service flags, "
              f"{n_blocks} doc code blocks)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
