#!/usr/bin/env python3
"""Tiny stdlib client for the KForge synthesis daemon (repro.service).

Talks plain HTTP/JSON to a running ``python -m repro.service`` daemon —
no repro import, no jax, safe to run anywhere. Doubles as the library
helper the tests and benches use (:class:`ServiceClient`).

Usage:
    python tools/kforge_client.py --port 8741 health
    python tools/kforge_client.py --port 8741 synthesize L1/swish \\
        --platform tpu_v5e --iters 2 --tenant alice --deadline 120
    python tools/kforge_client.py --port 8741 report
    python tools/kforge_client.py --port 8741 shutdown
"""
from __future__ import annotations

import argparse
import json
import sys
import urllib.error
import urllib.request
from typing import Any, Dict, Optional


class ServiceClient:
    """Thin JSON-over-HTTP wrapper bound to one daemon address.

    Every method returns the decoded response body as a dict; HTTP error
    statuses are NOT raised — the daemon's structured
    ``{"ok": false, "error": {...}}`` payload is returned as-is (callers
    branch on ``resp["ok"]``, like the daemon's own tests do). Only
    transport-level failures (daemon not listening) raise.
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 8741, *,
                 timeout_s: float = 600.0) -> None:
        self.base = f"http://{host}:{port}"
        self.timeout_s = timeout_s

    def _call(self, method: str, path: str,
              body: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        data = (json.dumps(body).encode()
                if body is not None else (b"" if method == "POST" else None))
        req = urllib.request.Request(
            self.base + path, data=data, method=method,
            headers={"Content-Type": "application/json"})
        try:
            with urllib.request.urlopen(req, timeout=self.timeout_s) as resp:
                return json.loads(resp.read().decode())
        except urllib.error.HTTPError as exc:
            # daemon errors carry a structured JSON body; surface it
            try:
                return json.loads(exc.read().decode())
            except (ValueError, OSError):
                return {"ok": False,
                        "error": {"kind": "http_error",
                                  "message": f"HTTP {exc.code}: "
                                             f"{exc.reason}"}}

    def health(self) -> Dict[str, Any]:
        return self._call("GET", "/health")

    def report(self) -> Dict[str, Any]:
        return self._call("GET", "/report")

    def synthesize(self, workload: str, **spec: Any) -> Dict[str, Any]:
        """POST /synthesize. Keyword args are the request spec fields:
        platform, backend, direction, search, tenant, deadline_s, isolate,
        iters, seed, population, generations, use_reference,
        use_profiling, single_shot."""
        body = {"workload": workload}
        body.update({k: v for k, v in spec.items() if v is not None})
        return self._call("POST", "/synthesize", body)

    def shutdown(self) -> Dict[str, Any]:
        return self._call("POST", "/shutdown")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="kforge_client",
        description="CLI client for the repro.service synthesis daemon")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8741)
    ap.add_argument("--timeout", type=float, default=600.0,
                    help="client-side HTTP timeout in seconds")
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("health", help="GET /health")
    sub.add_parser("report", help="GET /report (rendered service report)")
    sub.add_parser("shutdown", help="POST /shutdown (graceful drain)")
    syn = sub.add_parser("synthesize", help="POST /synthesize and wait")
    syn.add_argument("workload", help="workload name, e.g. L1/swish")
    syn.add_argument("--platform", default=None)
    syn.add_argument("--backend", default=None,
                     choices=("template", "llm"))
    syn.add_argument("--direction", default=None,
                     choices=("fwd", "fwd_bwd"))
    syn.add_argument("--search", default=None, choices=("lineage", "pbt"))
    syn.add_argument("--tenant", default=None)
    syn.add_argument("--deadline", type=float, default=None, metavar="S",
                     help="per-request deadline_s")
    syn.add_argument("--iters", type=int, default=None)
    syn.add_argument("--seed", type=int, default=None)
    syn.add_argument("--isolate", action="store_true",
                     help="run on a pre-forked isolation worker")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    client = ServiceClient(args.host, args.port, timeout_s=args.timeout)
    if args.cmd == "health":
        out = client.health()
    elif args.cmd == "report":
        out = client.report()
        if out.get("ok"):
            print(out["report"])
            return 0
    elif args.cmd == "shutdown":
        out = client.shutdown()
    else:
        out = client.synthesize(
            args.workload, platform=args.platform, backend=args.backend,
            direction=args.direction, search=args.search,
            tenant=args.tenant, deadline_s=args.deadline,
            iters=args.iters, seed=args.seed,
            isolate=args.isolate or None)
    print(json.dumps(out, indent=2, default=str))
    return 0 if out.get("ok") else 1


if __name__ == "__main__":
    sys.exit(main())
