"""Pre-forked isolation workers for the synthesis daemon.

THE PRE-FORK RULE (DESIGN.md §12): this module must stay stdlib-only at
import time, and :class:`PreforkPool` must be constructed BEFORE anything
imports jax. ``fork()`` after jax import is the classic hazard the
campaign CLI's ``--isolate`` mode documents — jax spins up threads and
holds locks that a forked child inherits mid-state. The daemon sidesteps
it structurally: ``python -m repro.service`` forks its worker pool first,
from a process that has never imported jax, and only then imports the
jax-heavy daemon module. Each worker imports jax *itself*, inside the
child, on its first request.

A worker is one long-lived child process looping on a duplex pipe:
``recv`` a request spec (a JSON-able dict), run it through the handler,
``send`` back a result dict. The parent-side :meth:`PreforkPool.submit`
is synchronous: it checks out an idle worker, ships the spec, and waits
for the reply up to ``timeout_s`` — on expiry the worker is SIGKILLed
(real kill semantics, like the PR-4 process-isolation scheduler) and a
fresh worker is forked in its place; on a worker dying mid-job (OOM kill,
segfault, bug) the death is detected as pipe EOF, the slot is reclaimed,
and the caller gets a structured ``worker_died`` error instead of a hang.
The pool never takes the daemon down: every failure path returns an
error dict and respawns the worker.

The cost of the lane (mirroring ``--isolate``): workers share no
in-memory caches with the daemon or each other — only the persistent
JSONL verification cache (``cache_path``) is shared, through the
filesystem. Thread-mode requests (the daemon default) are where the
shared WorkloadIOCache/ExecutableCache/VerificationCache stack dedupes
concurrent tenants; the prefork lane buys kill-ability instead.
"""
from __future__ import annotations

import multiprocessing
import queue
import threading
from typing import Any, Callable, Dict, List, Optional


def _default_handler(spec: Dict[str, Any]) -> Dict[str, Any]:
    """Resolve the synthesis handler lazily INSIDE the child — this import
    pulls jax, which must happen after the fork, never before it."""
    from repro.service.daemon import isolated_request_handler
    return isolated_request_handler(spec)


def _worker_main(conn, handler: Optional[Callable[[Dict], Dict]]) -> None:
    """Child process loop: request spec in, result dict out, forever.

    ``None`` over the pipe (or parent-side EOF) is the shutdown sentinel.
    A handler exception is isolated into a structured error result — a
    worker only dies for process-level reasons (kill, OOM, crash), which
    the parent detects as EOF.
    """
    if handler is None:
        handler = _default_handler
    while True:
        try:
            spec = conn.recv()
        except (EOFError, OSError):
            return
        if spec is None:
            return
        try:
            result = handler(spec)
        except BaseException as exc:  # noqa: BLE001 — isolate the worker
            result = {"ok": False,
                      "error": {"kind": "worker_error",
                                "message": f"{type(exc).__name__}: {exc}"}}
        try:
            conn.send(result)
        except (BrokenPipeError, OSError):
            return


class _Worker:
    __slots__ = ("proc", "conn")

    def __init__(self, proc, conn) -> None:
        self.proc = proc
        self.conn = conn


class PreforkPool:
    """``n`` pre-forked worker processes behind a checkout queue.

    Args:
        n: worker count (also the pool's concurrency bound — callers
            holding no idle worker block in :meth:`submit`).
        handler: request handler run in the child; ``None`` (the daemon
            default) lazily imports the synthesis handler inside the
            child after the fork. Tests inject cheap handlers here.

    Thread-safe: any number of daemon threads may ``submit`` concurrently;
    each checks out one worker for the duration of its request.
    """

    def __init__(self, n: int, *,
                 handler: Optional[Callable[[Dict], Dict]] = None) -> None:
        if n < 1:
            raise ValueError(f"PreforkPool needs >= 1 worker, got {n}")
        self.size = int(n)
        self._handler = handler
        self._ctx = multiprocessing.get_context("fork")
        self._lock = threading.Lock()
        self._closed = False
        self.respawns = 0            # workers replaced after death/kill
        self.jobs = 0
        self._idle: "queue.Queue[_Worker]" = queue.Queue()
        self._workers: List[_Worker] = []
        for _ in range(self.size):
            w = self._spawn()
            self._workers.append(w)
            self._idle.put(w)

    def _spawn(self) -> _Worker:
        parent_conn, child_conn = self._ctx.Pipe(duplex=True)
        proc = self._ctx.Process(target=_worker_main,
                                 args=(child_conn, self._handler),
                                 daemon=True)
        proc.start()
        child_conn.close()
        return _Worker(proc, parent_conn)

    def _replace(self, dead: _Worker) -> None:
        """Reclaim a dead worker's slot with a fresh fork; the pool's
        capacity is restored before the caller's error even returns."""
        try:
            dead.conn.close()
        except OSError:
            pass
        with self._lock:
            if self._closed:
                return
            self.respawns += 1
            fresh = self._spawn()
            try:
                self._workers[self._workers.index(dead)] = fresh
            except ValueError:
                self._workers.append(fresh)
        self._idle.put(fresh)

    @property
    def pids(self) -> List[int]:
        """Live worker pids (tests kill these to exercise the death path)."""
        with self._lock:
            return [w.proc.pid for w in self._workers]

    def submit(self, spec: Dict[str, Any],
               timeout_s: Optional[float] = None) -> Dict[str, Any]:
        """Run one request on an idle worker; always returns a dict.

        Failure paths (worker killed on ``timeout_s`` expiry, worker died
        mid-job) come back as ``{"ok": False, "error": {"kind": ...}}`` —
        callers never see an exception and the dead worker's slot is
        respawned before returning.
        """
        if self._closed:
            return {"ok": False,
                    "error": {"kind": "pool_closed",
                              "message": "worker pool is shut down"}}
        worker = self._idle.get()
        with self._lock:
            self.jobs += 1
        pid = worker.proc.pid
        try:
            worker.conn.send(spec)
        except (BrokenPipeError, OSError):
            self._replace(worker)
            return {"ok": False,
                    "error": {"kind": "worker_died",
                              "message": f"worker pid={pid} was dead at "
                                         "submit; respawned"}}
        if not worker.conn.poll(timeout_s):
            # deadline: the child is actually killed (PR-4 semantics) and
            # its slot comes back with a fresh fork
            worker.proc.kill()
            worker.proc.join(10.0)
            self._replace(worker)
            return {"ok": False,
                    "error": {"kind": "deadline",
                              "message": f"worker pid={pid} killed after "
                                         f"{timeout_s:.3g}s deadline"}}
        try:
            result = worker.conn.recv()
        except (EOFError, OSError):
            worker.proc.join(10.0)
            code = worker.proc.exitcode
            self._replace(worker)
            return {"ok": False,
                    "error": {"kind": "worker_died",
                              "message": f"worker pid={pid} died mid-job "
                                         f"(exit code {code}); slot "
                                         "respawned"}}
        self._idle.put(worker)
        return result

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"workers": self.size, "jobs": self.jobs,
                    "respawns": self.respawns}

    def close(self) -> None:
        """Shut every worker down (sentinel first, kill as backstop)."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            workers = list(self._workers)
        for w in workers:
            try:
                w.conn.send(None)
            except (BrokenPipeError, OSError):
                pass
        for w in workers:
            w.proc.join(2.0)
            if w.proc.is_alive():
                w.proc.kill()
                w.proc.join(10.0)
            try:
                w.conn.close()
            except OSError:
                pass
