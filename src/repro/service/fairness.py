"""Per-tenant fairness over the fleet rate budget (DESIGN.md §12).

The PR-5 :class:`repro.llm.RateLimiter` already solves fleet-wide pacing:
one shared token-bucket pair (rpm/tpm) turns N concurrent workers into an
evenly spaced call train. A multi-tenant daemon needs one more property —
a single hot tenant must not monopolize the whole fleet budget while
everyone else starves.

:class:`TenantFairLimiter` composes two bucket layers:

* **the fleet bucket** — every reserve, from every tenant, debits it, so
  the aggregate issue schedule can never exceed the fleet budget no
  matter how tenants interleave (the hypothesis property lane proves
  this: burst allowance + refill is a hard ceiling);
* **a per-tenant bucket** (lazily minted per tenant when per-tenant
  budgets are configured) — a tenant that has spent its share waits on
  its OWN deficit, while a fresh tenant's bucket is full, so its pacing
  delay is bounded by the fleet deficit alone rather than by the hot
  tenant's backlog.

``reserve(tenant, tokens)`` returns ``max(fleet delay, tenant delay)`` —
debiting both layers immediately, never sleeping (sleeping is the
caller's job, exactly like the underlying limiter). ``for_tenant``
returns a bound single-argument adapter that satisfies the
``LLMSession(limiter=...)`` duck type, so the daemon's per-request LLM
sessions draw their pacing from the tenant's buckets transparently.

Deterministic under an injected ``clock``; thread-safe.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, Optional

from repro.llm.limiter import RateLimiter


class _TenantBoundLimiter:
    """``RateLimiter``-shaped view of one tenant's slice: ``reserve(tokens)``
    delegates to ``fair.reserve(tenant, tokens)``. What the daemon hands to
    per-request :class:`repro.llm.LLMSession` instances."""

    __slots__ = ("_fair", "tenant")

    def __init__(self, fair: "TenantFairLimiter", tenant: str) -> None:
        self._fair = fair
        self.tenant = tenant

    def reserve(self, tokens: int = 0) -> float:
        return self._fair.reserve(self.tenant, tokens)

    def stats(self) -> Dict[str, Optional[float]]:
        return self._fair.tenant_stats(self.tenant)


class TenantFairLimiter:
    """Fleet bucket + lazily minted per-tenant buckets; see module doc.

    Args:
        rpm / tpm: the FLEET budgets (requests / tokens per minute;
            ``None`` = unlimited), enforced across all tenants combined.
        tenant_rpm / tenant_tpm: each tenant's own budget. ``None`` skips
            the per-tenant layer entirely (fleet pacing only).
        clock: monotonic time source (injectable for the property tests).
    """

    def __init__(self, rpm: Optional[float] = None,
                 tpm: Optional[float] = None, *,
                 tenant_rpm: Optional[float] = None,
                 tenant_tpm: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic) -> None:
        self.fleet = RateLimiter(rpm=rpm, tpm=tpm, clock=clock)
        self.tenant_rpm = tenant_rpm
        self.tenant_tpm = tenant_tpm
        self._clock = clock
        self._lock = threading.Lock()
        self._tenants: Dict[str, RateLimiter] = {}

    def _bucket(self, tenant: str) -> Optional[RateLimiter]:
        if self.tenant_rpm is None and self.tenant_tpm is None:
            return None
        with self._lock:
            bucket = self._tenants.get(tenant)
            if bucket is None:
                bucket = RateLimiter(rpm=self.tenant_rpm,
                                     tpm=self.tenant_tpm, clock=self._clock)
                self._tenants[tenant] = bucket
            return bucket

    def reserve(self, tenant: str, tokens: int = 0) -> float:
        """Debit one request (+ ``tokens``) from the fleet bucket AND the
        tenant's own bucket; return the pacing delay (the max of the two
        layers' deficits). Never sleeps, never blocks."""
        wait = self.fleet.reserve(tokens)
        bucket = self._bucket(tenant)
        if bucket is not None:
            wait = max(wait, bucket.reserve(tokens))
        return wait

    def for_tenant(self, tenant: str) -> _TenantBoundLimiter:
        """A ``limiter.reserve(tokens)``-shaped adapter bound to one
        tenant — drop-in for :class:`repro.llm.LLMSession`'s limiter."""
        return _TenantBoundLimiter(self, tenant)

    def tenant_stats(self, tenant: str) -> Dict[str, Optional[float]]:
        bucket = self._bucket(tenant)
        return bucket.stats() if bucket is not None else {}

    def stats(self) -> Dict[str, object]:
        """Fleet stats plus per-tenant reserved-work counters — the
        daemon's ``/health`` fairness section."""
        with self._lock:
            tenants = {name: bucket.stats()
                       for name, bucket in sorted(self._tenants.items())}
        return {"fleet": self.fleet.stats(),
                "tenant_rpm": self.tenant_rpm,
                "tenant_tpm": self.tenant_tpm,
                "tenants": tenants}
