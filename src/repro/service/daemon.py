"""Synthesis-as-a-service daemon (DESIGN.md §12).

NOT :mod:`repro.serve` — that is the seed's *batched model-inference*
engine (prefill/decode slots over a fixed-shape KV cache). This package,
``repro.service``, is the *synthesis* service: a long-running daemon that
keeps one warm process alive (jax imported once), accepts queued synthesis
requests ``(workload, platform, backend, direction, search)`` over a local
HTTP JSON API, and multiplexes them onto the PR-4 job-graph
:class:`repro.campaign.Scheduler`.

Why a daemon: the batch CLI pays the jax import, trace, and compile cost
per process, and two users asking for the same kernel pay it twice. Here
all tenants share one :class:`~repro.campaign.cache.VerificationCache` /
:class:`~repro.core.evalio.WorkloadIOCache` /
:class:`~repro.core.evalio.ExecutableCache` stack plus a completed-request
memo, so duplicate requests dedupe at four layers:

1. **memo** — an identical completed request is answered sub-ms from the
   response memo, no scheduler round-trip at all;
2. **in-flight coalescing** — concurrent identical requests attach to the
   one running job (one verification bill, N responses);
3. **verification cache** — a re-run with warm verifications (e.g. after
   a daemon restart resumed from the journal) re-verifies nothing;
4. **IO/executable caches** — distinct requests on the same workload
   share generated inputs, the reference oracle, and compiled programs.

Every request is journaled through the existing JSONL event layer
(``request_received`` / ``request_done`` with tenant, queue latency and
cache-hit stats, plus campaign-shaped ``iteration`` / ``workload_done``
events), so ``repro.campaign.report_from_events`` renders a combined
fast_p + service report from a service journal, and a restarted daemon
pre-warms its verification cache from it (resume-safe).

Fairness: every admission (and every LLM call of an LLM-backed request)
reserves from a :class:`repro.service.fairness.TenantFairLimiter` — a
per-tenant bucket pair drawing on the fleet rpm/tpm budget — so one hot
tenant paces itself instead of starving the rest.

Isolation: thread-mode requests (default) share the caches above and are
deadline-bounded by the PR-6 scheduler watchdog (a hung job resolves as a
timeout at the deadline, its thread abandoned). Requests with
``"isolate": true`` run on the pre-forked
:class:`repro.service.workers.PreforkPool` — forked BEFORE jax import by
``python -m repro.service`` (the pre-fork rule), so a deadline actually
SIGKILLs the worker and reclaims the slot. LLM-backed requests are
thread-mode only: the whole point of the daemon is that they share one
transport/limiter/meter (the ROADMAP fork-splits-shared-state gap), and
per-request :class:`~repro.llm.UsageMeter` deltas attribute each tenant's
spend exactly.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import threading
import time
from collections import OrderedDict
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from pathlib import Path
from typing import Any, Dict, Optional, Tuple, Union

from repro.campaign import events as ev_mod
from repro.campaign.cache import VerificationCache
from repro.campaign.events import EventLog
from repro.campaign.scheduler import Scheduler
from repro.core import kernelbench
from repro.core import verification as verif_mod
from repro.core.evalio import ExecutableCache, WorkloadIOCache
from repro.core.refinement import LoopConfig, run_workload
from repro.platforms import DEFAULT_PLATFORM, available_platforms
from repro.service.fairness import TenantFairLimiter
from repro.service.workers import PreforkPool


class ServiceError(Exception):
    """A structured request failure: ``kind`` is machine-readable (the
    client switch key), ``status`` the HTTP code. Raised by validation and
    mapped to ``{"ok": false, "error": {"kind", "message"}}`` bodies."""

    def __init__(self, kind: str, message: str, status: int = 400) -> None:
        super().__init__(message)
        self.kind = kind
        self.status = status

    def payload(self) -> Dict[str, Any]:
        return {"ok": False,
                "error": {"kind": self.kind, "message": str(self)}}


@dataclasses.dataclass
class ServiceConfig:
    """Daemon configuration (the ``python -m repro.service`` flags)."""
    host: str = "127.0.0.1"
    port: int = 0                       # 0 = ephemeral (read service.port)
    workers: int = 4                    # scheduler slot budget
    suite: str = "small"                # workload resolution suite
    request_timeout_s: Optional[float] = None   # scheduler watchdog deadline
    log_path: Optional[Union[str, Path]] = None  # JSONL service journal
    cache_path: Optional[str] = None    # persistent verification cache
    rpm: Optional[float] = None         # fleet budget (admissions + LLM calls)
    tpm: Optional[float] = None
    tenant_rpm: Optional[float] = None  # each tenant's slice of the budget
    tenant_tpm: Optional[float] = None
    llm_record: Optional[str] = None    # record LLM sessions to this JSONL
    llm_replay: Optional[str] = None    # replay a recorded session (0 live)
    memo_entries: int = 256             # completed-request memo LRU cap


# request fields accepted by /synthesize; anything else is a bad_request
# (catching typos like "platfrom" instead of silently using the default)
_SPEC_FIELDS = frozenset((
    "workload", "platform", "backend", "direction", "search", "tenant",
    "deadline_s", "isolate", "iters", "seed", "population", "generations",
    "use_reference", "use_profiling", "single_shot",
))


@dataclasses.dataclass
class _Request:
    tenant: str
    workload: Any                       # resolved Workload
    loop: LoopConfig
    backend: str
    isolate: bool
    deadline_s: Optional[float]
    key: str                            # canonical dedupe address
    rid: int = 0

    @property
    def name(self) -> str:
        return self.workload.name


class _Inflight:
    """One running (or queued) deduped job plus its waiter count."""

    __slots__ = ("job", "tenant", "t_enqueue", "waiters")

    def __init__(self, tenant: str) -> None:
        self.job = None
        self.tenant = tenant
        self.t_enqueue = time.perf_counter()
        self.waiters = 1


def _key_sha(key: str) -> str:
    return hashlib.sha256(key.encode()).hexdigest()[:16]


class SynthesisService:
    """The long-running synthesis daemon; see module docstring.

    Construct, :meth:`start` (binds the loopback HTTP server and returns
    immediately), talk to it via ``tools/kforge_client.py`` or raw HTTP,
    and :meth:`stop` to drain + shut down. ``pool`` (optional) is a
    :class:`PreforkPool` created before jax import — required for
    ``"isolate": true`` requests. ``llm`` (optional) injects a prebuilt
    :class:`repro.llm.LLMContext`; by default one is built lazily from the
    config's record/replay settings on the first LLM-backed request.
    """

    def __init__(self, cfg: ServiceConfig, *,
                 pool: Optional[PreforkPool] = None,
                 llm: Optional[Any] = None) -> None:
        if cfg.suite not in ("small", "full"):
            raise ValueError(f"suite must be 'small' or 'full', "
                             f"got {cfg.suite!r}")
        self.cfg = cfg
        self.pool = pool
        self.cache = (VerificationCache.open(cfg.cache_path)
                      if cfg.cache_path else VerificationCache())
        self.io_cache = WorkloadIOCache()
        self.exe_cache = ExecutableCache()
        self.scheduler = Scheduler(max_workers=cfg.workers,
                                   timeout_s=cfg.request_timeout_s)
        self.fairness = TenantFairLimiter(
            rpm=cfg.rpm, tpm=cfg.tpm,
            tenant_rpm=cfg.tenant_rpm, tenant_tpm=cfg.tenant_tpm)
        self.log = EventLog(cfg.log_path) if cfg.log_path else None
        self._llm = llm
        self._llm_lock = threading.Lock()
        self._lock = threading.Lock()
        self._inflight: Dict[str, _Inflight] = {}
        self._memo: "OrderedDict[str, Dict]" = OrderedDict()
        self._rid = 0
        self._counters = {"total": 0, "ok": 0, "errors": 0, "deduped": 0,
                          "disconnects": 0}
        self._tenants: Dict[str, Dict[str, Any]] = {}
        self._accepting = True
        self._stopped = False
        self._stop_event = threading.Event()
        self._t_start = time.perf_counter()
        self._httpd: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None
        self._warmed = 0
        if self.log is not None:
            # resume-safe journal: a restarted daemon pre-warms its
            # verification cache from the previous runs' iteration /
            # generation events, so a re-submitted request re-verifies
            # nothing it already paid for
            self._warmed = ev_mod.warm_cache(self.cache, self.log.events())

    # -- lifecycle ---------------------------------------------------------

    @property
    def host(self) -> str:
        return self.cfg.host

    @property
    def port(self) -> int:
        if self._httpd is None:
            raise RuntimeError("service not started")
        return self._httpd.server_address[1]

    def start(self) -> "SynthesisService":
        """Bind the loopback HTTP server and serve in a daemon thread."""
        self._httpd = _ServiceHTTPServer((self.cfg.host, self.cfg.port),
                                         _Handler)
        self._httpd.service = self
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="kforge-service-http",
                                        daemon=True)
        self._thread.start()
        if self.log is not None:
            self.log.append({
                "event": "service_start", "host": self.cfg.host,
                "port": self.port, "suite": self.cfg.suite,
                "workers": self.cfg.workers,
                "request_timeout_s": self.cfg.request_timeout_s,
                "prefork_workers": self.pool.size if self.pool else 0,
                "warmed_cache_entries": self._warmed,
            })
        return self

    def begin_shutdown(self) -> int:
        """Stop admitting new requests; returns the in-flight count. The
        HTTP /shutdown route calls this before responding, then finishes
        via :meth:`stop` on a separate thread."""
        with self._lock:
            self._accepting = False
            return len(self._inflight)

    def stop(self, drain: bool = True) -> None:
        """Graceful shutdown: refuse new work, drain in-flight jobs (every
        accepted request still gets its response), journal ``service_stop``
        with the final cache stats (the persistent verification cache is
        append-on-put, so its file is already flushed), close the HTTP
        server and the prefork pool. Idempotent."""
        with self._lock:
            if self._stopped:
                return
            self._stopped = True
            self._accepting = False
            jobs = [e.job for e in self._inflight.values()
                    if e.job is not None]
        if drain:
            for job in jobs:
                job.done.wait()
        if self.log is not None:
            self.log.append({
                "event": "service_stop", "drained": len(jobs),
                "requests": dict(self._counters),
                "cache": self.cache.stats(),
                "io_cache": self.io_cache.stats(),
                "exe_cache": self.exe_cache.stats(),
            })
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(5.0)
        if self.pool is not None:
            self.pool.close()
        self._stop_event.set()

    def wait(self) -> None:
        """Block until :meth:`stop` completes (the CLI foreground loop)."""
        self._stop_event.wait()

    # -- request validation ------------------------------------------------

    def _parse(self, body: Dict[str, Any]) -> _Request:
        if not isinstance(body, dict):
            raise ServiceError("bad_request",
                               "request body must be a JSON object")
        unknown = sorted(set(body) - _SPEC_FIELDS)
        if unknown:
            raise ServiceError(
                "bad_request",
                f"unknown request field(s) {', '.join(unknown)}; accepted: "
                + ", ".join(sorted(_SPEC_FIELDS)))
        name = body.get("workload")
        if not isinstance(name, str) or not name:
            raise ServiceError("bad_request",
                               "'workload' (string) is required")
        small = self.cfg.suite == "small"
        try:
            wl = kernelbench.by_name(name, small=small)
        except KeyError:
            names = ", ".join(w.name for w in kernelbench.suite(small=small))
            raise ServiceError("bad_request",
                               f"unknown workload {name!r}; available "
                               f"({self.cfg.suite} suite): {names}")
        platform = body.get("platform", DEFAULT_PLATFORM)
        if platform not in available_platforms():
            raise ServiceError(
                "bad_request",
                f"unknown platform {platform!r}; available: "
                + ", ".join(available_platforms()))
        backend = body.get("backend", "template")
        if backend not in ("template", "llm"):
            raise ServiceError("bad_request",
                               f"backend must be 'template' or 'llm', "
                               f"got {backend!r}")
        direction = body.get("direction", "fwd")
        if direction not in ("fwd", "fwd_bwd"):
            raise ServiceError("bad_request",
                               f"direction must be 'fwd' or 'fwd_bwd', "
                               f"got {direction!r}")
        if direction == "fwd_bwd" and not wl.differentiable:
            raise ServiceError(
                "bad_request",
                f"workload {name!r} is not differentiable; fwd_bwd "
                "verification needs a jax.vjp-compatible oracle")
        search = body.get("search", "lineage")
        if search not in ("lineage", "pbt"):
            raise ServiceError("bad_request",
                               f"search must be 'lineage' or 'pbt', "
                               f"got {search!r}")
        if search == "pbt" and backend == "llm":
            raise ServiceError(
                "bad_request",
                "search 'pbt' requires the template backend: population "
                "search exploit-copies declarative tiling params, which "
                "LLM callable candidates do not carry")
        isolate = bool(body.get("isolate", False))
        if isolate and self.pool is None:
            raise ServiceError(
                "bad_request",
                "isolate requested but this daemon has no pre-forked "
                "worker pool (start it with --isolate-workers N)")
        if isolate and backend == "llm":
            raise ServiceError(
                "bad_request",
                "LLM-backed requests are thread-mode only: a forked worker "
                "would split the daemon's shared transport/limiter/meter "
                "state (drop 'isolate')")
        deadline = body.get("deadline_s")
        if deadline is not None:
            if not isinstance(deadline, (int, float)) or deadline <= 0:
                raise ServiceError("bad_request",
                                   f"deadline_s must be a positive number, "
                                   f"got {deadline!r}")
            deadline = float(deadline)
        iters = body.get("iters", 5)
        if not isinstance(iters, int) or iters < 1:
            raise ServiceError("bad_request",
                               f"iters must be a positive integer, "
                               f"got {iters!r}")
        population = body.get("population", 4)
        generations = body.get("generations", 4)
        if search == "pbt":
            if not isinstance(population, int) or population < 2:
                raise ServiceError("bad_request",
                                   f"population must be an integer >= 2, "
                                   f"got {population!r}")
            if not isinstance(generations, int) or generations < 1:
                raise ServiceError("bad_request",
                                   f"generations must be an integer >= 1, "
                                   f"got {generations!r}")
        tenant = body.get("tenant", "anon")
        if not isinstance(tenant, str) or not tenant:
            raise ServiceError("bad_request",
                               "'tenant' must be a non-empty string")
        loop = LoopConfig(
            num_iterations=iters, seed=int(body.get("seed", 0)),
            platform=platform, direction=direction, search=search,
            population=population, generations=generations,
            use_reference=bool(body.get("use_reference", False)),
            use_profiling=bool(body.get("use_profiling", False)),
            single_shot=bool(body.get("single_shot", False)))
        key = json.dumps({"workload": name, "suite": self.cfg.suite,
                          "backend": backend, "isolate": isolate,
                          "loop": dataclasses.asdict(loop)}, sort_keys=True)
        return _Request(tenant=tenant, workload=wl, loop=loop,
                        backend=backend, isolate=isolate,
                        deadline_s=deadline, key=key)

    # -- LLM context -------------------------------------------------------

    def _llm_context(self):
        """The daemon-wide LLM fleet context (one shared transport, meter
        and — via the fairness limiter — pacing), built lazily on the
        first LLM-backed request."""
        with self._llm_lock:
            if self._llm is None:
                from repro.llm import build_llm_context
                self._llm = build_llm_context(record=self.cfg.llm_record,
                                              replay=self.cfg.llm_replay)
            return self._llm

    # -- request execution -------------------------------------------------

    def _execute(self, req: _Request) -> Dict[str, Any]:
        """Run one request to completion inside a scheduler job; returns
        the response core (always a dict, ``ok`` False on infra errors)."""
        wl, loop = req.workload, req.loop
        t0 = time.perf_counter()
        if req.isolate:
            spec = {"workload": wl.name, "suite": self.cfg.suite,
                    "loop": dataclasses.asdict(loop),
                    "cache_path": self.cfg.cache_path}
            timeout = req.deadline_s or self.cfg.request_timeout_s
            core = self.pool.submit(spec, timeout_s=timeout)
            core.setdefault("workload", wl.name)
            core.setdefault("platform", loop.platform)
            core["isolated"] = True
            core["duration_s"] = time.perf_counter() - t0
            core["llm_usage"] = None
            if core.get("ok") and self.log is not None:
                self._journal_workload_done(req, core)
            return core
        meter = None
        agent = None
        if req.backend == "llm":
            ctx = self._llm_context()
            from repro.llm import UsageMeter
            # per-request meter parented on the fleet meter: THIS tenant's
            # spend journals as its own delta (the PR-5 matrix-leg pattern)
            # while the fleet meter still totals everything
            meter = UsageMeter(parent=ctx.usage)
            agent = ctx.agent_factory(
                platform=loop.platform, scheduler=self.scheduler,
                usage=meter, limiter=self.fairness.for_tenant(req.tenant))()
        on_iteration = None
        if self.log is not None:
            def on_iteration(it):
                self.log.append(ev_mod.iteration_event(
                    wl.name, wl.level, it, platform=loop.platform))
        if loop.search == "pbt":
            from repro.campaign import population as pop_mod
            outcome = pop_mod.run_workload_pbt(
                wl, loop, cache=self.cache, io_cache=self.io_cache,
                exe_cache=self.exe_cache, scheduler=self.scheduler,
                on_generation=(self.log.append if self.log is not None
                               else None))
        else:
            outcome = run_workload(
                wl, loop, agent=agent, cache=self.cache,
                io_cache=self.io_cache, exe_cache=self.exe_cache,
                on_iteration=on_iteration)
        final = outcome.final
        usage = meter.snapshot() if meter is not None else None
        if usage is not None:
            self._account_llm(req.tenant, usage)
        core = {
            "ok": True, "workload": wl.name, "platform": loop.platform,
            "level": wl.level, "state": final.state.value,
            "correct": final.correct, "speedup": final.speedup,
            "model_time_s": final.model_time_s,
            "iterations": len(outcome.logs),
            "iters_to_correct": ev_mod.iterations_to_correct(outcome.logs),
            "result": ev_mod.result_to_dict(final),
            "isolated": False,
            "duration_s": time.perf_counter() - t0,
            "llm_usage": usage,
        }
        if self.log is not None:
            self._journal_workload_done(req, core)
        return core

    def _journal_workload_done(self, req: _Request, core: Dict) -> None:
        """Campaign-shaped terminal event: the service journal stays a
        valid campaign log (``--report-only`` and resume both work)."""
        self.log.append({
            "event": "workload_done", "workload": req.name,
            "level": req.workload.level,
            "duration_s": core.get("duration_s"),
            "iterations": core.get("iterations"),
            "iters_to_correct": core.get("iters_to_correct"),
            "io": core.get("io") or verif_mod.io_signature(req.workload),
            "platform": req.loop.platform,
            "direction": req.loop.direction,
            "loop": dataclasses.asdict(req.loop),
            "final": core["result"],
        })

    def _run_request(self, req: _Request) -> Dict[str, Any]:
        """The scheduler-job body: execute, then retire the in-flight
        entry and (on success) memoize the response core."""
        try:
            core = self._execute(req)
        finally:
            with self._lock:
                self._inflight.pop(req.key, None)
        if core.get("ok"):
            memo = dict(core)
            # memo copies never re-attribute the creator's LLM spend
            memo["llm_usage"] = None
            with self._lock:
                self._memo[req.key] = memo
                self._memo.move_to_end(req.key)
                while len(self._memo) > self.cfg.memo_entries:
                    self._memo.popitem(last=False)
        return core

    # -- the /synthesize route ---------------------------------------------

    def handle_synthesize(self, body: Dict[str, Any]
                          ) -> Tuple[int, Dict[str, Any]]:
        t_recv = time.perf_counter()
        req = self._parse(body)
        with self._lock:
            if not self._accepting:
                raise ServiceError("shutting_down",
                                   "daemon is draining; not accepting new "
                                   "requests", status=503)
            self._rid += 1
            req.rid = self._rid
        if self.log is not None:
            self.log.append({
                "event": "request_received", "rid": req.rid,
                "tenant": req.tenant, "workload": req.name,
                "platform": req.loop.platform, "backend": req.backend,
                "search": req.loop.search,
                "direction": req.loop.direction,
                "isolate": req.isolate, "key": _key_sha(req.key),
            })
        # per-tenant admission pacing: the delay is slept HERE, in the
        # handler thread, before the request ever touches the scheduler
        throttle_s = self.fairness.reserve(req.tenant, tokens=0)
        if throttle_s > 0:
            time.sleep(throttle_s)

        served_from = "run"
        entry: Optional[_Inflight] = None
        with self._lock:
            memo = self._memo.get(req.key)
            if memo is not None:
                self._memo.move_to_end(req.key)
            else:
                entry = self._inflight.get(req.key)
                if entry is not None:
                    entry.waiters += 1
                    served_from = "coalesced"
                else:
                    entry = _Inflight(req.tenant)
                    self._inflight[req.key] = entry
                    entry.job = self.scheduler.submit(
                        f"req{req.rid}:{req.name}",
                        lambda: self._run_request(req))
        if memo is not None:
            resp = dict(memo)
            resp.update(served_from="memo", queue_s=0.0,
                        throttle_s=round(throttle_s, 6))
            return self._finish(req, 200, resp, t_recv)

        job = entry.job
        if not job.done.wait(req.deadline_s):
            cancelled = False
            with self._lock:
                entry.waiters -= 1
                if entry.waiters == 0 and job.try_cancel(
                        f"deadline {req.deadline_s}s exceeded while queued"):
                    self._inflight.pop(req.key, None)
                    cancelled = True
            tail = ("cancelled while queued" if cancelled else
                    "still running; its result will land in the daemon's "
                    "memo and caches")
            resp = {"ok": False, "workload": req.name,
                    "served_from": served_from,
                    "throttle_s": round(throttle_s, 6),
                    "error": {"kind": "deadline",
                              "message": f"request exceeded its "
                                         f"{req.deadline_s}s deadline "
                                         f"({tail})"}}
            return self._finish(req, 504, resp, t_recv)

        queue_s = max(0.0, (job.started_at or entry.t_enqueue)
                      - entry.t_enqueue)
        if job.error is not None:
            kind = "timeout" if "timeout" in job.error else "run_error"
            resp = {"ok": False, "workload": req.name,
                    "served_from": served_from,
                    "queue_s": round(queue_s, 6),
                    "throttle_s": round(throttle_s, 6),
                    "error": {"kind": kind, "message": job.error}}
            return self._finish(req, 504 if kind == "timeout" else 500,
                                resp, t_recv)
        resp = dict(job.value)
        resp.update(served_from=served_from, queue_s=round(queue_s, 6),
                    throttle_s=round(throttle_s, 6))
        if served_from == "coalesced":
            # the job creator's tenant owns the LLM spend, not attachers
            resp["llm_usage"] = None
        if not resp.get("ok"):
            err = resp.get("error") or {}
            status = 504 if err.get("kind") == "deadline" else 500
            return self._finish(req, status, resp, t_recv)
        return self._finish(req, 200, resp, t_recv)

    def _finish(self, req: _Request, status: int, resp: Dict[str, Any],
                t_recv: float) -> Tuple[int, Dict[str, Any]]:
        """Stamp response metadata, bump counters, journal request_done."""
        resp["rid"] = req.rid
        resp["tenant"] = req.tenant
        resp["wall_s"] = round(time.perf_counter() - t_recv, 6)
        ok = bool(resp.get("ok"))
        deduped = resp.get("served_from") in ("memo", "coalesced")
        with self._lock:
            self._counters["total"] += 1
            self._counters["ok" if ok else "errors"] += 1
            if deduped:
                self._counters["deduped"] += 1
            t = self._tenants.setdefault(
                req.tenant, {"requests": 0, "ok": 0, "errors": 0,
                             "deduped": 0, "llm_usage": None})
            t["requests"] += 1
            t["ok" if ok else "errors"] += 1
            if deduped:
                t["deduped"] += 1
        if self.log is not None:
            self.log.append({
                "event": "request_done", "rid": req.rid,
                "tenant": req.tenant, "workload": req.name,
                "platform": req.loop.platform, "ok": ok, "status": status,
                "served_from": resp.get("served_from"),
                "state": resp.get("state"),
                "queue_s": resp.get("queue_s"),
                "wall_s": resp.get("wall_s"),
                "throttle_s": resp.get("throttle_s"),
                "llm_usage": resp.get("llm_usage"),
                "error": resp.get("error"),
                # cumulative shared-cache snapshots: cache effectiveness is
                # auditable per request from the journal alone
                "cache": self.cache.stats(),
                "io_cache": self.io_cache.stats(),
                "exe_cache": self.exe_cache.stats(),
            })
        return status, resp

    def _account_llm(self, tenant: str, usage: Dict[str, Any]) -> None:
        with self._lock:
            t = self._tenants.setdefault(
                tenant, {"requests": 0, "ok": 0, "errors": 0,
                         "deduped": 0, "llm_usage": None})
            if t["llm_usage"] is None:
                t["llm_usage"] = dict(usage)
            else:
                for k, v in usage.items():
                    t["llm_usage"][k] = round(t["llm_usage"].get(k, 0) + v, 6)

    def note_disconnect(self) -> None:
        """A client vanished mid-request (broken pipe while replying);
        journaled so operators can see flapping clients — the daemon
        itself keeps serving."""
        with self._lock:
            self._counters["disconnects"] += 1
        if self.log is not None:
            self.log.append({"event": "request_error",
                             "kind": "client_disconnect"})

    def note_bad_request(self, kind: str, message: str) -> None:
        with self._lock:
            self._counters["errors"] += 1
        if self.log is not None:
            self.log.append({"event": "request_error", "kind": kind,
                             "error": message})

    # -- the /health route -------------------------------------------------

    def health(self) -> Dict[str, Any]:
        with self._lock:
            counters = dict(self._counters)
            tenants = {k: dict(v) for k, v in sorted(self._tenants.items())}
            inflight = len(self._inflight)
            memo_entries = len(self._memo)
            accepting = self._accepting
        out = {
            "ok": True, "accepting": accepting,
            "uptime_s": round(time.perf_counter() - self._t_start, 3),
            "suite": self.cfg.suite,
            "requests": counters, "tenants": tenants,
            "inflight": inflight, "memo_entries": memo_entries,
            "warmed_cache_entries": self._warmed,
            "cache": self.cache.stats(),
            "io_cache": self.io_cache.stats(),
            "exe_cache": self.exe_cache.stats(),
            "scheduler": self.scheduler.telemetry(),
            "fairness": self.fairness.stats(),
            "pool": self.pool.stats() if self.pool is not None else None,
        }
        if self._llm is not None:
            out["llm_usage"] = self._llm.usage.snapshot()
        return out

    def report_text(self) -> str:
        """The combined fast_p + service report rendered from the journal
        (requires ``log_path``)."""
        from repro.campaign.report import format_report, report_from_events
        if self.log is None:
            raise ServiceError("no_journal",
                               "this daemon runs without --log; no journal "
                               "to report from", status=404)
        return format_report(report_from_events(self.log.events()))


# -- prefork child-side handler ---------------------------------------------

def isolated_request_handler(spec: Dict[str, Any]) -> Dict[str, Any]:
    """The request handler run INSIDE a pre-forked worker (imported by the
    child after the fork — this module pulls jax, which is exactly why
    :mod:`repro.service.workers` defers the import).

    Isolated workers share no memory with the daemon: only the persistent
    JSONL verification cache (``cache_path``) is shared, via the
    filesystem. Returns the same response core shape as the thread path.
    """
    wl = kernelbench.by_name(spec["workload"],
                             small=spec.get("suite", "small") == "small")
    loop = LoopConfig(**spec["loop"])
    cache = (VerificationCache.open(spec["cache_path"])
             if spec.get("cache_path") else None)
    t0 = time.perf_counter()
    outcome = run_workload(wl, loop, cache=cache)
    final = outcome.final
    return {
        "ok": True, "workload": wl.name, "platform": loop.platform,
        "level": wl.level, "state": final.state.value,
        "correct": final.correct, "speedup": final.speedup,
        "model_time_s": final.model_time_s,
        "iterations": len(outcome.logs),
        "iters_to_correct": ev_mod.iterations_to_correct(outcome.logs),
        "result": ev_mod.result_to_dict(final),
        "io": verif_mod.io_signature(wl),
        "duration_s": time.perf_counter() - t0,
    }


# -- HTTP layer --------------------------------------------------------------

class _ServiceHTTPServer(ThreadingHTTPServer):
    daemon_threads = True
    allow_reuse_address = True
    service: SynthesisService


class _Handler(BaseHTTPRequestHandler):
    """Thin JSON shim over :class:`SynthesisService`: one thread per
    connection (ThreadingHTTPServer), every route answered with a JSON
    body, every failure structured. Client disconnects while replying are
    absorbed (``note_disconnect``) — a flapping client never takes the
    daemon down."""

    protocol_version = "HTTP/1.1"
    server_version = "KForgeService/1.0"
    timeout = 120

    @property
    def service(self) -> SynthesisService:
        return self.server.service

    def log_message(self, fmt: str, *args: Any) -> None:  # quiet
        pass

    def _reply(self, status: int, payload: Dict[str, Any]) -> None:
        data = json.dumps(payload, default=str).encode()
        try:
            self.send_response(status)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(data)))
            self.end_headers()
            self.wfile.write(data)
        except (BrokenPipeError, ConnectionResetError, OSError):
            self.close_connection = True
            self.service.note_disconnect()

    def _json_body(self) -> Dict[str, Any]:
        length = int(self.headers.get("Content-Length") or 0)
        raw = self.rfile.read(length) if length > 0 else b""
        if len(raw) < length:
            raise ServiceError(
                "client_disconnect",
                f"request body truncated ({len(raw)}/{length} bytes) — "
                "client disconnected mid-request", status=400)
        try:
            return json.loads(raw.decode() or "{}")
        except (json.JSONDecodeError, UnicodeDecodeError) as exc:
            raise ServiceError("bad_json",
                               f"request body is not valid JSON: {exc}")

    def do_POST(self) -> None:  # noqa: N802 — stdlib handler contract
        try:
            if self.path == "/shutdown":
                drained = self.service.begin_shutdown()
                self._reply(200, {"ok": True, "draining": drained})
                threading.Thread(target=self.service.stop,
                                 daemon=True).start()
                return
            body = self._json_body()
            if self.path == "/synthesize":
                status, payload = self.service.handle_synthesize(body)
            else:
                raise ServiceError("not_found",
                                   f"unknown route {self.path!r}; POST "
                                   "/synthesize or /shutdown", status=404)
        except ServiceError as exc:
            if exc.kind in ("bad_json", "bad_request", "client_disconnect"):
                self.service.note_bad_request(exc.kind, str(exc))
            status, payload = exc.status, exc.payload()
        except Exception as exc:  # noqa: BLE001 — daemon must stay up
            status, payload = 500, {
                "ok": False,
                "error": {"kind": "internal",
                          "message": f"{type(exc).__name__}: {exc}"}}
        self._reply(status, payload)

    def do_GET(self) -> None:  # noqa: N802 — stdlib handler contract
        try:
            if self.path == "/health":
                status, payload = 200, self.service.health()
            elif self.path == "/report":
                status, payload = 200, {"ok": True,
                                        "report": self.service.report_text()}
            else:
                raise ServiceError("not_found",
                                   f"unknown route {self.path!r}; GET "
                                   "/health or /report", status=404)
        except ServiceError as exc:
            status, payload = exc.status, exc.payload()
        except Exception as exc:  # noqa: BLE001 — daemon must stay up
            status, payload = 500, {
                "ok": False,
                "error": {"kind": "internal",
                          "message": f"{type(exc).__name__}: {exc}"}}
        self._reply(status, payload)
