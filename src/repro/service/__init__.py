"""repro.service — the synthesis-as-a-service daemon (DESIGN.md §12).

Naming note: this is NOT :mod:`repro.serve`. ``repro.serve`` is the
seed's batched *model-inference* engine (prefill/decode slots over a
fixed-shape KV cache); ``repro.service`` is the *synthesis* daemon — a
long-running process that accepts queued synthesis requests over a local
HTTP JSON API and multiplexes them onto the shared scheduler + cache
stack. Start it with ``python -m repro.service``; talk to it with
``tools/kforge_client.py``.

Import discipline: importing this package must NOT import jax. The
``python -m repro.service`` entrypoint pre-forks isolation workers
*before* the jax-heavy daemon module loads (the pre-fork rule —
:mod:`repro.service.workers`), so the daemon classes are exported lazily
via PEP 562 ``__getattr__``; only :class:`PreforkPool` and
:class:`TenantFairLimiter` (both stdlib-only) load eagerly.
"""
from repro.service.fairness import TenantFairLimiter
from repro.service.workers import PreforkPool

# jax-heavy names resolved lazily from repro.service.daemon on first touch
_DAEMON_EXPORTS = ("ServiceConfig", "SynthesisService", "ServiceError",
                   "isolated_request_handler")

__all__ = ["PreforkPool", "TenantFairLimiter", *_DAEMON_EXPORTS]


def __getattr__(name):
    if name in _DAEMON_EXPORTS:
        from repro.service import daemon
        return getattr(daemon, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
