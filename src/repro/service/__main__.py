"""CLI entrypoint: ``python -m repro.service`` (DESIGN.md §12).

Order of operations is the whole point of this file: parse args, fork
the isolation worker pool from a process that has never imported jax
(the pre-fork rule — :mod:`repro.service.workers`), and only THEN import
the jax-heavy daemon module and start serving. Keep module-level imports
stdlib-only.
"""
from __future__ import annotations

import argparse
import signal
import sys
import threading
from typing import List, Optional


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.service",
        description="KForge synthesis-as-a-service daemon: accepts queued "
                    "synthesis requests over a local HTTP JSON API and "
                    "multiplexes them onto a shared scheduler + cache "
                    "stack (NOT repro.serve, the batched inference "
                    "engine).")
    ap.add_argument("--host", default="127.0.0.1",
                    help="bind address (loopback only by design)")
    ap.add_argument("--port", type=int, default=8741,
                    help="TCP port; 0 picks an ephemeral port")
    ap.add_argument("--workers", type=int, default=4,
                    help="scheduler slots: concurrent thread-mode requests")
    ap.add_argument("--suite", choices=("small", "full"), default="small",
                    help="workload resolution suite")
    ap.add_argument("--timeout", type=float, default=None, metavar="S",
                    help="per-request watchdog deadline in seconds "
                         "(thread-mode runaway backstop)")
    ap.add_argument("--log", default=None, metavar="PATH",
                    help="JSONL service journal (also the resume source: "
                         "a restarted daemon pre-warms its verification "
                         "cache from it)")
    ap.add_argument("--cache-path", default=None, metavar="PATH",
                    help="persistent JSONL verification cache shared with "
                         "isolated workers")
    ap.add_argument("--isolate-workers", type=int, default=0, metavar="N",
                    help="pre-fork N isolation workers before jax import; "
                         "0 disables the isolate lane")
    ap.add_argument("--rpm", type=float, default=None,
                    help="fleet requests-per-minute budget (admissions + "
                         "LLM calls)")
    ap.add_argument("--tpm", type=float, default=None,
                    help="fleet tokens-per-minute budget")
    ap.add_argument("--tenant-rpm", type=float, default=None,
                    help="per-tenant requests-per-minute slice of the "
                         "fleet budget")
    ap.add_argument("--tenant-tpm", type=float, default=None,
                    help="per-tenant tokens-per-minute slice")
    ap.add_argument("--record", default=None, metavar="PATH",
                    help="record LLM-backed requests' sessions to this "
                         "JSONL")
    ap.add_argument("--replay", default=None, metavar="PATH",
                    help="serve LLM-backed requests from a recorded "
                         "session (zero live calls)")
    return ap


def main(argv: Optional[List[str]] = None) -> int:
    ap = build_parser()
    args = ap.parse_args(argv)
    if args.record and args.replay:
        ap.error("--record and --replay are mutually exclusive")
    if args.isolate_workers < 0:
        ap.error("--isolate-workers must be >= 0")

    pool = None
    if args.isolate_workers:
        # fork BEFORE the daemon import below pulls jax — children that
        # fork from a jax-free parent can each import jax safely themselves
        from repro.service.workers import PreforkPool
        pool = PreforkPool(args.isolate_workers)

    from repro.service.daemon import ServiceConfig, SynthesisService
    cfg = ServiceConfig(
        host=args.host, port=args.port, workers=args.workers,
        suite=args.suite, request_timeout_s=args.timeout,
        log_path=args.log, cache_path=args.cache_path,
        rpm=args.rpm, tpm=args.tpm,
        tenant_rpm=args.tenant_rpm, tenant_tpm=args.tenant_tpm,
        llm_record=args.record, llm_replay=args.replay)
    service = SynthesisService(cfg, pool=pool)
    service.start()
    print(f"kforge service on http://{service.host}:{service.port} "
          f"(suite={cfg.suite}, workers={cfg.workers}, "
          f"isolate_workers={args.isolate_workers}) — POST /synthesize, "
          "GET /health, POST /shutdown", flush=True)

    def _term(signum, frame):
        threading.Thread(target=service.stop, daemon=True).start()

    signal.signal(signal.SIGTERM, _term)
    try:
        service.wait()
    except KeyboardInterrupt:
        print("draining...", flush=True)
        service.stop()
    return 0


if __name__ == "__main__":
    sys.exit(main())
