"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state. The dry-run overrides the host
platform device count to 512 before any jax import; smoke tests and
benchmarks see the real single device.
"""
from __future__ import annotations

import math

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False) -> "jax.sharding.Mesh":
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"need {need} devices, have {len(devices)} — the dry-run must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before any "
            "jax import")
    devs = np.asarray(devices[:need]).reshape(shape)
    return jax.sharding.Mesh(devs, axes)


def make_debug_mesh(data: int = 1, model: int = 1) -> "jax.sharding.Mesh":
    """Tiny mesh over available devices for tests."""
    devs = np.asarray(jax.devices()[: data * model]).reshape(data, model)
    return jax.sharding.Mesh(devs, ("data", "model"))


def mesh_desc(mesh) -> str:
    return "x".join(f"{k}={v}" for k, v in mesh.shape.items())
