"""Training launcher.

Single-host debug runs execute real steps on the local device(s); with
``--dryrun`` it delegates to launch/dryrun.py semantics (lower+compile only).
On a real TPU fleet this same entrypoint runs under
``jax.distributed.initialize()`` with one process per host.

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch starcoder2-7b \
      --reduced --steps 20 --seq-len 128 --global-batch 8
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.data import DataConfig, TokenPipeline
from repro.models import build_model
from repro.train import Trainer, TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config of the same family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--peak-lr", type=float, default=3e-4)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    tc = TrainConfig(
        peak_lr=args.peak_lr, total_steps=args.steps,
        warmup_steps=max(1, args.steps // 10),
        microbatches=args.microbatches,
        grad_compression=args.grad_compression,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    pipe = TokenPipeline(DataConfig(
        seq_len=args.seq_len, global_batch=args.global_batch,
        vocab_size=cfg.vocab_size, seed=args.seed))
    trainer = Trainer(model, tc, rng=jax.random.PRNGKey(args.seed))
    if trainer.restore_if_available(pipe):
        print(f"restored from step {trainer.step_num}")

    t0 = time.monotonic()
    for metrics in trainer.fit(pipe, args.steps):
        if trainer.step_num % args.log_every == 0 or \
                trainer.step_num == args.steps:
            tok_s = (args.global_batch * args.seq_len
                     / max(metrics["step_time_s"], 1e-9))
            print(f"step {trainer.step_num:5d} loss={metrics['loss']:.4f} "
                  f"gnorm={metrics['grad_norm']:.2f} "
                  f"tok/s={tok_s:,.0f}", flush=True)
    wall = time.monotonic() - t0
    print(json.dumps({"steps": trainer.step_num, "wall_s": round(wall, 1),
                      "final_loss": trainer.history[-1]["loss"]}))


if __name__ == "__main__":
    main()
