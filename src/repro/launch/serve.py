"""Serving launcher: batched generation with the slot-based engine.

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-moe-a2.7b \
      --reduced --requests 4 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serve import Engine, ServeConfig
from repro.serve.engine import Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = Engine(model, params, ServeConfig(
        max_batch=args.max_batch, max_seq=args.max_seq,
        temperature=args.temperature))

    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        plen = int(rng.integers(4, 17))
        engine.submit(Request(
            rid=rid, prompt=rng.integers(0, cfg.vocab_size, plen),
            max_new_tokens=args.max_new))
    t0 = time.monotonic()
    done = engine.run()
    wall = time.monotonic() - t0
    total_tokens = sum(len(v) for v in done.values())
    for rid in sorted(done):
        print(f"request {rid}: {done[rid]}")
    print(f"{total_tokens} tokens in {wall:.2f}s "
          f"({total_tokens / max(wall, 1e-9):.1f} tok/s)")


if __name__ == "__main__":
    main()
