import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

# Multi-pod dry-run entrypoint. The two lines above MUST run before any jax
# import (jax locks the device count on first init); all machinery lives in
# launch/cells.py so tests can import it without the 512-device side effect.
#
# Usage:
#   python -m repro.launch.dryrun --arch yi-34b --shape train_4k --mesh both
#   python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun

import argparse  # noqa: E402

from repro.configs import get_config, list_archs  # noqa: E402
from repro.launch.cells import (  # noqa: E402,F401  (re-exported for compat)
    OVERRIDES, lower_cell, model_flops_total, run_cell,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = list_archs() if args.all or not args.arch else [args.arch]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = 0
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([args.shape] if args.shape
                  else [s.name for s in cfg.shapes()]
                  + [s.name for s, _ in cfg.skipped_shapes()])
        for shape_name in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape_name, mp, out_dir=args.out)
                status = rec["status"]
                line = f"[{status:4s}] {rec['cell']}"
                if status == "ok":
                    r = rec["roofline"]
                    line += (f"  compile={rec['compile_s']}s"
                             f"  dom={r['dominant']}"
                             f"  step≈{r['step_time_s']*1e3:.1f}ms"
                             f"  roofline={r['roofline_fraction']:.2%}")
                elif status == "skip":
                    line += f"  ({rec['reason']})"
                else:
                    failures += 1
                    line += f"  {rec['error']}"
                print(line, flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
