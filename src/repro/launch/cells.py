"""Dry-run cell machinery (mesh-parameterized lower+compile+roofline).

Imported by launch/dryrun.py (which owns the 512-device XLA flag) and by
tests (which use a debug mesh). Original doc: Multi-pod dry-run: ``.lower().compile()`` every (arch × shape × mesh).

For each cell this lowers the REAL step function (train_step including the
optimizer update, prefill_step, or decode_step) with production shardings on
the 16×16 single-pod mesh and the 2×16×16 multi-pod mesh, compiles it on the
forced-512-device host platform, and records:

  * ``compiled.memory_analysis()``  — bytes/device (proves the cell fits)
  * ``compiled.cost_analysis()``    — per-device FLOPs / bytes (§Roofline)
  * collective bytes parsed from the optimized HLO

Usage:
  python -m repro.launch.dryrun --arch yi-34b --shape train_4k --mesh both
  python -m repro.launch.dryrun --all --mesh single --out experiments/dryrun
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.configs.base import SHAPES
from repro.launch.mesh import make_production_mesh, mesh_desc
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.roofline import roofline_report
from repro.sharding import make_rules, resolve_axes, set_rules, spec_tree
from repro.train.trainer import TrainConfig, make_train_step

DTYPE = jnp.bfloat16

# Per-(arch, shape) overrides tuned during §Perf iterations.
OVERRIDES: dict = {}


def _named(mesh, axes, shapes, rules):
    return jax.tree.map(
        lambda ax, sds: jax.sharding.NamedSharding(
            mesh, resolve_axes(ax, rules, tuple(sds.shape))),
        axes, shapes, is_leaf=lambda t: isinstance(t, tuple))


def _abstract_opt_state(params_abs, logical, cfg: AdamWConfig):
    f32 = lambda sds: jax.ShapeDtypeStruct(sds.shape, jnp.float32)
    state = {"mu": jax.tree.map(f32, params_abs),
             "nu": jax.tree.map(f32, params_abs),
             "step": jax.ShapeDtypeStruct((), jnp.int32)}
    axes = {"mu": logical, "nu": logical, "step": ()}
    if cfg.master_fp32:
        state["master"] = jax.tree.map(f32, params_abs)
        axes["master"] = logical
    return state, axes


def lower_cell(arch: str, shape_name: str, mesh, *,
               microbatches: int = 1):
    """Lower + compile one cell. Returns (compiled, lowered, aux dict)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    tp = mesh.shape.get("model", 1)
    model = build_model(cfg, tp_size=tp)
    rules = make_rules(mesh)
    params_abs = model.abstract_params(DTYPE)
    logical = model.logical_specs()
    params_sh = spec_tree(logical, rules, params_abs)
    in_specs = model.input_specs(shape, DTYPE)
    in_axes = model.input_logical_axes(shape)
    in_sh = _named(mesh, in_axes, in_specs, rules)

    with set_rules(rules):
        if shape.kind == "train":
            tc = TrainConfig(impl="xla", remat=True, microbatches=microbatches,
                             adamw=AdamWConfig())
            step = make_train_step(model, tc)
            opt_abs, opt_axes = _abstract_opt_state(params_abs, logical,
                                                    tc.adamw)
            opt_sh = _named(mesh, opt_axes, opt_abs, rules)
            lowered = jax.jit(
                step,
                in_shardings=(params_sh, opt_sh, in_sh),
                out_shardings=(params_sh, opt_sh, None),
                donate_argnums=(0, 1),
            ).lower(params_abs, opt_abs, in_specs)
        elif shape.kind == "prefill":
            extra_name = {"encdec": "frames", "vlm": "vision"}.get(cfg.family)

            if extra_name:
                def prefill(params, tokens, extra):
                    return model.prefill_fn(params, tokens, impl="xla",
                                            **{extra_name: extra})
                lowered = jax.jit(
                    prefill,
                    in_shardings=(params_sh, in_sh["tokens"],
                                  in_sh[extra_name]),
                ).lower(params_abs, in_specs["tokens"],
                        in_specs[extra_name])
            else:
                def prefill(params, tokens):
                    return model.prefill_fn(params, tokens, impl="xla")
                lowered = jax.jit(
                    prefill, in_shardings=(params_sh, in_sh["tokens"]),
                ).lower(params_abs, in_specs["tokens"])
        else:  # decode
            cache_abs, cache_axes = model.abstract_cache(
                shape.global_batch, shape.seq_len, DTYPE)
            cache_sh = _named(mesh, cache_axes, cache_abs, rules)

            def decode(params, cache, tokens, lengths):
                return model.decode_fn(params, cache, tokens, lengths,
                                       impl="xla")

            lowered = jax.jit(
                decode,
                in_shardings=(params_sh, cache_sh, in_sh["tokens"],
                              in_sh["lengths"]),
                out_shardings=(None, cache_sh),
                donate_argnums=(1,),
            ).lower(params_abs, cache_abs, in_specs["tokens"],
                    in_specs["lengths"])
        compiled = lowered.compile()
    return compiled, lowered, {"cfg": cfg, "shape": shape, "model": model}


def model_flops_total(cfg, shape) -> float:
    n = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6 if shape.kind == "train" else 2
    return float(mult * n * tokens)


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir=None):
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    cell = f"{arch} × {shape_name} × {'2x16x16' if multi_pod else '16x16'}"
    if shape.kind == "decode" and not cfg.has_decoder:
        return {"cell": cell, "status": "skip",
                "reason": "encoder-only arch has no decode step"}
    if shape.subquadratic_only and not cfg.subquadratic:
        return {"cell": cell, "status": "skip",
                "reason": "full-attention arch; long_500k needs sub-quadratic"}
    t0 = time.monotonic()
    mesh = make_production_mesh(multi_pod=multi_pod)
    mb = OVERRIDES.get((arch, shape_name), {}).get("microbatches", 1)
    try:
        compiled, lowered, aux = lower_cell(arch, shape_name, mesh,
                                            microbatches=mb)
    except Exception as exc:  # noqa: BLE001
        return {"cell": cell, "status": "FAIL",
                "error": f"{type(exc).__name__}: {exc}",
                "trace": traceback.format_exc()[-2000:]}
    compile_s = time.monotonic() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    chips = mesh.devices.size
    rep = roofline_report(
        arch=arch, shape=shape_name, mesh_desc=mesh_desc(mesh), chips=chips,
        cost=cost, hlo_text=hlo,
        model_flops_total=model_flops_total(cfg, shape),
        bytes_per_device=getattr(mem, "temp_size_in_bytes", None))
    record = {
        "cell": cell, "status": "ok", "compile_s": round(compile_s, 1),
        "memory": {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)},
        "roofline": rep.to_dict(),
    }
    if out_dir:
        out_dir = Path(out_dir)
        out_dir.mkdir(parents=True, exist_ok=True)
        name = f"{arch}_{shape_name}_{'multi' if multi_pod else 'single'}.json"
        (out_dir / name).write_text(json.dumps(record, indent=2))
    return record


