"""Kernel workloads: the KForge task definition.

A workload is one benchmark problem: an oracle (the 'PyTorch module' of
KernelBench, here a pure-jnp reference), an input generator, the op family
the generation agent targets, and a difficulty level (paper §4.1):
  L1 — single primitives, L2 — fusable operation sequences,
  L3 — architecture blocks from the assigned archs.

Training-shaped workloads set ``differentiable=True`` and gain a gradient
oracle: ``jax.vjp`` over ``ref_fn`` with a seed-derived cotangent.
``direction="fwd_bwd"`` verification (core/verification.py) scores a
candidate against both the forward output and these reference gradients.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Workload:
    name: str
    level: int                      # 1 | 2 | 3
    op: str                         # candidate op family (candidates.SPACES)
    ref_fn: Callable                # oracle
    input_fn: Callable              # rng -> dict of named arrays
    input_shapes: Dict[str, Tuple[int, ...]]
    tol: float = 2e-3
    description: str = ""
    arch_tag: Optional[str] = None  # assigned architecture it derives from
    differentiable: bool = False    # eligible for direction="fwd_bwd"

    def inputs(self, seed: int = 0) -> Dict[str, jax.Array]:
        return self.input_fn(np.random.default_rng(seed))

    def reference(self, inputs: Dict[str, jax.Array]) -> jax.Array:
        return self.ref_fn(**inputs)

    # -- gradient oracle (direction="fwd_bwd") ------------------------------

    def grad_input_names(self, inputs: Dict[str, jax.Array]) -> Tuple[str, ...]:
        """Inputs the backward pass differentiates with respect to: the
        inexact (floating-point) ones. Integer inputs (labels, positions)
        carry no gradient."""
        return tuple(k for k, v in inputs.items()
                     if jnp.issubdtype(jnp.asarray(v).dtype, jnp.inexact))

    def cotangent(self, inputs: Dict[str, jax.Array],
                  seed: int = 0) -> jax.Array:
        """Seed-derived cotangent shaped like the reference output.

        Deterministic per (workload inputs, seed) and derived from a seed
        stream distinct from ``inputs(seed)``'s so the cotangent is not
        correlated with the input draw. Uses ``jax.eval_shape`` so the
        oracle itself never runs just to size the cotangent."""
        out = jax.eval_shape(lambda ins: self.ref_fn(**ins), inputs)
        leaf = jax.tree_util.tree_leaves(out)[0]
        rng = np.random.default_rng([seed, _COTANGENT_STREAM])
        return jnp.asarray(rng.standard_normal(leaf.shape), leaf.dtype)

    def grad_reference(self, inputs: Dict[str, jax.Array],
                       cotangent: jax.Array) -> Dict[str, jax.Array]:
        """Oracle gradients: ``jax.vjp`` over ``ref_fn`` w.r.t. every
        float input, pulled back through ``cotangent``. Returns a dict
        keyed like ``inputs`` (float entries only)."""
        names = self.grad_input_names(inputs)
        rest = {k: v for k, v in inputs.items() if k not in names}

        def f(diff):
            return self.ref_fn(**diff, **rest)

        _, vjp = jax.vjp(f, {k: inputs[k] for k in names})
        (grads,) = vjp(cotangent)
        return dict(grads)


#: Second word of the cotangent SeedSequence — keeps the cotangent draw
#: decorrelated from ``inputs(seed)``'s ``default_rng(seed)`` stream.
_COTANGENT_STREAM = 0xC07A


def randn(rng, shape, scale=1.0, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)
