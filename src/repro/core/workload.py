"""Kernel workloads: the KForge task definition.

A workload is one benchmark problem: an oracle (the 'PyTorch module' of
KernelBench, here a pure-jnp reference), an input generator, the op family
the generation agent targets, and a difficulty level (paper §4.1):
  L1 — single primitives, L2 — fusable operation sequences,
  L3 — architecture blocks from the assigned archs.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Workload:
    name: str
    level: int                      # 1 | 2 | 3
    op: str                         # candidate op family (candidates.SPACES)
    ref_fn: Callable                # oracle
    input_fn: Callable              # rng -> dict of named arrays
    input_shapes: Dict[str, Tuple[int, ...]]
    tol: float = 2e-3
    description: str = ""
    arch_tag: Optional[str] = None  # assigned architecture it derives from

    def inputs(self, seed: int = 0) -> Dict[str, jax.Array]:
        return self.input_fn(np.random.default_rng(seed))

    def reference(self, inputs: Dict[str, jax.Array]) -> jax.Array:
        return self.ref_fn(**inputs)


def randn(rng, shape, scale=1.0, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)
