"""Performance-analysis agent G (paper §3.2).

``G : (o, k, {v0..vn}) -> r`` — consumes the optimization prompt, the
current candidate, and profiling artifacts, and returns ONE recommendation
for the next synthesis iteration (the paper's design point: profiling data
is huge, optimization signals are sparse, so a separate agent distills one
action).

Two backends:
  * RuleBasedAnalyzer — deterministic TPU-roofline reasoning over the same
    profile dict the verifier produces (and, for dry-run cells, the
    loop-aware HLO cost report). This is what runs offline.
  * LLMAnalysisBackend hook — builds the §3.2 prompt (text + profile) for an
    external multimodal/chat model; see core/prompts.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

from repro.core.candidates import MXU, SPACES, Candidate
from repro.roofline.analysis import HW_V5E


@dataclasses.dataclass
class Recommendation:
    """One actionable optimization (the paper prompts G for exactly one)."""
    text: str                       # human/LLM readable
    param: Optional[str] = None     # structured action for the search backend
    value: Any = None

    def apply(self, cand: Candidate) -> Candidate:
        if self.param is None or self.param not in SPACES.get(cand.op, {}):
            return cand
        params = dict(cand.params)
        params[self.param] = self.value
        return Candidate(cand.op, params)


class RuleBasedAnalyzer:
    """Deterministic analysis over the candidate's profile."""

    def analyze(self, profile: Dict[str, Any]) -> Recommendation:
        op = profile["op"]
        params = profile["params"]
        shapes = profile["shapes"]
        model_t = profile["model_time_s"]
        flops = profile.get("flops", 0.0)
        compute_t = flops / HW_V5E["peak_flops"]
        space = SPACES.get(op, {})

        # Rule 1: compute far from roofline because tiles are MXU-misaligned.
        for key in ("block_m", "block_n", "block_q"):
            if key in params and params[key] < MXU and key in space \
                    and MXU in space[key]:
                return Recommendation(
                    text=(f"{key}={params[key]} underfills the 128x128 MXU "
                          f"systolic array; raise it to {MXU} so every pass "
                          "issues full-width matmuls."),
                    param=key, value=MXU)

        # Rule 2: memory-bound with tiny row tiles -> per-tile overheads and
        # poor HBM streaming; grow the sublane dimension (TPU analogue of
        # the paper's 8-elements-per-thread Metal optimization, §7.2).
        if compute_t < 0.5 * model_t:
            for key in ("block_rows", "block_t", "block_lanes", "block_cols",
                        "block_v"):
                if key in params and key in space:
                    bigger = [c for c in space[key] if c > params[key]]
                    if bigger:
                        return Recommendation(
                            text=(f"kernel is HBM-bound; {key}={params[key]} "
                                  f"tiles are too small to hide memory "
                                  f"latency — raise to {min(bigger)} to "
                                  "amortize per-tile overhead."),
                            param=key, value=min(bigger))

        # Rule 3: matmul K-tile too large relative to M/N starves the
        # accumulation pipeline; prefer squarer VMEM tiles.
        if op == "matmul" and params.get("block_k", 0) > \
                2 * max(params.get("block_m", 0), params.get("block_n", 0)):
            return Recommendation(
                text=("block_k dominates the VMEM working set; rebalance "
                      "toward square tiles (block_k=128) to double-buffer "
                      "more output tiles."),
                param="block_k", value=128)

        # Rule 4: attention kv tile growth reduces K/V re-streaming.
        if op == "attention" and "block_k" in params:
            bigger = [c for c in space["block_k"] if c > params["block_k"]]
            if bigger:
                return Recommendation(
                    text=("raise the KV tile so each K/V block streamed from "
                          "HBM amortizes over more query rows."),
                    param="block_k", value=min(bigger))

        return Recommendation(
            text="profile is near the modeled roofline; no single change "
                 "is predicted to exceed a 5% gain.")


def analyze_dryrun_cell(roofline: Dict[str, Any]) -> Recommendation:
    """G applied to a whole dry-run cell (the §Perf loop's advisor)."""
    dom = roofline["dominant"]
    cb = roofline.get("collective_breakdown", {})
    if dom == "collective":
        worst = max(cb, key=cb.get) if cb else "all-gather"
        hints = {
            "all-gather": "coalesce FSDP parameter gathers (gather once per "
                          "layer, reuse across microbatches) or shift the "
                          "sharding of the gathered tensor onto the pod axis",
            "all-reduce": "replace gradient all-reduce with reduce-scatter "
                          "into ZeRO shards, and keep TP partial sums in "
                          "bf16",
            "all-to-all": "batch the MoE dispatch all-to-all per layer and "
                          "shard the capacity buffer on the expert axis only",
            "collective-permute": "fold halo exchanges into the collective-"
                                  "matmul overlap",
        }
        return Recommendation(text=f"collective-bound ({worst}): "
                              f"{hints.get(worst, 'overlap collectives with compute')}")
    if dom == "memory":
        return Recommendation(text="memory-bound: raise arithmetic intensity "
                              "— fuse elementwise chains into the matmul "
                              "epilogue, keep activations bf16, and check "
                              "for remat-induced re-reads")
    return Recommendation(text="compute-bound: good — verify "
                          "useful_flops_fraction; if < 0.7, reduce remat "
                          "recompute or switch the checkpoint policy")
