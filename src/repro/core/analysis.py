"""Performance-analysis agent G (paper §3.2).

``G : (o, k, {v0..vn}) -> r`` — consumes the optimization prompt, the
current candidate, and profiling artifacts, and returns ONE recommendation
for the next synthesis iteration (the paper's design point: profiling data
is huge, optimization signals are sparse, so a separate agent distills one
action).

Two backends:
  * RuleBasedAnalyzer — deterministic TPU-roofline reasoning over the same
    profile dict the verifier produces (and, for dry-run cells, the
    loop-aware HLO cost report). This is what runs offline.
  * LLMAnalysisBackend hook — builds the §3.2 prompt (text + profile) for an
    external multimodal/chat model; see core/prompts.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

from repro.core.candidates import SPACES, Candidate, space_for
from repro.platforms import PlatformLike, resolve_platform


@dataclasses.dataclass
class Recommendation:
    """One actionable optimization (the paper prompts G for exactly one).

    ``source`` names the analyzer that produced it — ``"rule"`` for the
    deterministic rule table, ``"llm"`` for a parsed LLM analysis reply
    (:class:`repro.llm.analyzer.LLMAnalyzer`). It is journaled on every
    iteration event, so a campaign log shows which agent drove each
    optimization pass.
    """
    text: str                       # human/LLM readable
    param: Optional[str] = None     # structured action for the search backend
    value: Any = None
    source: str = "rule"            # which analyzer produced it

    def apply(self, cand: Candidate) -> Candidate:
        if self.param is None or self.param not in SPACES.get(cand.op, {}):
            return cand
        params = dict(cand.params)
        params[self.param] = self.value
        return Candidate(cand.op, params)


class RuleBasedAnalyzer:
    """Deterministic analysis over the candidate's profile.

    All thresholds derive from the platform profile: the matrix-unit
    alignment rule fires against ``platform.matrix_align`` (128 on the TPU
    MXU, 16 on a tensor-core-class GPU), the compute roofline against
    ``platform.peak_flops``, and candidate spaces are the platform-legal
    ones — so the same profile dict yields genuinely different
    recommendations on different targets.
    """

    def __init__(self, platform: PlatformLike = None):
        self.platform = resolve_platform(platform)

    def analyze(self, profile: Dict[str, Any]) -> Recommendation:
        op = profile["op"]
        params = profile["params"]
        shapes = profile["shapes"]
        model_t = profile["model_time_s"]
        flops = profile.get("flops", 0.0)
        plat = self.platform
        align = plat.matrix_align
        compute_t = flops / plat.peak_flops
        space = space_for(op, plat) if op in SPACES else {}

        # Rule 1: compute far from roofline because matrix tiles are
        # misaligned for this platform's matrix-unit width.
        for key in ("block_m", "block_n", "block_q"):
            if key in params and key in space:
                target = plat.align_target(space[key], params[key])
                if target is not None:
                    return Recommendation(
                        text=(f"{key}={params[key]} underfills the "
                              f"{align}x{align} matrix unit on {plat.name}; "
                              f"raise it to {target} so every pass issues "
                              "full-width matmuls."),
                        param=key, value=target)

        # Rule 2: memory-bound with tiny row tiles -> per-tile overheads and
        # poor HBM streaming; grow the sublane/thread-coarsening dimension
        # (the analogue of the paper's 8-elements-per-thread Metal
        # optimization, §7.2).
        if compute_t < 0.5 * model_t:
            for key in ("block_rows", "block_t", "block_lanes", "block_cols",
                        "block_v"):
                if key in params and key in space:
                    bigger = [c for c in space[key] if c > params[key]]
                    if bigger:
                        return Recommendation(
                            text=(f"kernel is HBM-bound; {key}={params[key]} "
                                  f"tiles are too small to hide memory "
                                  f"latency — raise to {min(bigger)} to "
                                  "amortize per-tile overhead."),
                            param=key, value=min(bigger))

        # Rule 3: matmul K-tile too large relative to M/N starves the
        # accumulation pipeline; prefer squarer fast-memory tiles. The
        # target is the legal choice nearest the output-tile width, not a
        # hardcoded constant — it must exist on every platform's space.
        mn = max(params.get("block_m", 0), params.get("block_n", 0))
        if op == "matmul" and "block_k" in space \
                and params.get("block_k", 0) > 2 * mn:
            target = min(space["block_k"], key=lambda c: abs(c - mn))
            if target < params["block_k"]:
                return Recommendation(
                    text=(f"block_k dominates the fast-memory working set; "
                          f"rebalance toward square tiles "
                          f"(block_k={target}) to double-buffer more "
                          "output tiles."),
                    param="block_k", value=target)

        # Rule 4: attention kv tile growth reduces K/V re-streaming. Guard
        # on the *space* too, not just the candidate's params: a profile
        # whose platform-legal space carries no block_k axis (foreign
        # profile, custom platform) must fall through to the roofline
        # verdict, not KeyError.
        if op == "attention" and "block_k" in params and "block_k" in space:
            bigger = [c for c in space["block_k"] if c > params["block_k"]]
            if bigger:
                return Recommendation(
                    text=("raise the KV tile so each K/V block streamed from "
                          "HBM amortizes over more query rows."),
                    param="block_k", value=min(bigger))

        return Recommendation(
            text="profile is near the modeled roofline; no single change "
                 "is predicted to exceed a 5% gain.")


def analyze_dryrun_cell(roofline: Dict[str, Any]) -> Recommendation:
    """G applied to a whole dry-run cell (the §Perf loop's advisor)."""
    dom = roofline["dominant"]
    cb = roofline.get("collective_breakdown", {})
    if dom == "collective":
        worst = max(cb, key=cb.get) if cb else "all-gather"
        hints = {
            "all-gather": "coalesce FSDP parameter gathers (gather once per "
                          "layer, reuse across microbatches) or shift the "
                          "sharding of the gathered tensor onto the pod axis",
            "all-reduce": "replace gradient all-reduce with reduce-scatter "
                          "into ZeRO shards, and keep TP partial sums in "
                          "bf16",
            "all-to-all": "batch the MoE dispatch all-to-all per layer and "
                          "shard the capacity buffer on the expert axis only",
            "collective-permute": "fold halo exchanges into the collective-"
                                  "matmul overlap",
        }
        return Recommendation(text=f"collective-bound ({worst}): "
                              f"{hints.get(worst, 'overlap collectives with compute')}")
    if dom == "memory":
        return Recommendation(text="memory-bound: raise arithmetic intensity "
                              "— fuse elementwise chains into the matmul "
                              "epilogue, keep activations bf16, and check "
                              "for remat-induced re-reads")
    return Recommendation(text="compute-bound: good — verify "
                          "useful_flops_fraction; if < 0.7, reduce remat "
                          "recompute or switch the checkpoint policy")
