"""Execution states and evaluation results (paper §3.3).

Terminal states per generation-evaluation iteration, mapped to JAX:
  generation failure   — backend produced no usable candidate
  compilation failure  — trace/lower/Mosaic error while jitting
  runtime error        — exception while executing the compiled program
  numeric/shape mismatch — outputs differ from the ref.py oracle
  grad mismatch        — fwd output matches but a gradient differs from
                         the ``jax.vjp`` oracle (``direction="fwd_bwd"``)
  correct              — shapes, dtypes and values match
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Dict, Optional


class ExecutionState(enum.Enum):
    GENERATION_FAILURE = "generation_failure"
    COMPILATION_FAILURE = "compilation_failure"
    RUNTIME_ERROR = "runtime_error"
    NUMERIC_MISMATCH = "numeric_mismatch"
    GRAD_MISMATCH = "grad_mismatch"
    CORRECT = "correct"


@dataclasses.dataclass
class EvalResult:
    state: ExecutionState
    error: Optional[str] = None
    # performance numbers (only meaningful when state == CORRECT)
    wall_time_s: Optional[float] = None        # measured (CPU/interpret)
    model_time_s: Optional[float] = None       # analytic TPU roofline estimate
    baseline_model_time_s: Optional[float] = None
    max_abs_err: Optional[float] = None
    profile: Optional[Dict[str, Any]] = None   # fed to the analysis agent
    cache_key: Optional[str] = None            # content address (campaign)

    @property
    def correct(self) -> bool:
        return self.state is ExecutionState.CORRECT

    @property
    def speedup(self) -> Optional[float]:
        """Model-roofline speedup of candidate vs. the naive baseline."""
        if not self.correct or not self.model_time_s:
            return None
        return self.baseline_model_time_s / self.model_time_s

    def feedback(self) -> str:
        """The message appended to the next generation prompt (paper §3)."""
        if self.state is ExecutionState.CORRECT:
            if self.model_time_s is None or self.speedup is None:
                # callable candidates without a performance model (no
                # declarative params and no naive fallback) are still
                # correct — feed that back without fabricating numbers
                return "correct (no performance model for this candidate)"
            return (f"correct; model_time={self.model_time_s:.3e}s "
                    f"speedup={self.speedup:.2f}x")
        return f"{self.state.value}: {self.error or 'unknown'}"
