"""KernelBench-JAX: the workload suite KForge is evaluated on.

Mirrors the paper's three levels with problems drawn from the assigned
architectures (DESIGN.md §7). Softmax-family workloads use large-magnitude
inputs so numerically-naive candidates genuinely fail (the functional pass
has real work to do), exactly like fp32 overflow on device.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.core.workload import Workload, randn
from repro.kernels import ref

_SUITE: List[Workload] = []
_SUITE_SMALL: List[Workload] = []


def _add(wl: Workload):
    _SUITE.append(wl)
    _SUITE_SMALL.append(_shrink(wl))
    return wl


def _shrink(wl: Workload, div: int = 4) -> Workload:
    """Same op/strategy space, dims divided by ``div`` — interpret-mode
    verification becomes fast while the analytic model still differentiates
    candidates. Used by the benchmark harness."""
    def small(shape):
        # snap to multiples of 64 so the tiling space keeps legal divisors
        return tuple(max(64, (s // div) // 64 * 64) if s >= 256 else s
                     for s in shape)

    shapes = {k: small(v) for k, v in wl.input_shapes.items()}

    def input_fn(rng, _wl=wl):
        full = _wl.input_fn(rng)
        out = {}
        for k, v in full.items():
            tgt = small(tuple(v.shape))
            sl = tuple(slice(0, t) for t in tgt)
            arr = v[sl]
            if k == "labels":
                # keep labels in range of the shrunken vocab
                vocab = shapes.get("logits", (0, arr.shape[-1] if arr.ndim
                                              else 0))[-1]
                if "logits" in shapes:
                    arr = arr % shapes["logits"][-1]
            out[k] = arr
        return out

    return dataclasses.replace(wl, input_fn=input_fn, input_shapes=shapes)


def suite(level=None, *, small: bool = False,
          differentiable: bool = None) -> List[Workload]:
    """Workloads by level; ``differentiable=True`` keeps only the
    training-shaped workloads eligible for ``--direction fwd_bwd``."""
    pool = _SUITE_SMALL if small else _SUITE
    return [w for w in pool
            if (level is None or w.level == level)
            and (differentiable is None or w.differentiable == differentiable)]


def by_name(name: str, *, small: bool = False) -> Workload:
    for w in (_SUITE_SMALL if small else _SUITE):
        if w.name == name:
            return w
    raise KeyError(name)


# ---------------------------------------------------------------------------
# Level 1 — single primitives
# ---------------------------------------------------------------------------

_add(Workload(
    name="L1/swish", level=1, op="swish",
    description="Swish activation (paper case study §7.2)",
    ref_fn=lambda x: ref.swish(x),
    input_fn=lambda rng: {"x": randn(rng, (2048, 2048))},
    input_shapes={"x": (2048, 2048)}))

_add(Workload(
    name="L1/softmax", level=1, op="softmax",
    description="row softmax; rows contain +-60 magnitude outliers",
    ref_fn=lambda x: ref.softmax(x),
    input_fn=lambda rng: {"x": randn(rng, (1024, 4096), scale=60.0)},
    input_shapes={"x": (1024, 4096)}))

_add(Workload(
    name="L1/rmsnorm", level=1, op="rmsnorm",
    description="RMSNorm over d_model=4096 (llama-family norm)",
    ref_fn=lambda x, g: ref.rmsnorm(x, g),
    input_fn=lambda rng: {"x": randn(rng, (2048, 4096)),
                          "g": randn(rng, (4096,), 0.5)},
    input_shapes={"x": (2048, 4096), "g": (4096,)}))

_add(Workload(
    name="L1/matmul", level=1, op="matmul",
    description="GEMM 1024x1024x1024 (MXU workload)",
    ref_fn=lambda a, b: ref.matmul(a, b),
    input_fn=lambda rng: {"a": randn(rng, (1024, 1024), 0.05),
                          "b": randn(rng, (1024, 1024), 0.05)},
    input_shapes={"a": (1024, 1024), "b": (1024, 1024)}, tol=5e-3))

_add(Workload(
    name="L1/matmul_tall", level=1, op="matmul",
    description="skinny GEMM 8192x512x1024 (mlp down-proj shape)",
    ref_fn=lambda a, b: ref.matmul(a, b),
    input_fn=lambda rng: {"a": randn(rng, (8192, 512), 0.05),
                          "b": randn(rng, (512, 1024), 0.05)},
    input_shapes={"a": (8192, 512), "b": (512, 1024)}, tol=5e-3))

_add(Workload(
    name="L1/xent", level=1, op="xent",
    description="softmax cross-entropy over 32k vocab, logits to +-50",
    ref_fn=lambda logits, labels: ref.softmax_xent(logits, labels),
    input_fn=lambda rng: {
        "logits": randn(rng, (512, 32768), scale=50.0),
        "labels": jnp.asarray(rng.integers(0, 32768, (512,)), jnp.int32)},
    input_shapes={"logits": (512, 32768), "labels": (512,)}))

_add(Workload(
    name="L1/rope", level=1, op="rope",
    description="rotary position embedding over (B,S,H,Dh)=(2,1024,8,64), "
                "angles computed in-kernel (llama-family positional path)",
    ref_fn=lambda x, positions: ref.rope(x, positions),
    input_fn=lambda rng: {
        "x": randn(rng, (2, 1024, 8, 64)),
        "positions": jnp.tile(jnp.arange(1024, dtype=jnp.int32)[None],
                              (2, 1))},
    input_shapes={"x": (2, 1024, 8, 64), "positions": (2, 1024)},
    differentiable=True))


# ---------------------------------------------------------------------------
# Level 2 — fusable operation sequences
# ---------------------------------------------------------------------------

_add(Workload(
    name="L2/swiglu", level=2, op="swiglu",
    description="SwiGLU gate fusion: silu(g) * u (Liger-style fusion target)",
    ref_fn=lambda gate, up: ref.swish(gate) * up,
    input_fn=lambda rng: {"gate": randn(rng, (4096, 2048)),
                          "up": randn(rng, (4096, 2048))},
    input_shapes={"gate": (4096, 2048), "up": (4096, 2048)}))

_add(Workload(
    name="L2/attention_gqa", level=2, op="attention",
    description="causal GQA attention block, S=1024 H=8 KV=2 (starcoder2-ish)",
    ref_fn=lambda q, k, v: ref.attention(q, k, v, causal=True),
    input_fn=lambda rng: {"q": randn(rng, (2, 1024, 8, 64), 4.0),
                          "k": randn(rng, (2, 1024, 2, 64), 4.0),
                          "v": randn(rng, (2, 1024, 2, 64))},
    input_shapes={"q": (2, 1024, 8, 64), "k": (2, 1024, 2, 64),
                  "v": (2, 1024, 2, 64)}))

_add(Workload(
    name="L2/attention_mha", level=2, op="attention",
    description="causal MHA, S=2048 H=8 (whisper/yi head shapes)",
    ref_fn=lambda q, k, v: ref.attention(q, k, v, causal=True),
    input_fn=lambda rng: {"q": randn(rng, (1, 2048, 8, 64), 4.0),
                          "k": randn(rng, (1, 2048, 8, 64), 4.0),
                          "v": randn(rng, (1, 2048, 8, 64))},
    input_shapes={"q": (1, 2048, 8, 64), "k": (1, 2048, 8, 64),
                  "v": (1, 2048, 8, 64)}))

_add(Workload(
    name="L2/attention_bwd", level=2, op="attention",
    description="training-shaped causal MHA, S=512 H=8: fwd output AND "
                "q/k/v gradients are verified (direction=fwd_bwd)",
    ref_fn=lambda q, k, v: ref.attention(q, k, v, causal=True),
    input_fn=lambda rng: {"q": randn(rng, (2, 512, 8, 64), 4.0),
                          "k": randn(rng, (2, 512, 8, 64), 4.0),
                          "v": randn(rng, (2, 512, 8, 64))},
    input_shapes={"q": (2, 512, 8, 64), "k": (2, 512, 8, 64),
                  "v": (2, 512, 8, 64)},
    tol=5e-3, differentiable=True))

_add(Workload(
    name="L2/swiglu_bwd", level=2, op="swiglu",
    description="training-shaped SwiGLU gate fusion: silu(g)*u plus "
                "gate/up gradients (direction=fwd_bwd)",
    ref_fn=lambda gate, up: ref.swish(gate) * up,
    input_fn=lambda rng: {"gate": randn(rng, (2048, 2048)),
                          "up": randn(rng, (2048, 2048))},
    input_shapes={"gate": (2048, 2048), "up": (2048, 2048)},
    differentiable=True))

_add(Workload(
    name="L2/softmax_wide", level=2, op="softmax",
    description="attention-logit-shaped softmax (rows=4096, cols=4096)",
    ref_fn=lambda x: ref.softmax(x),
    input_fn=lambda rng: {"x": randn(rng, (4096, 4096), scale=40.0)},
    input_shapes={"x": (4096, 4096)}))

def _ssd_ref(x, a, b, c):
    y, _ = ref.ssd(x, a, b, c)
    return y


_add(Workload(
    name="L2/ssd_scan", level=2, op="ssd",
    description="Mamba2 SSD over T=1024 (zamba2 head geometry): the agent "
                "must discover the chunk-parallel matrix form (§Perf B1)",
    arch_tag="zamba2-7b",
    ref_fn=_ssd_ref,
    input_fn=lambda rng: {
        "x": randn(rng, (2, 1024, 4, 64)),
        "a": jnp.asarray(rng.uniform(0.5, 0.999, (2, 1024, 4)), jnp.float32),
        "b": randn(rng, (2, 1024, 4, 16)),
        "c": randn(rng, (2, 1024, 4, 16))},
    input_shapes={"x": (2, 1024, 4, 64), "a": (2, 1024, 4),
                  "b": (2, 1024, 4, 16), "c": (2, 1024, 4, 16)},
    tol=5e-3))


_add(Workload(
    name="L2/xent_moonshot", level=2, op="xent",
    description="LM loss over moonshot's 163840 vocab (chunked logsumexp)",
    ref_fn=lambda logits, labels: ref.softmax_xent(logits, labels),
    input_fn=lambda rng: {
        "logits": randn(rng, (128, 163840), scale=30.0),
        "labels": jnp.asarray(rng.integers(0, 163840, (128,)), jnp.int32)},
    input_shapes={"logits": (128, 163840), "labels": (128,)},
    arch_tag="moonshot-v1-16b-a3b"))


# ---------------------------------------------------------------------------
# Level 3 — architecture blocks from the assigned archs
# ---------------------------------------------------------------------------

def _attn_block_ref(x, g, wq, wk, wv, wo):
    h = ref.rmsnorm(x[0], g)
    q = jnp.einsum("sd,dhk->shk", h, wq)[None]
    k = jnp.einsum("sd,dhk->shk", h, wk)[None]
    v = jnp.einsum("sd,dhk->shk", h, wv)[None]
    o = ref.attention(q, k, v, causal=True)[0]
    return x[0] + jnp.einsum("shk,hkd->sd", o, wo)


_add(Workload(
    name="L3/starcoder2_attn_block", level=3, op="attention",
    description="full pre-norm GQA attention block (starcoder2-7b reduced)",
    arch_tag="starcoder2-7b",
    ref_fn=_attn_block_ref,
    input_fn=lambda rng: {
        "x": randn(rng, (1, 1024, 256)),
        "g": randn(rng, (256,), 0.5),
        "wq": randn(rng, (256, 8, 64), 0.05),
        "wk": randn(rng, (256, 2, 64), 0.05),
        "wv": randn(rng, (256, 2, 64), 0.05),
        "wo": randn(rng, (8, 64, 256), 0.05)},
    input_shapes={"x": (1, 1024, 256)}, tol=5e-3))


def _mlp_block_ref(x, g, wg, wu, wd):
    h = ref.rmsnorm(x, g)
    return x + ref.swiglu(h, wg, wu, wd)


_add(Workload(
    name="L3/yi_mlp_block", level=3, op="swiglu",
    description="pre-norm SwiGLU MLP block (yi-34b reduced ratio)",
    arch_tag="yi-34b",
    ref_fn=_mlp_block_ref,
    input_fn=lambda rng: {
        "x": randn(rng, (2048, 512)),
        "g": randn(rng, (512,), 0.5),
        "wg": randn(rng, (512, 1408), 0.05),
        "wu": randn(rng, (512, 1408), 0.05),
        "wd": randn(rng, (1408, 512), 0.05)},
    input_shapes={"x": (2048, 512), "gate": (2048, 1408),
                  "up": (2048, 1408)}, tol=5e-3))


def _lm_head_ref(x, w, labels):
    return ref.softmax_xent(jnp.dot(x, w, preferred_element_type=jnp.float32),
                            labels)


_add(Workload(
    name="L3/qwen_lm_head", level=3, op="xent",
    description="fused LM head + CE over qwen2's 151936 vocab",
    arch_tag="qwen2-moe-a2.7b",
    ref_fn=_lm_head_ref,
    input_fn=lambda rng: {
        "x": randn(rng, (128, 512), 1.0),
        "w": randn(rng, (512, 151936 + 2 * 1024 - 151936 % (2 * 1024)), 0.2),
        "labels": jnp.asarray(rng.integers(0, 151936, (128,)), jnp.int32)},
    input_shapes={"logits": (128, 153600), "labels": (128,)}))


_add(Workload(
    name="L3/mamba2_ssd_bwd", level=3, op="ssd",
    description="training-shaped Mamba2 SSD (zamba2 head geometry): the "
                "chunk-parallel form must also match the scan's gradients "
                "for x/b/c and the decay gates (direction=fwd_bwd)",
    arch_tag="zamba2-7b",
    ref_fn=_ssd_ref,
    input_fn=lambda rng: {
        "x": randn(rng, (2, 512, 4, 64)),
        "a": jnp.asarray(rng.uniform(0.5, 0.999, (2, 512, 4)), jnp.float32),
        "b": randn(rng, (2, 512, 4, 16)),
        "c": randn(rng, (2, 512, 4, 16))},
    input_shapes={"x": (2, 512, 4, 64), "a": (2, 512, 4),
                  "b": (2, 512, 4, 16), "c": (2, 512, 4, 16)},
    tol=5e-3, differentiable=True))


_add(Workload(
    name="L3/phi3_gemm_stack", level=3, op="matmul",
    description="qkv-projection GEMM at phi3-medium geometry (5120->7680)",
    arch_tag="phi3-medium-14b",
    ref_fn=lambda a, b: ref.matmul(a, b),
    input_fn=lambda rng: {"a": randn(rng, (2048, 1280), 0.05),
                          "b": randn(rng, (1280, 1920), 0.05)},
    input_shapes={"a": (2048, 1280), "b": (1280, 1920)}, tol=5e-3))


def workload_for_candidate_inputs(wl: Workload, inputs: Dict):
    """Extract the arrays a candidate callable consumes, by op family."""
    if wl.op == "attention" and "wq" in inputs:
        h = ref.rmsnorm(inputs["x"][0], inputs["g"])
        q = jnp.einsum("sd,dhk->shk", h, inputs["wq"])[None]
        k = jnp.einsum("sd,dhk->shk", h, inputs["wk"])[None]
        v = jnp.einsum("sd,dhk->shk", h, inputs["wv"])[None]
        return {"q": q, "k": k, "v": v}
    if wl.op == "swiglu" and "wg" in inputs:
        h = ref.rmsnorm(inputs["x"], inputs["g"])
        return {"gate": jnp.dot(h, inputs["wg"]),
                "up": jnp.dot(h, inputs["wu"])}
    if wl.op == "xent" and "w" in inputs:
        return {"logits": jnp.dot(inputs["x"], inputs["w"],
                                  preferred_element_type=jnp.float32),
                "labels": inputs["labels"]}
    return inputs


def finish_candidate_output(wl: Workload, inputs: Dict, out):
    """Complete the surrounding block math for L3 workloads."""
    if wl.op == "attention" and "wq" in inputs:
        return inputs["x"][0] + jnp.einsum("shk,hkd->sd", out[0], inputs["wo"])
    if wl.op == "swiglu" and "wg" in inputs:
        return inputs["x"] + jnp.dot(out, inputs["wd"],
                                     preferred_element_type=jnp.float32
                                     ).astype(inputs["x"].dtype)
    return out
