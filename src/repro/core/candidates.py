"""The Pallas candidate space the generation agent explores.

A candidate is (strategy, parameters) for one op family — exactly the
degrees of freedom a kernel engineer (or the paper's LLM) controls:
  * tiling / BlockSpec shapes (VMEM working set, MXU alignment),
  * elements-per-"thread" vectorization (the paper's §7.2 Metal trick →
    sublane rows per grid step on TPU),
  * numerically-naive vs online-softmax strategies,
  * fused vs staged elementwise epilogues.

``materialize`` turns a candidate into a callable (Pallas interpret-mode on
CPU / real kernel on TPU); ``model_time`` is the analytic roofline estimate
used as the performance signal (wall-clock of interpret mode measures the
interpreter, not the kernel — DESIGN.md §8.2). Every performance/legality
judgement is parameterized by a :class:`repro.platforms.Platform` — the
hardware target is an explicit axis, not a module constant (DESIGN.md §1).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.kernels import (flash_attention as _fa, matmul as _mm,
                           rmsnorm as _rn, rope as _rp, softmax as _sm,
                           swiglu as _sg, swish as _sw, xent as _xe)
from repro.platforms import PlatformLike, resolve_platform

# Historical name for the default target's matrix-unit width; prefer
# ``resolve_platform(...).matrix_align`` in new code.
MXU = 128


@dataclasses.dataclass(frozen=True)
class Candidate:
    op: str                    # op family: swish, softmax, matmul, ...
    params: Dict[str, Any]     # block sizes / strategy flags

    def describe(self) -> str:
        kv = " ".join(f"{k}={v}" for k, v in sorted(self.params.items()))
        return f"{self.op}({kv})"


# ---------------------------------------------------------------------------
# Parameter spaces per op family (what the agent can mutate)
# ---------------------------------------------------------------------------

SPACES: Dict[str, Dict[str, Tuple]] = {
    "swish": {"block_rows": (1, 8, 64), "block_lanes": (128, 512, 2048)},
    "softmax": {"block_rows": (8, 64, 128, 256), "online": (False, True)},
    "rmsnorm": {"block_rows": (8, 64, 256, 512)},
    "matmul": {"block_m": (64, 128, 256, 512), "block_n": (64, 128, 256, 512),
               "block_k": (64, 128, 256, 512)},
    "swiglu": {"block_rows": (8, 64, 128), "block_cols": (64, 128, 512, 2048),
               "fused": (False, True)},
    "attention": {"block_q": (64, 128, 256, 512),
                  "block_k": (64, 128, 256, 512), "online": (False, True)},
    "xent": {"block_t": (32, 128, 256), "block_v": (512, 2048, 8192),
             "online": (False, True)},
    # SSD/Mamba2 recurrence: the strategy axis is recurrent (token-by-token
    # state updates) vs matrix (chunk-parallel MXU form) — the same
    # transformation EXPERIMENTS.md §Perf B1 applies by hand.
    "ssd": {"chunk": (32, 64, 128, 256), "form": ("recurrent", "matrix")},
    "rope": {"block_s": (64, 128, 256, 512)},
}

# Heuristic defaults a model proposes with NO reference implementation:
# plausible but naive — numerically unstable softmax, undersized tiles.
NAIVE_DEFAULTS: Dict[str, Dict[str, Any]] = {
    "swish": {"block_rows": 1, "block_lanes": 128},
    "softmax": {"block_rows": 8, "online": False},
    "rmsnorm": {"block_rows": 8},
    "matmul": {"block_m": 64, "block_n": 64, "block_k": 512},
    "swiglu": {"block_rows": 8, "block_cols": 128, "fused": False},
    "attention": {"block_q": 64, "block_k": 64, "online": False},
    "xent": {"block_t": 32, "block_v": 512, "online": False},
    "ssd": {"chunk": 64, "form": "recurrent"},
    "rope": {"block_s": 64},
}

# What a correct cross-platform reference implementation teaches the agent:
# the *strategy* (online softmax, fusion) transfers even though the tiling
# must be re-derived for the target hardware (paper §6.2). Platforms extend
# these per-target via Platform.reference_hints, and transfer sweeps inject
# per-workload harvested hints on top (campaign/transfer.py).
REFERENCE_HINTS: Dict[str, Dict[str, Any]] = {
    "softmax": {"online": True},
    "attention": {"online": True},
    "xent": {"online": True},
    "swiglu": {"fused": True},
    "ssd": {"form": "matrix"},
}

_TILE_KEYS = ("block_", "chunk")


def _is_tile_key(k: str) -> bool:
    return k.startswith("block_") or k == "chunk"


def space_for(op: str, platform: PlatformLike = None) -> Dict[str, Tuple]:
    """The platform-legal parameter space for one op family.

    Tile dimensions above ``platform.max_tile`` never fit the target's fast
    memory and are removed; if that would empty an axis the smallest choice
    is kept so every family stays synthesizable. Strategy axes (online,
    fused, form) are hardware-independent and pass through.
    """
    p = resolve_platform(platform)
    out: Dict[str, Tuple] = {}
    for k, choices in SPACES[op].items():
        if _is_tile_key(k):
            legal = tuple(c for c in choices if c <= p.max_tile)
            out[k] = legal or (min(choices),)
        else:
            out[k] = choices
    return out


def _snap_to_space(op: str, params: Dict[str, Any],
                   space: Dict[str, Tuple]) -> Dict[str, Any]:
    """Clamp tile params to the platform-legal space (largest legal <= v)."""
    out = dict(params)
    for k, v in params.items():
        if not _is_tile_key(k) or k not in space or v in space[k]:
            continue
        smaller = [c for c in space[k] if c <= v]
        out[k] = max(smaller) if smaller else min(space[k])
    return out


def initial_candidate(op: str, *, use_reference: bool,
                      platform: PlatformLike = None,
                      hints: Optional[Dict[str, Any]] = None) -> Candidate:
    """The agent's first proposal for one op family on one platform.

    ``hints`` (optional) are per-workload reference hints — e.g. the
    strategy params harvested from another platform's best verified
    candidate in a transfer sweep — applied on top of the global
    REFERENCE_HINTS and the platform's own reference_hints extension.
    """
    plat = resolve_platform(platform)
    space = space_for(op, plat)
    params = _snap_to_space(op, dict(NAIVE_DEFAULTS[op]), space)
    if use_reference:
        merged = dict(REFERENCE_HINTS.get(op, {}))
        merged.update(plat.reference_hints.get(op, {}))
        merged.update(hints or {})
        params.update(merged)
        params = _snap_to_space(op, params, space)
        # reference kernels in the paper's dataset are aligned to the source
        # platform's matrix unit; transferring them biases tile choices
        # toward the *target's* alignment (re-derived tiling, same strategy).
        for k in params:
            if k.startswith("block_"):
                target = plat.align_target(space[k], params[k])
                if target is not None:
                    params[k] = target
    return Candidate(op=op, params=params)


def mutations(cand: Candidate,
              platform: PlatformLike = None) -> Dict[str, Candidate]:
    """All single-parameter mutations within the platform-legal space."""
    out = {}
    for k, choices in space_for(cand.op, platform).items():
        cur = cand.params.get(k)
        for c in choices:
            if c != cur:
                p = dict(cand.params)
                p[k] = c
                out[f"{k}->{c}"] = Candidate(cand.op, p)
    return out


def in_space(cand: Candidate, platform: PlatformLike = None) -> bool:
    """True iff every param names a known axis and holds a platform-legal
    value — the legality predicate population search applies before
    adopting an analyzer recommendation into a member."""
    space = space_for(cand.op, platform)
    return all(k in space and v in space[k] for k, v in cand.params.items())


def copy_tiling(dst: Candidate, src: Candidate,
                platform: PlatformLike = None) -> Candidate:
    """The PBT exploit step: ``dst`` with ``src``'s tile params (block_*,
    chunk) copied over, validated against the platform-legal space — a
    copied value outside it snaps to the largest legal choice below it.
    Strategy axes (online, fused, form) stay ``dst``'s own; they are what
    the explore step mutates."""
    space = space_for(dst.op, platform)
    p = dict(dst.params)
    for k, v in src.params.items():
        if _is_tile_key(k) and k in space:
            p[k] = v
    return Candidate(dst.op, _snap_to_space(dst.op, p, space))


# ---------------------------------------------------------------------------
# Materialization: candidate -> callable
# ---------------------------------------------------------------------------


def _naive_softmax(x):
    """Numerically naive softmax (no max subtraction) — overflows for
    large-magnitude rows, exactly the bug iterative refinement must fix."""
    e = jnp.exp(x.astype(jnp.float32))
    return (e / jnp.sum(e, axis=-1, keepdims=True)).astype(x.dtype)


def materialize(cand: Candidate, *, interpret: bool = True,
                platform: PlatformLike = None,
                differentiable: bool = False) -> Callable:
    """Turn a candidate into a callable kernel.

    ``platform`` (name, instance, or None for the default target) selects
    the backend compiler params the underlying Pallas call is built with
    (``kernels.ops.compiler_params_for``): TPU targets get Mosaic params,
    other targets get none. Interpret-mode numerics are identical either
    way; on real hardware the compiled artifact differs.

    ``differentiable`` makes the callable usable under ``jax.vjp`` for
    ``direction="fwd_bwd"`` verification: Pallas-backed strategies (which
    have no VJP rule) are wrapped in :func:`repro.kernels.ops.recompute_vjp`
    — forward runs the kernel under test, backward is flash-style recompute
    through the pure-XLA equivalent, exactly the ``_pallas_attention``
    machinery generalized. Pure-jnp strategies (naive softmax/attention,
    staged swiglu, both SSD forms) differentiate directly, so their
    gradients are honestly the candidate's own. Forward numerics are
    identical either way.
    """
    p = cand.params
    op = cand.op
    plat = None if platform is None else resolve_platform(platform).name
    if op == "swish":
        def fn(x):
            r, l = x.shape
            if r % p["block_rows"] or l % p["block_lanes"]:
                raise ValueError(
                    f"grid misalignment: {x.shape} not divisible by "
                    f"({p['block_rows']},{p['block_lanes']})")
            return _sw.swish(x, block_rows=p["block_rows"],
                             block_lanes=p["block_lanes"],
                             interpret=interpret, platform=plat)
        if differentiable:
            return ops.recompute_vjp(fn, ref.swish)
        return fn
    if op == "softmax":
        def fn(x):
            if not p["online"]:
                return _naive_softmax(x)
            if x.shape[0] % p["block_rows"]:
                raise ValueError(f"rows {x.shape[0]} % {p['block_rows']} != 0")
            return _sm.softmax(x, block_rows=p["block_rows"],
                               interpret=interpret, platform=plat)
        if differentiable and p["online"]:
            return ops.recompute_vjp(fn, ref.softmax)
        return fn
    if op == "rmsnorm":
        def fn(x, g):
            if x.shape[0] % p["block_rows"]:
                raise ValueError(f"rows {x.shape[0]} % {p['block_rows']} != 0")
            return _rn.rmsnorm(x, g, block_rows=p["block_rows"],
                               interpret=interpret, platform=plat)
        if differentiable:
            return ops.recompute_vjp(fn, ref.rmsnorm)
        return fn
    if op == "matmul":
        def fn(a, b):
            m, k = a.shape
            _, n = b.shape
            if m % p["block_m"] or n % p["block_n"] or k % p["block_k"]:
                raise ValueError(
                    f"matmul tiles {p} do not divide {(m, k, n)}")
            return _mm.matmul(a, b, block_m=p["block_m"],
                              block_n=p["block_n"], block_k=p["block_k"],
                              interpret=interpret, platform=plat)
        if differentiable:
            return ops.recompute_vjp(fn, ref.matmul)
        return fn
    if op == "swiglu":
        def fn(g, u):
            if not p["fused"]:
                return (ref.swish(g.astype(jnp.float32)) *
                        u.astype(jnp.float32)).astype(g.dtype)
            if g.shape[0] % p["block_rows"] or g.shape[1] % p["block_cols"]:
                raise ValueError(f"swiglu tiles {p} do not divide {g.shape}")
            return _sg.swiglu_act(g, u, block_rows=p["block_rows"],
                                  block_cols=p["block_cols"],
                                  interpret=interpret, platform=plat)
        if differentiable and p["fused"]:
            return ops.recompute_vjp(
                fn, lambda g, u: (ref.swish(g.astype(jnp.float32)) *
                                  u.astype(jnp.float32)).astype(g.dtype))
        return fn
    if op == "attention":
        def fn(q, k, v):
            if not p["online"]:
                # full S×S materialization with naive softmax
                b, sq, h, d = q.shape
                logits = jnp.einsum("bqhd,bkhd->bhqk", q,
                                    ref._expand_kv(k, h)) * (d ** -0.5)
                qi = jnp.arange(sq)[:, None]
                ki = jnp.arange(k.shape[1])[None, :]
                logits = jnp.where(ki <= qi, logits, -1e30)
                pr = _naive_softmax(logits)
                return jnp.einsum("bhqk,bkhd->bqhd", pr,
                                  ref._expand_kv(v, h)).astype(q.dtype)
            if q.shape[1] % p["block_q"] or k.shape[1] % p["block_k"]:
                raise ValueError(
                    f"attention tiles {p} do not divide "
                    f"{(q.shape[1], k.shape[1])}")
            return _fa.flash_attention(q, k, v, causal=True,
                                       block_q=p["block_q"],
                                       block_k=p["block_k"],
                                       interpret=interpret, platform=plat)
        if differentiable and p["online"]:
            return ops.recompute_vjp(
                fn, lambda q, k, v: ops.xla_chunked_attention(
                    q, k, v, causal=True))
        return fn
    if op == "ssd":
        def fn(x, a, b, c):
            if p["form"] == "recurrent":
                from repro.kernels import ref as _ref
                y, _ = _ref.ssd(x, a, b, c)
                return y
            from repro.kernels import ops as _ops
            t = x.shape[1]
            if t % p["chunk"]:
                raise ValueError(f"chunk {p['chunk']} does not divide T={t}")
            y, _ = _ops.ssd_matrix(x, a, b, c, chunk=p["chunk"])
            return y
        return fn  # both SSD forms are pure jnp — natively differentiable
    if op == "xent":
        def fn(logits, labels):
            if not p["online"]:
                lf = logits.astype(jnp.float32)
                lse = jnp.log(jnp.sum(jnp.exp(lf), axis=-1))  # overflows
                gold = jnp.take_along_axis(lf, labels[:, None], axis=-1)[:, 0]
                return lse - gold
            t, v = logits.shape
            if t % p["block_t"] or v % p["block_v"]:
                raise ValueError(f"xent tiles {p} do not divide {(t, v)}")
            return _xe.softmax_xent(logits, labels, block_t=p["block_t"],
                                    block_v=p["block_v"],
                                    interpret=interpret, platform=plat)
        if differentiable and p["online"]:
            return ops.recompute_vjp(fn, ref.softmax_xent)
        return fn
    if op == "rope":
        def fn(x, positions):
            if x.shape[1] % p["block_s"]:
                raise ValueError(
                    f"rope block_s {p['block_s']} does not divide "
                    f"S={x.shape[1]}")
            return _rp.rope(x, positions, block_s=p["block_s"],
                            interpret=interpret, platform=plat)
        if differentiable:
            return ops.recompute_vjp(fn, ref.rope)
        return fn
    raise KeyError(f"unknown op family {op!r}")


# ---------------------------------------------------------------------------
# Analytic per-platform performance model (the optimization signal)
# ---------------------------------------------------------------------------


def model_time(cand: Candidate, shapes: Dict[str, Tuple[int, ...]],
               platform: PlatformLike = None) -> float:
    """Estimated kernel time on the target platform: max(compute, HBM
    traffic) with tiling-dependent re-load factors and matrix-unit
    alignment penalties, all drawn from the platform profile."""
    plat = resolve_platform(platform)
    p = cand.params
    op = cand.op
    hw = plat.hw
    bw, peak = hw["hbm_bw"], hw["peak_flops"]
    align = plat.matrix_align
    vpu_peak = peak / plat.vpu_ratio  # elementwise ops skip the matrix unit

    def _mxu_eff(dim: int) -> float:
        # matrix-unit utilization penalty for tiles under the native width
        return min(1.0, dim / align) if dim < align else 1.0

    def elemwise(n_elems, n_streams, rows, lanes):
        bytes_ = n_elems * 4 * n_streams
        # tiny tiles pay per-grid-step overhead (launch + pipeline bubbles)
        steps = n_elems / max(1, rows * lanes)
        overhead = steps * plat.grid_step_overhead_s
        return max(n_elems / vpu_peak, bytes_ / bw) + overhead

    if op == "swish":
        (r, l) = shapes["x"]
        return elemwise(r * l, 2, p["block_rows"], p["block_lanes"])
    if op == "swiglu":
        (r, l) = shapes["gate"]
        streams = 3 if p["fused"] else 5  # staged: extra intermediate r/w
        return elemwise(r * l, streams, p.get("block_rows", 8),
                        p.get("block_cols", 128))
    if op == "rmsnorm":
        (r, l) = shapes["x"]
        return elemwise(r * l, 2, p["block_rows"], l)
    if op == "softmax":
        (r, l) = shapes["x"]
        streams = 2 if p["online"] else 4  # naive: exp pass + sum pass
        return elemwise(r * l, streams, p.get("block_rows", 8), l)
    if op == "matmul":
        m, k = shapes["a"]
        _, n = shapes["b"]
        flops = 2 * m * n * k
        eff = _mxu_eff(p["block_m"]) * _mxu_eff(p["block_n"])
        # each A tile re-loaded n/bn times, each B tile m/bm times
        bytes_ = 4 * (m * k * (n / p["block_n"]) + k * n * (m / p["block_m"])
                      + m * n)
        vmem = 4 * (p["block_m"] * p["block_k"] + p["block_k"] * p["block_n"]
                    + p["block_m"] * p["block_n"])
        if vmem > hw["vmem_bytes"]:
            return float("inf")  # does not fit VMEM
        return max(flops / (peak * eff), bytes_ / bw)
    if op == "attention":
        b, sq, h, d = shapes["q"]
        sk = shapes["k"][1]
        kv = shapes["k"][2]
        flops = 4 * b * h * sq * sk * d * 0.5  # causal
        if not p["online"]:
            # materializes S×S logits+probs in HBM: reads+writes dominate
            bytes_ = 4 * b * h * sq * sk * 3
            return max(flops / peak, bytes_ / bw)
        eff = _mxu_eff(p["block_q"]) * _mxu_eff(min(p["block_k"], d))
        # K/V streamed once per q-block row
        kv_reload = sq / p["block_q"]
        bytes_ = 4 * (b * h * sq * d + b * kv * sk * d * kv_reload * 0.5 * 2
                      + b * h * sq * d)
        return max(flops / (peak * eff), bytes_ / bw)
    if op == "xent":
        t, v = shapes["logits"]
        streams = 2 if p["online"] else 4
        return elemwise(t * v, streams, p.get("block_t", 32), p["block_v"])
    if op == "ssd":
        bsz, t, h, pdim = shapes["x"]
        n = shapes["b"][-1]
        if p["form"] == "recurrent":
            # one (P,N) f32 state read+write per token per head, fully
            # latency/memory-bound; no matrix-unit utilization
            state_traffic = bsz * t * h * pdim * n * 4 * 2
            return state_traffic / bw + t * plat.seq_step_latency_s
        c = p["chunk"]
        nc = t // max(c, 1)
        flops = 2 * bsz * nc * h * (c * c * n + c * c * pdim) \
            + 2 * bsz * nc * h * c * pdim * n
        bytes_ = 4 * bsz * t * h * (pdim + 2 * n) \
            + 4 * bsz * nc * c * c * h  # decay-ratio tensor
        eff = _mxu_eff(min(c, align))
        return max(flops / (peak * eff), bytes_ / bw) \
            + nc * plat.seq_step_latency_s
    if op == "rope":
        b, s, h, d = shapes["x"]
        # positions traffic is s/(h*d) of x's — negligible; 2 streams (r+w)
        return elemwise(b * s * h * d, 2, p["block_s"], h * d)
    raise KeyError(op)


def naive_candidate(op: str, platform: PlatformLike = None) -> Candidate:
    """The naive/default candidate, snapped to the platform-legal space."""
    space = space_for(op, platform)
    return Candidate(op, _snap_to_space(op, dict(NAIVE_DEFAULTS[op]), space))


def baseline_time(op: str, shapes: Dict[str, Tuple[int, ...]],
                  platform: PlatformLike = None) -> float:
    """Roofline time of the naive/default implementation (the 'PyTorch eager'
    analogue): unfused, non-online, 8-row tiles — on the same platform the
    candidate is modeled for, so speedups stay platform-internal."""
    return model_time(naive_candidate(op, platform), shapes, platform)


# ---------------------------------------------------------------------------
# Backward-pass cost model (direction="fwd_bwd", §8 extension)
# ---------------------------------------------------------------------------

# Relative dgrad FLOP count per op family: how much math the backward pass
# does ON TOP of the flash-style recompute of the forward. matmul dgrad is
# two GEMMs of the forward's size (dA = dY·Bᵀ, dB = Aᵀ·dY); attention
# dq/dk/dv re-runs the score matmuls plus three output-sized GEMMs; the
# SSD dgrad mirrors the chunked forward for both dx and d(b,c); pure
# elementwise families pay roughly one more pass over the data.
_BWD_DGRAD_FACTOR: Dict[str, float] = {
    "swish": 1.0, "softmax": 1.0, "rmsnorm": 1.5, "matmul": 2.0,
    "swiglu": 1.5, "attention": 1.5, "xent": 1.0, "ssd": 2.0, "rope": 1.0,
}


def bwd_cost_factor(op: str) -> float:
    """bwd ≈ recompute (one forward) + dgrad FLOPs, as a multiple of the
    forward roofline."""
    return 1.0 + _BWD_DGRAD_FACTOR.get(op, 1.0)


def model_time_bwd(cand: Candidate, shapes: Dict[str, Tuple[int, ...]],
                   platform: PlatformLike = None) -> float:
    """Roofline estimate of the candidate's backward pass on the target.

    Scales the forward roofline by :func:`bwd_cost_factor` — the backward
    of every differentiable strategy here is recompute-based (no residual
    tensors round-trip HBM), so the forward's tiling-dependent traffic
    model is the right base, and the estimate stays per-platform because
    the forward roofline is."""
    return model_time(cand, shapes, platform) * bwd_cost_factor(cand.op)


def baseline_time_bwd(op: str, shapes: Dict[str, Tuple[int, ...]],
                      platform: PlatformLike = None) -> float:
    """Backward roofline of the naive/default implementation."""
    return baseline_time(op, shapes, platform) * bwd_cost_factor(op)
