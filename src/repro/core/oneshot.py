"""Back-compat shim: the one-shot examples now live with the platform
registry (:mod:`repro.platforms.examples`) so each hardware target carries
its own prompt example. Import from there in new code."""

from repro.platforms.examples import (  # noqa: F401
    VECTOR_ADD_CUDA, VECTOR_ADD_PALLAS,
)
