"""KForge core: the paper's contribution as a composable JAX module.

Two collaborating agents (generation F, performance-analysis G), five-state
program verification, the iterative refinement loop (functional pass →
optimization pass), cross-platform reference transfer, the KernelBench-JAX
suite, and the fast_p metric.
"""
from repro.core.states import EvalResult, ExecutionState  # noqa: F401
from repro.core.workload import Workload  # noqa: F401
from repro.core import kernelbench  # noqa: F401
from repro.core.candidates import Candidate, initial_candidate  # noqa: F401
from repro.core.synthesis import (  # noqa: F401
    Generation, LLMBackend, TemplateSearchBackend,
)
from repro.core.analysis import (  # noqa: F401
    Recommendation, RuleBasedAnalyzer, analyze_dryrun_cell,
)
from repro.core.verification import verify  # noqa: F401
from repro.core.refinement import (  # noqa: F401
    LoopConfig, RefinementOutcome, run_suite, run_workload,
)
from repro.core.metrics import (  # noqa: F401
    fast_p, fast_p_curve, speedup_distribution, state_histogram,
)
