"""Program verification (paper §3.3): classify each candidate into one of
the execution states and measure its performance.

Inputs are re-randomized on every call (fresh seed), so constant-output
"cheating" candidates (paper §7.3) are caught as numeric mismatches instead
of surviving evaluation.

``direction`` selects what is verified. ``"fwd"`` (the default, and the
byte-identical special case of everything below) checks the forward output
against the reference oracle, exactly as before this axis existed.
``"fwd_bwd"`` — legal only for workloads registered ``differentiable=True``
— additionally pulls a seed-derived cotangent back through the candidate
with ``jax.vjp`` and compares every input gradient against the workload's
gradient oracle; a forward-correct candidate whose gradients disagree
scores the dedicated ``GRAD_MISMATCH`` state with feedback naming the
worst-offending gradient, and a correct one carries a two-section profile
(fwd + bwd phase timings and rooflines). Direction folds into
:func:`cache_key`/:func:`executable_key` ONLY when it is ``"fwd_bwd"``, so
every pre-existing forward key — including persistent caches on disk —
stays byte-identical while fwd results are never served for fwd_bwd
requests.

``verify`` optionally consults a verification cache (anything with
``get(key) -> Optional[EvalResult]`` / ``put(key, result)``, e.g.
:class:`repro.campaign.VerificationCache`): declarative candidates are
content-addressed by :func:`cache_key` so a repeated (candidate, workload,
platform, seed) tuple across iterations, configs, or whole campaigns is
never re-verified. The platform is part of the content address — results
modeled for different hardware targets must not collide.

Two more cache layers make up the verification fast path (DESIGN.md §4):
a :class:`repro.core.evalio.WorkloadIOCache` shares the generated inputs
and the reference-oracle output per (workload, seed) across candidates and
matrix legs, and a :class:`repro.core.evalio.ExecutableCache` reuses
compiled executables across seeds.  :func:`verify_batch` evaluates many
candidates of one workload against a single shared input set, deduping
identical candidates by content address first.

When no ``seed`` is passed, verify draws one from a deterministic per-call
counter (NOT wall-clock entropy): the Nth seedless call of a process always
sees the same inputs, so runs are reproducible and the cache stays
effective. Callers wanting fresh entropy must pass their own seed.
"""
from __future__ import annotations

import hashlib
import itertools
import json
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.core import candidates as cand_mod
from repro.core import evalio
from repro.core import kernelbench as kb
from repro.core.evalio import ExecutableCache, IOEntry, WorkloadIOCache
from repro.core.states import EvalResult, ExecutionState
from repro.core.workload import Workload
from repro.platforms import PlatformLike, resolve_platform

# Deterministic fallback seed source for seedless verify() calls.
_FRESH_SEEDS = itertools.count(1)


def io_signature(wl: Workload):
    """Kernel-level input (name, shape, dtype) triples for a workload.

    Computed abstractly: the workload's ``input_fn`` runs against a
    :class:`repro.core.evalio.ShapeOnlyRng` (constant fills, no random-bit
    generation) and the kernel-input transform is traced with
    ``jax.eval_shape``, so reading a signature never executes the L3 block
    math or materializes candidate-sized arrays.  Shapes/dtypes are
    seed-independent, so the signature is memoized on the workload instance
    itself.  ``_io_sig`` is not a dataclass field, so ``dataclasses.replace``
    clones — e.g. the shrunken small-suite workloads — never inherit a
    stale signature.
    """
    sig = getattr(wl, "_io_sig", None)
    if sig is None:
        try:
            raw = wl.input_fn(evalio.ShapeOnlyRng())
            structs = {k: jax.ShapeDtypeStruct(np.shape(v),
                                               getattr(v, "dtype", None)
                                               or np.asarray(v).dtype)
                       for k, v in raw.items()}
            kernel = jax.eval_shape(
                lambda ins: kb.workload_for_candidate_inputs(wl, ins),
                structs)
        except Exception:  # noqa: BLE001 — exotic input_fn: concrete path
            # Count the fallback (WorkloadIOCache.stats()["io_sig_fallbacks"],
            # surfaced in campaign reports): generating real inputs just to
            # read metadata is the slow path, and a regression that breaks
            # the abstract path for a whole suite must not stay silent.
            evalio.WorkloadIOCache.count_io_sig_fallback()
            kernel = kb.workload_for_candidate_inputs(wl, wl.inputs(0))
        sig = sorted((k, [int(d) for d in v.shape], str(v.dtype))
                     for k, v in kernel.items())
        wl._io_sig = sig
    return sig


def _fold_direction(sig: Dict, direction: str) -> Dict:
    """Fold the verification direction into a content-address signature.

    ``"fwd"`` adds NOTHING — the forward-only key must stay byte-identical
    to what it was before the direction axis existed, so persistent caches
    written by older runs remain valid. Any other direction becomes an
    explicit key, so fwd and fwd_bwd results can never collide.
    """
    if direction != "fwd":
        sig["direction"] = direction
    return sig


def cache_key(candidate: cand_mod.Candidate, wl: Workload, seed: int,
              platform: PlatformLike = None, direction: str = "fwd") -> str:
    """Content address of one verification: op, sorted candidate params, the
    kernel-level input shapes/dtypes, tolerance, the input seed, the
    hardware platform the performance model scored against, and — for
    ``fwd_bwd`` only — the verification direction.

    Two verify calls with equal keys see byte-identical inputs, an identical
    candidate program, and the same platform profile, so their
    ``EvalResult`` is interchangeable. Results for the same candidate on
    different platforms carry different model times and must never collide;
    neither may a forward-only result ever satisfy a ``fwd_bwd`` request
    (it proved nothing about gradients).
    """
    sig = _fold_direction({
        "workload": wl.name,
        "op": candidate.op,
        "params": sorted((k, repr(v)) for k, v in candidate.params.items()),
        "io": io_signature(wl),
        "tol": wl.tol,
        "seed": int(seed),
        "platform": resolve_platform(platform).name,
    }, direction)
    blob = json.dumps(sig, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def executable_key(candidate: cand_mod.Candidate, wl: Workload,
                   platform: PlatformLike = None,
                   direction: str = "fwd") -> str:
    """Content address of one *compiled executable*: :func:`cache_key`
    minus seed and tolerance — the program ``jax.jit(...).lower().compile()``
    produces depends on the candidate, the kernel input shapes/dtypes, and
    the platform's compiler params, but not on which seed filled the arrays
    or how tightly the oracle is compared.  This is what lets a candidate
    revisited under a *fresh* seed (the §7.3 anti-cheating ladder) skip
    recompilation even though its verification result cannot be reused.

    ``direction="fwd_bwd"`` addresses the compiled *gradient* program — a
    different executable from the forward one, stored under a direction-
    folded key. The forward executable itself keeps the unchanged fwd key
    and is shared between fwd and fwd_bwd verifications (the primal
    computation is identical).
    """
    sig = _fold_direction({
        "op": candidate.op,
        "params": sorted((k, repr(v)) for k, v in candidate.params.items()),
        "io": io_signature(wl),
        "platform": resolve_platform(platform).name,
    }, direction)
    blob = json.dumps(sig, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def _check_direction(direction: str, wl: Workload) -> None:
    if direction not in ("fwd", "fwd_bwd"):
        raise ValueError(f"unknown direction {direction!r}; "
                         "expected 'fwd' or 'fwd_bwd'")
    if direction == "fwd_bwd" and not wl.differentiable:
        raise ValueError(
            f"workload {wl.name!r} is not differentiable — register it "
            "with differentiable=True to verify direction='fwd_bwd'")


def verify(candidate: cand_mod.Candidate, wl: Workload, *,
           seed: Optional[int] = None, measure_wall: bool = False,
           fn: Optional[Callable] = None, cache=None,
           platform: PlatformLike = None,
           io_cache: Optional[WorkloadIOCache] = None,
           exe_cache: Optional[ExecutableCache] = None,
           direction: str = "fwd") -> EvalResult:
    """Run the verification pipeline for one candidate against one workload,
    scoring performance against ``platform``'s roofline profile.

    ``io_cache`` / ``exe_cache`` (optional) plug in the fast-path cache
    layers: shared inputs + reference oracle per (workload, seed), and
    compiled-executable reuse per (candidate, io, platform).

    ``direction="fwd_bwd"`` (differentiable workloads only) adds the
    gradient check — see the module docstring.
    """
    plat = resolve_platform(platform)
    _check_direction(direction, wl)
    # Deterministic per-call counter, NOT time_ns(): wall-clock seeds defeat
    # the cache and make runs irreproducible. Pass a seed for fresh entropy.
    seed = next(_FRESH_SEEDS) % (2 ** 31) if seed is None else seed

    # -- verification cache: only declarative candidates are addressable ----
    key = None
    if cache is not None and fn is None:
        key = cache_key(candidate, wl, seed, plat, direction)
        hit = cache.get(key)
        # a hit recorded without wall-clock cannot satisfy a measure_wall
        # request — fall through, re-verify, and upgrade the entry.
        if hit is not None and (not measure_wall
                                or hit.wall_time_s is not None):
            return hit

    t0 = time.perf_counter()
    entry = io_cache.entry(wl, seed) if io_cache is not None \
        else IOEntry(wl, seed)
    phase = {"input_gen": time.perf_counter() - t0}
    result = _verify_uncached(candidate, wl, entry,
                              measure_wall=measure_wall, fn=fn, platform=plat,
                              exe_cache=exe_cache, phase=phase,
                              direction=direction)
    result.cache_key = key
    if key is not None:
        cache.put(key, result)
    return result


def verify_batch(candidates: Sequence[cand_mod.Candidate], wl: Workload, *,
                 seed: Optional[int] = None, measure_wall: bool = False,
                 cache=None, platform: PlatformLike = None,
                 io_cache: Optional[WorkloadIOCache] = None,
                 exe_cache: Optional[ExecutableCache] = None,
                 direction: str = "fwd") -> List[EvalResult]:
    """Verify many declarative candidates of ONE workload in a batch.

    All candidates see the SAME seed (so the refinement loop's fan-out
    shares one input set and one reference-oracle evaluation); the §7.3
    freshness defense lives at the *iteration* level, where each batch
    draws a new seed.  Before any work, candidates are deduped by
    :func:`cache_key` — exact duplicates (common in overlapping mutation
    neighborhoods) get the first occurrence's result object.  Input
    generation happens lazily: a batch fully served by the verification
    cache never touches the arrays.  Results come back in input order.

    Callable (LLM) candidates are not batchable — they have no content
    address to dedupe or compile-cache on; verify them singly.
    """
    plat = resolve_platform(platform)
    _check_direction(direction, wl)
    seed = next(_FRESH_SEEDS) % (2 ** 31) if seed is None else seed
    results: List[Optional[EvalResult]] = [None] * len(candidates)
    first_of: Dict[str, int] = {}
    keys: List[Optional[str]] = [None] * len(candidates)
    entry: Optional[IOEntry] = None
    for i, cand in enumerate(candidates):
        key = cache_key(cand, wl, seed, plat, direction)
        keys[i] = key
        if key in first_of:          # duplicate: resolved after the loop
            continue
        first_of[key] = i
        if cache is not None:
            hit = cache.get(key)
            if hit is not None and (not measure_wall
                                    or hit.wall_time_s is not None):
                results[i] = hit
                continue
        if entry is None:
            t0 = time.perf_counter()
            entry = io_cache.entry(wl, seed) if io_cache is not None \
                else IOEntry(wl, seed)
            input_gen_s = time.perf_counter() - t0
        result = _verify_uncached(cand, wl, entry,
                                  measure_wall=measure_wall, fn=None,
                                  platform=plat, exe_cache=exe_cache,
                                  phase={"input_gen": input_gen_s},
                                  direction=direction)
        input_gen_s = 0.0            # amortized: charged to the first miss
        result.cache_key = key
        if cache is not None:
            cache.put(key, result)
        results[i] = result
    for i, key in enumerate(keys):
        if results[i] is None:
            results[i] = results[first_of[key]]
    return results


def _verify_uncached(candidate, wl, entry: IOEntry, *,
                     measure_wall, fn, platform,
                     exe_cache: Optional[ExecutableCache] = None,
                     phase: Optional[Dict[str, float]] = None,
                     direction: str = "fwd") -> EvalResult:
    phase = {} if phase is None else phase
    kernel_inputs = entry.kernel_inputs
    shapes = entry.shapes

    # -- generation state handled by the caller; here candidate exists -------
    declarative = fn is None
    if fn is None:
        try:
            fn = cand_mod.materialize(
                candidate, platform=platform,
                differentiable=direction == "fwd_bwd")
        except Exception as exc:  # noqa: BLE001
            return EvalResult(ExecutionState.GENERATION_FAILURE,
                              error=f"{type(exc).__name__}: {exc}")

    # -- compilation: trace + lower ------------------------------------------
    t0 = time.perf_counter()
    exe_key = compiled = None
    if exe_cache is not None and declarative:
        exe_key = executable_key(candidate, wl, platform)
        compiled = exe_cache.get(exe_key)
    if compiled is None:
        try:
            compiled = jax.jit(fn).lower(*kernel_inputs.values()).compile()
        except Exception as exc:  # noqa: BLE001 — trace errors (TypeError,
            # ValueError, ...) and lowering errors classify identically
            return EvalResult(ExecutionState.COMPILATION_FAILURE,
                              error=f"{type(exc).__name__}: {exc}")
        if exe_key is not None:
            exe_cache.put(exe_key, compiled)
    phase["compile"] = time.perf_counter() - t0

    # -- runtime ---------------------------------------------------------------
    t0 = time.perf_counter()
    try:
        out = compiled(*kernel_inputs.values())
        out = jax.block_until_ready(out)
    except Exception as exc:  # noqa: BLE001
        return EvalResult(ExecutionState.RUNTIME_ERROR,
                          error=f"{type(exc).__name__}: {exc}")
    phase["run"] = time.perf_counter() - t0

    # -- numeric / shape check ---------------------------------------------------
    t0 = time.perf_counter()
    expected = entry.expected()
    full_out = kb.finish_candidate_output(wl, entry.inputs, out)
    if tuple(full_out.shape) != tuple(expected.shape):
        return EvalResult(
            ExecutionState.NUMERIC_MISMATCH,
            error=f"shape {tuple(full_out.shape)} != {tuple(expected.shape)}")
    a = np.asarray(full_out, np.float32)
    b = np.asarray(expected, np.float32)
    denom = np.maximum(np.abs(b), 1.0)
    err = float(np.max(np.abs(a - b) / denom)) if a.size else 0.0
    if not np.isfinite(a).all():
        return EvalResult(ExecutionState.NUMERIC_MISMATCH,
                          error="non-finite values in output", max_abs_err=err)
    if err > wl.tol:
        return EvalResult(ExecutionState.NUMERIC_MISMATCH,
                          error=f"max rel err {err:.2e} > tol {wl.tol:.0e}",
                          max_abs_err=err)
    phase["check"] = time.perf_counter() - t0

    # -- backward pass (direction="fwd_bwd" only) -----------------------------
    worst_grad_err = None
    if direction == "fwd_bwd":
        bad = _check_gradients(candidate, wl, entry, fn=fn,
                               declarative=declarative, platform=platform,
                               exe_cache=exe_cache, phase=phase)
        if isinstance(bad, EvalResult):
            return bad
        worst_grad_err = bad

    # -- performance ----------------------------------------------------------
    t0 = time.perf_counter()
    model_t = _model_time_tolerant(candidate, shapes, platform)
    base_t = _baseline_time_tolerant(candidate.op, shapes, platform)
    wall = None
    if measure_wall:
        t_w = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(compiled(*kernel_inputs.values()))
        wall = (time.perf_counter() - t_w) / 3
    phase["model"] = time.perf_counter() - t0
    profile = {
        "op": candidate.op,
        "platform": platform.name,
        "params": dict(candidate.params),
        "shapes": shapes,
        "model_time_s": model_t,
        "baseline_time_s": base_t,
        "flops": _op_flops(candidate.op, shapes),
        # per-phase wall seconds of THIS verification (journaled with the
        # iteration event; bench_verify_throughput aggregates them)
        "phase_s": {k: round(v, 6) for k, v in phase.items()},
    }
    if direction == "fwd_bwd":
        # Two-section profile: the top-level roofline keys become fwd+bwd
        # TOTALS (so analyzers built on them keep working and speedups
        # cover the whole training step), with each pass broken out.
        factor = cand_mod.bwd_cost_factor(candidate.op)
        flops = profile["flops"]
        bwd_model_t = _bwd_time_tolerant(
            cand_mod.model_time_bwd, candidate, shapes, platform)
        bwd_base_t = _bwd_time_tolerant(
            lambda c, s, p: cand_mod.baseline_time_bwd(c.op, s, p),
            candidate, shapes, platform)
        profile["direction"] = "fwd_bwd"
        profile["fwd"] = {"model_time_s": model_t,
                          "baseline_time_s": base_t, "flops": flops}
        profile["bwd"] = {"model_time_s": bwd_model_t,
                          "baseline_time_s": bwd_base_t,
                          "flops": flops * factor,
                          "max_rel_err": worst_grad_err}
        model_t = None if (model_t is None or bwd_model_t is None) \
            else model_t + bwd_model_t
        base_t = None if (base_t is None or bwd_base_t is None) \
            else base_t + bwd_base_t
        profile["model_time_s"] = model_t
        profile["baseline_time_s"] = base_t
    return EvalResult(ExecutionState.CORRECT, wall_time_s=wall,
                      model_time_s=model_t, baseline_model_time_s=base_t,
                      max_abs_err=err, profile=profile)


def _check_gradients(candidate, wl, entry: IOEntry, *, fn, declarative,
                     platform, exe_cache, phase):
    """The ``fwd_bwd`` gradient leg of verification.

    Differentiates the full composition the oracle is differentiated over
    — workload inputs → kernel-input transform → candidate → output
    completion — w.r.t. every float input, pulls the entry's shared
    cotangent back through it, and compares each gradient against the
    ``jax.vjp`` oracle under the workload's relative-error tolerance.

    Returns an :class:`EvalResult` on failure (COMPILATION_FAILURE /
    RUNTIME_ERROR with a ``bwd:`` prefix, or GRAD_MISMATCH naming the
    worst-offending gradient), else the worst observed relative error.
    The compiled gradient program is cached under the direction-folded
    executable key; it takes all inputs as arguments (nothing is baked in
    as a constant), so it is reusable across seeds like the forward one.
    """
    t0 = time.perf_counter()
    cot = entry.cotangent()
    diff_names = wl.grad_input_names(entry.inputs)
    diff = {k: entry.inputs[k] for k in diff_names}
    rest = {k: v for k, v in entry.inputs.items() if k not in diff_names}
    grad_key = compiled_grad = None
    if exe_cache is not None and declarative:
        grad_key = executable_key(candidate, wl, platform,
                                  direction="fwd_bwd")
        compiled_grad = exe_cache.get(grad_key)
    if compiled_grad is None:
        # Dicts round-tripped through jit come back KEY-SORTED; the merge
        # must rebuild the workload's declared input order or positional
        # kernels would silently receive permuted arguments.
        order = list(entry.inputs.keys())

        def grad_call(diff_inputs, rest_inputs, cot):
            def primal(d):
                merged = {k: (d[k] if k in d else rest_inputs[k])
                          for k in order}
                kins = kb.workload_for_candidate_inputs(wl, merged)
                out = fn(*kins.values())
                return kb.finish_candidate_output(wl, merged, out)
            _, vjp = jax.vjp(primal, diff_inputs)
            return vjp(cot)[0]
        try:
            compiled_grad = jax.jit(grad_call) \
                .lower(diff, rest, cot).compile()
        except Exception as exc:  # noqa: BLE001 — bwd trace/lower errors
            return EvalResult(ExecutionState.COMPILATION_FAILURE,
                              error=f"bwd: {type(exc).__name__}: {exc}")
        if grad_key is not None:
            exe_cache.put(grad_key, compiled_grad)
    phase["grad_compile"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    try:
        got = jax.block_until_ready(compiled_grad(diff, rest, cot))
    except Exception as exc:  # noqa: BLE001
        return EvalResult(ExecutionState.RUNTIME_ERROR,
                          error=f"bwd: {type(exc).__name__}: {exc}")
    phase["grad_run"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    oracle = entry.grads()
    worst_name, worst_err = None, 0.0
    for name in sorted(oracle):
        ga = np.asarray(got[name], np.float32)
        gb = np.asarray(oracle[name], np.float32)
        if not np.isfinite(ga).all():
            return EvalResult(
                ExecutionState.GRAD_MISMATCH,
                error=f"non-finite values in gradient wrt '{name}'")
        denom = np.maximum(np.abs(gb), 1.0)
        gerr = float(np.max(np.abs(ga - gb) / denom)) if ga.size else 0.0
        if gerr > worst_err:
            worst_name, worst_err = name, gerr
    phase["grad_check"] = time.perf_counter() - t0
    if worst_err > wl.tol:
        return EvalResult(
            ExecutionState.GRAD_MISMATCH,
            error=(f"gradient wrt '{worst_name}' (worst of "
                   f"{len(oracle)}): max rel err {worst_err:.2e} > "
                   f"tol {wl.tol:.0e}"),
            max_abs_err=worst_err)
    return worst_err


def _bwd_time_tolerant(time_fn, candidate, shapes, platform
                       ) -> Optional[float]:
    try:
        return time_fn(candidate, shapes, platform)
    except Exception:  # noqa: BLE001 — op without a bwd model
        return None


def _model_time_tolerant(candidate, shapes, platform) -> Optional[float]:
    """Roofline model time for candidates that may carry partial params.

    LLM-generated candidates arrive as callables whose declarative params
    are absent, partial, or arbitrarily malformed (a ``PARAMS`` block is
    model output: missing keys, wrong types, zeros); ``model_time`` would
    raise (KeyError/TypeError/ZeroDivisionError) and take the whole
    verification down *after* correctness was already established. Broken
    or missing params are replaced by the op's naive defaults instead, so
    such a candidate scores as the naive implementation (speedup 1.0) —
    conservative, never flattering. Returns None only when the op has no
    model at all.
    """
    try:
        return cand_mod.model_time(candidate, shapes, platform)
    except Exception:  # noqa: BLE001 — PARAMS is untrusted model output
        pass
    try:
        naive = cand_mod.naive_candidate(candidate.op, platform)
        filled = dict(naive.params)
        filled.update({k: v for k, v in candidate.params.items()
                       if type(v) is type(filled.get(k)) and v})
        return cand_mod.model_time(cand_mod.Candidate(candidate.op, filled),
                                   shapes, platform)
    except Exception:  # noqa: BLE001
        pass
    try:
        return cand_mod.baseline_time(candidate.op, shapes, platform)
    except Exception:  # noqa: BLE001 — op without a model at all
        return None


def _baseline_time_tolerant(op, shapes, platform) -> Optional[float]:
    try:
        return cand_mod.baseline_time(op, shapes, platform)
    except Exception:  # noqa: BLE001 — op without a model at all
        return None


def _op_flops(op: str, shapes) -> float:
    if op == "matmul":
        m, k = shapes["a"]
        n = shapes["b"][1]
        return 2.0 * m * n * k
    if op == "attention":
        b, sq, h, d = shapes["q"]
        sk = shapes["k"][1]
        return 2.0 * b * h * sq * sk * d
    first = next(iter(shapes.values()))
    n = 1
    for d in first:
        n *= d
    return float(4 * n)
