"""Program verification (paper §3.3): classify each candidate into one of the
five execution states and measure its performance.

Inputs are re-randomized on every call (fresh seed), so constant-output
"cheating" candidates (paper §7.3) are caught as numeric mismatches instead
of surviving evaluation.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.core import candidates as cand_mod
from repro.core import kernelbench as kb
from repro.core.states import EvalResult, ExecutionState
from repro.core.workload import Workload

_TRACE_ERRORS = (TypeError, ValueError, AssertionError, KeyError,
                 IndexError, NotImplementedError)


def verify(candidate: cand_mod.Candidate, wl: Workload, *,
           seed: Optional[int] = None, measure_wall: bool = False,
           fn: Optional[Callable] = None) -> EvalResult:
    """Run the verification pipeline for one candidate against one workload."""
    seed = int(time.time_ns() % (2 ** 31)) if seed is None else seed
    inputs = wl.inputs(seed)
    kernel_inputs = kb.workload_for_candidate_inputs(wl, inputs)
    shapes = {k: tuple(v.shape) for k, v in kernel_inputs.items()}

    # -- generation state handled by the caller; here candidate exists -------
    if fn is None:
        try:
            fn = cand_mod.materialize(candidate)
        except Exception as exc:  # noqa: BLE001
            return EvalResult(ExecutionState.GENERATION_FAILURE,
                              error=f"{type(exc).__name__}: {exc}")

    # -- compilation: trace + lower ------------------------------------------
    try:
        jitted = jax.jit(fn)
        lowered = jitted.lower(*kernel_inputs.values())
        compiled = lowered.compile()
    except _TRACE_ERRORS as exc:
        return EvalResult(ExecutionState.COMPILATION_FAILURE,
                          error=f"{type(exc).__name__}: {exc}")
    except Exception as exc:  # noqa: BLE001
        return EvalResult(ExecutionState.COMPILATION_FAILURE,
                          error=f"{type(exc).__name__}: {exc}")

    # -- runtime ---------------------------------------------------------------
    try:
        out = compiled(*kernel_inputs.values())
        out = jax.block_until_ready(out)
    except Exception as exc:  # noqa: BLE001
        return EvalResult(ExecutionState.RUNTIME_ERROR,
                          error=f"{type(exc).__name__}: {exc}")

    # -- numeric / shape check ---------------------------------------------------
    expected = wl.reference(inputs)
    full_out = kb.finish_candidate_output(wl, inputs, out)
    if tuple(full_out.shape) != tuple(expected.shape):
        return EvalResult(
            ExecutionState.NUMERIC_MISMATCH,
            error=f"shape {tuple(full_out.shape)} != {tuple(expected.shape)}")
    a = np.asarray(full_out, np.float32)
    b = np.asarray(expected, np.float32)
    denom = np.maximum(np.abs(b), 1.0)
    err = float(np.max(np.abs(a - b) / denom)) if a.size else 0.0
    if not np.isfinite(a).all():
        return EvalResult(ExecutionState.NUMERIC_MISMATCH,
                          error="non-finite values in output", max_abs_err=err)
    if err > wl.tol:
        return EvalResult(ExecutionState.NUMERIC_MISMATCH,
                          error=f"max rel err {err:.2e} > tol {wl.tol:.0e}",
                          max_abs_err=err)

    # -- performance ----------------------------------------------------------
    model_t = cand_mod.model_time(candidate, shapes)
    base_t = cand_mod.baseline_time(candidate.op, shapes)
    wall = None
    if measure_wall:
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(compiled(*kernel_inputs.values()))
        wall = (time.perf_counter() - t0) / 3
    profile = {
        "op": candidate.op,
        "params": dict(candidate.params),
        "shapes": shapes,
        "model_time_s": model_t,
        "baseline_time_s": base_t,
        "flops": _op_flops(candidate.op, shapes),
    }
    return EvalResult(ExecutionState.CORRECT, wall_time_s=wall,
                      model_time_s=model_t, baseline_model_time_s=base_t,
                      max_abs_err=err, profile=profile)


def _op_flops(op: str, shapes) -> float:
    if op == "matmul":
        m, k = shapes["a"]
        n = shapes["b"][1]
        return 2.0 * m * n * k
    if op == "attention":
        b, sq, h, d = shapes["q"]
        sk = shapes["k"][1]
        return 2.0 * b * h * sq * sk * d
    first = next(iter(shapes.values()))
    n = 1
    for d in first:
        n *= d
    return float(4 * n)
