"""Cross-platform reference-implementation registry (paper §6.2).

The paper shows that a correct CUDA kernel substantially improves Metal
synthesis. Two reference flavours exist here:

* the *oracle* reference — the pure-jnp source for the op family
  (:func:`reference_source`), the "other platform" being XLA;
* a *harvested* reference — the best verified candidate from a campaign on
  another registered platform (:func:`strategy_hints`,
  :func:`candidate_reference_source`), which is what the transfer sweep in
  :mod:`repro.campaign.transfer` injects — and what the all-pairs matrix
  (:mod:`repro.campaign.matrix`) harvests once per platform and re-injects
  into every warm leg that platform feeds.

Either way the transferable part is the *strategy* (online softmax, fusion,
matrix form); the tiling must be re-derived for the target platform —
``candidates.initial_candidate`` re-aligns tile params to the target's
matrix unit when a reference is injected.
"""
from __future__ import annotations

import inspect
from typing import Any, Dict, Optional

from repro.core.workload import Workload
from repro.kernels import ref as ref_mod

_REF_SOURCES = {
    "swish": "swish",
    "softmax": "softmax",
    "rmsnorm": "rmsnorm",
    "matmul": "matmul",
    "swiglu": "swiglu",
    "attention": "attention",
    "xent": "softmax_xent",
}


def reference_source(wl: Workload) -> Optional[str]:
    """Source text of the XLA-oracle reference implementation."""
    name = _REF_SOURCES.get(wl.op)
    if name is None:
        return None
    fn = getattr(ref_mod, name, None)
    if fn is None:
        return None
    try:
        return inspect.getsource(fn)
    except OSError:
        return None


def workload_source(wl: Workload) -> str:
    try:
        return inspect.getsource(wl.ref_fn)
    except (OSError, TypeError):
        return f"# {wl.name}: {wl.description}\n# oracle: kernels/ref.py::{wl.op}"


def strategy_hints(params: Dict[str, Any]) -> Dict[str, Any]:
    """The platform-portable subset of a candidate's params.

    Strategy axes (online-softmax, fusion, recurrence form, ...) transfer
    across accelerators; tile/chunk sizes do not — they are re-derived for
    the target's alignment and fast-memory budget (paper §6.2)."""
    return {k: v for k, v in params.items()
            if not (k.startswith("block_") or k == "chunk")}


def candidate_reference_source(op: str, params: Dict[str, Any],
                               platform_name: str) -> str:
    """Render a harvested best-verified candidate as prompt reference text.

    The template backend consumes the structured hints directly; for LLM
    backends this block plays the role of the paper's correct-CUDA-kernel
    reference (LLMBackend.reference_sources)."""
    kv = "\n".join(f"#   {k} = {v!r}" for k, v in sorted(params.items()))
    portable = strategy_hints(params)
    strat = ", ".join(f"{k}={v!r}" for k, v in sorted(portable.items())) \
        or "(tiling only)"
    return (f"# Best verified {op} kernel from platform {platform_name!r}\n"
            f"# (campaign-harvested; tiling is platform-specific, the\n"
            f"#  strategy transfers): {strat}\n{kv}\n")
