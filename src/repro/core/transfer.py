"""Cross-platform reference-implementation registry (paper §6.2).

The paper shows that a correct CUDA kernel substantially improves Metal
synthesis. The TPU mapping: the "other platform" is XLA — the pure-jnp
oracle source (plus any known-good Pallas kernel for the same family) is
injected into the synthesis prompt, and teaches the offline search backend
the correct *strategy* (online softmax, fusion) via candidates.REFERENCE_HINTS.
"""
from __future__ import annotations

import inspect
from typing import Optional

from repro.core.workload import Workload
from repro.kernels import ref as ref_mod

_REF_SOURCES = {
    "swish": "swish",
    "softmax": "softmax",
    "rmsnorm": "rmsnorm",
    "matmul": "matmul",
    "swiglu": "swiglu",
    "attention": "attention",
    "xent": "softmax_xent",
}


def reference_source(wl: Workload) -> Optional[str]:
    """Source text of the reference implementation for the prompt."""
    name = _REF_SOURCES.get(wl.op)
    if name is None:
        return None
    fn = getattr(ref_mod, name, None)
    if fn is None:
        return None
    try:
        return inspect.getsource(fn)
    except OSError:
        return None


def workload_source(wl: Workload) -> str:
    try:
        return inspect.getsource(wl.ref_fn)
    except (OSError, TypeError):
        return f"# {wl.name}: {wl.description}\n# oracle: kernels/ref.py::{wl.op}"
