"""Prompt templates for the LLM backends (paper Listing 1 and §3.2).

Plain ``str.format`` stands in for Jinja2 (same fields as the paper's
template); the offline template-search backend consumes the same structured
fields, so the prompt is the single source of task context either way.

**The per-platform prompt contract.** A synthesis prompt is assembled from
exactly three platform-owned fields plus per-iteration state; everything
platform-specific flows through the registry (``repro.platforms``), never
through template forks:

* ``Platform.descriptor`` → ``{accelerator}`` — names the target in every
  instruction line ("Pallas TPU (v5e)", "Apple Metal GPU (M2-class)", ...).
* ``Platform.oneshot_example`` → ``{example_src}`` — one complete kernel in
  the target's own idiom (Pallas for the TPUs, CUDA for ``gpu_sim``, MSL
  for ``metal_m2``): the paper's one-shot example that teaches syntax,
  tiling, and launch integration in a single shot.
* ``Platform.constraints_note`` → ``{constraints}`` — the working-set
  budget and alignment rules the candidate must respect (VMEM 128 MiB /
  MXU 128 on TPU, threadgroup 32 KiB / simdgroup 8 on Metal, ...).

Per-iteration state renders into two optional blocks: ``REFERENCE_BLOCK``
(a correct implementation from another platform — the §6.2 transfer
channel; ``LLMBackend.reference_sources`` supplies campaign-harvested
kernels, the XLA-oracle source is the fallback) and ``FEEDBACK_BLOCK``
(the previous attempt's ``EvalResult.feedback()`` string, its source, and
agent G's single recommendation — the compilation/repair loop of §3.3).

The reply contract is fixed across platforms: one fenced code block
defining ``candidate(*inputs)`` (optionally a ``PARAMS`` dict with the
declarative tiling the performance model should score —
``LLMBackend.generate`` adopts it).

``ANALYSIS_TEMPLATE`` is agent G's side of the conversation: it receives
the verification profile JSON (roofline terms, tiling params, collective
summary — all platform-stamped by ``verify``) and must answer with ONE
actionable parameter recommendation, mirroring
``analysis.RuleBasedAnalyzer``'s single-recommendation contract.

Prompt drift is guarded by golden snapshots: ``tests/test_prompts_golden.py``
renders this template for every registered platform and diffs against
``tests/goldens/`` — regenerate with ``UPDATE_GOLDENS=1`` when a change is
intentional, so review sees the full prompt diff.
"""
from __future__ import annotations

SYNTHESIS_TEMPLATE = """\
You write custom {accelerator} kernels to replace the JAX/XLA operators in
the given workload to get speedups.

Here's an example to show you the syntax of a custom {accelerator} kernel
with explicit tiling, its scheduling logic and launch/jit integration:

{example_src}

You are given the following workload (reference implementation in pure
jax.numpy — treat it as the correctness oracle):

{workload_src}
{reference_block}
Optimize the workload named {workload_name} with a custom {accelerator}
kernel. {constraints}
{feedback_block}
Output the new code in codeblocks. The code must define a function
`candidate(*inputs)` returning the workload output.
"""

REFERENCE_BLOCK = """
A functionally correct implementation for a different accelerator ({ref_platform})
is provided as a reference — the parallel decomposition transfers even though
the tiling must be re-derived for the target:

{ref_src}
"""

FEEDBACK_BLOCK = """
Your previous attempt produced:

{prev_result}

Previous program:

{prev_src}

Fix the error if any; otherwise improve performance guided by:
{recommendation}
"""

ANALYSIS_TEMPLATE = """\
You are a TPU performance engineer. Below are profiling artifacts for a
kernel candidate: the roofline terms (compute / HBM / interconnect seconds),
the tiling parameters, and the optimized-HLO collective summary.

Profile:
{profile_json}

Identify the SINGLE change most likely to improve performance, and reply
with one actionable recommendation (one sentence, name the parameter and
target value).
"""


def render_synthesis(accelerator: str, example_src: str, workload_src: str,
                     workload_name: str, *, ref_src: str = "",
                     ref_platform: str = "CUDA", prev_src: str = "",
                     prev_result: str = "", recommendation: str = "",
                     constraints: str = "") -> str:
    """Assemble one synthesis prompt (see the module docstring for the
    field contract). The reference block renders only when ``ref_src`` is
    non-empty; the feedback block only when there was a previous attempt
    (``prev_src`` or ``prev_result``); an empty ``constraints`` falls back
    to the registry default target's note."""
    from repro.platforms import resolve_platform
    ref_block = REFERENCE_BLOCK.format(
        ref_platform=ref_platform, ref_src=ref_src) if ref_src else ""
    fb = FEEDBACK_BLOCK.format(prev_result=prev_result, prev_src=prev_src,
                               recommendation=recommendation or "(none)") \
        if prev_src or prev_result else ""
    return SYNTHESIS_TEMPLATE.format(
        accelerator=accelerator, example_src=example_src,
        workload_src=workload_src, workload_name=workload_name,
        reference_block=ref_block, feedback_block=fb,
        # default: the registry default target's note (single source)
        constraints=constraints or resolve_platform(None).constraints_note)
