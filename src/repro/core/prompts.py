"""Prompt templates for the LLM backends (paper Listing 1 and §3.2).

Plain ``str.format`` stands in for Jinja2 (same fields as the paper's
template); the offline template-search backend consumes the same structured
fields, so the prompt is the single source of task context either way.

**The per-platform prompt contract.** A synthesis prompt is assembled from
exactly three platform-owned fields plus per-iteration state; everything
platform-specific flows through the registry (``repro.platforms``), never
through template forks:

* ``Platform.descriptor`` → ``{accelerator}`` — names the target in every
  instruction line ("Pallas TPU (v5e)", "Apple Metal GPU (M2-class)", ...).
* ``Platform.oneshot_example`` → ``{example_src}`` — one complete kernel in
  the target's own idiom (Pallas for the TPUs, CUDA for ``gpu_sim``, MSL
  for ``metal_m2``): the paper's one-shot example that teaches syntax,
  tiling, and launch integration in a single shot.
* ``Platform.constraints_note`` → ``{constraints}`` — the working-set
  budget and alignment rules the candidate must respect (VMEM 128 MiB /
  MXU 128 on TPU, threadgroup 32 KiB / simdgroup 8 on Metal, ...).

Per-iteration state renders into two optional blocks: ``REFERENCE_BLOCK``
(a correct implementation from another platform — the §6.2 transfer
channel; ``LLMBackend.reference_sources`` supplies campaign-harvested
kernels, the XLA-oracle source is the fallback) and ``FEEDBACK_BLOCK``
(the previous attempt's ``EvalResult.feedback()`` string, its source, and
agent G's single recommendation — the compilation/repair loop of §3.3).

The reply contract is fixed across platforms: one fenced code block
defining ``candidate(*inputs)`` (optionally a ``PARAMS`` dict with the
declarative tiling the performance model should score —
``LLMBackend.generate`` adopts it).

``ANALYSIS_TEMPLATE`` is agent G's side of the conversation: it receives
the verification profile JSON (roofline terms, tiling params, collective
summary — all platform-stamped by ``verify``) plus the platform-legal
parameter space, and must answer with ONE actionable parameter
recommendation, mirroring ``analysis.RuleBasedAnalyzer``'s
single-recommendation contract. The reply contract is three labelled
lines (``RECOMMENDATION:`` / ``PARAM:`` / ``VALUE:``) so the reply is
machine-checkable: :func:`repro.llm.analyzer.parse_recommendation` turns
it into a structured :class:`repro.core.analysis.Recommendation`, and the
session layer re-prompts replies missing the ``RECOMMENDATION:`` line the
same way it re-prompts fence-less synthesis completions. The profile is
embedded as a fenced ``json`` block so offline oracles
(``MockTransport``'s analysis branch) can recover it verbatim.

Prompt drift is guarded by golden snapshots: ``tests/test_prompts_golden.py``
renders this template for every registered platform and diffs against
``tests/goldens/`` — regenerate with ``UPDATE_GOLDENS=1`` when a change is
intentional, so review sees the full prompt diff.
"""
from __future__ import annotations

SYNTHESIS_TEMPLATE = """\
You write custom {accelerator} kernels to replace the JAX/XLA operators in
the given workload to get speedups.

Here's an example to show you the syntax of a custom {accelerator} kernel
with explicit tiling, its scheduling logic and launch/jit integration:

{example_src}

You are given the following workload (reference implementation in pure
jax.numpy — treat it as the correctness oracle):

{workload_src}
{reference_block}
Optimize the workload named {workload_name} with a custom {accelerator}
kernel. {constraints}
{feedback_block}
Output the new code in codeblocks. The code must define a function
`candidate(*inputs)` returning the workload output.
"""

REFERENCE_BLOCK = """
A functionally correct implementation for a different accelerator ({ref_platform})
is provided as a reference — the parallel decomposition transfers even though
the tiling must be re-derived for the target:

{ref_src}
"""

FEEDBACK_BLOCK = """
Your previous attempt produced:

{prev_result}

Previous program:

{prev_src}

Fix the error if any; otherwise improve performance guided by:
{recommendation}
"""

# Every analysis prompt contains this line verbatim (the {accelerator}
# field renders elsewhere), so transports can recognize an agent-G turn
# without parsing: MockTransport routes it to its deterministic analysis
# oracle. Re-prompts quote the original prompt, so the marker survives them.
ANALYSIS_MARKER = "the performance-analysis agent of a two-agent"

ANALYSIS_TEMPLATE = """\
You are a {accelerator} performance engineer acting as
the performance-analysis agent of a two-agent kernel-synthesis loop.
Below is the verification profile of a CORRECT kernel candidate: the
roofline terms (modeled kernel seconds against the XLA baseline), its
tiling parameters, and the platform the profile was stamped against.

```json
{profile_json}
```

The platform-legal parameter space for this op — any PARAM you name must
be one of these keys, and a numeric VALUE one of that key's choices:

{space_json}

Identify the SINGLE change most likely to improve performance (the loop
applies exactly one recommendation per iteration). Reply with exactly
three lines:

RECOMMENDATION: <one sentence naming the parameter and target value>
PARAM: <parameter name from the space above, or none>
VALUE: <target value as a JSON literal, or none>
"""

# Appended to the analysis prompt ONLY for training-shaped (fwd_bwd)
# profiles — forward-only prompts stay byte-identical to their pre-direction
# renderings (replay sessions and golden snapshots key on the bytes).
ANALYSIS_FWD_BWD_NOTE = """
This profile is training-shaped: the `fwd` and `bwd` sections carry
separate roofline terms for the forward pass and the backward
(gradient) pass, and the top-level modeled times are their sum. The
backward pass recomputes the forward inside its VJP, so a tiling
change moves BOTH terms — weigh the recommendation against the
combined time, not the forward roofline alone.
"""


def is_analysis_prompt(prompt: str) -> bool:
    """True when ``prompt`` is (or re-prompts) an agent-G analysis turn —
    judged by :data:`ANALYSIS_MARKER`, which every rendered
    ``ANALYSIS_TEMPLATE`` contains verbatim."""
    return ANALYSIS_MARKER in prompt


def render_analysis(accelerator: str, profile: dict,
                    space: dict | None = None) -> str:
    """Assemble one agent-G analysis prompt (§3.2).

    ``profile`` is the verification profile dict ``verify`` stamps on a
    CORRECT result (op, platform, params, shapes, modeled times, flops);
    ``space`` the platform-legal parameter space for the profile's op
    (``candidates.space_for``). Both render as deterministic JSON
    (sorted keys), so identical inputs produce byte-identical prompts —
    what record/replay sessions key on. Wall-clock measurement keys
    (``phase_s``) are stripped first: their values differ on every run,
    and a prompt that embeds them can never replay."""
    import json
    profile = {k: v for k, v in profile.items() if k != "phase_s"}
    prompt = ANALYSIS_TEMPLATE.format(
        accelerator=accelerator,
        profile_json=json.dumps(profile, indent=2, sort_keys=True,
                                default=str),
        space_json=json.dumps(space or {}, sort_keys=True, default=str))
    if profile.get("direction") == "fwd_bwd":
        prompt += ANALYSIS_FWD_BWD_NOTE
    return prompt


def render_synthesis(accelerator: str, example_src: str, workload_src: str,
                     workload_name: str, *, ref_src: str = "",
                     ref_platform: str = "CUDA", prev_src: str = "",
                     prev_result: str = "", recommendation: str = "",
                     constraints: str = "") -> str:
    """Assemble one synthesis prompt (see the module docstring for the
    field contract). The reference block renders only when ``ref_src`` is
    non-empty; the feedback block only when there was a previous attempt
    (``prev_src`` or ``prev_result``); an empty ``constraints`` falls back
    to the registry default target's note."""
    from repro.platforms import resolve_platform
    ref_block = REFERENCE_BLOCK.format(
        ref_platform=ref_platform, ref_src=ref_src) if ref_src else ""
    fb = FEEDBACK_BLOCK.format(prev_result=prev_result, prev_src=prev_src,
                               recommendation=recommendation or "(none)") \
        if prev_src or prev_result else ""
    return SYNTHESIS_TEMPLATE.format(
        accelerator=accelerator, example_src=example_src,
        workload_src=workload_src, workload_name=workload_name,
        reference_block=ref_block, feedback_block=fb,
        # default: the registry default target's note (single source)
        constraints=constraints or resolve_platform(None).constraints_note)
