"""Verification fast path: shared input/oracle and executable caches.

Verification is the hot path of the whole system — every candidate, every
refinement iteration, every matrix leg funnels through ``verify()``.  Two
of its per-call costs are *not* candidate-specific and this module
memoizes them (DESIGN.md §4, "Verification fast path"):

* :class:`WorkloadIOCache` — the workload inputs for one seed, the
  kernel-level input dict derived from them, and (lazily) the
  reference-oracle output.  All three are **platform-independent**, so a
  single entry serves every candidate of a refinement iteration AND every
  leg of a transfer matrix that shares the (workload, seed) pair.

* :class:`ExecutableCache` — compiled executables (the product of
  ``jax.jit(fn).lower(...).compile()``) keyed by candidate content +
  kernel io signature + platform.  Candidates revisited under *different
  seeds* miss the result-level VerificationCache (the seed is part of its
  content address, §7.3) but compile to the identical executable — this
  cache hands it back.

Neither cache weakens the §7.3 anti-cheating defense: the IO cache keys on
the seed (two seeds never share inputs or an oracle output), and the
executable cache stores compiled *programs*, never results.

Both are thread-safe, bounded (LRU), and expose ``stats()`` snapshots that
campaigns journal next to the VerificationCache stats.  Neither survives a
fork or a pickle round-trip by design: locks and compiled executables must
be born in the process that uses them (matrix legs under process isolation
build fresh caches inside each child, mirroring ``leg_cache()``).
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

from repro.core import kernelbench as kb
from repro.core.workload import Workload


class ShapeOnlyRng:
    """A ``numpy.random.Generator`` stand-in whose draws are constant.

    ``io_signature`` only needs the *shapes and dtypes* a workload's
    ``input_fn`` produces; spending random-bit generation (hundreds of ms
    for the large suites) to read metadata is waste.  The known ``input_fn``
    draw methods return zero-filled (or low-bound-filled, to stay in any
    domain the op expects) arrays of the right shape and dtype instead.
    Any other Generator method falls through to a real seeded generator,
    so exotic future ``input_fn``s stay correct, just slower.
    """

    def __init__(self) -> None:
        self._real = None

    def _fallback(self):
        if self._real is None:
            self._real = np.random.default_rng(0)
        return self._real

    def standard_normal(self, size=None, dtype=np.float64):
        return np.zeros(() if size is None else size, dtype=dtype)

    def uniform(self, low=0.0, high=1.0, size=None):
        return np.full(() if size is None else size, low, dtype=np.float64)

    def integers(self, low, high=None, size=None, dtype=np.int64,
                 endpoint=False):
        fill = 0 if high is None else low
        return np.full(() if size is None else size, fill, dtype=dtype)

    def __getattr__(self, name):
        return getattr(self._fallback(), name)


class IOEntry:
    """Materialized verification inputs for one (workload, seed).

    Carries the named input arrays, the kernel-level input dict, and their
    shapes; the reference-oracle output is computed lazily on first
    :meth:`expected` call (a batch of candidates that all fail compilation
    never pays for the oracle) and memoized under a per-entry lock so
    concurrent legs compute it once.  ``direction="fwd_bwd"`` verification
    additionally draws on :meth:`cotangent` (the seed-derived pull-back
    vector) and :meth:`grads` (the ``jax.vjp`` oracle gradients) — both
    lazy and memoized the same way, so a batch of candidates shares ONE
    cotangent draw and ONE oracle-gradient evaluation per (workload, seed).
    """

    __slots__ = ("wl", "seed", "inputs", "kernel_inputs", "shapes",
                 "_expected", "_cotangent", "_grads", "_lock", "_on_oracle",
                 "_on_grad_oracle")

    def __init__(self, wl: Workload, seed: int,
                 on_oracle: Optional[Callable[[], None]] = None,
                 on_grad_oracle: Optional[Callable[[], None]] = None) -> None:
        self.wl = wl
        self.seed = int(seed)
        self.inputs = wl.inputs(seed)
        self.kernel_inputs = kb.workload_for_candidate_inputs(wl, self.inputs)
        self.shapes = {k: tuple(v.shape)
                       for k, v in self.kernel_inputs.items()}
        self._expected = None
        self._cotangent = None
        self._grads = None
        self._lock = threading.Lock()
        self._on_oracle = on_oracle
        self._on_grad_oracle = on_grad_oracle

    def expected(self):
        """The reference-oracle output for these inputs (computed once)."""
        with self._lock:
            if self._expected is None:
                self._expected = self.wl.reference(self.inputs)
                if self._on_oracle is not None:
                    self._on_oracle()
            return self._expected

    def cotangent(self):
        """The seed-derived cotangent for the backward check (drawn once)."""
        with self._lock:
            if self._cotangent is None:
                self._cotangent = self.wl.cotangent(self.inputs, self.seed)
            return self._cotangent

    def grads(self):
        """Oracle gradients (``jax.vjp`` over ``ref_fn``, computed once)."""
        cot = self.cotangent()
        with self._lock:
            if self._grads is None:
                self._grads = self.wl.grad_reference(self.inputs, cot)
                if self._on_grad_oracle is not None:
                    self._on_grad_oracle()
            return self._grads


def _workload_key(wl: Workload, seed: int) -> Tuple:
    """IO-cache key: workload identity + input seed.  ``input_shapes`` is
    part of the key because the small and full suites share workload names
    (same reason the campaign resume path compares io signatures)."""
    return (wl.name, wl.level,
            tuple(sorted((k, tuple(int(d) for d in v))
                         for k, v in wl.input_shapes.items())),
            int(seed))


class WorkloadIOCache:
    """Thread-safe bounded LRU of :class:`IOEntry` per (workload, seed).

    ``max_entries=0`` disables storage entirely (every call builds a fresh
    entry and counts a miss) — the benchmark's cold arm and a memory
    escape hatch.  ``oracle_computes`` counts reference-oracle evaluations
    actually performed through entries this cache handed out; with sharing
    working, a matrix run's count stays strictly below legs × workloads.
    """

    # Process-wide tally of io_signature()'s concrete fallback (the
    # abstract eval_shape path failed and real inputs were generated just
    # to read metadata). Class-level on purpose: the fallback fires inside
    # repro.core.verification.io_signature, which has no instance in
    # scope, and a nonzero count is a performance regression worth
    # surfacing in every campaign report regardless of which cache
    # instance the campaign used.
    _io_sig_fallbacks = 0
    _class_lock = threading.Lock()

    def __init__(self, max_entries: int = 128) -> None:
        self.max_entries = int(max_entries)
        self._store: "OrderedDict[Tuple, IOEntry]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.oracle_computes = 0
        self.grad_oracle_computes = 0
        self.input_computes = 0

    @classmethod
    def count_io_sig_fallback(cls) -> None:
        """Record one abstract-path failure in ``io_signature``."""
        with cls._class_lock:
            cls._io_sig_fallbacks += 1

    @classmethod
    def io_sig_fallbacks(cls) -> int:
        with cls._class_lock:
            return cls._io_sig_fallbacks

    def _count_oracle(self) -> None:
        with self._lock:
            self.oracle_computes += 1

    def _count_grad_oracle(self) -> None:
        with self._lock:
            self.grad_oracle_computes += 1

    def entry(self, wl: Workload, seed: int) -> IOEntry:
        """The (possibly cached) IOEntry for one (workload, seed)."""
        key = _workload_key(wl, seed)
        with self._lock:
            cached = self._store.get(key)
            if cached is not None:
                self.hits += 1
                self._store.move_to_end(key)
                return cached
            self.misses += 1
        # Build outside the cache lock: input generation is the expensive
        # part and must not serialize unrelated workloads. If two threads
        # race the same key, the first to publish wins; the loser's entry
        # is dropped unused (its counters were already charged — they
        # reflect work genuinely done).
        entry = IOEntry(wl, seed, on_oracle=self._count_oracle,
                        on_grad_oracle=self._count_grad_oracle)
        with self._lock:
            self.input_computes += 1
            current = self._store.get(key)
            if current is not None:
                return current
            if self.max_entries > 0:
                self._store[key] = entry
                while len(self._store) > self.max_entries:
                    self._store.popitem(last=False)
        return entry

    def __len__(self) -> int:
        with self._lock:
            return len(self._store)

    def stats(self) -> Dict[str, int]:
        """Snapshot of {entries, hits, misses, oracle_computes,
        grad_oracle_computes, input_computes, io_sig_fallbacks} —
        journaled on campaign_done events next to the VerificationCache
        stats. ``io_sig_fallbacks`` is the process-wide concrete-fallback
        tally (see :meth:`count_io_sig_fallback`), snapshotted here so
        abstract-path regressions surface in campaign reports."""
        with self._lock:
            return {"entries": len(self._store), "hits": self.hits,
                    "misses": self.misses,
                    "oracle_computes": self.oracle_computes,
                    "grad_oracle_computes": self.grad_oracle_computes,
                    "input_computes": self.input_computes,
                    "io_sig_fallbacks": self.io_sig_fallbacks()}


class ExecutableCache:
    """Thread-safe bounded LRU of compiled executables.

    Keys come from :func:`repro.core.verification.executable_key` — the
    candidate content address minus seed and tolerance (the compiled
    program depends on neither).  Values are whatever
    ``jax.jit(fn).lower(...).compile()`` returned; they are process-local
    and never pickled or journaled (only the counters are).
    """

    def __init__(self, max_entries: int = 256) -> None:
        self.max_entries = int(max_entries)
        self._store: "OrderedDict[str, Any]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: str) -> Optional[Any]:
        with self._lock:
            exe = self._store.get(key)
            if exe is None:
                self.misses += 1
            else:
                self.hits += 1
                self._store.move_to_end(key)
            return exe

    def put(self, key: str, exe: Any) -> None:
        if self.max_entries <= 0:
            return
        with self._lock:
            self._store[key] = exe
            while len(self._store) > self.max_entries:
                self._store.popitem(last=False)

    def __len__(self) -> int:
        with self._lock:
            return len(self._store)

    def stats(self) -> Dict[str, int]:
        """Snapshot of {entries, hits, misses}."""
        with self._lock:
            return {"entries": len(self._store), "hits": self.hits,
                    "misses": self.misses}
