"""fast_p metric (paper §4.2) and result aggregation."""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional

from repro.core.states import EvalResult, ExecutionState


def fast_p(results: Iterable[EvalResult], p: float) -> float:
    """Fraction of problems correct AND speedup > p. fast_0 = correctness."""
    results = list(results)
    if not results:
        return 0.0
    hits = 0
    for r in results:
        if not r.correct:
            continue
        if p <= 0:
            hits += 1
            continue
        sp = r.speedup
        if sp is not None and sp > p:
            hits += 1
    return hits / len(results)


def fast_p_curve(results: Iterable[EvalResult],
                 ps=(0.0, 0.5, 1.0, 1.5, 2.0)) -> Dict[float, float]:
    results = list(results)
    return {p: fast_p(results, p) for p in ps}


def state_histogram(results: Iterable[EvalResult]) -> Dict[str, int]:
    hist: Dict[str, int] = {s.value: 0 for s in ExecutionState}
    for r in results:
        hist[r.state.value] += 1
    return {k: v for k, v in hist.items() if v}


def speedup_distribution(results: Iterable[EvalResult]) -> List[float]:
    """Continuous speedups (the finer-grained view the paper's §8 asks for)."""
    return sorted(r.speedup for r in results if r.correct and r.speedup)
