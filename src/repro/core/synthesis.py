"""Generation agent F (paper §3.1): ``F : (p, k_{t-1}, r_{t-1}) -> k_t``.

Backends behind one protocol:

* ``TemplateSearchBackend`` — the offline deterministic synthesizer. It
  explores the same candidate space an LLM navigates (tiling, vectorization,
  online-softmax strategy, fusion), consuming the same feedback strings: on
  a failure it repairs the specific error (functional pass); on a
  recommendation from agent G it applies the suggested parameter change,
  falling back to the best predicted mutation (optimization pass).

* ``LLMBackend`` — builds the paper's prompt (core/prompts.py) and calls a
  ``complete(prompt) -> str`` — in production an
  :class:`repro.llm.LLMSession` over a real transport, in tests any
  callable (a canned transcript, a MockTransport session). The returned
  code block is exec'd in a restricted namespace to recover
  ``candidate(*inputs)``; a ``PARAMS`` dict defined alongside it is adopted
  as the candidate's declarative tiling params so the performance model can
  score the LLM's choice. Constructing an ``LLMBackend`` without a
  completion channel is an immediate ``ValueError`` (pass
  ``prompt_only=True`` for prompt inspection without one).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Dict, Optional, Protocol, Tuple

from repro.core import candidates as cand_mod
from repro.core import prompts, transfer
from repro.core.analysis import Recommendation
from repro.core.states import EvalResult, ExecutionState
from repro.core.workload import Workload
from repro.platforms import PlatformLike, resolve_platform


@dataclasses.dataclass
class Generation:
    """One synthesis result: a candidate and/or source, or a failure."""
    candidate: Optional[cand_mod.Candidate] = None
    source: Optional[str] = None
    callable_fn: Optional[Callable] = None
    failure: Optional[str] = None


class GenerationAgent(Protocol):
    def generate(self, wl: Workload, *, prev: Optional[Generation],
                 prev_result: Optional[EvalResult],
                 recommendation: Optional[Recommendation],
                 use_reference: bool) -> Generation:
        ...


# ---------------------------------------------------------------------------
# Offline deterministic backend
# ---------------------------------------------------------------------------


class TemplateSearchBackend:
    """Deterministic agent over the platform-legal candidate space.

    ``platform`` selects the hardware target the search optimizes for
    (tile legality, alignment bias, performance model). ``reference_hints``
    — workload name -> {param: value} — injects per-workload transferred
    strategy hints (harvested from another platform's best verified
    candidate, campaign/transfer.py) on top of the global REFERENCE_HINTS
    whenever ``use_reference`` is set.
    """

    def __init__(self, platform: PlatformLike = None,
                 reference_hints: Optional[Dict[str, Dict]] = None):
        self.platform = resolve_platform(platform)
        self.reference_hints = dict(reference_hints or {})

    def generate(self, wl: Workload, *, prev: Optional[Generation] = None,
                 prev_result: Optional[EvalResult] = None,
                 recommendation: Optional[Recommendation] = None,
                 use_reference: bool = False) -> Generation:
        if wl.op not in cand_mod.SPACES:
            return Generation(failure=f"no template family for op {wl.op!r}")
        if prev is None or prev.candidate is None:
            cand = cand_mod.initial_candidate(
                wl.op, use_reference=use_reference, platform=self.platform,
                hints=self.reference_hints.get(wl.name))
            cand = self._repair_shapes(cand, wl, "") or cand
            return Generation(candidate=cand, source=cand.describe())

        cand = prev.candidate
        state = prev_result.state if prev_result else None

        # ---- functional pass: repair the reported failure -----------------
        if state in (ExecutionState.COMPILATION_FAILURE,
                     ExecutionState.RUNTIME_ERROR):
            fixed = self._repair_shapes(cand, wl, prev_result.error or "")
            if fixed is not None:
                return Generation(candidate=fixed, source=fixed.describe())
            return Generation(failure=f"cannot repair: {prev_result.error}")
        if state is ExecutionState.NUMERIC_MISMATCH:
            err = (prev_result.error or "")
            if ("non-finite" in err or "inf" in err or "nan" in err.lower()) \
                    and "online" in cand_mod.SPACES[wl.op]:
                p = dict(cand.params)
                p["online"] = True  # numerically-stable strategy
                fixed = cand_mod.Candidate(wl.op, p)
                fixed = self._repair_shapes(fixed, wl, "") or fixed
                return Generation(candidate=fixed, source=fixed.describe())
            return Generation(failure=f"cannot repair numerics: {err}")
        if state is ExecutionState.GRAD_MISMATCH:
            # gradient-specific functional repair: the canonical cause is a
            # numerically-unstable strategy whose forward squeaks under the
            # tolerance while its backward blows up (naive softmax paths) —
            # switch to the stable strategy axis when one exists.
            p = dict(cand.params)
            if "online" in cand_mod.SPACES[wl.op] and not p.get("online"):
                p["online"] = True
                fixed = cand_mod.Candidate(wl.op, p)
                fixed = self._repair_shapes(fixed, wl, "") or fixed
                return Generation(candidate=fixed, source=fixed.describe())
            return Generation(
                failure=f"cannot repair gradients: {prev_result.error}")

        # ---- optimization pass ---------------------------------------------
        if recommendation is not None and recommendation.param:
            nxt = recommendation.apply(cand)
            nxt = self._repair_shapes(nxt, wl, "") or nxt
            if self._legal(nxt, wl) and nxt.params != cand.params:
                return Generation(candidate=nxt, source=nxt.describe())
        # fall back: best predicted single mutation on this platform
        shapes = {k: tuple(v) for k, v in wl.input_shapes.items()}
        best, best_t = None, cand_mod.model_time(cand, shapes, self.platform) \
            if self._legal(cand, wl) else float("inf")
        for _, mut in cand_mod.mutations(cand, self.platform).items():
            if not self._legal(mut, wl):
                continue
            t = cand_mod.model_time(mut, shapes, self.platform)
            if t < best_t:
                best, best_t = mut, t
        if best is not None:
            return Generation(candidate=best, source=best.describe())
        return Generation(candidate=cand, source=cand.describe())

    # -- legality helpers -----------------------------------------------------

    def _dims_for(self, wl: Workload):
        first = next(iter(wl.input_shapes.values()))
        return first

    def _legal(self, cand: cand_mod.Candidate, wl: Workload) -> bool:
        return self._repair_shapes(cand, wl, "", check_only=True) is not None

    def _repair_shapes(self, cand, wl, error: str, check_only=False):
        """Snap block params to divisors of the workload dims."""
        dims = dict(wl.input_shapes)
        key0 = next(iter(dims.values()))
        gate = dims.get("gate", key0)
        pairs = {
            "block_rows": gate[0] if cand.op == "swiglu" else key0[0],
            "block_lanes": key0[-1],
            "block_cols": gate[-1], "block_t": key0[0],
            "block_m": dims.get("a", key0)[0],
            "block_k": (dims.get("a", key0)[-1] if cand.op == "matmul"
                        else dims.get("k", key0)[1] if "k" in dims else
                        key0[-1]),
            "block_n": dims.get("b", key0)[-1],
            "block_q": dims.get("q", key0)[1] if "q" in dims else key0[0],
            "block_v": dims.get("logits", key0)[-1],
            "chunk": key0[1] if len(key0) > 1 else key0[0],
            "block_s": key0[1] if len(key0) > 1 else key0[0],
        }
        params = dict(cand.params)
        changed = False
        for k, v in cand.params.items():
            if not (k.startswith("block_") or k == "chunk"):
                continue
            dim = pairs.get(k)
            if dim is None or dim % v == 0:
                continue
            if check_only:
                return None
            space = cand_mod.space_for(cand.op, self.platform)
            choices = [c for c in space[k] if dim % c == 0]
            if not choices:
                return None
            params[k] = max(choices)
            changed = True
        if check_only:
            return cand
        if not changed:
            return None
        return cand_mod.Candidate(cand.op, params)


# ---------------------------------------------------------------------------
# LLM backend (production path; exercised offline via canned completions)
# ---------------------------------------------------------------------------

# One *complete* fenced code block (closing fence required). The single
# source of truth for what counts as a usable completion: generate()
# extracts code through it, and repro.llm.LLMSession decides malformed-
# completion re-prompting against the SAME pattern, so the two layers can
# never disagree about which replies are parseable.
CODE_BLOCK_RE = re.compile(r"```(?:python)?\n(.*?)```", re.S)
_CODE_RE = CODE_BLOCK_RE


class LLMBackend:
    """Prompt-building production backend.

    The platform supplies every target-specific degree of freedom of the
    generation prompt (see :mod:`repro.core.prompts` for the template
    contract): the ``descriptor`` naming the accelerator, the
    ``oneshot_example`` kernel in the target's own idiom (Pallas for the
    TPUs, CUDA for ``gpu_sim``, MSL for ``metal_m2``), and the
    ``constraints_note`` stating the working-set budget and alignment rules
    — retargeting the LLM to a new accelerator is a registry entry, not a
    prompt fork. ``reference_sources`` (workload name -> (platform name,
    source text)) overrides the default XLA-oracle reference with e.g. a
    best-verified kernel harvested from another platform's campaign
    (``campaign.transfer.reference_sources`` renders them; warm matrix legs
    inject them per leg).

    ``complete`` is the completion channel — any ``prompt -> str``
    callable; production campaigns pass an :class:`repro.llm.LLMSession`
    (transport + rate limiting + retry + accounting). It is required at
    construction: a backend without one would fail every generation deep
    inside the refinement loop, one opaque ``GENERATION_FAILURE`` per
    workload, so the misconfiguration is rejected up front instead. For
    prompt inspection without a completion channel (docs, tests, the
    synthesize_kernel example) pass ``prompt_only=True``; such a backend
    renders prompts but refuses to ``generate``.
    """

    def __init__(self, complete: Optional[Callable[[str], str]] = None,
                 accelerator: Optional[str] = None,
                 platform: PlatformLike = None,
                 reference_sources: Optional[Dict[str, Tuple[str, str]]]
                 = None,
                 prompt_only: bool = False):
        if complete is None and not prompt_only:
            raise ValueError(
                "LLMBackend needs a completion channel: pass "
                "complete=<prompt -> str> (e.g. an repro.llm.LLMSession "
                "over a MockTransport / ReplayTransport / HTTPTransport), "
                "or prompt_only=True to only build prompts")
        self.complete = complete
        self.prompt_only = prompt_only
        self.platform = resolve_platform(platform)
        self.accelerator = accelerator or self.platform.descriptor
        self.reference_sources = dict(reference_sources or {})

    def build_prompt(self, wl: Workload, *, prev: Optional[Generation],
                     prev_result: Optional[EvalResult],
                     recommendation: Optional[Recommendation],
                     use_reference: bool) -> str:
        """Render the §3.2 synthesis prompt for one workload/iteration.

        Reference resolution when ``use_reference`` is set: a harvested
        per-workload entry from ``reference_sources`` wins (its recorded
        source platform is named in the prompt); otherwise the XLA-oracle
        source of the op family (``core.transfer.reference_source``)."""
        ref_src, ref_platform = "", "XLA (jax.numpy)"
        if use_reference:
            if wl.name in self.reference_sources:
                ref_platform, ref_src = self.reference_sources[wl.name]
            else:
                ref_src = transfer.reference_source(wl) or ""
        return prompts.render_synthesis(
            self.accelerator, self.platform.oneshot_example,
            transfer.workload_source(wl), wl.name,
            ref_src=ref_src, ref_platform=ref_platform,
            prev_src=(prev.source or "") if prev else "",
            prev_result=prev_result.feedback() if prev_result else "",
            recommendation=recommendation.text if recommendation else "",
            constraints=self.platform.constraints_note)

    def generate(self, wl: Workload, *, prev=None, prev_result=None,
                 recommendation=None, use_reference=False) -> Generation:
        """One prompt → completion → candidate round trip.

        The completion's fenced code block is exec'd in a fresh namespace;
        the recovered ``candidate(*inputs)`` callable is verified directly
        (it bypasses the declarative verification cache). When the block
        also defines a ``PARAMS`` dict, it is adopted as the candidate's
        declarative tiling params — the performance model then scores the
        LLM's stated tiling instead of the naive fallback."""
        if self.complete is None:
            raise RuntimeError(
                "this LLMBackend was built prompt_only=True; it renders "
                "prompts but cannot generate — construct it with a "
                "complete= callable to run synthesis")
        prompt = self.build_prompt(wl, prev=prev, prev_result=prev_result,
                                   recommendation=recommendation,
                                   use_reference=use_reference)
        try:
            reply = self.complete(prompt)
        except Exception as exc:  # noqa: BLE001 — network errors etc.
            return Generation(failure=f"model call failed: {exc}")
        m = _CODE_RE.search(reply or "")
        if not m:
            return Generation(failure="reply contains no code block")
        src = m.group(1)
        ns: dict = {}
        try:
            exec(compile(src, f"<kforge:{wl.name}>", "exec"), ns)  # noqa: S102
        except Exception as exc:  # noqa: BLE001
            return Generation(source=src, failure=f"exec failed: {exc}")
        fn = ns.get("candidate")
        if fn is None:
            return Generation(source=src,
                              failure="no `candidate` function defined")
        params = ns.get("PARAMS")
        cand = cand_mod.Candidate(wl.op, dict(params)) \
            if isinstance(params, dict) else None
        return Generation(candidate=cand, source=src, callable_fn=fn)
