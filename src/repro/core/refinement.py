"""The KForge iterative loop (paper Figure 1).

Two phases per workload:
  1. functional pass — regenerate until the candidate compiles, runs, and
     matches the oracle (bounded by ``num_iterations``);
  2. optimization pass — feed agent G's single recommendation back into
     agent F; keep the best verified candidate.

Detailed per-iteration logs are retained (paper §3.3 'we save detailed logs
for each workload').
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.core import candidates as cand_mod
from repro.core.analysis import Recommendation, RuleBasedAnalyzer
from repro.core.states import EvalResult, ExecutionState
from repro.core.synthesis import Generation, TemplateSearchBackend
from repro.core.verification import io_signature, verify, verify_batch
from repro.core.workload import Workload
from repro.platforms import resolve_platform


@dataclasses.dataclass
class IterationLog:
    iteration: int
    phase: str                       # functional | optimization
    candidate_desc: Optional[str]
    result: EvalResult
    recommendation: Optional[str] = None
    candidate: Optional[cand_mod.Candidate] = None
    seed: Optional[int] = None       # verification input seed (None: reused)
    # which analyzer produced `recommendation` ("rule" | "llm"; None when
    # no recommendation was made this iteration) — journaled per event so
    # logs show which agent drove each optimization pass
    recommendation_source: Optional[str] = None


@dataclasses.dataclass
class RefinementOutcome:
    workload: str
    best: Optional[EvalResult]
    best_candidate: Optional[cand_mod.Candidate]
    logs: List[IterationLog]

    @property
    def final(self) -> EvalResult:
        if self.best is not None:
            return self.best
        if not self.logs:
            # num_iterations=0 (or every iteration short-circuited before
            # logging): report a generation failure, don't IndexError.
            return EvalResult(ExecutionState.GENERATION_FAILURE,
                              error="no refinement iterations ran")
        return self.logs[-1].result


@dataclasses.dataclass
class LoopConfig:
    """Configuration of one refinement loop (and the campaign event-log
    discriminator: resume only skips workloads whose terminal event was
    written under an identical config)."""
    num_iterations: int = 5          # paper: num_iterations=5
    use_reference: bool = False      # reference-transfer configuration (§6.2)
    use_profiling: bool = False      # profiling-information configuration (§5.2)
    single_shot: bool = False        # one generation, no refinement
    seed: int = 0
    platform: str = "tpu_v5e"        # hardware target (repro.platforms)
    # Source platform of the references a warm transfer leg injects (None
    # outside transfer sweeps). The loop itself never reads it, but it keeps
    # warm legs fed from different sources distinguishable in a shared event
    # log — without it, resume would let (A -> B) warm results masquerade as
    # (C -> B) warm results, since both run on B with use_reference=True.
    transfer_from: Optional[str] = None
    # Mutation fan-out width for optimization iterations: each iteration
    # verifies the agent's proposal PLUS the top (fanout - 1) predicted
    # single-parameter mutations as one verify_batch sharing the
    # iteration's inputs and reference oracle. 1 = classic single-candidate
    # loop. Only declarative (template) candidates fan out; LLM callables
    # verify singly regardless.
    fanout: int = 1
    # Candidate-search mode. "lineage" (default) is the single-lineage
    # refinement loop above; "pbt" maintains a population of `population`
    # candidate lineages per workload and runs `generations` rounds of
    # truncation selection + exploit/explore over them
    # (repro.campaign.population). num_iterations is ignored under "pbt";
    # population/generations are ignored under "lineage".
    search: str = "lineage"
    population: int = 4
    generations: int = 4
    # Verification direction: "fwd" checks the forward output only (the
    # pre-existing behavior, byte-identical cache keys); "fwd_bwd" — legal
    # only for differentiable workloads — additionally verifies input
    # gradients against the jax.vjp oracle and scores both passes'
    # rooflines (core/verification.py).
    direction: str = "fwd"


def _fanout_candidates(cand, wl, platform, agent, k: int,
                       seen: dict) -> List[cand_mod.Candidate]:
    """The top-``k`` single-parameter mutations of ``cand`` by modeled
    time — the refinement loop's verify_batch companions. Skips candidates
    already evaluated this loop and (when the agent exposes a legality
    probe, e.g. ``TemplateSearchBackend._legal``) workload-illegal tilings,
    so the batch spends its budget on plausible programs. Ranking uses the
    kernel-level shapes from :func:`io_signature`, the same shapes the
    verifier scores against."""
    if k <= 0:
        return []
    shapes = {name: tuple(dims) for name, dims, _ in io_signature(wl)}
    legal = getattr(agent, "_legal", None)
    scored = []
    for m in cand_mod.mutations(cand, platform).values():
        mk = (m.op, tuple(sorted(m.params.items())))
        if mk in seen:
            continue
        if legal is not None and not legal(m, wl):
            continue
        try:
            t = cand_mod.model_time(m, shapes, platform)
        except Exception:  # noqa: BLE001 — op/shape combos the model lacks
            continue
        if t != t or t == float("inf"):
            continue
        scored.append((t, m.describe(), m))
    scored.sort(key=lambda s: (s[0], s[1]))
    return [m for _, _, m in scored[:k]]


def run_workload(wl: Workload, cfg: LoopConfig, *,
                 agent=None, analyzer=None, cache=None,
                 on_iteration=None, io_cache=None,
                 exe_cache=None) -> RefinementOutcome:
    """Run the refinement loop for one workload.

    ``cache`` (optional) is a verification cache (see
    :func:`repro.core.verification.verify`): repeated candidate+seed pairs —
    across configs or across whole campaign runs — skip re-verification.

    ``io_cache`` / ``exe_cache`` (optional) are the fast-path cache layers
    (:class:`repro.core.evalio.WorkloadIOCache` /
    :class:`repro.core.evalio.ExecutableCache`): shared workload inputs +
    reference oracle per seed, and compiled-executable reuse across seeds.
    Pass ONE of each per campaign (or per matrix) so concurrent workloads
    and legs share them.

    ``on_iteration`` (optional) is called with each :class:`IterationLog`
    as soon as it exists — the campaign runner journals iterations through
    it, so a run killed mid-workload still persists the verifications it
    already paid for.

    ``cfg.platform`` selects the hardware target end-to-end: the default
    agent searches that platform's legal space, the default analyzer derives
    its thresholds from its profile, and every verification is scored (and
    cache-addressed) against it. Explicitly passed agents/analyzers are
    used as-is — construct them with the same platform.

    ``cfg.search`` selects the search mode: ``"lineage"`` runs the loop
    below; ``"pbt"`` dispatches to
    :func:`repro.campaign.population.run_workload_pbt` (population-based
    search journals per *generation*, so ``on_iteration`` does not apply
    there — campaign journaling goes through its ``on_generation`` hook).
    """
    if cfg.search == "pbt":
        # lazy import: repro.core must stay importable without the campaign
        # layer (population lives there because it builds on verify_batch
        # scheduling + event journaling)
        from repro.campaign.population import run_workload_pbt
        return run_workload_pbt(wl, cfg, agent=agent, analyzer=analyzer,
                                cache=cache, io_cache=io_cache,
                                exe_cache=exe_cache)
    if cfg.search != "lineage":
        raise ValueError(f"unknown search mode {cfg.search!r}; "
                         "expected 'lineage' or 'pbt'")
    platform = resolve_platform(cfg.platform)
    agent = agent or TemplateSearchBackend(platform=platform)
    analyzer = analyzer or RuleBasedAnalyzer(platform=platform)
    logs: List[IterationLog] = []

    def record(entry: IterationLog) -> None:
        logs.append(entry)
        if on_iteration is not None:
            on_iteration(entry)
    best: Optional[EvalResult] = None
    best_cand: Optional[cand_mod.Candidate] = None

    prev: Optional[Generation] = None
    prev_result: Optional[EvalResult] = None
    rec: Optional[Recommendation] = None

    iters = 1 if cfg.single_shot else cfg.num_iterations
    seen: dict = {}
    for i in range(iters):
        phase = "functional" if (prev_result is None or
                                 not prev_result.correct) else "optimization"
        gen = agent.generate(wl, prev=prev, prev_result=prev_result,
                             recommendation=rec,
                             use_reference=cfg.use_reference)
        if gen.failure or (gen.candidate is None and gen.callable_fn is None):
            result = EvalResult(ExecutionState.GENERATION_FAILURE,
                                error=gen.failure or "no candidate")
            record(IterationLog(i, phase, None, result))
            prev, prev_result = gen, result
            continue
        key = (gen.candidate.op, tuple(sorted(gen.candidate.params.items()))) \
            if gen.candidate and gen.callable_fn is None else None
        if key is not None and key in seen:
            # converged: the agent proposes an already-evaluated candidate
            record(IterationLog(i, phase, gen.candidate.describe(),
                                seen[key], "converged",
                                candidate=gen.candidate))
            break
        fan: List[cand_mod.Candidate] = []
        if cfg.fanout > 1 and phase == "optimization" and key is not None:
            fan = _fanout_candidates(gen.candidate, wl, platform, agent,
                                     cfg.fanout - 1, seen)
        if fan:
            # batched iteration: the proposal plus its best predicted
            # mutations share one input set and one oracle evaluation;
            # every member lands in `seen`, and the iteration carries the
            # batch's best CORRECT result (the agent's own proposal when
            # nothing verified correct) so the next iteration refines from
            # the strongest member.
            batch = [gen.candidate] + fan
            batch_results = verify_batch(batch, wl, seed=cfg.seed + i,
                                         cache=cache, platform=platform,
                                         io_cache=io_cache,
                                         exe_cache=exe_cache,
                                         direction=cfg.direction)
            for c, r in zip(batch, batch_results):
                seen[(c.op, tuple(sorted(c.params.items())))] = r
            best_j = min((j for j, r in enumerate(batch_results)
                          if r.correct),
                         key=lambda j: batch_results[j].model_time_s or 1e9,
                         default=0)
            result = batch_results[best_j]
            gen = dataclasses.replace(gen, candidate=batch[best_j],
                                      source=batch[best_j].describe())
        else:
            result = verify(gen.candidate or cand_mod.Candidate(wl.op, {}),
                            wl, seed=cfg.seed + i, fn=gen.callable_fn,
                            cache=cache, platform=platform,
                            io_cache=io_cache, exe_cache=exe_cache,
                            direction=cfg.direction)
            if key is not None:
                seen[key] = result
        rec_text = rec_source = None
        if result.correct and cfg.use_profiling and not cfg.single_shot:
            rec = analyzer.analyze(result.profile)
            rec_text = rec.text
            rec_source = getattr(rec, "source", None)
        else:
            # no profiled CORRECT result this iteration -> no live
            # recommendation. Clearing on *incorrect* results matters: a
            # candidate that regresses after a correct iteration must not
            # leak that iteration's optimization advice into the next
            # functional-phase prompt alongside the failure feedback.
            rec = None
        record(IterationLog(i, phase,
                            gen.candidate.describe() if gen.candidate
                            else "llm-candidate", result, rec_text,
                            candidate=gen.candidate, seed=cfg.seed + i,
                            recommendation_source=rec_source))
        if result.correct and (best is None or
                               (result.model_time_s or 1e9) <
                               (best.model_time_s or 1e9)):
            best, best_cand = result, gen.candidate
        prev, prev_result = gen, result

    return RefinementOutcome(workload=wl.name, best=best,
                             best_candidate=best_cand, logs=logs)


def run_suite(workloads, cfg: LoopConfig, **kw) -> List[RefinementOutcome]:
    """Serial in-process sweep. Prefer :mod:`repro.campaign` for anything
    bigger than a handful of workloads: it fans out over a worker pool,
    memoizes verifications, and is resumable from its JSONL event log."""
    return [run_workload(wl, cfg, **kw) for wl in workloads]
