"""LLM-backend campaign subsystem (DESIGN.md §9, ``docs/llm_backends.md``).

The transport layer behind :class:`repro.core.synthesis.LLMBackend`:
pluggable :class:`Transport` implementations (deterministic
:class:`MockTransport`, JSONL record/replay :class:`ReplayTransport`,
env-configured :class:`HTTPTransport`), a shared request/token
:class:`RateLimiter`, and the per-worker :class:`LLMSession` /
:class:`LLMContext` layer that retries, re-prompts malformed completions,
yields scheduler slots while throttled, and meters usage into the campaign
event log — plus :class:`LLMAnalyzer`, the LLM-backed performance-analysis
agent G (paper §3.2) that rides the same session stack for its analysis
calls.

Import direction: ``repro.llm`` imports ``repro.core`` (never the other way
round), and ``repro.campaign`` imports ``repro.llm`` — the campaign layer
is the only caller that wires sessions into worker pools.
"""
from repro.llm.analyzer import (  # noqa: F401
    ANALYSIS_REPROMPT, LLMAnalyzer, analysis_reply_reason,
    parse_recommendation,
)
from repro.llm.limiter import RateLimiter  # noqa: F401
from repro.llm.session import (  # noqa: F401
    LLMContext, LLMSession, UsageMeter, build_llm_context, format_usage,
    reprompt,
)
from repro.llm.transport import (  # noqa: F401
    Completion, HTTPTransport, MockTransport, RateLimitError, ReplayMissError,
    ReplayTransport, Transport, TransportError, default_mock_analysis_reply,
    default_mock_reply, estimate_tokens, prompt_key,
)
