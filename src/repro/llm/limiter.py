"""Shared request/token rate limiter for LLM-backed campaigns.

One :class:`RateLimiter` is shared by every session of a campaign (and by
every leg of a transfer matrix), so the *fleet's* aggregate call rate obeys
the endpoint budget no matter how many workers are in flight.

Two continuous token buckets — requests per minute (``rpm``) and tokens per
minute (``tpm``) — refilled from a monotonic clock. ``reserve`` debits a
request (plus its estimated tokens) immediately and returns how long the
caller must *pace* before issuing it; the bucket may go negative (work
borrowed against future refill), which is what converts a burst of N
concurrent workers into an evenly spaced call train instead of N-1
rejections. The limiter never sleeps and never blocks: sleeping —
and yielding the scheduler slot while doing so — is the session's job
(:class:`repro.llm.session.LLMSession`), so a throttled worker's slot goes
to verification work instead of idling.

Deterministic under an injected ``clock``; thread-safe.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, Optional


class RateLimiter:
    """Token-bucket pacing over requests/minute and tokens/minute.

    Args:
        rpm: request budget per minute (None = unlimited).
        tpm: token budget per minute, prompt + completion estimate
            (None = unlimited).
        clock: monotonic time source (injectable for tests).

    Buckets start full (one minute of burst) and refill continuously at
    ``budget / 60`` per second, capped at the per-minute budget.
    """

    def __init__(self, rpm: Optional[float] = None,
                 tpm: Optional[float] = None, *,
                 clock: Callable[[], float] = time.monotonic) -> None:
        if rpm is not None and rpm <= 0:
            raise ValueError(f"rpm must be positive, got {rpm}")
        if tpm is not None and tpm <= 0:
            raise ValueError(f"tpm must be positive, got {tpm}")
        self.rpm = rpm
        self.tpm = tpm
        self._clock = clock
        self._lock = threading.Lock()
        self._req_level = float(rpm) if rpm else 0.0
        self._tok_level = float(tpm) if tpm else 0.0
        self._last = clock()
        self.reserved_requests = 0
        self.reserved_tokens = 0

    def _refill(self, now: float) -> None:
        dt = max(0.0, now - self._last)
        self._last = now
        if self.rpm:
            self._req_level = min(float(self.rpm),
                                  self._req_level + dt * self.rpm / 60.0)
        if self.tpm:
            self._tok_level = min(float(self.tpm),
                                  self._tok_level + dt * self.tpm / 60.0)

    def reserve(self, tokens: int = 0) -> float:
        """Debit one request + ``tokens`` tokens; return the pacing delay.

        The caller should wait the returned number of seconds before
        issuing the call (0.0 = go now). The debit happens immediately, so
        N concurrent reserves serialize into an evenly spaced schedule —
        each sees the deficit left by the previous one.
        """
        with self._lock:
            self._refill(self._clock())
            self.reserved_requests += 1
            self.reserved_tokens += int(tokens)
            wait = 0.0
            if self.rpm:
                self._req_level -= 1.0
                if self._req_level < 0:
                    wait = max(wait, -self._req_level * 60.0 / self.rpm)
            if self.tpm:
                self._tok_level -= float(tokens)
                if self._tok_level < 0:
                    wait = max(wait, -self._tok_level * 60.0 / self.tpm)
            return wait

    def stats(self) -> Dict[str, Optional[float]]:
        """Snapshot: configured budgets plus total reserved work."""
        with self._lock:
            return {"rpm": self.rpm, "tpm": self.tpm,
                    "reserved_requests": self.reserved_requests,
                    "reserved_tokens": self.reserved_tokens}
