"""LLM-backed performance-analysis agent G (paper §3.2).

The paper's architecture is TWO collaborating agents: generation (F) and a
performance-analysis agent (G) that interprets profiling data and distills
it into ONE actionable recommendation per iteration. ``RuleBasedAnalyzer``
(``repro.core.analysis``) is the offline deterministic G; this module is
the production one: :class:`LLMAnalyzer` renders the candidate's
verification profile into ``ANALYSIS_TEMPLATE``
(``repro.core.prompts.render_analysis``), calls an
:class:`repro.llm.session.LLMSession` — so rate limiting, retry/backoff,
record/replay, and usage accounting apply to analysis calls exactly as to
generation calls — and parses the structured three-line reply into the
same :class:`repro.core.analysis.Recommendation` the refinement loop
already consumes.

Failure containment, in order:

* a reply missing its ``RECOMMENDATION:`` line is re-prompted by the
  session (:func:`analysis_reply_reason` is the session's ``reply_check``,
  :data:`ANALYSIS_REPROMPT` restates the contract), metered as a
  ``reprompts`` hit like any malformed generation;
* a reply still unparseable after the session's retries — or a dead
  transport — falls back to the rule table
  (:class:`repro.core.analysis.RuleBasedAnalyzer`), so a campaign never
  dies on a bad analysis turn: ``analyze`` never raises;
* a parsed ``PARAM``/``VALUE`` outside the platform-legal space is dropped
  to a text-only recommendation (the prose still reaches the next prompt;
  the structured action would have been rejected by the search backend
  anyway).

Recommendations parsed from an LLM reply carry ``source="llm"``; fallback
recommendations keep the rule table's ``source="rule"`` — the refinement
loop journals the source per iteration event, so the campaign log shows
which agent drove each optimization pass.
"""
from __future__ import annotations

import json
import re
from typing import Any, Callable, Dict, Optional

from repro.core.analysis import Recommendation, RuleBasedAnalyzer
from repro.core.candidates import SPACES, space_for
from repro.core.prompts import render_analysis
from repro.platforms import PlatformLike, resolve_platform

_REC_RE = re.compile(r"^\s*RECOMMENDATION:\s*(?P<text>.+?)\s*$", re.M)
_PARAM_RE = re.compile(r"^\s*PARAM:\s*(?P<param>\S+)\s*$", re.M)
_VALUE_RE = re.compile(r"^\s*VALUE:\s*(?P<value>.+?)\s*$", re.M)

_NONE_WORDS = ("none", "null", "-", "n/a")

# The analysis reply contract restated on a re-prompt (the analysis
# session's counterpart of session.CODE_REPROMPT).
ANALYSIS_REPROMPT = (
    "Reply again with exactly three lines:\n\n"
    "RECOMMENDATION: <one sentence naming the parameter and target value>\n"
    "PARAM: <parameter name, or none>\n"
    "VALUE: <target value as a JSON literal, or none>")


def analysis_reply_reason(text: str) -> Optional[str]:
    """Why an analysis reply is unusable, or None when it parses — the
    ``LLMSession.reply_check`` for analysis sessions, mirroring how
    generation sessions judge completions by their code block."""
    if _REC_RE.search(text or ""):
        return None
    return "it contained no `RECOMMENDATION:` line"


def parse_recommendation(text: str, *, op: Optional[str] = None,
                         platform: PlatformLike = None
                         ) -> Optional[Recommendation]:
    """Parse one three-line analysis reply into a
    :class:`Recommendation` (``source="llm"``), or None when the reply has
    no ``RECOMMENDATION:`` line at all.

    ``PARAM``/``VALUE`` are validated against the platform-legal space for
    ``op``: an unknown parameter, or a value outside its choices, strips
    the structured action (param/value -> None) while keeping the prose —
    an illegal action would be silently ignored downstream
    (``Recommendation.apply`` guards space membership), so dropping it
    here keeps the journaled recommendation honest about what can apply.
    ``VALUE`` is decoded as a JSON literal (``128``, ``true``) with a
    raw-string fallback.
    """
    m = _REC_RE.search(text or "")
    if m is None:
        return None
    param: Optional[str] = None
    value: Any = None
    pm = _PARAM_RE.search(text)
    if pm is not None:
        raw = pm.group("param").strip("`")
        if raw.lower() not in _NONE_WORDS:
            param = raw
    vm = _VALUE_RE.search(text)
    if param is not None and vm is not None:
        raw = vm.group("value").strip().strip("`")
        if raw.lower() in _NONE_WORDS:
            param = None
        else:
            try:
                value = json.loads(raw)
            except json.JSONDecodeError:
                value = raw
    elif param is not None:             # PARAM without a VALUE line
        param = None
    if param is not None:
        space = space_for(op, platform) if op in SPACES else {}
        choices = space.get(param)
        if choices is None or value not in list(choices):
            param, value = None, None
    return Recommendation(text=m.group("text"), param=param, value=value,
                          source="llm")


class LLMAnalyzer:
    """Agent G over an LLM session: profile -> prompt -> completion ->
    :class:`Recommendation`.

    Plugs in wherever ``RuleBasedAnalyzer`` does (``run_workload``'s
    ``analyzer=``, ``Campaign(analyzer_factory=...)``); construct one per
    worker via :meth:`repro.llm.LLMContext.analyzer_factory` so sessions
    are never shared across threads.

    ``session`` is the completion channel — any ``prompt -> str`` callable;
    production campaigns pass an :class:`repro.llm.LLMSession` built with
    :func:`analysis_reply_reason` as its reply check, so malformed analysis
    replies are re-prompted inside the session with full accounting.
    ``fallback`` (default: the rule table on the same platform) answers
    when the session fails or the final reply never parses — ``analyze``
    never raises.
    """

    def __init__(self, session: Callable[[str], str],
                 platform: PlatformLike = None,
                 fallback: Optional[Any] = None) -> None:
        self.session = session
        self.platform = resolve_platform(platform)
        self.fallback = fallback if fallback is not None \
            else RuleBasedAnalyzer(platform=self.platform)
        self.accelerator = self.platform.descriptor

    def build_prompt(self, profile: Dict[str, Any]) -> str:
        """Render the §3.2 analysis prompt for one verification profile:
        the profile JSON plus the platform-legal space for its op."""
        op = profile.get("op")
        space = space_for(op, self.platform) if op in SPACES else {}
        return render_analysis(self.accelerator, profile, space)

    def analyze(self, profile: Dict[str, Any]) -> Recommendation:
        """One analysis round trip; falls back to the rule table on any
        transport failure or a reply that never parsed."""
        prompt = self.build_prompt(profile)
        try:
            reply = self.session(prompt)
        except Exception:  # noqa: BLE001 — exhausted retries, replay miss
            return self.fallback.analyze(profile)
        rec = parse_recommendation(reply, op=profile.get("op"),
                                   platform=self.platform)
        if rec is None:
            return self.fallback.analyze(profile)
        return rec
