"""Per-worker LLM sessions: retry, re-prompt, pacing, and accounting.

The campaign runner builds one agent per workload via ``agent_factory``
(stateful backends must never be shared across worker threads, see
:class:`repro.campaign.Campaign`). For LLM-backed campaigns that factory is
:meth:`LLMContext.agent_factory`: each call mints a fresh
:class:`LLMSession` around the campaign's **shared** transport, rate
limiter, and usage meter, wraps it in a
:class:`repro.core.synthesis.LLMBackend`, and binds the leg's platform and
harvested ``reference_sources``.

What a session adds on top of a bare transport:

* **pacing** — before every call it reserves its estimated tokens from the
  shared :class:`repro.llm.limiter.RateLimiter` and sleeps out the returned
  delay *with its scheduler slot yielded* (``Scheduler.yielding``), so a
  throttled LLM leg donates its slot to verification work instead of
  blocking a worker;
* **retry/backoff** — :class:`RateLimitError` from the transport is slept
  off (honoring ``retry_after_s``, else exponential backoff), again
  slot-yielded, up to ``max_attempts``;
* **malformed-completion re-prompting** — a reply with no complete fenced
  code block (missing or truncated fence) is fed back to the model with the
  defect named, the same compilation-feedback shape the refinement loop
  uses for failed candidates (paper §3.3);
* **accounting** — every request, token, throttle wait, rate-limit hit and
  re-prompt lands in the shared :class:`UsageMeter`, which the campaign
  journals into its event log (``campaign_done.llm_usage``) and surfaces in
  ``Campaign.report()``.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, Optional

from repro.core.synthesis import CODE_BLOCK_RE, LLMBackend
from repro.llm.limiter import RateLimiter
from repro.llm.transport import (Completion, HTTPTransport, MockTransport,
                                 RateLimitError, ReplayTransport, Transport,
                                 TransportError, estimate_tokens)

REPROMPT_TEMPLATE = """{prompt}

Your previous reply was not usable: {reason}.

Previous reply:
{reply}

{instruction}
"""

# The generation agent's reply contract, restated on a re-prompt. Analysis
# sessions substitute their own (repro.llm.analyzer.ANALYSIS_REPROMPT
# restates the three-line RECOMMENDATION/PARAM/VALUE contract).
CODE_REPROMPT = ("Reply again with exactly ONE complete fenced ```python "
                 "code block defining\n`candidate(*inputs)`.")


def reprompt(prompt: str, reply: str, reason: str,
             instruction: str = CODE_REPROMPT) -> str:
    """The malformed-completion feedback prompt: the original task plus the
    defect named, the bad reply quoted (paper §3.3's feedback shape,
    applied one level below candidate verification), and the reply
    contract restated (``instruction``)."""
    return REPROMPT_TEMPLATE.format(prompt=prompt, reason=reason, reply=reply,
                                    instruction=instruction)


class UsageMeter:
    """Thread-safe token/request accounting shared by a campaign's sessions.

    ``snapshot()`` is what the campaign journals into its event log and
    prints in reports; counters only ever grow.

    ``parent`` chains meters: every increment also lands on the parent.
    The matrix gives each concurrently running leg its OWN meter parented
    on the fleet meter, so per-leg journal deltas attribute only that
    leg's spend (a shared meter's wall-clock delta would absorb every
    overlapping leg's calls and the summed report would over-count) while
    the fleet meter still totals everything for telemetry."""

    _FIELDS = ("requests", "prompt_tokens", "completion_tokens",
               "rate_limit_hits", "reprompts", "throttle_waits", "failures")

    def __init__(self, parent: Optional["UsageMeter"] = None) -> None:
        self._lock = threading.Lock()
        self.parent = parent
        self.requests = 0
        self.prompt_tokens = 0
        self.completion_tokens = 0
        self.rate_limit_hits = 0        # transport raised RateLimitError
        self.reprompts = 0              # malformed completions re-asked
        self.throttle_waits = 0         # limiter imposed a pacing delay
        self.throttle_wait_s = 0.0
        self.failures = 0               # calls abandoned after max_attempts

    def add_completion(self, comp: Completion) -> None:
        with self._lock:
            self.requests += 1
            self.prompt_tokens += comp.prompt_tokens
            self.completion_tokens += comp.completion_tokens
        if self.parent is not None:
            self.parent.add_completion(comp)

    def note_rate_limited(self) -> None:
        with self._lock:
            self.rate_limit_hits += 1
        if self.parent is not None:
            self.parent.note_rate_limited()

    def note_reprompt(self) -> None:
        with self._lock:
            self.reprompts += 1
        if self.parent is not None:
            self.parent.note_reprompt()

    def note_throttle(self, wait_s: float) -> None:
        with self._lock:
            self.throttle_waits += 1
            self.throttle_wait_s += wait_s
        if self.parent is not None:
            self.parent.note_throttle(wait_s)

    def note_failure(self) -> None:
        with self._lock:
            self.failures += 1
        if self.parent is not None:
            self.parent.note_failure()

    def snapshot(self) -> Dict[str, Any]:
        """JSON-serializable counter snapshot (event-log / report shape)."""
        with self._lock:
            out = {name: getattr(self, name) for name in self._FIELDS}
            out["throttle_wait_s"] = round(self.throttle_wait_s, 6)
            out["total_tokens"] = self.prompt_tokens + self.completion_tokens
            return out


def format_usage(usage: Dict[str, Any]) -> str:
    """One-line rendering of a :meth:`UsageMeter.snapshot` dict — the single
    format the CLI and reports print."""
    return (f"{usage.get('requests', 0)} requests, "
            f"{usage.get('prompt_tokens', 0)}+"
            f"{usage.get('completion_tokens', 0)} tokens, "
            f"{usage.get('rate_limit_hits', 0)} rate-limit hits, "
            f"{usage.get('throttle_waits', 0)} throttled, "
            f"{usage.get('reprompts', 0)} re-prompts")


class LLMSession:
    """One worker's completion channel; plugs in as ``LLMBackend.complete``.

    Sessions are cheap per-worker shells around the shared transport,
    limiter, and meter; ``scheduler`` (optional) is the campaign's
    :class:`repro.campaign.Scheduler` — every sleep (pacing or backoff)
    happens inside ``scheduler.yielding()``, releasing the worker's slot to
    runnable jobs for the duration.

    ``reply_check`` / ``reprompt_instruction`` make the re-prompt contract
    pluggable: generation sessions keep the default (a complete fenced
    code block, judged by the same ``CODE_BLOCK_RE`` the backend extracts
    with), analysis sessions check for agent G's ``RECOMMENDATION:`` line
    instead — both ride the same retry, pacing, and ``reprompts``
    accounting. ``reply_check(text)`` returns why the reply is unusable,
    or None when it is fine.
    """

    def __init__(self, transport: Transport, *,
                 limiter: Optional[RateLimiter] = None,
                 scheduler: Optional[Any] = None,
                 usage: Optional[UsageMeter] = None,
                 max_attempts: int = 3,
                 backoff_s: float = 0.05,
                 completion_tokens_estimate: int = 512,
                 reply_check: Optional[Callable[[str], Optional[str]]] = None,
                 reprompt_instruction: str = CODE_REPROMPT,
                 sleep: Callable[[float], None] = time.sleep) -> None:
        if max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {max_attempts}")
        self.transport = transport
        self.limiter = limiter
        self.scheduler = scheduler
        self.usage = usage if usage is not None else UsageMeter()
        self.max_attempts = max_attempts
        self.backoff_s = backoff_s
        self.reply_check = reply_check or self._malformed_reason
        self.reprompt_instruction = reprompt_instruction
        # tpm reservations cover the reply too (the limiter's budget is
        # prompt + completion); the reply's size is unknown at reserve
        # time, so this flat estimate stands in — kernel code blocks run a
        # few hundred tokens
        self.completion_tokens_estimate = completion_tokens_estimate
        self._sleep = sleep

    # -- pacing ------------------------------------------------------------

    def _pause(self, seconds: float) -> None:
        """Sleep with the scheduler slot yielded (when running on one)."""
        if seconds <= 0:
            return
        if self.scheduler is not None:
            with self.scheduler.yielding():
                self._sleep(seconds)
        else:
            self._sleep(seconds)

    def _throttle(self, prompt: str) -> None:
        if self.limiter is None:
            return
        wait = self.limiter.reserve(estimate_tokens(prompt)
                                    + self.completion_tokens_estimate)
        if wait > 0:
            self.usage.note_throttle(wait)
            self._pause(wait)

    # -- completion --------------------------------------------------------

    @staticmethod
    def _malformed_reason(text: str) -> Optional[str]:
        """Why a completion is unusable, or None when it is fine — judged
        by the same ``CODE_BLOCK_RE`` the backend extracts code with (a
        complete fenced block: a truncated stream whose fence never closed
        re-prompts too)."""
        if CODE_BLOCK_RE.search(text):
            return None
        if "```" in text:
            return "the code block was truncated (fence never closed)"
        return "it contained no fenced code block"

    def complete(self, prompt: str) -> str:
        """Prompt → completion text, absorbing rate limits and malformed
        replies up to ``max_attempts`` total transport calls.

        Raises :class:`TransportError` when every attempt was rate-limited
        away; returns the last (still malformed) text when re-prompting
        never produced a code block — ``LLMBackend`` then reports the
        precise ``reply contains no code block`` generation failure.
        """
        current = prompt
        last_exc: Optional[TransportError] = None
        text: Optional[str] = None
        for attempt in range(1, self.max_attempts + 1):
            self._throttle(current)
            try:
                comp = self.transport.complete(current)
            except RateLimitError as exc:
                self.usage.note_rate_limited()
                last_exc = exc
                if attempt == self.max_attempts:
                    break
                self._pause(exc.retry_after_s
                            if exc.retry_after_s is not None
                            else self.backoff_s * 2 ** (attempt - 1))
                continue
            self.usage.add_completion(comp)
            text = comp.text
            reason = self.reply_check(text)
            if reason is None:
                return text
            if attempt == self.max_attempts:
                break
            self.usage.note_reprompt()
            current = reprompt(prompt, text, reason,
                               instruction=self.reprompt_instruction)
        self.usage.note_failure()
        if text is not None:
            return text                 # malformed; backend names the failure
        raise TransportError(
            f"gave up after {self.max_attempts} rate-limited attempts: "
            f"{last_exc}")

    __call__ = complete


@dataclasses.dataclass
class LLMContext:
    """Everything a campaign's workers share for one LLM fleet: transport,
    rate limiter, usage meter, and the session policy. The per-worker /
    per-leg pieces (session, backend, platform, references) are minted by
    the two factory methods."""

    transport: Transport
    limiter: Optional[RateLimiter] = None
    usage: UsageMeter = dataclasses.field(default_factory=UsageMeter)
    max_attempts: int = 3
    backoff_s: float = 0.05

    def session(self, scheduler: Optional[Any] = None,
                usage: Optional[UsageMeter] = None,
                reply_check: Optional[Callable[[str], Optional[str]]] = None,
                reprompt_instruction: Optional[str] = None,
                limiter: Optional[Any] = None) -> LLMSession:
        """A fresh session over the shared transport/limiter; accounting
        goes to ``usage`` (e.g. a per-leg meter parented on the fleet
        meter) or the context's own meter. ``reply_check`` /
        ``reprompt_instruction`` override the re-prompt contract (analysis
        sessions); the defaults are the generation code-block contract.
        ``limiter`` overrides the context's shared limiter — the service
        daemon passes a tenant-bound view of its fairness limiter here so
        each tenant's sessions pace against that tenant's own budget."""
        return LLMSession(self.transport,
                          limiter=(limiter if limiter is not None
                                   else self.limiter),
                          scheduler=scheduler,
                          usage=usage if usage is not None else self.usage,
                          max_attempts=self.max_attempts,
                          backoff_s=self.backoff_s,
                          reply_check=reply_check,
                          reprompt_instruction=(reprompt_instruction
                                                or CODE_REPROMPT))

    def leg_meter(self) -> UsageMeter:
        """A fresh meter parented on the fleet meter: concurrent campaigns
        (matrix legs) each journal their own spend while the context's
        ``usage`` keeps the fleet total."""
        return UsageMeter(parent=self.usage)

    def agent_factory(self, platform=None, *,
                      reference_sources: Optional[Dict] = None,
                      scheduler: Optional[Any] = None,
                      usage: Optional[UsageMeter] = None,
                      limiter: Optional[Any] = None
                      ) -> Callable[[], LLMBackend]:
        """A ``Campaign(agent_factory=...)``-shaped builder: every call
        returns a new ``LLMBackend`` with its own session, bound to
        ``platform`` and (for warm transfer legs) the harvested
        ``reference_sources`` by value — concurrency-safe the same way the
        matrix binds template-backend factories. ``usage`` redirects the
        sessions' accounting (per-leg meters); ``limiter`` overrides the
        shared limiter (per-tenant pacing in the service daemon)."""
        refs = dict(reference_sources or {})

        def build(platform=platform, refs=refs, usage=usage) -> LLMBackend:
            return LLMBackend(complete=self.session(scheduler, usage=usage,
                                                    limiter=limiter),
                              platform=platform, reference_sources=refs)
        return build

    def analyzer_factory(self, platform=None, *,
                         scheduler: Optional[Any] = None,
                         usage: Optional[UsageMeter] = None,
                         limiter: Optional[Any] = None
                         ) -> Callable[[], Any]:
        """A ``Campaign(analyzer_factory=...)``-shaped builder for agent G:
        every call returns a new :class:`repro.llm.analyzer.LLMAnalyzer`
        with its own session over the shared transport — so analysis calls
        get rate limiting, retry/backoff, record/replay, and usage
        accounting exactly like generation calls. The session's re-prompt
        contract is the analysis three-line reply, and ``usage`` (e.g. a
        per-leg meter) journals analysis tokens alongside generation
        tokens."""
        from repro.llm.analyzer import (ANALYSIS_REPROMPT, LLMAnalyzer,
                                        analysis_reply_reason)

        def build(platform=platform, usage=usage) -> Any:
            session = self.session(scheduler, usage=usage,
                                   reply_check=analysis_reply_reason,
                                   reprompt_instruction=ANALYSIS_REPROMPT,
                                   limiter=limiter)
            return LLMAnalyzer(session=session, platform=platform)
        return build


def build_llm_context(*, transport: Optional[Transport] = None,
                      record: Optional[str] = None,
                      replay: Optional[str] = None,
                      rpm: Optional[float] = None,
                      tpm: Optional[float] = None,
                      usage: Optional[UsageMeter] = None,
                      max_attempts: int = 3,
                      backoff_s: float = 0.05) -> LLMContext:
    """Assemble an :class:`LLMContext` the way the CLI does.

    Transport resolution order:

    * ``replay=PATH`` — :class:`ReplayTransport` in replay mode (zero live
      calls; the file must exist).
    * ``record=PATH`` — a recording wrapper around the live transport:
      ``transport`` if given, else :class:`HTTPTransport` when
      ``KFORGE_LLM_ENDPOINT`` is exported, else the deterministic
      :class:`MockTransport`.
    * neither — the live transport alone (same fallback chain).

    ``rpm``/``tpm`` attach a shared :class:`RateLimiter`.
    """
    if record and replay:
        raise ValueError("--record and --replay are mutually exclusive: a "
                         "replayed session never makes the live calls a "
                         "recording would capture")
    # explicit None checks: rpm/tpm of 0 must reach RateLimiter and fail
    # its positivity validation, not be silently dropped as falsy
    want_limiter = rpm is not None or tpm is not None
    if replay:
        if transport is not None:
            raise ValueError("pass either transport= or replay=, not both")
        transport = ReplayTransport.replay(replay)
    else:
        if transport is None:
            transport = (HTTPTransport.from_env()
                         if HTTPTransport.configured() else MockTransport())
        if record:
            transport = ReplayTransport.record(record, transport)
    limiter = RateLimiter(rpm=rpm, tpm=tpm) if want_limiter else None
    return LLMContext(transport=transport, limiter=limiter,
                      usage=usage if usage is not None else UsageMeter(),
                      max_attempts=max_attempts, backoff_s=backoff_s)
