"""LLM completion transports (the wire behind ``LLMBackend.complete``).

The generation agent of the paper is an LLM session; everything between the
rendered prompt (``core/prompts.py``) and the returned completion text is a
:class:`Transport`. Three implementations, one protocol:

* :class:`MockTransport` — deterministic and offline. It answers every
  synthesis prompt with a code block that mirrors the workload's reference
  oracle, so a MockTransport campaign genuinely exercises the full
  LLM data path (prompt → completion → ``exec`` → callable verification)
  in CI with zero network. Faults are injectable on a deterministic
  schedule: rate-limit errors every Nth call, malformed (fence-less) or
  truncated (unterminated-fence) completions, and artificial latency —
  exactly the failure modes the session layer must absorb.
* :class:`ReplayTransport` — records prompt → completion pairs to a JSONL
  session file and replays them byte-for-byte. Keys are sha256 content
  addresses of the full prompt (the same idea as the verification cache),
  so replay is order-independent across concurrent workers, and *record*
  mode is resume-safe: a key already on disk is served from the file
  instead of re-spending a live call.
* :class:`HTTPTransport` — the production stub: a minimal JSON-over-HTTP
  client configured entirely from environment variables
  (``KFORGE_LLM_ENDPOINT`` / ``KFORGE_LLM_API_KEY`` / ``KFORGE_LLM_MODEL``),
  mapping HTTP 429 onto :class:`RateLimitError` with the server's
  ``retry-after``. Nothing in the repo calls it unless the endpoint env
  var is set.

Transports return a :class:`Completion` (text + token counts) rather than a
bare string so the session layer can meter token budgets; token counts fall
back to :func:`estimate_tokens` when the backend does not report real ones.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import re
import threading
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional, Protocol, Union


class TransportError(RuntimeError):
    """Base class for transport failures (network, replay miss, ...).

    ``LLMBackend.generate`` turns these into ``GENERATION_FAILURE``
    results, so a dead transport degrades a campaign's results instead of
    crashing the worker pool."""


class RateLimitError(TransportError):
    """The backend refused the call for rate/budget reasons.

    ``retry_after_s`` (optional) is the backend's own back-off request; the
    session layer honors it, yielding its scheduler slot while it waits.
    """

    def __init__(self, message: str = "rate limited",
                 retry_after_s: Optional[float] = None) -> None:
        super().__init__(message)
        self.retry_after_s = retry_after_s


class ReplayMissError(TransportError):
    """A replay-mode session was asked for a prompt it never recorded."""


def estimate_tokens(text: str) -> int:
    """Cheap deterministic token estimate (~4 chars/token) used whenever a
    transport does not report real counts; the rate limiter and the usage
    meter only need a consistent currency, not exact BPE counts."""
    return max(1, len(text) // 4)


def prompt_key(prompt: str) -> str:
    """Content address of one prompt (sha256 hex) — the record/replay JSONL
    key, mirroring how the verification cache addresses verifications."""
    return hashlib.sha256(prompt.encode()).hexdigest()


@dataclasses.dataclass(frozen=True)
class Completion:
    """One transport round trip: the completion text plus token accounting
    (real counts when the backend reports them, estimates otherwise)."""
    text: str
    prompt_tokens: int
    completion_tokens: int


class Transport(Protocol):
    """Anything that turns a prompt into a :class:`Completion`.

    May raise :class:`RateLimitError` (retryable; the session backs off and
    yields its scheduler slot) or any other :class:`TransportError`
    (non-retryable; surfaces as a generation failure)."""

    def complete(self, prompt: str) -> Completion:
        ...


# ---------------------------------------------------------------------------
# MockTransport — deterministic, fault-injectable, offline
# ---------------------------------------------------------------------------

_WORKLOAD_NAME_RE = re.compile(r"workload named (\S+)")

# The ```json fence ANALYSIS_TEMPLATE embeds the verification profile in —
# what the analysis oracle recovers the profile from.
_PROFILE_JSON_RE = re.compile(r"```json\n(.*?)```", re.S)

# op → the candidate body the mock emits; mirrors the reference oracle on
# the *kernel-level* inputs (what verification hands the callable), so the
# default mock completion verifies CORRECT for every template op family.
_MOCK_BODIES: Dict[str, str] = {
    "attention": "return _ref.attention(*inputs)",
    "rmsnorm": "return _ref.rmsnorm(*inputs)",
    "softmax": "return _ref.softmax(*inputs)",
    "swiglu": "return _ref.swish(inputs[0]) * inputs[1]",
    "matmul": "return _ref.matmul(*inputs)",
    "swish": "return _ref.swish(*inputs)",
    "xent": "return _ref.softmax_xent(*inputs)",
    "ssd": "return _ref.ssd(*inputs)[0]",
}


def _op_for_workload_name(name: str) -> Optional[str]:
    """Op family of a prompt's workload name: the KernelBench registry is
    authoritative (L3 block names like ``L3/qwen_lm_head`` embed no op
    substring); ad-hoc test workloads fall back to an op-token scan of the
    name itself (``T1/swish-wide`` → swish)."""
    try:
        from repro.core import kernelbench
        return kernelbench.by_name(name).op
    except Exception:  # noqa: BLE001 — not a registered workload
        pass
    tail = name.split("/")[-1]
    for op in sorted(_MOCK_BODIES, key=len, reverse=True):
        if op in tail:
            return op
    return None


def default_mock_analysis_reply(prompt: str) -> str:
    """The MockTransport's deterministic agent-G oracle.

    Recovers the verification profile from the analysis prompt's ``json``
    fence (``ANALYSIS_TEMPLATE`` embeds it verbatim for exactly this
    purpose), answers from the rule table on the profile's own platform
    (:class:`repro.core.analysis.RuleBasedAnalyzer`), and formats the
    three-line ``RECOMMENDATION:``/``PARAM:``/``VALUE:`` reply contract —
    so an offline MockTransport campaign with ``--analysis llm`` exercises
    the genuine two-agent data path (render → transport → parse → apply)
    end to end. An unreadable profile degrades to a no-change
    recommendation rather than an exception: a broken oracle must surface
    as campaign results, not a crashed transport.
    """
    from repro.core.analysis import RuleBasedAnalyzer
    rec = None
    m = _PROFILE_JSON_RE.search(prompt)
    if m is not None:
        try:
            profile = json.loads(m.group(1))
            rec = RuleBasedAnalyzer(
                platform=profile.get("platform")).analyze(profile)
        except Exception:  # noqa: BLE001 — torn fence, foreign profile shape
            rec = None
    if rec is None:
        return ("RECOMMENDATION: the profile could not be read; keep the "
                "current tiling unchanged.\nPARAM: none\nVALUE: none")
    param = rec.param if rec.param is not None else "none"
    value = json.dumps(rec.value) if rec.param is not None else "none"
    return f"RECOMMENDATION: {rec.text}\nPARAM: {param}\nVALUE: {value}"


def default_mock_reply(prompt: str) -> str:
    """The MockTransport's canned reply for one prompt.

    Agent-G analysis prompts (recognized by
    :func:`repro.core.prompts.is_analysis_prompt`, whose marker survives
    re-prompts) route to the deterministic rule-table oracle
    (:func:`default_mock_analysis_reply`). Synthesis prompts recover the
    workload from the ``Optimize the workload named ...`` line and resolve
    it to its op family (:func:`_op_for_workload_name`); the reply's code
    block computes the reference oracle on the kernel inputs, so it
    verifies CORRECT for every template op family at every KernelBench
    level. Unknown ops get an echo candidate that fails verification as a
    numeric mismatch — deterministically exercising the feedback/repair
    path.
    """
    from repro.core.prompts import is_analysis_prompt
    if is_analysis_prompt(prompt):
        return default_mock_analysis_reply(prompt)
    m = _WORKLOAD_NAME_RE.search(prompt)
    name = m.group(1) if m else ""
    op = _op_for_workload_name(name) if name else None
    body = _MOCK_BODIES.get(op, "return inputs[0]")
    return (f"Targeting {name or 'the workload'}: the parallel decomposition "
            "mirrors the reference oracle; tiling is left to the compiler.\n\n"
            "```python\n"
            "from repro.kernels import ref as _ref\n\n\n"
            "def candidate(*inputs):\n"
            f"    {body}\n"
            "```\n")


class MockTransport:
    """Deterministic offline transport with fault injection.

    Every call increments a (thread-safe) counter ``calls``; faults fire on
    a fixed modulo schedule of that counter, so a single-threaded test sees
    a byte-identical transcript on every run:

    * ``rate_limit_every=N`` — every Nth call raises :class:`RateLimitError`
      (with ``retry_after_s``) *instead of* producing a completion.
    * ``malformed_every=N`` — every Nth completion breaks its reply
      contract: synthesis replies lose their code fences (no extractable
      block), analysis replies lose their ``RECOMMENDATION:`` label — each
      agent's session re-prompts on its own contract.
    * ``truncate_every=N`` — every Nth completion is cut mid-stream: a
      synthesis reply mid-block (opening fence present, closing fence
      missing), an analysis reply mid-label.
    * ``latency_s`` — sleep injected per successful call (via ``sleep``,
      injectable for tests).

    ``completion_fn`` overrides the default oracle-echo reply; faults still
    apply on top of it.
    """

    def __init__(self, *, completion_fn: Optional[Callable[[str], str]] = None,
                 rate_limit_every: int = 0,
                 retry_after_s: float = 0.05,
                 malformed_every: int = 0,
                 truncate_every: int = 0,
                 latency_s: float = 0.0,
                 sleep: Callable[[float], None] = time.sleep) -> None:
        self.completion_fn = completion_fn or default_mock_reply
        self.rate_limit_every = rate_limit_every
        self.retry_after_s = retry_after_s
        self.malformed_every = malformed_every
        self.truncate_every = truncate_every
        self.latency_s = latency_s
        self._sleep = sleep
        self._lock = threading.Lock()
        self.calls = 0                  # total complete() calls (faults incl.)

    def complete(self, prompt: str) -> Completion:
        with self._lock:
            self.calls += 1
            n = self.calls
        if self.rate_limit_every and n % self.rate_limit_every == 0:
            raise RateLimitError(
                f"mock rate limit (call {n})", retry_after_s=self.retry_after_s)
        if self.latency_s:
            self._sleep(self.latency_s)
        text = self.completion_fn(prompt)
        is_analysis = "RECOMMENDATION:" in text
        if self.malformed_every and n % self.malformed_every == 0:
            if is_analysis:
                # break the analysis contract, not the (absent) fences
                text = text.replace("RECOMMENDATION:", "VERDICT:")
            else:
                text = text.replace("```python\n", "").replace("```", "")
        elif self.truncate_every and n % self.truncate_every == 0:
            if is_analysis:
                text = text.partition("RECOMMENDATION:")[0] + "RECOMMENDA"
            else:
                head, sep, _ = text.partition("```python\n")
                text = head + sep + "def candidate(*inp"   # cut mid-stream
        return Completion(text, estimate_tokens(prompt),
                          estimate_tokens(text))


# ---------------------------------------------------------------------------
# ReplayTransport — record / replay JSONL sessions
# ---------------------------------------------------------------------------


class ReplayTransport:
    """Record prompt → completion pairs to JSONL, or replay them.

    One ``{"key", "prompt", "completion", "prompt_tokens",
    "completion_tokens"}`` object per line; ``key`` is
    :func:`prompt_key` of the full prompt. Identical prompts issued more
    than once stack per-key FIFO, so a recorded session replays in the
    exact per-prompt order it was captured, independent of worker
    interleaving across *different* prompts.

    * ``ReplayTransport.record(path, inner)`` — consult the file first
      (resume-safe: an interrupted ``--record`` run never re-spends live
      calls for keys already on disk), fall through to ``inner`` on a
      miss, and append the result.
    * ``ReplayTransport.replay(path)`` — no inner transport at all, so a
      replayed campaign makes **zero** live calls by construction. A prompt
      whose key was never recorded raises :class:`ReplayMissError`; a key
      asked for more times than it was recorded repeats its last completion
      (deterministic resume).
    """

    def __init__(self, path: Union[str, Path], *,
                 inner: Optional[Transport] = None,
                 mode: str = "replay") -> None:
        if mode not in ("record", "replay"):
            raise ValueError(f"mode must be 'record' or 'replay', got {mode!r}")
        if mode == "record" and inner is None:
            raise ValueError("record mode needs an inner transport to call "
                             "on cache misses")
        self.path = Path(path)
        self.inner = inner
        self.mode = mode
        self._lock = threading.Lock()
        self._queues: Dict[str, List[Completion]] = {}
        self._last: Dict[str, Completion] = {}
        self.served_from_file = 0       # completions answered without inner
        if mode == "replay" and not self.path.exists():
            raise TransportError(
                f"replay session {self.path} does not exist — record one "
                "first (CLI: --record PATH)")
        self._load()
        if mode == "record":
            self.path.parent.mkdir(parents=True, exist_ok=True)

    @classmethod
    def record(cls, path: Union[str, Path], inner: Transport
               ) -> "ReplayTransport":
        """Recording transport around ``inner`` (resume-safe, see class)."""
        return cls(path, inner=inner, mode="record")

    @classmethod
    def replay(cls, path: Union[str, Path]) -> "ReplayTransport":
        """Replay-only transport over an existing session file."""
        return cls(path, mode="replay")

    def _load(self) -> None:
        if not self.path.exists():
            return
        with self.path.open() as fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                    comp = Completion(rec["completion"],
                                      int(rec.get("prompt_tokens", 0)),
                                      int(rec.get("completion_tokens", 0)))
                    key = rec["key"]
                except (json.JSONDecodeError, KeyError, TypeError,
                        ValueError):
                    continue            # torn tail write from a killed run
                self._queues.setdefault(key, []).append(comp)
                self._last[key] = comp

    def __len__(self) -> int:
        """Distinct recorded prompts (loaded + appended this run)."""
        with self._lock:
            return len(self._last)

    def _pop(self, key: str) -> Optional[Completion]:
        with self._lock:
            queue = self._queues.get(key)
            if queue:
                self.served_from_file += 1
                return queue.pop(0)
            if self.mode == "replay":
                # exhausted key: repeat its last completion, so a resumed
                # replay that asks once more than the recording stays
                # deterministic. Record mode falls through to a live call
                # instead — a fresh completion is worth capturing.
                last = self._last.get(key)
                if last is not None:
                    self.served_from_file += 1
                    return last
            return None

    def _append(self, key: str, prompt: str, comp: Completion) -> None:
        line = json.dumps({
            "key": key, "prompt": prompt, "completion": comp.text,
            "prompt_tokens": comp.prompt_tokens,
            "completion_tokens": comp.completion_tokens,
        }, sort_keys=True)
        with self._lock:
            self._last[key] = comp
            with self.path.open("a") as fh:
                fh.write(line + "\n")

    def complete(self, prompt: str) -> Completion:
        key = prompt_key(prompt)
        hit = self._pop(key)
        if hit is not None:
            return hit
        if self.mode == "replay":
            raise ReplayMissError(
                f"prompt {key[:12]}… was never recorded in {self.path} "
                "(stale session? re-record with --record)")
        comp = self.inner.complete(prompt)      # may raise RateLimitError
        self._append(key, prompt, comp)
        return comp


# ---------------------------------------------------------------------------
# HTTPTransport — production endpoint stub, env-configured
# ---------------------------------------------------------------------------


class HTTPTransport:
    """Minimal JSON-over-HTTP completion client (stdlib ``urllib`` only).

    Env config (nothing constructs this unless the endpoint is set):

    * ``KFORGE_LLM_ENDPOINT`` — full URL of a completions endpoint.
    * ``KFORGE_LLM_API_KEY`` — optional bearer token.
    * ``KFORGE_LLM_MODEL`` — optional model name sent in the payload.

    The request body is ``{"model", "prompt", "max_tokens"}``; the reply may
    be ``{"text": ...}`` or an OpenAI-style ``{"choices": [{"text"|
    "message": {"content"}}], "usage": {...}}``. HTTP 429 maps onto
    :class:`RateLimitError` carrying the server's ``retry-after``; any
    other failure is a :class:`TransportError`.
    """

    ENV_ENDPOINT = "KFORGE_LLM_ENDPOINT"
    ENV_API_KEY = "KFORGE_LLM_API_KEY"
    ENV_MODEL = "KFORGE_LLM_MODEL"

    def __init__(self, endpoint: str, *, api_key: Optional[str] = None,
                 model: str = "", timeout_s: float = 120.0,
                 max_output_tokens: int = 2048) -> None:
        if not endpoint:
            raise TransportError("HTTPTransport needs a non-empty endpoint")
        self.endpoint = endpoint
        self.api_key = api_key
        self.model = model
        self.timeout_s = timeout_s
        self.max_output_tokens = max_output_tokens

    @classmethod
    def configured(cls) -> bool:
        """True when the endpoint env var is set (the CLI's live-backend
        auto-detection)."""
        return bool(os.environ.get(cls.ENV_ENDPOINT))

    @classmethod
    def from_env(cls) -> "HTTPTransport":
        endpoint = os.environ.get(cls.ENV_ENDPOINT, "")
        if not endpoint:
            raise TransportError(
                f"{cls.ENV_ENDPOINT} is not set; export it (plus optional "
                f"{cls.ENV_API_KEY}/{cls.ENV_MODEL}) to use a live endpoint, "
                "or use MockTransport / --replay for offline runs")
        return cls(endpoint, api_key=os.environ.get(cls.ENV_API_KEY),
                   model=os.environ.get(cls.ENV_MODEL, ""))

    @staticmethod
    def _parse_retry_after(value: Optional[str]) -> Optional[float]:
        """Seconds from a Retry-After header. RFC 7231 also allows an
        HTTP-date form; anything non-numeric degrades to None (the session
        then applies its own backoff) instead of raising — a retryable 429
        must never escape as an unretried failure."""
        if not value:
            return None
        try:
            return float(value)
        except ValueError:
            return None

    @staticmethod
    def _extract_text(payload: Dict) -> str:
        if isinstance(payload.get("text"), str):
            return payload["text"]
        choices = payload.get("choices") or []
        if choices:
            choice = choices[0]
            if isinstance(choice.get("text"), str):
                return choice["text"]
            message = choice.get("message") or {}
            if isinstance(message.get("content"), str):
                return message["content"]
        raise TransportError(
            f"unrecognized completion payload shape: {sorted(payload)}")

    def complete(self, prompt: str) -> Completion:
        import urllib.error
        import urllib.request

        body = json.dumps({"model": self.model, "prompt": prompt,
                           "max_tokens": self.max_output_tokens}).encode()
        headers = {"content-type": "application/json"}
        if self.api_key:
            headers["authorization"] = f"Bearer {self.api_key}"
        req = urllib.request.Request(self.endpoint, data=body,
                                     headers=headers, method="POST")
        try:
            with urllib.request.urlopen(req, timeout=self.timeout_s) as resp:
                payload = json.load(resp)
        except urllib.error.HTTPError as exc:
            if exc.code == 429:
                retry = self._parse_retry_after(
                    exc.headers.get("retry-after"))
                raise RateLimitError("endpoint rate limited (HTTP 429)",
                                     retry_after_s=retry) from exc
            raise TransportError(
                f"endpoint error HTTP {exc.code}: {exc.reason}") from exc
        except (OSError, json.JSONDecodeError) as exc:
            raise TransportError(f"endpoint unreachable: {exc}") from exc
        text = self._extract_text(payload)
        usage = payload.get("usage") or {}
        return Completion(
            text,
            int(usage.get("prompt_tokens") or estimate_tokens(prompt)),
            int(usage.get("completion_tokens") or estimate_tokens(text)))
