"""Unified model API: build_model(cfg) -> Model facade used by trainer,
serving engine, launcher, and the dry-run."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import dense, encdec, hybrid, moe, param_util, rwkv

_FAMILY = {
    "dense": dense,
    "vlm": dense,
    "moe": moe,
    "ssm": rwkv,
    "hybrid": hybrid,
    "encdec": encdec,
}


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    mod: Any
    tp_size: int = 1

    # -- parameters ---------------------------------------------------------
    def defs(self):
        return self.mod.make_defs(self.cfg, self.tp_size)

    def init(self, rng, dtype=jnp.float32):
        return param_util.init_params(self.defs(), rng, dtype)

    def abstract_params(self, dtype=jnp.bfloat16):
        return param_util.abstract_params(self.defs(), dtype)

    def logical_specs(self):
        return param_util.logical_specs(self.defs())

    def param_bytes(self, dtype=jnp.bfloat16):
        return param_util.param_bytes(self.defs(), dtype)

    # -- steps --------------------------------------------------------------
    def loss_fn(self, params, batch, *, impl="xla", remat=True):
        return self.mod.loss_fn(params, batch, self.cfg, impl=impl,
                                remat=remat)

    def prefill_fn(self, params, tokens, *, impl="xla", **kw):
        return self.mod.prefill_fn(params, tokens, self.cfg, impl=impl, **kw)

    def decode_fn(self, params, cache, tokens, lengths, *, impl="xla"):
        return self.mod.decode_fn(params, cache, tokens, lengths, self.cfg,
                                  impl=impl)

    def init_cache(self, batch, seq, dtype=jnp.bfloat16):
        return self.mod.init_cache(self.cfg, batch, seq, dtype)

    def abstract_cache(self, batch, seq, dtype=jnp.bfloat16):
        return self.mod.abstract_cache(self.cfg, batch, seq, dtype)

    # -- inputs -------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig,
                    dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input of a shape cell."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "train":
            specs = {
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
            if cfg.family == "vlm":
                specs["vision"] = jax.ShapeDtypeStruct(
                    (b, cfg.encoder.num_positions, cfg.d_model), dtype)
            if cfg.family == "encdec":
                specs["frames"] = jax.ShapeDtypeStruct(
                    (b, cfg.encoder.num_positions, cfg.encoder.d_model), dtype)
            return specs
        if shape.kind == "prefill":
            specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
            if cfg.family == "encdec":
                specs["frames"] = jax.ShapeDtypeStruct(
                    (b, cfg.encoder.num_positions, cfg.encoder.d_model), dtype)
            if cfg.family == "vlm":
                specs["vision"] = jax.ShapeDtypeStruct(
                    (b, cfg.encoder.num_positions, cfg.d_model), dtype)
            return specs
        # decode: one token vs a cache of seq_len
        return {
            "tokens": jax.ShapeDtypeStruct((b, 1), i32),
            "lengths": jax.ShapeDtypeStruct((b,), i32),
        }

    def input_logical_axes(self, shape: ShapeConfig) -> Dict[str, tuple]:
        cfg = self.cfg
        if shape.kind == "train":
            axes = {"tokens": ("batch", None), "labels": ("batch", None)}
            if cfg.family == "vlm":
                axes["vision"] = ("batch", None, None)
            if cfg.family == "encdec":
                axes["frames"] = ("batch", None, None)
            return axes
        if shape.kind == "prefill":
            axes = {"tokens": ("batch", None)}
            if cfg.family == "vlm":
                axes["vision"] = ("batch", None, None)
            if cfg.family == "encdec":
                axes["frames"] = ("batch", None, None)
            return axes
        return {"tokens": ("batch", None), "lengths": ("batch",)}

    def make_batch(self, rng, shape: ShapeConfig, dtype=jnp.float32):
        """Concrete random batch for smoke tests / examples."""
        cfg = self.cfg
        specs = self.input_specs(shape, dtype)
        keys = jax.random.split(rng, len(specs))
        out = {}
        for key, (name, sds) in zip(keys, sorted(specs.items())):
            if jnp.issubdtype(sds.dtype, jnp.integer):
                if name == "lengths":
                    out[name] = jnp.full(sds.shape, shape.seq_len // 2,
                                         jnp.int32)
                else:
                    out[name] = jax.random.randint(key, sds.shape, 0,
                                                   cfg.vocab_size, jnp.int32)
            else:
                out[name] = (jax.random.normal(key, sds.shape, jnp.float32)
                             .astype(sds.dtype))
        return out


def build_model(cfg: ModelConfig, tp_size: int = 1) -> Model:
    if cfg.family not in _FAMILY:
        raise ValueError(f"unknown family {cfg.family}")
    return Model(cfg=cfg, mod=_FAMILY[cfg.family], tp_size=tp_size)
