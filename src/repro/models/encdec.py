"""whisper-base: encoder-decoder transformer.

The mel/conv frontend is a STUB per the assignment — ``input_specs()``
provides precomputed frame embeddings (B, 1500, d_model) as encoder input.
Positions are sinusoidal (computed, not learned — documented deviation),
norms are LayerNorm with bias, MLPs are GELU, attention is MHA (kv = heads).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.models import common as cm
from repro.models.param_util import ParamDef
from repro.sharding import constrain


def _enc_cfg(cfg):
    return cfg.encoder


def _ln_defs(l, d):
    return {
        "g": ParamDef((l, d), ("layers", None), init="ones"),
        "b": ParamDef((l, d), ("layers", None), init="zeros"),
    }


def _attn_defs(l, d, h):
    hd = d // h
    la = ("layers",)
    return {
        "wq": ParamDef((l, d, h, hd), la + ("fsdp", "tp", None)),
        "wk": ParamDef((l, d, h, hd), la + ("fsdp", "tp", None)),
        "wv": ParamDef((l, d, h, hd), la + ("fsdp", "tp", None)),
        "wo": ParamDef((l, h, hd, d), la + ("tp", None, "fsdp")),
    }


def _mlp_defs(l, d, f):
    la = ("layers",)
    return {
        "w1": ParamDef((l, d, f), la + ("fsdp", "tp")),
        "b1": ParamDef((l, f), la + ("tp",), init="zeros"),
        "w2": ParamDef((l, f, d), la + ("tp", "fsdp")),
        "b2": ParamDef((l, d), la + (None,), init="zeros"),
    }


def make_defs(cfg, tp_size: int = 1) -> Dict:
    del tp_size
    e = _enc_cfg(cfg)
    ld, dd, fd = cfg.num_layers, cfg.d_model, cfg.d_ff
    v, hd_ = cfg.vocab_size, cfg.num_heads
    enc = {
        "ln1": _ln_defs(e.num_layers, e.d_model),
        "attn": _attn_defs(e.num_layers, e.d_model, e.num_heads),
        "ln2": _ln_defs(e.num_layers, e.d_model),
        "mlp": _mlp_defs(e.num_layers, e.d_model, e.d_ff),
    }
    dec = {
        "ln1": _ln_defs(ld, dd),
        "self_attn": _attn_defs(ld, dd, hd_),
        "ln2": _ln_defs(ld, dd),
        "cross_attn": _attn_defs(ld, dd, hd_),
        "ln3": _ln_defs(ld, dd),
        "mlp": _mlp_defs(ld, dd, fd),
    }
    return {
        "embed": ParamDef((v, dd), ("tp", "fsdp")),
        "enc_blocks": enc,
        "dec_blocks": dec,
        "ln_enc": {"g": ParamDef((e.d_model,), (None,), init="ones"),
                   "b": ParamDef((e.d_model,), (None,), init="zeros")},
        "ln_f": {"g": ParamDef((dd,), (None,), init="ones"),
                 "b": ParamDef((dd,), (None,), init="zeros")},
        "lm_head": ParamDef((dd, v), ("fsdp", "tp")),
    }


def _ln(x, p, eps):
    return ref.layernorm(x, p["g"].astype(jnp.float32),
                         p["b"].astype(jnp.float32), eps)


def _mha(p, xq, xkv, *, causal, impl, return_kv=False, kv_override=None):
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"],
                   preferred_element_type=jnp.float32).astype(xq.dtype)
    if kv_override is None:
        k = jnp.einsum("bsd,dhk->bshk", xkv, p["wk"],
                       preferred_element_type=jnp.float32).astype(xq.dtype)
        v = jnp.einsum("bsd,dhk->bshk", xkv, p["wv"],
                       preferred_element_type=jnp.float32).astype(xq.dtype)
    else:
        k, v = kv_override
    q = constrain(q, cm.ACT_HEADS)
    o = ops.attention(q, k, v, causal=causal, impl=impl)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"],
                     preferred_element_type=jnp.float32).astype(xq.dtype)
    if return_kv:
        return out, (k, v)
    return out


def _gelu_mlp(p, x):
    h = jnp.einsum("bsd,df->bsf", x, p["w1"],
                   preferred_element_type=jnp.float32) + p["b1"][None, None]
    h = jax.nn.gelu(h).astype(x.dtype)
    h = constrain(h, cm.ACT_FF)
    out = jnp.einsum("bsf,fd->bsd", h, p["w2"],
                     preferred_element_type=jnp.float32) \
        + p["b2"][None, None].astype(jnp.float32)
    return out.astype(x.dtype)


def encode(params, frames, cfg, *, impl: str = "xla", remat: bool = True):
    """frames (B, P, D_enc) precomputed embeddings (frontend stub)."""
    e = _enc_cfg(cfg)
    x = frames + cm.sinusoidal_positions(frames.shape[1], e.d_model,
                                         frames.dtype)[None]
    x = constrain(x, ("batch", None, None))

    def body(layer_p, y, _):
        y = y + _mha(layer_p["attn"], _ln(y, layer_p["ln1"], cfg.norm_eps),
                     _ln(y, layer_p["ln1"], cfg.norm_eps), causal=False,
                     impl=impl)
        y = y + _gelu_mlp(layer_p["mlp"], _ln(y, layer_p["ln2"], cfg.norm_eps))
        return y

    x = cm.scan_layers(params["enc_blocks"], x, body, remat=remat)
    return _ln(x, params["ln_enc"], cfg.norm_eps)


def _decoder(params, tokens, enc_out, cfg, impl, remat):
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + cm.sinusoidal_positions(s, cfg.d_model, x.dtype)[None]
    x = constrain(x, ("batch", None, None))

    def body(layer_p, y, enc):
        y = y + _mha(layer_p["self_attn"], _ln(y, layer_p["ln1"], cfg.norm_eps),
                     _ln(y, layer_p["ln1"], cfg.norm_eps), causal=True,
                     impl=impl)
        kq = _ln(y, layer_p["ln2"], cfg.norm_eps)
        k = jnp.einsum("bsd,dhk->bshk", enc, layer_p["cross_attn"]["wk"],
                       preferred_element_type=jnp.float32).astype(y.dtype)
        v = jnp.einsum("bsd,dhk->bshk", enc, layer_p["cross_attn"]["wv"],
                       preferred_element_type=jnp.float32).astype(y.dtype)
        y = y + _mha(layer_p["cross_attn"], kq, enc, causal=False, impl=impl,
                     kv_override=(k, v))
        y = y + _gelu_mlp(layer_p["mlp"], _ln(y, layer_p["ln3"], cfg.norm_eps))
        return y

    return cm.scan_layers(params["dec_blocks"], x, body, remat=remat,
                          extra=enc_out)


def loss_fn(params, batch, cfg, *, impl: str = "xla", remat: bool = True):
    enc_out = encode(params, batch["frames"], cfg, impl=impl, remat=remat)
    x = _decoder(params, batch["tokens"], enc_out, cfg, impl, remat)
    h = _ln(x, params["ln_f"], cfg.norm_eps)
    total, count = ops.xla_chunked_xent(
        lambda xs, w: jnp.einsum("bsd,dv->bsv", xs, w,
                                 preferred_element_type=jnp.float32),
        h, batch["labels"], params["lm_head"])
    loss = total / jnp.maximum(count, 1.0)
    return loss, {"loss": loss}


def _state_shapes(cfg, batch, seq, dtype):
    e = _enc_cfg(cfg)
    l, h, hd = cfg.num_layers, cfg.num_heads, cfg.resolved_head_dim
    return {
        "k": ((l, batch, seq, h, hd), dtype),
        "v": ((l, batch, seq, h, hd), dtype),
        "cross_k": ((l, batch, e.num_positions, h, hd), dtype),
        "cross_v": ((l, batch, e.num_positions, h, hd), dtype),
    }


_CACHE_AXES = {
    "k": ("layers", "batch", "seq_kv", None, None),
    "v": ("layers", "batch", "seq_kv", None, None),
    "cross_k": ("layers", "batch", None, "tp", None),
    "cross_v": ("layers", "batch", None, "tp", None),
}


def init_cache(cfg, batch: int, seq: int, dtype=jnp.bfloat16):
    shapes = _state_shapes(cfg, batch, seq, dtype)
    return ({k: jnp.zeros(s, dt) for k, (s, dt) in shapes.items()},
            dict(_CACHE_AXES))


def abstract_cache(cfg, batch: int, seq: int, dtype=jnp.bfloat16):
    shapes = _state_shapes(cfg, batch, seq, dtype)
    return ({k: jax.ShapeDtypeStruct(s, dt) for k, (s, dt) in shapes.items()},
            dict(_CACHE_AXES))


def prefill_fn(params, tokens, cfg, *, impl: str = "xla", frames=None):
    """Encode frames + run decoder prompt, building self & cross caches."""
    b, s = tokens.shape
    if frames is None:
        e = _enc_cfg(cfg)
        frames = jnp.zeros((b, e.num_positions, e.d_model), jnp.bfloat16)
    enc_out = encode(params, frames, cfg, impl=impl, remat=False)
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + cm.sinusoidal_positions(s, cfg.d_model, x.dtype)[None]

    def body(carry, layer_p):
        y = carry
        out, kv = _mha(layer_p["self_attn"],
                       _ln(y, layer_p["ln1"], cfg.norm_eps),
                       _ln(y, layer_p["ln1"], cfg.norm_eps), causal=True,
                       impl=impl, return_kv=True)
        y = y + out
        ck = jnp.einsum("bsd,dhk->bshk", enc_out, layer_p["cross_attn"]["wk"],
                        preferred_element_type=jnp.float32).astype(y.dtype)
        cv = jnp.einsum("bsd,dhk->bshk", enc_out, layer_p["cross_attn"]["wv"],
                        preferred_element_type=jnp.float32).astype(y.dtype)
        y = y + _mha(layer_p["cross_attn"], _ln(y, layer_p["ln2"], cfg.norm_eps),
                     enc_out, causal=False, impl=impl, kv_override=(ck, cv))
        y = y + _gelu_mlp(layer_p["mlp"], _ln(y, layer_p["ln3"], cfg.norm_eps))
        return y, (kv[0], kv[1], ck, cv)

    x, (k, v, ck, cv) = jax.lax.scan(body, x, params["dec_blocks"])
    h = _ln(x[:, -1:], params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("btd,dv->btv", h, params["lm_head"],
                        preferred_element_type=jnp.float32)[:, 0]
    cache = {"k": k, "v": v, "cross_k": ck, "cross_v": cv}
    return logits, cache, jnp.full((b,), s, jnp.int32)


def decode_fn(params, cache, tokens, lengths, cfg, *, impl: str = "xla"):
    b = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0)
    # sinusoidal position embedding at each sequence's current position
    x = x + cm.sinusoidal_at(lengths, cfg.d_model, x.dtype)[:, None]
    e = _enc_cfg(cfg)

    def body(carry, xs):
        y = carry
        layer_p, k, v, ck, cv = xs
        h1 = _ln(y, layer_p["ln1"], cfg.norm_eps)
        qn = jnp.einsum("bsd,dhk->bshk", h1, layer_p["self_attn"]["wq"],
                        preferred_element_type=jnp.float32).astype(y.dtype)
        kn = jnp.einsum("bsd,dhk->bshk", h1, layer_p["self_attn"]["wk"],
                        preferred_element_type=jnp.float32).astype(y.dtype)
        vn = jnp.einsum("bsd,dhk->bshk", h1, layer_p["self_attn"]["wv"],
                        preferred_element_type=jnp.float32).astype(y.dtype)
        k = cm.insert_kv(k, kn, lengths)
        v = cm.insert_kv(v, vn, lengths)
        o = ops.decode_attention(qn, k, v, lengths + 1, impl=impl)
        y = y + jnp.einsum("bshk,hkd->bsd", o, layer_p["self_attn"]["wo"],
                           preferred_element_type=jnp.float32).astype(y.dtype)
        h2 = _ln(y, layer_p["ln2"], cfg.norm_eps)
        q2 = jnp.einsum("bsd,dhk->bshk", h2, layer_p["cross_attn"]["wq"],
                        preferred_element_type=jnp.float32).astype(y.dtype)
        full = jnp.full((b,), e.num_positions, jnp.int32)
        o2 = ops.decode_attention(q2, ck, cv, full, impl=impl)
        y = y + jnp.einsum("bshk,hkd->bsd", o2, layer_p["cross_attn"]["wo"],
                           preferred_element_type=jnp.float32).astype(y.dtype)
        y = y + _gelu_mlp(layer_p["mlp"], _ln(y, layer_p["ln3"], cfg.norm_eps))
        return y, (k, v)

    x, (k, v) = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    h = _ln(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("btd,dv->btv", h, params["lm_head"],
                        preferred_element_type=jnp.float32)[:, 0]
    return logits, dict(cache, k=k, v=v)
