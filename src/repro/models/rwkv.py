"""RWKV6 "Finch" LM (rwkv6-7b): attention-free, data-dependent decay.

Time-mix uses token-shift lerps and a low-rank (LoRA) data-dependent decay
w_t = exp(-exp(w0 + tanh(x̄ A) B)); the WKV recurrence runs through
kernels/rwkv6.py on TPU and the chunk-parallel matrix form
(ops.wkv6_matrix) under XLA training.

Channel-mix is the RWKV squared-ReLU MLP.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.models import common as cm
from repro.models.param_util import ParamDef
from repro.sharding import constrain

_LORA = 64


def make_defs(cfg, tp_size: int = 1) -> Dict:
    del tp_size
    l, d, v, f = cfg.num_layers, cfg.d_model, cfg.vocab_size, cfg.d_ff
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    la = ("layers",)

    def vec(init="normal", scale=0.02):
        return ParamDef((l, d), la + (None,), init=init, scale=scale)

    tm = {
        "ln": cm.norm_def(cfg, stack=l),
        "mu_r": vec("zeros"), "mu_k": vec("zeros"), "mu_v": vec("zeros"),
        "mu_w": vec("zeros"), "mu_g": vec("zeros"),
        "wr": ParamDef((l, d, d), la + ("fsdp", "tp")),
        "wk": ParamDef((l, d, d), la + ("fsdp", "tp")),
        "wv": ParamDef((l, d, d), la + ("fsdp", "tp")),
        "wg": ParamDef((l, d, d), la + ("fsdp", "tp")),
        "w_lora_a": ParamDef((l, d, _LORA), la + ("fsdp", None)),
        "w_lora_b": ParamDef((l, _LORA, d), la + (None, "tp")),
        "w0": vec("zeros"),
        "u": ParamDef((l, h, hd), la + ("tp", None)),
        "ln_x": cm.norm_def(cfg, stack=l),
        "wo": ParamDef((l, d, d), la + ("tp", "fsdp")),
    }
    cmix = {
        "ln": cm.norm_def(cfg, stack=l),
        "mu": vec("zeros"),
        "wk": ParamDef((l, d, f), la + ("fsdp", "tp")),
        "wv": ParamDef((l, f, d), la + ("tp", "fsdp")),
    }
    return {
        "embed": ParamDef((v, d), ("tp", "fsdp")),
        "blocks": {"tm": tm, "cm": cmix},
        "ln_f": cm.norm_def(cfg),
        "lm_head": ParamDef((d, v), ("fsdp", "tp")),
    }


def _token_shift(x):
    """x (B,S,D) -> previous token (zeros at position 0)."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def _lerp(x, x_prev, mu):
    return x + (x_prev - x) * jax.nn.sigmoid(mu)


def wkv6_train(r, k, v, w, u, *, chunk: int = 32, impl: str = "xla",
               return_state: bool = False):
    """Chunk-parallel WKV6 (matrix form) for training. r/k/v/w (B,T,H,D).

    §Perf (beyond the three hillclimb cells): replaces the 4096-step token
    recurrence (rank-1 (B,H,D,D) state updates — memory-bound) with
    per-chunk masked matmuls + a T/chunk-step inter-chunk scan; exact for
    arbitrary per-channel data-dependent decay (see ops.wkv6_matrix).
    """
    if impl == "pallas" and not return_state:
        return ops.wkv6(r, k, v, w, u, impl="pallas", chunk=max(chunk, 128))
    outs, state = ops.wkv6_matrix(r, k, v, w, u, chunk=chunk)
    if return_state:
        return outs, state
    return outs


def time_mix(p, x, cfg, *, impl: str = "xla", state=None, x_prev=None,
             return_state: bool = False):
    """RWKV6 time-mix. Train: full sequence. Decode: state/x_prev carried.

    Returns (delta, new_state, new_x_prev) — latter two None in train mode
    unless ``return_state`` (prefill) is set.
    """
    h_, hd = cfg.num_heads, cfg.resolved_head_dim
    b = x.shape[0]
    hx = cm.rmsnorm(x, p["ln"], cfg.norm_eps, impl)
    decode = state is not None
    prev = x_prev[:, None, :] if decode else _token_shift(hx)

    def mix(mu):
        return _lerp(hx, prev, mu)

    mm = lambda y, w: jnp.einsum("bsd,de->bse", y, w,
                                 preferred_element_type=jnp.float32)
    r = mm(mix(p["mu_r"]), p["wr"])
    k = mm(mix(p["mu_k"]), p["wk"])
    v = mm(mix(p["mu_v"]), p["wv"])
    g = mm(mix(p["mu_g"]), p["wg"])
    xw = mix(p["mu_w"])
    logw = p["w0"][None, None] + jnp.einsum(
        "bsl,le->bse", jnp.tanh(jnp.einsum("bsd,dl->bsl", xw, p["w_lora_a"])),
        p["w_lora_b"])
    w = jnp.exp(-jnp.exp(logw.astype(jnp.float32)))          # (B,S,D) in (0,1)

    per_head = lambda y: y.reshape(b, -1, h_, hd)
    r4, k4, v4, w4 = per_head(r), per_head(k), per_head(v), per_head(w)
    if decode:
        out, state = ref.wkv6_decode(r4[:, 0], k4[:, 0], v4[:, 0], w4[:, 0],
                                     p["u"], state)
        out = out[:, None]
        new_prev = hx[:, -1]
    elif return_state:
        out, state = wkv6_train(r4, k4, v4, w4, p["u"],
                                chunk=cfg.ssm.chunk if cfg.ssm else 128,
                                impl=impl, return_state=True)
        new_prev = hx[:, -1]
    else:
        out = wkv6_train(r4, k4, v4, w4, p["u"],
                         chunk=cfg.ssm.chunk if cfg.ssm else 128, impl=impl)
        state, new_prev = None, None
    out = out.reshape(b, -1, h_ * hd)
    out = cm.rmsnorm(out.astype(x.dtype), p["ln_x"], cfg.norm_eps, impl)
    out = out * ref.swish(g).astype(x.dtype)
    delta = jnp.einsum("bse,ed->bsd", out, p["wo"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
    return constrain(delta, cm.RESID), state, new_prev


def channel_mix(p, x, cfg, *, impl: str = "xla", x_prev=None):
    hx = cm.rmsnorm(x, p["ln"], cfg.norm_eps, impl)
    decode = x_prev is not None
    prev = x_prev[:, None, :] if decode else _token_shift(hx)
    xk = _lerp(hx, prev, p["mu"])
    k = jnp.einsum("bsd,df->bsf", xk, p["wk"],
                   preferred_element_type=jnp.float32)
    k = constrain(jnp.square(jax.nn.relu(k)).astype(x.dtype), cm.ACT_FF)
    delta = jnp.einsum("bsf,fd->bsd", k, p["wv"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
    new_prev = hx[:, -1] if decode else None
    return constrain(delta, cm.RESID), new_prev


def loss_fn(params, batch, cfg, *, impl: str = "xla", remat: bool = True):
    tokens, labels = batch["tokens"], batch["labels"]
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, cm.RESID)

    def body(layer_p, y, _extra):
        d1, _, _ = time_mix(layer_p["tm"], y, cfg, impl=impl)
        y = y + d1
        d2, _ = channel_mix(layer_p["cm"], y, cfg, impl=impl)
        return constrain(y + d2, cm.RESID)

    x = cm.scan_layers(params["blocks"], x, body, remat=remat)
    loss = cm.lm_loss(x, labels, params["ln_f"], params["lm_head"], cfg,
                      impl=impl)
    return loss, {"loss": loss}


def init_cache(cfg, batch: int, seq: int, dtype=jnp.bfloat16):
    del seq  # O(1) state — this is the point of long_500k for this arch
    l, d = cfg.num_layers, cfg.d_model
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    cache = {
        "wkv": jnp.zeros((l, batch, h, hd, hd), jnp.float32),
        "x_tm": jnp.zeros((l, batch, d), dtype),
        "x_cm": jnp.zeros((l, batch, d), dtype),
    }
    axes = {
        "wkv": ("layers", "batch", "tp", None, None),
        "x_tm": ("layers", "batch", None),
        "x_cm": ("layers", "batch", None),
    }
    return cache, axes


def abstract_cache(cfg, batch: int, seq: int, dtype=jnp.bfloat16):
    cache, axes = init_cache(cfg, batch, seq, dtype)
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        cache), axes


def prefill_fn(params, tokens, cfg, *, impl: str = "xla"):
    """Prefill = run the recurrence over the prompt, keeping final states."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, cm.RESID)

    def body(carry, layer_p):
        y = carry
        d1, wkv_s, x_tm = time_mix(layer_p["tm"], y, cfg, impl=impl,
                                   return_state=True)
        y = y + d1
        hx2 = cm.rmsnorm(y, layer_p["cm"]["ln"], cfg.norm_eps, impl)
        x_cm = hx2[:, -1]
        d2, _ = channel_mix(layer_p["cm"], y, cfg, impl=impl)
        y = constrain(y + d2, cm.RESID)
        return y, (wkv_s, x_tm, x_cm)

    x, (wkv, x_tm, x_cm) = jax.lax.scan(body, x, params["blocks"])
    cache = {"wkv": wkv, "x_tm": x_tm.astype(x.dtype),
             "x_cm": x_cm.astype(x.dtype)}
    h = cm.rmsnorm(x[:, -1:], params["ln_f"], cfg.norm_eps, impl)
    logits = jnp.einsum("btd,dv->btv", h, params["lm_head"],
                        preferred_element_type=jnp.float32)[:, 0]
    return logits, cache, jnp.full((b,), s, jnp.int32)


def decode_fn(params, cache, tokens, lengths, cfg, *, impl: str = "xla"):
    del lengths  # state-based; no positional bookkeeping needed
    x = jnp.take(params["embed"], tokens, axis=0)

    def body(carry, xs):
        y = carry
        layer_p, wkv_s, x_tm, x_cm = xs
        d1, wkv_s, x_tm = time_mix(layer_p["tm"], y, cfg, impl=impl,
                                   state=wkv_s, x_prev=x_tm)
        y = y + d1
        d2, x_cm = channel_mix(layer_p["cm"], y, cfg, impl=impl, x_prev=x_cm)
        y = y + d2
        return y, (wkv_s, x_tm, x_cm)

    x, (wkv, x_tm, x_cm) = jax.lax.scan(
        body, x, (params["blocks"], cache["wkv"], cache["x_tm"],
                  cache["x_cm"]))
    h = cm.rmsnorm(x, params["ln_f"], cfg.norm_eps, impl)
    logits = jnp.einsum("btd,dv->btv", h, params["lm_head"],
                        preferred_element_type=jnp.float32)[:, 0]
    return logits, {"wkv": wkv, "x_tm": x_tm, "x_cm": x_cm}
