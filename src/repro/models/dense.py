"""Dense GQA transformer LM (llama-family): deepseek-67b, yi-34b,
phi3-medium-14b, starcoder2-7b — and the VLM variant (internvl2-2b) whose
vision tower is a stub providing precomputed patch embeddings.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import common as cm
from repro.models.param_util import ParamDef
from repro.sharding import constrain


def make_defs(cfg, tp_size: int = 1) -> Dict:
    l, d, v = cfg.num_layers, cfg.d_model, cfg.vocab_size
    del tp_size
    blocks = {
        "attn": dict(cm.attention_defs(cfg, stack=l),
                     ln=cm.norm_def(cfg, stack=l)),
        "mlp": dict(cm.mlp_defs(cfg, stack=l), ln=cm.norm_def(cfg, stack=l)),
    }
    defs = {
        "embed": ParamDef((v, d), ("tp", "fsdp")),
        "blocks": blocks,
        "ln_f": cm.norm_def(cfg),
        "lm_head": ParamDef((d, v), ("fsdp", "tp")),
    }
    if cfg.family == "vlm":
        defs["vision_proj"] = ParamDef((d, d), ("fsdp", "tp"))
    return defs


def _embed(params, tokens):
    return jnp.take(params["embed"], tokens, axis=0)


def _block(layer_p, x, extra, cfg, impl):
    positions = extra
    x = x + cm.attention_sublayer(layer_p["attn"], x, positions, cfg,
                                  impl=impl)
    x = x + cm.mlp_sublayer(layer_p["mlp"], x, cfg, impl=impl)
    return constrain(x, cm.RESID)


def loss_fn(params, batch, cfg, *, impl: str = "xla", remat: bool = True):
    tokens, labels = batch["tokens"], batch["labels"]
    b, s = tokens.shape
    x = _embed(params, tokens)
    if cfg.family == "vlm":
        vis = jnp.einsum("bpd,de->bpe", batch["vision"], params["vision_proj"],
                         preferred_element_type=jnp.float32).astype(x.dtype)
        x = jnp.concatenate([vis, x], axis=1)
        labels = jnp.concatenate(
            [jnp.full((b, vis.shape[1]), -1, labels.dtype), labels], axis=1)
        s = x.shape[1]
    x = constrain(x, cm.RESID)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = cm.scan_layers(params["blocks"], x,
                       lambda p, y, e: _block(p, y, e, cfg, impl),
                       remat=remat, extra=positions)
    loss = cm.lm_loss(x, labels, params["ln_f"], params["lm_head"], cfg,
                      impl=impl)
    return loss, {"loss": loss}


def prefill_fn(params, tokens, cfg, *, impl: str = "xla", vision=None):
    """Prompt pass. Returns (next-token logits (B,V), cache, lengths)."""
    b, s = tokens.shape
    x = _embed(params, tokens)
    if cfg.family == "vlm" and vision is not None:
        vis = jnp.einsum("bpd,de->bpe", vision, params["vision_proj"],
                         preferred_element_type=jnp.float32).astype(x.dtype)
        x = jnp.concatenate([vis, x], axis=1)
        s = x.shape[1]
    x = constrain(x, cm.RESID)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(carry, layer_p):
        y = carry
        out, kv = cm.attention_sublayer(layer_p["attn"], y, positions, cfg,
                                        impl=impl, return_kv=True)
        y = y + out
        y = y + cm.mlp_sublayer(layer_p["mlp"], y, cfg, impl=impl)
        return constrain(y, cm.RESID), kv

    x, (ck, cv) = jax.lax.scan(body, x, params["blocks"])
    h = cm.rmsnorm(x[:, -1:], params["ln_f"], cfg.norm_eps, impl)
    logits = jnp.einsum("btd,dv->btv", h, params["lm_head"],
                        preferred_element_type=jnp.float32)[:, 0]
    lengths = jnp.full((b,), s, jnp.int32)
    return logits, {"k": ck, "v": cv}, lengths


def init_cache(cfg, batch: int, seq: int, dtype=jnp.bfloat16):
    l, kv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    shape = (l, batch, seq, kv, hd)
    axes = ("layers", "batch", "seq_kv", None, None)
    return ({"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)},
            {"k": axes, "v": axes})


def abstract_cache(cfg, batch: int, seq: int, dtype=jnp.bfloat16):
    l, kv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    sds = jax.ShapeDtypeStruct((l, batch, seq, kv, hd), dtype)
    axes = ("layers", "batch", "seq_kv", None, None)
    return {"k": sds, "v": sds}, {"k": axes, "v": axes}


def decode_fn(params, cache, tokens, lengths, cfg, *, impl: str = "xla"):
    """One decode step. tokens (B,1); lengths (B,). Returns (logits, cache)."""
    x = _embed(params, tokens)

    def body(carry, xs):
        y = carry
        layer_p, ck, cv = xs
        delta, ck, cv = cm.decode_attention_sublayer(
            layer_p["attn"], y, ck, cv, lengths, cfg, impl=impl)
        y = y + delta
        y = y + cm.mlp_sublayer(layer_p["mlp"], y, cfg, impl=impl)
        return y, (ck, cv)

    x, (ck, cv) = jax.lax.scan(body, x, (params["blocks"], cache["k"],
                                         cache["v"]))
    h = cm.rmsnorm(x, params["ln_f"], cfg.norm_eps, impl)
    logits = jnp.einsum("btd,dv->btv", h, params["lm_head"],
                        preferred_element_type=jnp.float32)[:, 0]
    return logits, {"k": ck, "v": cv}
