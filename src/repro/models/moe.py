"""Mixture-of-Experts transformer (moonshot-v1-16b-a3b, qwen2-moe-a2.7b).

Routing is top-k with capacity; dispatch is *sort-based* (MegaBlocks-style
argsort into a dense (E, C, D) buffer) rather than one-hot einsum, so the
dispatch tensors stay O(T·k) instead of O(T·E·C). Expert weights carry the
'expert' logical axis (EP over the TP mesh axis when E divides |model|,
otherwise TP over d_ff — qwen's 60 experts don't divide 16).

Shared experts (both assigned MoEs have them) run as a dense SwiGLU branch.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.models import common as cm
from repro.models import dense
from repro.models.param_util import ParamDef
from repro.sharding import constrain

EXPERT_BUF = ("batch", "expert", None, None)


def make_defs(cfg, tp_size: int = 1) -> Dict:
    l, d, v = cfg.num_layers, cfg.d_model, cfg.vocab_size
    m = cfg.moe
    ep_ok = tp_size <= 1 or m.num_experts % tp_size == 0
    # EP when experts divide the TP axis; otherwise shard expert d_ff on TP.
    e_axes = ("layers", "expert", "fsdp", None) if ep_ok \
        else ("layers", None, "fsdp", "tp")
    e_axes_dn = ("layers", "expert", None, "fsdp") if ep_ok \
        else ("layers", None, "tp", "fsdp")
    moe_block = {
        "router": ParamDef((l, d, m.num_experts), ("layers", "fsdp", None)),
        "wg": ParamDef((l, m.num_experts, d, m.expert_d_ff), e_axes),
        "wu": ParamDef((l, m.num_experts, d, m.expert_d_ff), e_axes),
        "wd": ParamDef((l, m.num_experts, m.expert_d_ff, d), e_axes_dn),
        "ln": cm.norm_def(cfg, stack=l),
    }
    if m.num_shared_experts:
        f_sh = m.shared_d_ff * m.num_shared_experts
        moe_block["shared"] = cm.mlp_defs(cfg, stack=l, d_ff=f_sh)
    blocks = {
        "attn": dict(cm.attention_defs(cfg, stack=l),
                     ln=cm.norm_def(cfg, stack=l)),
        "moe": moe_block,
    }
    return {
        "embed": ParamDef((v, d), ("tp", "fsdp")),
        "blocks": blocks,
        "ln_f": cm.norm_def(cfg),
        "lm_head": ParamDef((d, v), ("fsdp", "tp")),
    }


def _capacity(group_size: int, k: int, e: int, cf: float) -> int:
    c = int(group_size * k / e * cf) + 1
    return max(8, -(-c // 8) * 8)  # round up to multiple of 8


def _dispatch_one_group(x, logits, *, k: int, e: int, c: int):
    """x (T,D); logits (T,E). Returns (buf (E,C,D), combine meta)."""
    t = x.shape[0]
    w, idx = ref.topk_router(logits, k)          # (T,k)
    flat_e = idx.reshape(-1)                     # (T*k,)
    flat_w = w.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    seg_start = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(t * k, dtype=jnp.int32) - seg_start[sorted_e]
    valid = pos_in_e < c
    slot = jnp.where(valid, sorted_e * c + pos_in_e, e * c)  # OOB -> dropped
    token_id = order // k
    buf = jnp.zeros((e * c, x.shape[1]), x.dtype).at[slot].set(
        x[token_id], mode="drop")
    meta = (slot, token_id, flat_w[order], valid)
    return buf.reshape(e, c, -1), meta


def _combine_one_group(y_buf, meta, t: int, d: int):
    """y_buf (E,C,D) expert outputs -> (T,D) weighted combine."""
    slot, token_id, w_sorted, valid = meta
    y_flat = y_buf.reshape(-1, d)
    picked = y_flat.at[slot].get(mode="fill", fill_value=0)  # OOB -> 0
    picked = picked * (w_sorted * valid.astype(jnp.float32)
                       )[:, None].astype(y_buf.dtype)
    return jnp.zeros((t, d), y_buf.dtype).at[token_id].add(
        picked.astype(y_buf.dtype))


def moe_sublayer(p, x, cfg, *, impl: str = "xla"):
    """Pre-norm MoE MLP. x (B,S,D). Returns (delta, aux_loss)."""
    m = cfg.moe
    b, s, d = x.shape
    h = cm.rmsnorm(x, p["ln"], cfg.norm_eps, impl)
    # gather the sequence-parallel residual BEFORE dispatch: row-gathers from
    # a seq-sharded tensor lower to cross-shard select+all-reduce chains
    # (§Perf C2 — was ~1 TB/step of f32/u32 collectives at moonshot scale)
    h = constrain(h, cm.GATHERED)
    logits = jnp.einsum("bsd,de->bse", h, p["router"],
                        preferred_element_type=jnp.float32)
    c = _capacity(s, m.top_k, m.num_experts, m.capacity_factor)

    buf, meta = jax.vmap(
        lambda xx, ll: _dispatch_one_group(xx, ll, k=m.top_k,
                                           e=m.num_experts, c=c))(h, logits)
    buf = constrain(buf, EXPERT_BUF)
    # ZeRO gather made explicit (§Perf C1): expert weights are stored
    # FSDP-sharded on D; without the constraint the SPMD partitioner keeps
    # them sharded and ALL-REDUCES the (B,E,C,F) activations over the data
    # axis instead (~10× the bytes of gathering the weights). Only worth it
    # when the token volume amortizes the gather — decode steps (B tokens)
    # keep the sharded weights.
    if b * s >= 4096:
        wg = constrain(p["wg"], ("expert", None, None))
        wu = constrain(p["wu"], ("expert", None, None))
        wd = constrain(p["wd"], ("expert", None, None))
    else:
        wg, wu, wd = p["wg"], p["wu"], p["wd"]
    # expert SwiGLU: (B,E,C,D) x (E,D,F)
    g = jnp.einsum("becd,edf->becf", buf, wg)
    u = jnp.einsum("becd,edf->becf", buf, wu)
    a = (ref.swish(g.astype(jnp.float32)) * u.astype(jnp.float32)
         ).astype(x.dtype)
    y_buf = jnp.einsum("becf,efd->becd", a, wd).astype(x.dtype)
    y_buf = constrain(y_buf, EXPERT_BUF)
    y = jax.vmap(lambda yy, mm: _combine_one_group(yy, mm, s, d))(y_buf, meta)

    if m.num_shared_experts:
        y = y + cm.mlp_sublayer(dict(p["shared"], ln=p["ln"]), x, cfg,
                                impl=impl)

    # Load-balance aux loss (Switch-style): E * sum_e f_e * p_e.
    probs = ref.softmax(logits, axis=-1)                      # (B,S,E)
    _, top_idx = jax.lax.top_k(logits, m.top_k)
    sel = jax.nn.one_hot(top_idx, m.num_experts, dtype=jnp.float32)
    f = jnp.mean(jnp.sum(sel, axis=2), axis=(0, 1))           # fraction routed
    pbar = jnp.mean(probs, axis=(0, 1))
    aux = m.num_experts * jnp.sum(f * pbar) / m.top_k
    return constrain(y, cm.RESID), aux


def _block(layer_p, carry, extra, cfg, impl):
    x, aux = carry
    positions = extra
    x = x + cm.attention_sublayer(layer_p["attn"], x, positions, cfg,
                                  impl=impl)
    delta, a = moe_sublayer(layer_p["moe"], x, cfg, impl=impl)
    x = constrain(x + delta, cm.RESID)
    return (x, aux + a)


def loss_fn(params, batch, cfg, *, impl: str = "xla", remat: bool = True):
    tokens, labels = batch["tokens"], batch["labels"]
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, cm.RESID)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(carry, layer_p):
        return _block(layer_p, carry, positions, cfg, impl), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    ce = cm.lm_loss(x, labels, params["ln_f"], params["lm_head"], cfg,
                    impl=impl)
    loss = ce + cfg.moe.router_aux_weight * aux / cfg.num_layers
    return loss, {"loss": loss, "ce": ce, "aux": aux}


def prefill_fn(params, tokens, cfg, *, impl: str = "xla"):
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, cm.RESID)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(carry, layer_p):
        y = carry
        out, kv = cm.attention_sublayer(layer_p["attn"], y, positions, cfg,
                                        impl=impl, return_kv=True)
        y = y + out
        delta, _ = moe_sublayer(layer_p["moe"], y, cfg, impl=impl)
        y = constrain(y + delta, cm.RESID)
        return y, kv

    x, (ck, cv) = jax.lax.scan(body, x, params["blocks"])
    h = cm.rmsnorm(x[:, -1:], params["ln_f"], cfg.norm_eps, impl)
    logits = jnp.einsum("btd,dv->btv", h, params["lm_head"],
                        preferred_element_type=jnp.float32)[:, 0]
    return logits, {"k": ck, "v": cv}, jnp.full((b,), s, jnp.int32)


init_cache = dense.init_cache
abstract_cache = dense.abstract_cache


def decode_fn(params, cache, tokens, lengths, cfg, *, impl: str = "xla"):
    x = jnp.take(params["embed"], tokens, axis=0)

    def body(carry, xs):
        y = carry
        layer_p, ck, cv = xs
        delta, ck, cv = cm.decode_attention_sublayer(
            layer_p["attn"], y, ck, cv, lengths, cfg, impl=impl)
        y = y + delta
        md, _ = moe_sublayer(layer_p["moe"], y, cfg, impl=impl)
        y = y + md
        return y, (ck, cv)

    x, (ck, cv) = jax.lax.scan(body, x, (params["blocks"], cache["k"],
                                         cache["v"]))
    h = cm.rmsnorm(x, params["ln_f"], cfg.norm_eps, impl)
    logits = jnp.einsum("btd,dv->btv", h, params["lm_head"],
                        preferred_element_type=jnp.float32)[:, 0]
    return logits, {"k": ck, "v": cv}
