"""Spec-first parameter definitions.

Each model describes its parameters once as a pytree of :class:`ParamDef`
(shape + logical sharding axes + initializer). Real initialization (smoke
tests, training), abstract ShapeDtypeStructs (dry-run), and logical sharding
specs (launcher) all derive from the same table, so they can never drift.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones
    scale: float = 0.02
    dtype: Optional[jnp.dtype] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_def(x):
    return isinstance(x, ParamDef)


def init_params(defs, rng: jax.Array, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    rngs = jax.random.split(rng, len(leaves))
    out = []
    for r, d in zip(rngs, leaves):
        dt = d.dtype or dtype
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dt))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dt))
        else:
            out.append((jax.random.normal(r, d.shape, jnp.float32)
                        * d.scale).astype(dt))
    return jax.tree.unflatten(treedef, out)


def abstract_params(defs, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype or dtype), defs,
        is_leaf=_is_def)


def logical_specs(defs):
    return jax.tree.map(lambda d: d.axes, defs, is_leaf=_is_def)


def param_bytes(defs, dtype=jnp.bfloat16) -> int:
    total = 0
    for d in jax.tree.leaves(defs, is_leaf=_is_def):
        itemsize = jnp.dtype(d.dtype or dtype).itemsize
        total += int(np.prod(d.shape)) * itemsize
    return total
