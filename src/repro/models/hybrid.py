"""zamba2-7b: Mamba2 backbone + a single weight-shared attention(+MLP) block
applied after every ``attn_period`` Mamba2 layers (13 applications for 81
layers, plus a 3-layer tail), Zamba-style.

Mamba2 blocks follow the SSD formulation: in-proj to (x, z, B, C, dt),
causal depthwise conv + SiLU on x/B/C, per-head scalar decay
a_t = exp(-exp(A_log)·dt_t), recurrence via kernels/mamba2.py (TPU) or a
chunk-rematerialized scan (training backward saves O(T/chunk) states).
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.models import common as cm
from repro.models.param_util import ParamDef
from repro.sharding import constrain

_P = 64  # mamba2 head dim


def _dims(cfg):
    d = cfg.d_model
    dinner = cfg.ssm.expand * d
    n_heads = dinner // _P
    return d, dinner, n_heads, cfg.ssm.state_dim, cfg.ssm.conv_width


def make_defs(cfg, tp_size: int = 1) -> Dict:
    del tp_size
    l, v = cfg.num_layers, cfg.vocab_size
    d, dinner, hm, n, w = _dims(cfg)
    la = ("layers",)
    mamba = {
        "ln": cm.norm_def(cfg, stack=l),
        "w_x": ParamDef((l, d, dinner), la + ("fsdp", "tp")),
        "w_z": ParamDef((l, d, dinner), la + ("fsdp", "tp")),
        "w_b": ParamDef((l, d, n), la + ("fsdp", None)),
        "w_c": ParamDef((l, d, n), la + ("fsdp", None)),
        "w_dt": ParamDef((l, d, hm), la + ("fsdp", "tp")),
        "dt_bias": ParamDef((l, hm), la + (None,), init="zeros"),
        "a_log": ParamDef((l, hm), la + (None,), init="zeros"),
        "conv_x": ParamDef((l, w, dinner), la + (None, "tp"), scale=0.1),
        "conv_b": ParamDef((l, w, n), la + (None, None), scale=0.1),
        "conv_c": ParamDef((l, w, n), la + (None, None), scale=0.1),
        "d_skip": ParamDef((l, hm), la + (None,), init="ones"),
        "w_out": ParamDef((l, dinner, d), la + ("tp", "fsdp")),
    }
    shared = {
        "attn": dict(cm.attention_defs(cfg), ln=cm.norm_def(cfg)),
        "mlp": dict(cm.mlp_defs(cfg), ln=cm.norm_def(cfg)),
    }
    return {
        "embed": ParamDef((v, d), ("tp", "fsdp")),
        "mamba": mamba,
        "shared": shared,
        "ln_f": cm.norm_def(cfg),
        "lm_head": ParamDef((d, v), ("fsdp", "tp")),
    }


def _causal_conv(x, w):
    """Depthwise causal conv. x (B,S,C); w (W,C)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros(x.shape, jnp.float32)
    s = x.shape[1]
    for i in range(width):
        out = out + xp[:, i:i + s].astype(jnp.float32) * w[i][None, None]
    return out.astype(x.dtype)


def ssd_train(x, a, b, c, *, chunk: int = 256, impl: str = "xla",
              return_state: bool = False):
    """Chunk-parallel SSD (matrix form). x (B,T,H,P); a (B,T,H); b/c (B,T,H,N).

    §Perf iteration B1: the token-by-token recurrence (4096 sequential
    (B,H,P,N) state updates per layer) made zamba2 train the worst cell of
    the fleet (0.18% of roofline, memory-bound). The SSD matrix form does
    per-chunk MXU matmuls + a 16-step inter-chunk scan instead.
    """
    if impl == "pallas" and not return_state:
        return ops.ssd(x, a, b, c, impl="pallas", chunk=chunk)
    ys, state = ops.ssd_matrix(x, a, b, c, chunk=chunk)
    if return_state:
        return ys, state
    return ys


def mamba_block(p, x, cfg, *, impl: str = "xla", state=None,
                return_state: bool = False):
    """Mamba2 sublayer. Train: state=None. Decode: state dict carried.

    Returns (delta, new_state)."""
    d, dinner, hm, n, width = _dims(cfg)
    bsz, s, _ = x.shape
    h = cm.rmsnorm(x, p["ln"], cfg.norm_eps, impl)
    mm = lambda y, w: jnp.einsum("bsd,de->bse", y, w,
                                 preferred_element_type=jnp.float32).astype(x.dtype)
    xin = mm(h, p["w_x"])                     # (B,S,dinner)
    z = mm(h, p["w_z"])
    b_in = mm(h, p["w_b"])                    # (B,S,N)
    c_in = mm(h, p["w_c"])
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", h, p["w_dt"],
                   preferred_element_type=jnp.float32)
        + p["dt_bias"][None, None].astype(jnp.float32))        # (B,S,Hm)

    decode = state is not None
    if decode:
        conv_win = jnp.concatenate([state["conv_x"], xin], axis=1)
        xc = jnp.einsum("bwc,wc->bc", conv_win.astype(jnp.float32),
                        p["conv_x"].astype(jnp.float32))[:, None]
        bwin = jnp.concatenate([state["conv_b"], b_in], axis=1)
        bc = jnp.einsum("bwc,wc->bc", bwin.astype(jnp.float32),
                        p["conv_b"].astype(jnp.float32))[:, None]
        cwin = jnp.concatenate([state["conv_c"], c_in], axis=1)
        cc = jnp.einsum("bwc,wc->bc", cwin.astype(jnp.float32),
                        p["conv_c"].astype(jnp.float32))[:, None]
        new_conv = {"conv_x": conv_win[:, 1:], "conv_b": bwin[:, 1:],
                    "conv_c": cwin[:, 1:]}
    else:
        xc = _causal_conv(xin, p["conv_x"])
        bc = _causal_conv(b_in, p["conv_b"])
        cc = _causal_conv(c_in, p["conv_c"])
    xc = ref.swish(xc.astype(jnp.float32))
    bc = ref.swish(bc.astype(jnp.float32))
    cc = ref.swish(cc.astype(jnp.float32))

    a = jnp.exp(-jnp.exp(p["a_log"].astype(jnp.float32))[None, None] * dt)
    xh = xc.reshape(bsz, -1, hm, _P) * dt[..., None]

    if decode:
        bh = jnp.broadcast_to(bc[:, :, None, :], (bsz, 1, hm, n))
        ch = jnp.broadcast_to(cc[:, :, None, :], (bsz, 1, hm, n))
        y, ssm = ref.ssd_decode(xh[:, 0], a[:, 0], bh[:, 0], ch[:, 0],
                                state["ssm"])
        y = y[:, None]
        new_state = dict(new_conv, ssm=ssm)
    else:
        # b/c stay (B,S,N): shared across heads, never broadcast (§Perf B2)
        y = ssd_train(xh, a, bc, cc, chunk=cfg.ssm.chunk, impl=impl)
        new_state = None
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] \
        * xc.reshape(bsz, -1, hm, _P)
    y = y.reshape(bsz, -1, dinner).astype(x.dtype)
    y = y * ref.swish(z.astype(jnp.float32)).astype(x.dtype)
    delta = jnp.einsum("bse,ed->bsd", y, p["w_out"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
    return constrain(delta, cm.RESID), new_state


def _shared_block(p, x, positions, cfg, impl):
    x = x + cm.attention_sublayer(p["attn"], x, positions, cfg, impl=impl)
    x = x + cm.mlp_sublayer(p["mlp"], x, cfg, impl=impl)
    return constrain(x, cm.RESID)


def _group_split(cfg):
    period = cfg.attn_period
    n_groups = cfg.num_layers // period
    tail = cfg.num_layers - n_groups * period
    return period, n_groups, tail


def _split_params(params, cfg):
    period, n_groups, tail = _group_split(cfg)
    head = jax.tree.map(
        lambda a: a[: n_groups * period].reshape(
            (n_groups, period) + a.shape[1:]), params["mamba"])
    tail_p = jax.tree.map(lambda a: a[n_groups * period:], params["mamba"])
    return head, tail_p, n_groups, tail


def loss_fn(params, batch, cfg, *, impl: str = "xla", remat: bool = True):
    tokens, labels = batch["tokens"], batch["labels"]
    bsz, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, cm.RESID)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (bsz, s))
    head, tail_p, n_groups, tail = _split_params(params, cfg)

    def mamba_step(carry, layer_p):
        delta, _ = mamba_block(layer_p, carry, cfg, impl=impl)
        return constrain(carry + delta, cm.RESID), None

    def group_body(carry, group_p):
        y, _ = jax.lax.scan(mamba_step, carry, group_p)
        y = _shared_block(params["shared"], y, positions, cfg, impl)
        return y, None

    if remat:
        group_body = jax.checkpoint(group_body, prevent_cse=False)
        mamba_tail = jax.checkpoint(mamba_step, prevent_cse=False)
    else:
        mamba_tail = mamba_step
    x, _ = jax.lax.scan(group_body, x, head)
    if tail:
        x, _ = jax.lax.scan(mamba_tail, x, tail_p)
    loss = cm.lm_loss(x, labels, params["ln_f"], params["lm_head"], cfg,
                      impl=impl)
    return loss, {"loss": loss}


def _state_shapes(cfg, batch: int, seq: int, dtype):
    d, dinner, hm, n, width = _dims(cfg)
    period, n_groups, tail = _group_split(cfg)
    l = cfg.num_layers
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "conv_x": ((l, batch, width - 1, dinner), dtype),
        "conv_b": ((l, batch, width - 1, n), dtype),
        "conv_c": ((l, batch, width - 1, n), dtype),
        "ssm": ((l, batch, hm, _P, n), jnp.float32),
        "attn_k": ((n_groups, batch, seq, kv, hd), dtype),
        "attn_v": ((n_groups, batch, seq, kv, hd), dtype),
    }


_CACHE_AXES = {
    "conv_x": ("layers", "batch", None, "tp"),
    "conv_b": ("layers", "batch", None, None),
    "conv_c": ("layers", "batch", None, None),
    "ssm": ("layers", "batch", "tp", None, None),
    "attn_k": ("layers", "batch", "seq_kv", None, None),
    "attn_v": ("layers", "batch", "seq_kv", None, None),
}


def init_cache(cfg, batch: int, seq: int, dtype=jnp.bfloat16):
    shapes = _state_shapes(cfg, batch, seq, dtype)
    return ({k: jnp.zeros(s, dt) for k, (s, dt) in shapes.items()},
            dict(_CACHE_AXES))


def abstract_cache(cfg, batch: int, seq: int, dtype=jnp.bfloat16):
    shapes = _state_shapes(cfg, batch, seq, dtype)
    return ({k: jax.ShapeDtypeStruct(s, dt) for k, (s, dt) in shapes.items()},
            dict(_CACHE_AXES))


def prefill_fn(params, tokens, cfg, *, impl: str = "xla"):
    """Prefill: run all blocks over the prompt, collecting final states."""
    bsz, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, cm.RESID)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (bsz, s))
    d, dinner, hm, n, width = _dims(cfg)
    period, n_groups, tail = _group_split(cfg)

    def mamba_prefill(carry, layer_p):
        y = carry
        h = cm.rmsnorm(y, layer_p["ln"], cfg.norm_eps, impl)
        xin = jnp.einsum("bsd,de->bse", h, layer_p["w_x"],
                         preferred_element_type=jnp.float32).astype(y.dtype)
        b_in = jnp.einsum("bsd,de->bse", h, layer_p["w_b"],
                          preferred_element_type=jnp.float32).astype(y.dtype)
        c_in = jnp.einsum("bsd,de->bse", h, layer_p["w_c"],
                          preferred_element_type=jnp.float32).astype(y.dtype)
        delta, _ = mamba_block(layer_p, y, cfg, impl=impl)
        # conv windows = last (width-1) pre-conv activations
        conv = (xin[:, s - width + 1:], b_in[:, s - width + 1:],
                c_in[:, s - width + 1:])
        # final ssm state via return_state replay of the decay recurrence
        dt = jax.nn.softplus(
            jnp.einsum("bsd,dh->bsh", h, layer_p["w_dt"],
                       preferred_element_type=jnp.float32)
            + layer_p["dt_bias"][None, None].astype(jnp.float32))
        xc = ref.swish(_causal_conv(xin, layer_p["conv_x"]).astype(jnp.float32))
        bc = ref.swish(_causal_conv(b_in, layer_p["conv_b"]).astype(jnp.float32))
        cc = ref.swish(_causal_conv(c_in, layer_p["conv_c"]).astype(jnp.float32))
        a = jnp.exp(-jnp.exp(layer_p["a_log"].astype(jnp.float32))[None, None]
                    * dt)
        xh = xc.reshape(bsz, s, hm, _P) * dt[..., None]
        _, ssm = ssd_train(xh, a, bc, cc, chunk=cfg.ssm.chunk, impl="xla",
                           return_state=True)
        return constrain(y + delta, cm.RESID), (conv, ssm)

    def group_body(carry, group_p):
        y, states = jax.lax.scan(mamba_prefill, carry, group_p)
        out, kv = cm.attention_sublayer(params["shared"]["attn"], y,
                                        positions, cfg, impl=impl,
                                        return_kv=True)
        y = y + out
        y = y + cm.mlp_sublayer(params["shared"]["mlp"], y, cfg, impl=impl)
        return constrain(y, cm.RESID), (states, kv)

    head, tail_p, n_groups, tail = _split_params(params, cfg)
    x, (head_states, (ck, cv)) = jax.lax.scan(group_body, x, head)
    states_list = [jax.tree.map(
        lambda a: a.reshape((n_groups * period,) + a.shape[2:]), head_states)]
    if tail:
        x, tail_states = jax.lax.scan(mamba_prefill, x, tail_p)
        states_list.append(tail_states)
    merged = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                          *states_list) if tail else states_list[0]
    (conv_x, conv_b, conv_c), ssm = merged
    cache = {"conv_x": conv_x.astype(x.dtype), "conv_b": conv_b.astype(x.dtype),
             "conv_c": conv_c.astype(x.dtype), "ssm": ssm,
             "attn_k": ck, "attn_v": cv}
    h = cm.rmsnorm(x[:, -1:], params["ln_f"], cfg.norm_eps, impl)
    logits = jnp.einsum("btd,dv->btv", h, params["lm_head"],
                        preferred_element_type=jnp.float32)[:, 0]
    return logits, cache, jnp.full((bsz,), s, jnp.int32)


def decode_fn(params, cache, tokens, lengths, cfg, *, impl: str = "xla"):
    x = jnp.take(params["embed"], tokens, axis=0)
    period, n_groups, tail = _group_split(cfg)

    def split_head_tail(tree, n_head):
        head = jax.tree.map(
            lambda a: a[:n_head].reshape((n_groups, period) + a.shape[1:]),
            tree)
        tl = jax.tree.map(lambda a: a[n_head:], tree)
        return head, tl

    mamba_cache = {k: cache[k] for k in ("conv_x", "conv_b", "conv_c", "ssm")}
    head_p, tail_p, _, _ = _split_params(params, cfg)
    head_c, tail_c = jax.tree.map(
        lambda t: t, split_head_tail(mamba_cache, n_groups * period))

    def mamba_step(carry, xs):
        y = carry
        layer_p, st = xs
        delta, new_st = mamba_block(layer_p, y, cfg, impl=impl, state=st)
        return y + delta, new_st

    def group_body(carry, xs):
        y = carry
        group_p, group_c, ck, cv = xs
        y, new_c = jax.lax.scan(mamba_step, y, (group_p, group_c))
        p = params["shared"]["attn"]
        delta, ck, cv = cm.decode_attention_sublayer(p, y, ck, cv, lengths,
                                                     cfg, impl=impl)
        y = y + delta
        y = y + cm.mlp_sublayer(params["shared"]["mlp"], y, cfg, impl=impl)
        return y, (new_c, ck, cv)

    x, (head_new, ck, cv) = jax.lax.scan(
        group_body, x, (head_p, head_c, cache["attn_k"], cache["attn_v"]))
    head_new = jax.tree.map(
        lambda a: a.reshape((n_groups * period,) + a.shape[2:]), head_new)
    if tail:
        x, tail_new = jax.lax.scan(mamba_step, x, (tail_p, tail_c))
        merged = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0),
                              head_new, tail_new)
    else:
        merged = head_new
    new_cache = dict(merged, attn_k=ck, attn_v=cv)
    h = cm.rmsnorm(x, params["ln_f"], cfg.norm_eps, impl)
    logits = jnp.einsum("btd,dv->btv", h, params["lm_head"],
                        preferred_element_type=jnp.float32)[:, 0]
    return logits, new_cache
