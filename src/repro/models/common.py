"""Shared transformer building blocks (functional; params are dicts).

Conventions:
  activations x: (B, S, D) in the model compute dtype (bf16 in production)
  einsums accumulate in f32 (``preferred_element_type``) then cast back
  residual stream is sequence-parallel: constrained to ('batch','seq_sp',None)
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.models.param_util import ParamDef
from repro.sharding import constrain

RESID = ("batch", "seq_sp", None)
GATHERED = ("batch", None, None)
ACT_HEADS = ("batch", None, "tp", None)
ACT_FF = ("batch", None, "tp")

# Accumulation dtype policy for activation einsums. "native" keeps the XLA
# graph in the param dtype (bf16): cross-device partial-sum reductions and
# backward dx collectives stay bf16 (half the ICI bytes; the MXU still
# accumulates f32 within a tile). "f32" forces f32 graph dtype (2× collective
# bytes — measured in EXPERIMENTS.md §Perf iteration A1).
ACCUM = "native"
GATHER_EXPLICIT = False


def _einsum(eq, *xs, out_dtype=None):
    if ACCUM == "native":
        out = jnp.einsum(eq, *xs)
    else:
        out = jnp.einsum(eq, *xs, preferred_element_type=jnp.float32)
    return out.astype(out_dtype or xs[0].dtype)


# ---------------------------------------------------------------------------
# Param tables
# ---------------------------------------------------------------------------


def attention_defs(cfg, stack: int = 0, d_model: Optional[int] = None,
                   num_heads: Optional[int] = None,
                   num_kv: Optional[int] = None) -> Dict[str, ParamDef]:
    d = d_model or cfg.d_model
    h = num_heads or cfg.num_heads
    kv = num_kv or cfg.num_kv_heads
    hd = cfg.resolved_head_dim if d_model is None else d // h
    lead = (stack,) if stack else ()
    lax = ("layers",) if stack else ()
    return {
        "wq": ParamDef(lead + (d, h, hd), lax + ("fsdp", "tp", None)),
        "wk": ParamDef(lead + (d, kv, hd), lax + ("fsdp", "tp", None)),
        "wv": ParamDef(lead + (d, kv, hd), lax + ("fsdp", "tp", None)),
        "wo": ParamDef(lead + (h, hd, d), lax + ("tp", None, "fsdp")),
    }


def mlp_defs(cfg, stack: int = 0, d_model: Optional[int] = None,
             d_ff: Optional[int] = None) -> Dict[str, ParamDef]:
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    lead = (stack,) if stack else ()
    lax = ("layers",) if stack else ()
    return {
        "wg": ParamDef(lead + (d, f), lax + ("fsdp", "tp")),
        "wu": ParamDef(lead + (d, f), lax + ("fsdp", "tp")),
        "wd": ParamDef(lead + (f, d), lax + ("tp", "fsdp")),
    }


def norm_def(cfg, stack: int = 0, d_model: Optional[int] = None) -> ParamDef:
    d = d_model or cfg.d_model
    if stack:
        return ParamDef((stack, d), ("layers", None), init="ones")
    return ParamDef((d,), (None,), init="ones")


# ---------------------------------------------------------------------------
# Forward blocks
# ---------------------------------------------------------------------------


def rmsnorm(x, gamma, eps, impl):
    if impl == "pallas":
        return ops.rmsnorm(x, gamma, eps=eps, impl="pallas")
    if x.dtype == jnp.bfloat16:
        # Stats in f32, but never materialize an f32 (B,S,D) tensor: the
        # SPMD partitioner otherwise moves the sequence-parallel all-gather
        # (and the FSDP param gathers feeding the next dot) in f32 — 2× the
        # ICI bytes (EXPERIMENTS.md §Perf iterations A2/A3).
        var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                       keepdims=True)
        inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
        return x * inv * gamma.astype(x.dtype)
    return ref.rmsnorm(x, gamma, eps)


def _gather_sp(h):
    """Gather the sequence-parallel residual post-norm, in the model dtype."""
    if GATHER_EXPLICIT:
        return constrain(h, GATHERED)
    return h


def attention_sublayer(p, x, positions, cfg, *, impl: str = "xla",
                       causal: bool = True, kv_override=None,
                       rope_theta: Optional[float] = None,
                       return_kv: bool = False):
    """Pre-norm GQA attention. Returns residual delta.

    ``kv_override``: (k, v) to attend over instead of self-derived KV
    (cross-attention). ``p`` needs keys ln, wq, wk, wv, wo.
    ``return_kv``: also return the (post-RoPE) K/V for cache priming.
    """
    theta = cfg.rope_theta if rope_theta is None else rope_theta
    h = rmsnorm(x, p["ln"], cfg.norm_eps, impl)
    h = _gather_sp(h)
    q = _einsum("bsd,dhk->bshk", h, p["wq"])
    if kv_override is None:
        k = _einsum("bsd,dhk->bshk", h, p["wk"])
        v = _einsum("bsd,dhk->bshk", h, p["wv"])
    else:
        k, v = kv_override
    if theta:
        q = ops.rope(q, positions, theta=theta, impl=impl)
        if kv_override is None:
            k = ops.rope(k, positions, theta=theta, impl=impl)
    q = constrain(q, ACT_HEADS)
    k = constrain(k, ACT_HEADS)
    v = constrain(v, ACT_HEADS)
    o = ops.attention(q, k, v, causal=causal, impl=impl)
    out = _einsum("bshk,hkd->bsd", o, p["wo"])
    out = constrain(out, RESID)
    if return_kv:
        return out, (k, v)
    return out


def mlp_sublayer(p, x, cfg, *, impl: str = "xla"):
    """Pre-norm SwiGLU MLP. Returns residual delta."""
    h = rmsnorm(x, p["ln"], cfg.norm_eps, impl)
    h = _gather_sp(h)
    g = _einsum("bsd,df->bsf", h, p["wg"])
    u = _einsum("bsd,df->bsf", h, p["wu"])
    g = constrain(g, ACT_FF)
    u = constrain(u, ACT_FF)
    if impl == "pallas":
        a = ops.swiglu_act(g, u, impl="pallas")
    else:
        a = (ref.swish(g.astype(jnp.float32)) *
             u.astype(jnp.float32)).astype(x.dtype)
    out = _einsum("bsf,fd->bsd", a, p["wd"])
    return constrain(out, RESID)


def decode_attention_sublayer(p, x, cache_k, cache_v, lengths, cfg, *,
                              impl: str = "xla", rope_theta=None):
    """One-token attention step. x (B,1,D); caches (B,S,KV,Dh) pre-update.

    Returns (delta, new_k_token, new_v_token); caller owns the cache insert.
    """
    theta = cfg.rope_theta if rope_theta is None else rope_theta
    h = rmsnorm(x, p["ln"], cfg.norm_eps, impl)
    q = _einsum("bsd,dhk->bshk", h, p["wq"])
    k = _einsum("bsd,dhk->bshk", h, p["wk"])
    v = _einsum("bsd,dhk->bshk", h, p["wv"])
    if theta:
        pos = lengths[:, None]
        q = ref.rope(q, pos, theta)
        k = ref.rope(k, pos, theta)
    cache_k = insert_kv(cache_k, k, lengths)
    cache_v = insert_kv(cache_v, v, lengths)
    o = ops.decode_attention(q, cache_k, cache_v, lengths + 1, impl=impl)
    out = _einsum("bshk,hkd->bsd", o, p["wo"])
    return out, cache_k, cache_v


def insert_kv(cache, token_kv, lengths):
    """cache (B,S,KV,Dh); token_kv (B,1,KV,Dh); write at position lengths[b]."""
    def one(c, t, l):
        return jax.lax.dynamic_update_slice(c, t, (l, 0, 0))
    return jax.vmap(one)(cache, token_kv, lengths)


def scan_layers(stacked_params, x, body, *, remat: bool = True, extra=None):
    """Run ``body(layer_params, x, extra) -> x`` over stacked layer params.

    ``remat`` checkpoints each layer (saves only the carried residual), which
    with sequence-parallel residuals bounds activation memory at
    L × |residual| / TP.
    """
    def step(carry, layer_p):
        return body(layer_p, carry, extra), None

    if remat:
        step = jax.checkpoint(step, prevent_cse=False)
    x, _ = jax.lax.scan(step, x, stacked_params)
    return x


def sinusoidal_positions(seq: int, d: int, dtype=jnp.float32):
    pos = jnp.arange(seq, dtype=jnp.float32)
    return sinusoidal_at(pos, d, dtype)


def sinusoidal_at(positions, d: int, dtype=jnp.float32):
    """Sinusoidal embedding at arbitrary positions. positions (...,) -> (..., d)."""
    pos = positions.astype(jnp.float32)[..., None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)
    ang = pos / jnp.power(10_000.0, dim / d)
    pe = jnp.zeros(positions.shape + (d,), jnp.float32)
    pe = pe.at[..., 0::2].set(jnp.sin(ang))
    pe = pe.at[..., 1::2].set(jnp.cos(ang))
    return pe.astype(dtype)


def lm_loss(x, labels, ln_f, w_vocab, cfg, *, impl: str = "xla",
            chunk_s: int = 512):
    """Final-norm + sequence-chunked LM cross-entropy. x (B,S,D); labels (B,S)."""
    h = rmsnorm(x, ln_f, cfg.norm_eps, impl)

    def logits_fn(xs, w):
        return _einsum("bsd,dv->bsv", xs, w, out_dtype=jnp.float32)

    total, count = ops.xla_chunked_xent(logits_fn, h, labels, w_vocab,
                                        chunk_s=chunk_s)
    return total / jnp.maximum(count, 1.0)
