"""Sharded, mesh-independent checkpointing with elastic restore.

Layout (one directory per step):
    <dir>/step_000100/
        meta.json          — step, pytree structure, shapes/dtypes, mesh used
        arrays.npz         — one entry per leaf, keyed by flattened path

Leaves are written via ``jax.device_get`` (gathering shards); restore
``device_put``s each leaf with the sharding of the *current* mesh, so a
checkpoint written on a 2×16×16 mesh restores onto 16×16 (or any other
divisible layout) — elastic down/up-scale. Writes are atomic
(tmp dir + rename) so a crash mid-save never corrupts the latest step.

On a real multi-host cluster the same format is written per-host with
process-local shards (commented where behaviour would differ); single-host
semantics are exact here.
"""
from __future__ import annotations

import json
import shutil
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save_checkpoint(directory, step: int, state: Dict[str, Any],
                    extra_meta: Optional[Dict] = None) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat = _flatten_with_paths(state)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    np.savez(tmp / "arrays.npz", **arrays)
    meta = {
        "step": step,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in arrays.items()},
    }
    if extra_meta:
        meta["extra"] = extra_meta
    (tmp / "meta.json").write_text(json.dumps(meta, indent=2))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic publish
    return final


def latest_step(directory) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in directory.iterdir()
                   if p.name.startswith("step_"))
    return steps[-1] if steps else None


def restore_checkpoint(directory, step: int, template: Dict[str, Any],
                       shardings=None) -> Dict[str, Any]:
    """Restore into the structure of ``template`` (shapes must match).

    ``shardings``: optional matching pytree of NamedShardings for the
    *current* mesh — this is the elastic-reshard path.
    """
    path = Path(directory) / f"step_{step:08d}"
    data = np.load(path / "arrays.npz")
    flat_template = _flatten_with_paths(template)
    missing = set(flat_template) - set(data.files)
    if missing:
        raise KeyError(f"checkpoint missing leaves: {sorted(missing)[:5]}...")
    flat_shard = _flatten_with_paths(shardings) if shardings else {}
    out = {}
    for key, tmpl in flat_template.items():
        arr = data[key]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"{key}: ckpt {arr.shape} != template {tmpl.shape}")
        if key in flat_shard and flat_shard[key] is not None:
            out[key] = jax.device_put(arr.astype(tmpl.dtype), flat_shard[key])
        else:
            out[key] = jax.numpy.asarray(arr.astype(tmpl.dtype))
    # unflatten back into template structure
    leaves_paths = jax.tree_util.tree_flatten_with_path(template)
    keys_in_order = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                              for p in path_) for path_, _ in leaves_paths[0]]
    return jax.tree_util.tree_unflatten(
        leaves_paths[1], [out[k] for k in keys_in_order])
