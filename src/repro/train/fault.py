"""Fault tolerance: step watchdog, retry-with-restore, straggler mitigation.

On a 1000+-node fleet the failure modes are (a) hard node loss — surfaces as
a collective timeout / RPC error, (b) stragglers — healthy but slow hosts,
(c) data-dependent NaN blowups. The hooks here implement the single-process
control logic; the distributed runtime (jax.distributed) surfaces (a) as
exceptions from the step function which the retry loop catches.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, List, Optional

log = logging.getLogger("repro.fault")


@dataclasses.dataclass
class WatchdogConfig:
    # step wall-time above median × factor counts as a straggler event
    straggler_factor: float = 2.5
    window: int = 32
    # consecutive straggler steps before we recommend re-layout
    trigger: int = 8


class StepWatchdog:
    """Tracks per-step wall time; flags stragglers and recommends action.

    With single-controller JAX a straggling host slows the whole step, so
    wall-time inflation *is* the straggler signal. Mitigation on a real
    fleet: evict the slow host and restore onto the remaining mesh
    (elastic restore path in checkpoint.py).
    """

    def __init__(self, cfg: WatchdogConfig = WatchdogConfig()):
        self.cfg = cfg
        self.times: List[float] = []
        self.consecutive = 0

    def record(self, seconds: float) -> Optional[str]:
        self.times.append(seconds)
        window = self.times[-self.cfg.window:]
        if len(window) < 8:
            return None
        med = sorted(window)[len(window) // 2]
        if seconds > med * self.cfg.straggler_factor:
            self.consecutive += 1
            if self.consecutive >= self.cfg.trigger:
                self.consecutive = 0
                return "relayout"  # evict straggler + elastic restore
            return "straggler"
        self.consecutive = 0
        return None


@dataclasses.dataclass
class RetryPolicy:
    max_retries: int = 3
    backoff_s: float = 1.0


def run_with_retry(step_fn: Callable, restore_fn: Callable,
                   policy: RetryPolicy = RetryPolicy()):
    """Run ``step_fn()``; on failure call ``restore_fn()`` and retry.

    Models the node-failure → checkpoint-restart path. ``restore_fn``
    must return fresh step inputs (state restored from the last
    checkpoint, possibly on a smaller mesh).
    """
    attempt = 0
    while True:
        try:
            return step_fn()
        except Exception as exc:  # noqa: BLE001 — any device/runtime failure
            attempt += 1
            if attempt > policy.max_retries:
                raise
            log.warning("step failed (%s); restore+retry %d/%d", exc,
                        attempt, policy.max_retries)
            time.sleep(policy.backoff_s * attempt)
            step_fn = restore_fn()
