"""Training loop: pjit'd train step with microbatch gradient accumulation,
clipping, LR schedule, optional error-feedback gradient compression,
checkpointing, and fault hooks.
"""
from __future__ import annotations

import dataclasses
import functools
import threading
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.api import Model
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         compression_init, cosine_schedule, ef_compress_grads)
from repro.train import checkpoint as ckpt
from repro.train.fault import StepWatchdog, WatchdogConfig


@dataclasses.dataclass
class TrainConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    microbatches: int = 1          # gradient accumulation factor
    grad_compression: bool = False
    remat: bool = True
    impl: str = "xla"
    adamw: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    async_ckpt: bool = False     # save on a background thread (device_get
    # happens synchronously; serialization/IO overlaps the next steps)


def make_train_step(model: Model, tc: TrainConfig) -> Callable:
    """Returns train_step(params, opt_state, batch[, comp_state]) pure fn."""

    def loss_of(params, batch):
        loss, metrics = model.loss_fn(params, batch, impl=tc.impl,
                                      remat=tc.remat)
        return loss, metrics

    def compute_grads(params, batch):
        if tc.microbatches <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
            return loss, metrics, grads

        n = tc.microbatches
        micro = jax.tree.map(
            lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)

        def body(acc, mb):
            loss_acc, grads_acc = acc
            (loss, _), grads = jax.value_and_grad(loss_of, has_aux=True)(
                params, mb)
            grads_acc = jax.tree.map(jnp.add, grads_acc, grads)
            return (loss_acc + loss, grads_acc), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zeros),
                                            micro)
        loss = loss_sum / n
        grads = jax.tree.map(lambda g: g / n, grads)
        return loss, {"loss": loss}, grads

    def train_step(params, opt_state, batch, comp_state=None):
        loss, metrics, grads = compute_grads(params, batch)
        if tc.grad_compression and comp_state is not None:
            grads, comp_state = ef_compress_grads(grads, comp_state)
        lr = cosine_schedule(opt_state["step"], tc.peak_lr, tc.warmup_steps,
                             tc.total_steps)
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, tc.adamw, lr)
        metrics = dict(metrics, **opt_metrics, loss=loss)
        if tc.grad_compression:
            return params, opt_state, comp_state, metrics
        return params, opt_state, metrics

    return train_step


class Trainer:
    """Single-controller trainer; mesh-aware when given shardings."""

    def __init__(self, model: Model, tc: TrainConfig, *, rng=None,
                 params=None, donate: bool = True):
        self.model = model
        self.tc = tc
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.params = params if params is not None else model.init(rng)
        self.opt_state = adamw_init(self.params, tc.adamw)
        self.comp_state = (compression_init(self.params)
                           if tc.grad_compression else None)
        step_fn = make_train_step(model, tc)
        donate_argnums = (0, 1, 3) if tc.grad_compression else (0, 1)
        self._step = jax.jit(
            step_fn, donate_argnums=donate_argnums if donate else ())
        self.watchdog = StepWatchdog(WatchdogConfig())
        self.step_num = 0
        self.history: list = []
        self._ckpt_thread: Optional[threading.Thread] = None

    def restore_if_available(self, data_pipeline=None):
        if not self.tc.ckpt_dir:
            return False
        last = ckpt.latest_step(self.tc.ckpt_dir)
        if last is None:
            return False
        state = {"params": self.params, "opt": self.opt_state}
        restored = ckpt.restore_checkpoint(self.tc.ckpt_dir, last, state)
        self.params, self.opt_state = restored["params"], restored["opt"]
        self.step_num = last
        if data_pipeline is not None:
            data_pipeline.load_state_dict({"step": last})
        return True

    def save(self):
        if not self.tc.ckpt_dir:
            return None
        state = {"params": self.params, "opt": self.opt_state}
        if not self.tc.async_ckpt:
            return ckpt.save_checkpoint(self.tc.ckpt_dir, self.step_num,
                                        state)
        # snapshot to host synchronously (donation-safe: the live buffers may
        # be donated by the next step), then serialize+publish off-thread
        self.wait_for_checkpoint()
        import numpy as np  # local to keep trainer import light
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                state)
        step = self.step_num
        self._ckpt_thread = threading.Thread(
            target=ckpt.save_checkpoint,
            args=(self.tc.ckpt_dir, step, snapshot), daemon=True)
        self._ckpt_thread.start()
        return None

    def wait_for_checkpoint(self):
        if self._ckpt_thread is not None:
            self._ckpt_thread.join()
            self._ckpt_thread = None

    def train_step(self, batch) -> Dict[str, Any]:
        t0 = time.monotonic()
        batch = jax.tree.map(jnp.asarray, batch)
        if self.tc.grad_compression:
            (self.params, self.opt_state, self.comp_state,
             metrics) = self._step(self.params, self.opt_state, batch,
                                   self.comp_state)
        else:
            self.params, self.opt_state, metrics = self._step(
                self.params, self.opt_state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        dt = time.monotonic() - t0
        flag = self.watchdog.record(dt)
        if flag:
            metrics["fault_flag"] = flag
        metrics["step_time_s"] = dt
        self.step_num += 1
        self.history.append(metrics)
        if self.tc.ckpt_dir and self.step_num % self.tc.ckpt_every == 0:
            self.save()
        return metrics

    def fit(self, pipeline, steps: int):
        for _ in range(steps):
            batch = next(pipeline)
            yield self.train_step(batch)
