from repro.serve.engine import ServeConfig, Engine  # noqa: F401
