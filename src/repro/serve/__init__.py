"""repro.serve — the batched MODEL-INFERENCE engine (prefill/decode slots
over a fixed-shape KV cache).

Not to be confused with :mod:`repro.service`, the synthesis-as-a-service
DAEMON (``python -m repro.service``): that package serves *synthesis
requests* — queued ``(workload, platform, backend, direction, search)``
jobs over a local HTTP JSON API — while this one serves *token
generation* for a loaded model. See DESIGN.md §12 for the
disambiguation.
"""
from repro.serve.engine import ServeConfig, Engine  # noqa: F401
