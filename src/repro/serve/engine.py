"""Batched serving engine: prefill + decode with slot-based continuous
batching over a fixed-shape KV cache (fixed shapes keep a single compiled
executable alive — no recompilation when requests come and go).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_seq: int = 256
    temperature: float = 0.0      # 0 = greedy
    impl: str = "xla"
    dtype: object = jnp.float32


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,)
    max_new_tokens: int
    generated: Optional[List[int]] = None


class Engine:
    """One decode step advances every active slot by one token."""

    def __init__(self, model: Model, params, sc: ServeConfig):
        self.model = model
        self.params = params
        self.sc = sc
        self.cache, _ = model.init_cache(sc.max_batch, sc.max_seq, sc.dtype)
        self.lengths = jnp.zeros((sc.max_batch,), jnp.int32)
        self.tokens = jnp.zeros((sc.max_batch, 1), jnp.int32)
        self.active = np.zeros((sc.max_batch,), bool)
        self.slot_req: List[Optional[Request]] = [None] * sc.max_batch
        self._decode = jax.jit(
            lambda p, c, t, l: model.decode_fn(p, c, t, l, impl=sc.impl))
        self._queue: List[Request] = []
        self._finished: Dict[int, List[int]] = {}

    # -- request management --------------------------------------------------
    def submit(self, req: Request):
        req.generated = []
        self._queue.append(req)

    def _admit(self):
        """Fill free slots by prefilling queued requests one at a time."""
        for slot in range(self.sc.max_batch):
            if self.active[slot] or not self._queue:
                continue
            req = self._queue.pop(0)
            ptoks = jnp.asarray(req.prompt, jnp.int32)[None]
            kw = {}
            logits, pcache, plen = self.model.prefill_fn(
                self.params, ptoks, impl=self.sc.impl, **kw)
            # graft the single-request prefill cache into the engine cache
            self.cache = jax.tree.map(
                lambda full, part: self._graft(full, part, slot),
                self.cache, pcache)
            self.lengths = self.lengths.at[slot].set(int(plen[0]))
            nxt = self._sample(logits)[0]
            self.tokens = self.tokens.at[slot, 0].set(nxt)
            req.generated.append(int(nxt))
            self.active[slot] = True
            self.slot_req[slot] = req

    def _graft(self, full, part, slot):
        """Insert request-0 of a prefill cache into engine slot ``slot``.

        Caches are stacked (L, B, S, ...) or (L, B, ...); batch is dim 1.
        """
        part0 = jax.lax.slice_in_dim(part, 0, 1, axis=1)
        if full.ndim >= 3 and part0.shape[2] != full.shape[2] \
                and part0.ndim == full.ndim:
            pad = [(0, 0)] * part0.ndim
            pad[2] = (0, full.shape[2] - part0.shape[2])
            part0 = jnp.pad(part0, pad)
        idx = [0] * full.ndim
        idx[1] = slot
        return jax.lax.dynamic_update_slice(full, part0.astype(full.dtype),
                                            tuple(idx))

    def _sample(self, logits) -> np.ndarray:
        if self.sc.temperature <= 0:
            return np.asarray(jnp.argmax(logits, axis=-1))
        key = jax.random.PRNGKey(int(np.sum(np.asarray(self.lengths))))
        return np.asarray(jax.random.categorical(
            key, logits / self.sc.temperature))

    # -- main loop -------------------------------------------------------------
    def step(self) -> int:
        """Admit + one decode step. Returns number of active slots."""
        self._admit()
        if not self.active.any():
            return 0
        logits, self.cache = self._decode(self.params, self.cache,
                                          self.tokens, self.lengths)
        nxt = self._sample(logits)
        self.lengths = self.lengths + jnp.asarray(self.active, jnp.int32)
        new_tokens = np.asarray(self.tokens).copy()
        for slot in range(self.sc.max_batch):
            if not self.active[slot]:
                continue
            req = self.slot_req[slot]
            req.generated.append(int(nxt[slot]))
            new_tokens[slot, 0] = int(nxt[slot])
            done = (len(req.generated) >= req.max_new_tokens
                    or int(self.lengths[slot]) >= self.sc.max_seq - 1)
            if done:
                self.active[slot] = False
                self.slot_req[slot] = None
                self._finished[req.rid] = req.generated
        self.tokens = jnp.asarray(new_tokens)
        return int(self.active.sum())

    def run(self, max_steps: int = 1_000) -> Dict[int, List[int]]:
        """Drain the queue; returns {rid: generated tokens}."""
        for _ in range(max_steps):
            n = self.step()
            if n == 0 and not self._queue:
                break
        done = dict(self._finished)
        self._finished.clear()
        for r in self.slot_req:  # still-active (hit max_steps)
            if r is not None:
                done[r.rid] = r.generated
        return done
