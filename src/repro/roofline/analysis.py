"""Three-term roofline analysis from compiled XLA artifacts.

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

``compiled.cost_analysis()`` is post-SPMD, i.e. per-device, so the
chips-denominator in the assignment formula is already applied.
Collective bytes are not in cost_analysis — we parse the optimized HLO and
sum result-shape bytes of every collective op.

This module doubles as the "profiler" whose output the KForge
performance-analysis agent G interprets (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple, Union

from repro.platforms import Platform, resolve_platform

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

# e.g.  "%ar = bf16[16,2048]{1,0} all-reduce(...)" or tuple shapes
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


def collective_bytes(hlo_text: str) -> Tuple[int, Dict[str, int]]:
    """Sum result-shape bytes of every collective op in optimized HLO."""
    per_op: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    total = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        matched = None
        for c in _COLLECTIVES:
            # fusion/computation labels can mention names; require call syntax
            if f" {c}(" in stripped or f"{c}-start(" in stripped:
                matched = c
                break
        if not matched:
            continue
        # result shape(s) = everything left of the '=' sign
        lhs_rhs = stripped.split("=", 1)
        if len(lhs_rhs) != 2:
            continue
        rhs = lhs_rhs[1]
        # take shapes up to the op name (the result type annotation)
        head = rhs.split(matched)[0]
        size = sum(_shape_bytes(dt, dims)
                   for dt, dims in _SHAPE_RE.findall(head))
        per_op[matched] += size
        total += size
    return total, {k: v for k, v in per_op.items() if v}


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    collective_bytes_per_device: float
    collective_breakdown: Dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_total: float
    bytes_per_device: Optional[float] = None  # from memory_analysis
    # TPU-wire estimate: CPU legalizes bf16 dots to f32 pre-SPMD, inflating
    # dot-adjacent collectives 2×; this term halves the f32 subset.
    collective_s_tpu_wire: float = 0.0
    # the platform profile the report was computed against (repro.platforms)
    platform: str = "tpu_v5e"
    peak_flops: float = 197e12

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step-time bound = max of the three overlappable terms
        (raw/conservative collective accounting)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def step_time_tpu_s(self) -> float:
        """Step-time bound with the TPU-wire collective estimate."""
        return max(self.compute_s, self.memory_s,
                   self.collective_s_tpu_wire or self.collective_s)

    @property
    def roofline_fraction_tpu(self) -> float:
        denom = self.chips * self.peak_flops * self.step_time_tpu_s
        return self.model_flops_total / denom if denom else 0.0

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × chips): remat/redundancy waste."""
        hw_total = self.hlo_flops_per_device * self.chips
        return self.model_flops_total / hw_total if hw_total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Model MFU bound: useful FLOPs / (chips × peak × step_time)."""
        denom = self.chips * self.peak_flops * self.step_time_s
        return self.model_flops_total / denom if denom else 0.0

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d.update(dominant=self.dominant, step_time_s=self.step_time_s,
                 useful_flops_fraction=self.useful_flops_fraction,
                 roofline_fraction=self.roofline_fraction,
                 step_time_tpu_s=self.step_time_tpu_s,
                 roofline_fraction_tpu=self.roofline_fraction_tpu)
        return d


def roofline_report(*, arch: str, shape: str, mesh_desc: str, chips: int,
                    cost: Dict, hlo_text: str, model_flops_total: float,
                    bytes_per_device: Optional[float] = None,
                    platform: Union[str, Platform, None] = None,
                    hw: Optional[Dict] = None) -> RooflineReport:
    """Build the three-term report.

    ``platform`` selects the hardware profile the three terms divide by
    (default: the registry's default target); ``hw`` is a raw-dict escape
    hatch that overrides it for ad-hoc what-if sweeps.

    ``compiled.cost_analysis()`` counts while-loop bodies once (verified —
    EXPERIMENTS.md §Roofline), so the terms use the loop-aware analyzer in
    :mod:`repro.roofline.hlo_cost`; the raw cost_analysis numbers are kept
    in the record for reference.
    """
    from repro.roofline import hlo_cost as _hc
    plat = resolve_platform(platform)
    if hw is None:
        hw = plat.hw
    res = _hc.analyze(hlo_text)
    flops = res.flops or float(cost.get("flops", 0.0))
    byts = res.bytes or float(cost.get("bytes accessed", 0.0))
    cbytes = res.collective_bytes
    breakdown = {k: int(v) for k, v in res.collective_breakdown.items()}
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_desc, chips=chips,
        hlo_flops_per_device=flops,
        hlo_bytes_per_device=byts,
        collective_bytes_per_device=float(cbytes),
        collective_breakdown=breakdown,
        compute_s=flops / hw["peak_flops"],
        memory_s=byts / hw["hbm_bw"],
        collective_s=cbytes / hw["ici_bw"],
        model_flops_total=model_flops_total,
        bytes_per_device=bytes_per_device,
        collective_s_tpu_wire=res.collective_bytes_tpu_wire / hw["ici_bw"],
        platform=plat.name,
        peak_flops=hw["peak_flops"],
    )
