"""Cell inspection: per-loop / per-op breakdown of the roofline terms.

This is the 'profiler drill-down' used by the §Perf hypothesis loop (and the
structured artifact the KForge analysis agent G reads for dry-run cells).
"""
from __future__ import annotations

import collections
import re
from typing import Dict, List, Tuple

from repro.roofline import hlo_cost as hc


def contributions(hlo: str, top: int = 15):
    """Returns (total HloCost, top (bytes, collective, flops) contributors).

    Contributor key: (computation, opcode, op-name-prefix); values include
    enclosing-loop multipliers.
    """
    comps = hc.parse_computations(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = hc._COMP_RE.match(line.strip())
            if m:
                entry = m.group(1)
            break
    byte_c: collections.Counter = collections.Counter()
    coll_c: collections.Counter = collections.Counter()
    flop_c: collections.Counter = collections.Counter()

    def walk(name: str, mult: float):
        comp = comps.get(name)
        if comp is None:
            return
        for op in comp.ops:
            oc = op.opcode
            if oc in hc._FREE_OPS:
                continue
            if oc == "while":
                known = re.search(r'"known_trip_count":\{"n":"(\d+)"\}',
                                  op.rest)
                trip = int(known.group(1)) if known else 1
                b = re.search(r"body=%?([\w.\-]+)", op.rest)
                if b:
                    walk(b.group(1), mult * trip)
                continue
            key = (name.split("_spmd")[0][-40:], oc,
                   re.sub(r"[.\d]+$", "", op.name))
            result_b = hc._nbytes(op.type_str)
            if oc in ("dynamic-slice", "slice", "gather"):
                nb = 2 * result_b
            elif oc == "dynamic-update-slice":
                names = hc._operand_names(op.rest)
                nb = 2 * (hc._nbytes(comp.symbols.get(names[1], ""))
                          if len(names) > 1 else result_b)
            elif oc == "scatter":
                names = hc._operand_names(op.rest)
                nb = 2 * (hc._nbytes(comp.symbols.get(names[-1], ""))
                          if names else result_b)
            elif oc in ("broadcast", "iota", "concatenate", "reverse", "pad"):
                nb = 2 * result_b
            elif oc == "fusion":
                m2 = re.search(r"calls=%?([\w.\-]+)", op.rest)
                callee = comps.get(m2.group(1)) if m2 else None
                res_adj = result_b
                if callee is not None and callee.ops:
                    root = callee.ops[-1]
                    if root.opcode == "dynamic-update-slice":
                        nr = hc._operand_names(root.rest)
                        if len(nr) > 1:
                            res_adj = 2 * hc._nbytes(
                                callee.symbols.get(nr[1], ""))
                nb = res_adj + hc._fusion_operand_bytes(op, comp, callee)
                inner = hc._cost_of(m2.group(1), comps, {}, fused=True) \
                    if m2 and m2.group(1) in comps else None
                if inner:
                    flop_c[key] += inner.flops * mult
            else:
                nb = result_b + sum(hc._nbytes(comp.symbols.get(n, ""))
                                    for n in hc._operand_names(op.rest))
            if oc == "dot":
                flop_c[key] += hc._dot_flops(op, comp) * mult
            byte_c[key] += nb * mult
            for c in hc._COLLECTIVES:
                if oc == c or oc == c + "-start":
                    coll_c[(key[0], c, op.type_str[:48])] += result_b * mult
    walk(entry, 1.0)
    return {
        "bytes": byte_c.most_common(top),
        "collective": coll_c.most_common(top),
        "flops": flop_c.most_common(top),
    }


def print_report(hlo: str, top: int = 12):
    res = hc.analyze(hlo)
    print(f"flops/dev={res.flops:.3e}  bytes/dev={res.bytes:.3e}  "
          f"coll/dev={res.collective_bytes:.3e}")
    c = contributions(hlo, top)
    print("-- top HBM traffic --")
    for (comp, oc, name), b in c["bytes"]:
        print(f"  {b/1e9:9.1f} GB  {oc:22s} {name:40s} in {comp}")
    print("-- top collectives --")
    for (comp, oc, shape), b in c["collective"]:
        print(f"  {b/1e9:9.1f} GB  {oc:18s} {shape:48s} in {comp}")
    print("-- top flops --")
    for (comp, oc, name), f in c["flops"]:
        print(f"  {f/1e12:9.2f} TF  {oc:22s} {name:40s} in {comp}")
