from repro.roofline.analysis import (  # noqa: F401
    collective_bytes, roofline_report, RooflineReport,
)
