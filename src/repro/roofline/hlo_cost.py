"""Loop-aware cost analysis over optimized HLO text.

``compiled.cost_analysis()`` counts every ``while`` body ONCE regardless of
trip count, which under-counts scan-over-layers models by ~num_layers×
(verified empirically — see EXPERIMENTS.md §Roofline). This module parses
the post-SPMD HLO, builds the computation call graph, extracts loop trip
counts from loop-condition constants, and accumulates:

  * FLOPs        — from dot ops (2 · |result| · K, K = contracted extent)
  * HBM bytes    — per top-level op ≈ one kernel: operand + result bytes
                   (fusions count their boundary, matching real HBM traffic)
  * collective bytes — result bytes of all-reduce / all-gather /
                   reduce-scatter / all-to-all / collective-permute,
                   multiplied by enclosing loop trip counts

All numbers are per-device (the HLO is the post-partitioning module).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# "  %name = <type> opcode(...), attrs" | "  ROOT %name = ..."
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*->.*\{\s*$")
_CALLEE_RE = re.compile(r"(?:to_apply|calls|body|condition|branch_computations)="
                        r"[{]?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)[}]?")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id"}


def _shapes_of(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, tuple(int(d) for d in dims.split(",") if d)))
    return out


def _nbytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shapes_of(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    rest: str  # operand list + attrs


@dataclasses.dataclass
class _Computation:
    name: str
    ops: List[_Op]
    symbols: Dict[str, str]  # op name -> result type string


def parse_computations(hlo: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_RE.match(line.strip())
            if m and "{" in line:
                cur = _Computation(m.group(1), [], {})
            continue
        s = line.strip()
        if s == "}" or s.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            name, type_str, opcode, rest = m.groups()
            op = _Op(name, type_str.strip(), opcode, rest)
            cur.ops.append(op)
            cur.symbols[name] = op.type_str
    return comps


def _operand_names(rest: str) -> List[str]:
    # operands come first, before `)`, as %name tokens
    head = rest.split(")")[0]
    return re.findall(r"%([\w.\-]+)", head)


def _dot_flops(op: _Op, comp: _Computation) -> float:
    result = _shapes_of(op.type_str)
    if not result:
        return 0.0
    n_result = 1
    for d in result[0][1]:
        n_result *= d
    # contracted extent from lhs shape + lhs_contracting_dims
    ops_ = _operand_names(op.rest)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    k = 1
    if m and ops_:
        lhs_type = comp.symbols.get(ops_[0], "")
        lhs_shapes = _shapes_of(lhs_type)
        if lhs_shapes:
            dims = lhs_shapes[0][1]
            for i in (int(x) for x in m.group(1).split(",") if x):
                if i < len(dims):
                    k *= dims[i]
    return 2.0 * n_result * k


def _trip_count(cond: _Computation) -> int:
    """jax loops compare the induction var against a constant with LT."""
    consts: Dict[str, int] = {}
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((\d+)\)", op.opcode + "(" + op.rest)
            if m:
                consts[op.name] = int(m.group(1))
    for op in cond.ops:
        if op.opcode == "compare" and "direction=LT" in op.rest:
            for name in _operand_names(op.rest):
                if name in consts:
                    return consts[name]
    # fallback: any constant in the cond
    return max(consts.values(), default=1)


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_bytes_f32: float = 0.0   # subset moved as f32 on the wire
    collective_breakdown: Dict[str, float] = dataclasses.field(
        default_factory=dict)

    def add(self, other: "HloCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        self.collective_bytes_f32 += other.collective_bytes_f32 * mult
        for k, v in other.collective_breakdown.items():
            self.collective_breakdown[k] = (
                self.collective_breakdown.get(k, 0.0) + v * mult)

    @property
    def collective_bytes_tpu_wire(self) -> float:
        """TPU-wire estimate: the host (CPU) backend legalizes every bf16 dot
        to f32 BEFORE SPMD partitioning (verified — EXPERIMENTS.md §Perf A3),
        so f32 collectives of bf16-model tensors are 2× inflated. On TPU the
        same collectives move bf16: halve the f32 subset."""
        return self.collective_bytes - self.collective_bytes_f32 / 2


def _fusion_operand_bytes(op: _Op, comp: _Computation,
                          callee: Optional[_Computation]) -> int:
    """Sum of fusion operand traffic. XLA fuses dynamic-slice into
    consumers, so an operand only consumed through slicing ops inside the
    fused computation is charged at the slice size, not the full tensor
    (per-layer parameter fetches from scan-stacked weights)."""
    names = _operand_names(op.rest)
    if callee is None:
        return sum(_nbytes(comp.symbols.get(n, "")) for n in names)
    # map parameter number -> counted bytes inside the fused computation
    param_cost: Dict[int, int] = {}
    param_name_to_idx: Dict[str, int] = {}
    for o in callee.ops:
        if o.opcode == "parameter":
            m = re.match(r"(\d+)", o.rest)
            if m:
                param_name_to_idx[o.name] = int(m.group(1))
                param_cost[int(m.group(1))] = _nbytes(o.type_str)
    slicing = ("dynamic-slice", "slice", "gather")
    for pname, idx in param_name_to_idx.items():
        consumers = [o for o in callee.ops
                     if pname in _operand_names(o.rest)]
        if not consumers:
            continue
        if all(c.opcode in slicing and _operand_names(c.rest)
               and _operand_names(c.rest)[0] == pname for c in consumers):
            param_cost[idx] = max(_nbytes(c.type_str) for c in consumers)
        elif all(c.opcode == "dynamic-update-slice"
                 and _operand_names(c.rest)
                 and _operand_names(c.rest)[0] == pname for c in consumers):
            # in-place update destination: aliased, only the update region
            # is touched (charged via the fusion result adjustment below)
            param_cost[idx] = 0
    total = 0
    for i, n in enumerate(names):
        full = _nbytes(comp.symbols.get(n, ""))
        total += min(full, param_cost.get(i, full)) if i in param_cost \
            else full
    return total


def _cost_of(comp_name: str, comps: Dict[str, _Computation],
             cache: Dict[str, HloCost], *,
             fused: bool = False) -> HloCost:
    if comp_name in cache:
        return cache[comp_name]
    comp = comps.get(comp_name)
    total = HloCost()
    if comp is None:
        cache[comp_name] = total
        return total
    cache[comp_name] = total  # break cycles defensively
    for op in comp.ops:
        oc = op.opcode
        if oc in _FREE_OPS:
            continue
        if oc == "while":
            callees = re.search(r"condition=%?([\w.\-]+)", op.rest)
            body = re.search(r"body=%?([\w.\-]+)", op.rest)
            known = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.rest)
            if known:
                trip = int(known.group(1))
            elif callees and callees.group(1) in comps:
                trip = _trip_count(comps[callees.group(1)])
            else:
                trip = 1
            if body:
                total.add(_cost_of(body.group(1), comps, cache), trip)
            if callees:
                total.add(_cost_of(callees.group(1), comps, cache), trip)
            continue
        if oc == "conditional":
            for m in re.finditer(r"%([\w.\-]+)", op.rest.split(")", 1)[-1]):
                if m.group(1) in comps:
                    total.add(_cost_of(m.group(1), comps, cache))
            continue
        if oc in ("fusion", "call", "custom-call", "reduce", "sort", "map",
                  "scatter", "select-and-scatter", "reduce-window"):
            m = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", op.rest)
            if m and m.group(1) in comps:
                inner = _cost_of(m.group(1), comps, cache, fused=True)
                # fused computations: count FLOPs from inside, but HBM
                # traffic only at the fusion boundary (below)
                total.flops += inner.flops
                total.collective_bytes += inner.collective_bytes
                for k, v in inner.collective_breakdown.items():
                    total.collective_breakdown[k] = (
                        total.collective_breakdown.get(k, 0.0) + v)
        if oc == "dot":
            total.flops += _dot_flops(op, comp)
        # HBM traffic: result + operands (the fusion boundary is the kernel
        # boundary). Inside fused computations only dots/collectives count.
        # Slicing ops only touch the slice, not the full operand; in-place
        # update ops (aliased) touch ~2× the update region.
        if not fused:
            result_b = _nbytes(op.type_str)
            if oc in ("dynamic-slice", "slice", "gather"):
                nbytes = 2 * result_b
            elif oc == "dynamic-update-slice":
                names = _operand_names(op.rest)
                upd = _nbytes(comp.symbols.get(names[1], "")) if \
                    len(names) > 1 else result_b
                nbytes = 2 * upd
            elif oc == "scatter":
                names = _operand_names(op.rest)
                upd = _nbytes(comp.symbols.get(names[-1], "")) if names \
                    else result_b
                nbytes = 2 * upd
            elif oc in ("broadcast", "iota", "concatenate", "reverse", "pad"):
                nbytes = 2 * result_b
            elif oc == "fusion":
                m2 = re.search(r"calls=%?([\w.\-]+)", op.rest)
                callee = comps.get(m2.group(1)) if m2 else None
                res_adj = result_b
                if callee is not None and callee.ops:
                    root = callee.ops[-1]
                    if root.opcode == "dynamic-update-slice":
                        names_r = _operand_names(root.rest)
                        if len(names_r) > 1:
                            # in-place DUS root: write only the update region
                            res_adj = 2 * _nbytes(
                                callee.symbols.get(names_r[1], ""))
                nbytes = res_adj + _fusion_operand_bytes(op, comp, callee)
            else:
                nbytes = result_b
                for name in _operand_names(op.rest):
                    nbytes += _nbytes(comp.symbols.get(name, ""))
            total.bytes += nbytes
        for c in _COLLECTIVES:
            if oc == c or oc == c + "-start":
                cb = _nbytes(op.type_str)
                total.collective_bytes += cb
                if "f32[" in op.type_str:
                    total.collective_bytes_f32 += cb
                total.collective_breakdown[c] = (
                    total.collective_breakdown.get(c, 0.0) + cb)
    cache[comp_name] = total
    return total


def analyze(hlo: str) -> HloCost:
    comps = parse_computations(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line.strip())
            if m:
                entry = m.group(1)
            break
    if entry is None:
        # fall back to the largest computation
        entry = max(comps, key=lambda n: len(comps[n].ops)) if comps else ""
    cache: Dict[str, HloCost] = {}
    return _cost_of(entry, comps, cache)
