"""AdamW with fp32 master weights and ZeRO-friendly state layout.

Optimizer state mirrors the parameter pytree leaf-for-leaf, so the launcher
shards it with the *same* PartitionSpecs as the parameters (params are
FSDP-sharded → states are FSDP-sharded → ZeRO-3 for free).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    master_fp32: bool = True


def adamw_init(params, cfg: AdamWConfig):
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "mu": jax.tree.map(zeros32, params),
        "nu": jax.tree.map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.master_fp32:
        # copy=True: params may already be f32 and astype would alias the
        # buffer, breaking donation in the jitted train step.
        state["master"] = jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
    return state


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: AdamWConfig,
                 lr: jax.Array) -> Tuple[Any, Any, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9)) \
        if cfg.clip_norm else jnp.ones(())

    def upd(g, mu, nu, master):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mu_hat = mu / (1 - cfg.b1 ** step.astype(jnp.float32))
        nu_hat = nu / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps) \
            + cfg.weight_decay * master
        master = master - lr * delta
        return mu, nu, master

    masters = state.get("master") or jax.tree.map(
        lambda p: p.astype(jnp.float32), params)
    flat_g, tdef = jax.tree.flatten(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    flat_ma = tdef.flatten_up_to(masters)
    new_mu, new_nu, new_ma = [], [], []
    for g, mu, nu, ma in zip(flat_g, flat_mu, flat_nu, flat_ma):
        mu, nu, ma = upd(g, mu, nu, ma)
        new_mu.append(mu)
        new_nu.append(nu)
        new_ma.append(ma)
    flat_p = tdef.flatten_up_to(params)
    new_params = tdef.unflatten(
        [m.astype(p.dtype) for m, p in zip(new_ma, flat_p)])
    new_state = {"mu": tdef.unflatten(new_mu), "nu": tdef.unflatten(new_nu),
                 "step": step}
    if "master" in state:
        new_state["master"] = tdef.unflatten(new_ma)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
