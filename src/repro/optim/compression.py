"""Gradient compression with error feedback (1000+-node DP reduce trick).

int8 per-tensor-scaled quantization with an error-feedback residual buffer:
the quantization error of step t is added back to the gradient of step t+1,
so the *accumulated* update is unbiased and convergence matches fp32 (Seide
et al. / Karimireddy et al.). On a real multi-pod deployment the quantized
tensor is what crosses the DCN between pods (8× fewer bytes on the slowest
link); in-pod reduction stays bf16.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CompressionState:
    error: Any  # pytree of f32 residuals, mirrors grads


def compression_init(params) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))


def compress_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """f32 -> (int8 values, f32 scale). Symmetric per-tensor scaling."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_grads(grads, state: CompressionState
                      ) -> Tuple[Any, CompressionState]:
    """Apply error-feedback int8 compression to a gradient pytree.

    Returns (decompressed grads as seen post-reduce, new residual state).
    """
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = compress_int8(corrected)
        deq = decompress_int8(q, s)
        return deq, corrected - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(state.error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = tdef.unflatten([o[0] for o in outs])
    new_e = tdef.unflatten([o[1] for o in outs])
    return new_g, CompressionState(error=new_e)
