from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update  # noqa: F401
from repro.optim.schedule import cosine_schedule, linear_warmup  # noqa: F401
from repro.optim.compression import (  # noqa: F401
    CompressionState, compress_int8, decompress_int8, ef_compress_grads,
    compression_init,
)
