"""zamba2-7b — hybrid Mamba2 backbone + shared attention blocks [arXiv:2411.15242; unverified].

81 Mamba2 layers; a single weight-shared GQA attention block is applied after
every 6th Mamba2 layer (13 applications), Zamba-style. Sub-quadratic: Mamba2
state is O(1) per token, shared-attention KV caches are sequence-sharded for
the long_500k decode cell.
"""
from repro.configs.base import ModelConfig, SSMConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm=SSMConfig(state_dim=64, expand=2, conv_width=4, chunk=256),
    attn_period=6,
    subquadratic=True,
    source="arXiv:2411.15242; unverified",
))
