"""Config system: model architecture + input-shape + parallelism configs.

Every assigned architecture registers a :class:`ModelConfig` via
``@register_arch``.  Shape cells (train_4k / prefill_32k / decode_32k /
long_500k) are :class:`ShapeConfig` instances shared across the LM family.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell.

    ``kind`` is 'train' (lower train_step), 'prefill' (serve prefill) or
    'decode' (serve_step: one new token against a KV cache of ``seq_len``).
    """

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode
    subquadratic_only: bool = False


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode", subquadratic_only=True)

LM_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)

SHAPES: Dict[str, ShapeConfig] = {s.name: s for s in LM_SHAPES}


# ---------------------------------------------------------------------------
# Model configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    num_shared_experts: int = 0
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64
    conv_width: int = 4
    expand: int = 2
    chunk: int = 256          # SSD chunk length
    num_heads: int = 0        # mamba2 heads; 0 -> derived


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Auxiliary encoder (whisper audio encoder / internvl vision tower stub).

    Frontends are STUBS per the assignment: ``input_specs()`` provides
    precomputed frame/patch embeddings of shape (batch, num_positions, d_model).
    """

    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    d_ff: int = 0
    num_positions: int = 0    # 1500 audio frames / 256 vision patches


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0         # 0 -> d_model // num_heads
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    attn_period: int = 0      # hybrid: shared attention applied after every N ssm layers
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    subquadratic: bool = False   # supports long_500k decode
    has_decoder: bool = True     # encoder-only archs skip decode shapes
    source: str = ""             # citation tag
    # training knobs (overridable per shape at launch)
    remat: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.resolved_head_dim
        n = V * d  # token embedding
        if not self.tie_embeddings:
            n += V * d  # lm head
        attn = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
            + self.num_heads * hd * d
        dense_mlp = 3 * d * self.d_ff  # SwiGLU: gate, up, down
        if self.family in ("dense", "vlm"):
            n += L * (attn + dense_mlp + 2 * d)
        elif self.family == "moe":
            m = self.moe
            expert = 3 * d * m.expert_d_ff
            shared = 3 * d * m.shared_d_ff * m.num_shared_experts
            router = d * m.num_experts
            n += L * (attn + m.num_experts * expert + shared + router + 2 * d)
        elif self.family == "ssm":
            # rwkv6: time-mix (~4 d^2 with lora decays) + channel-mix (~2*d*d_ff... use 3 for swiglu-like)
            n += L * (4 * d * d + 2 * d * self.d_ff + 2 * d)
        elif self.family == "hybrid":
            s = self.ssm
            dinner = s.expand * d
            mamba = d * 2 * dinner + dinner * d + dinner * (2 * s.state_dim) \
                + s.conv_width * dinner
            n += L * (mamba + 2 * d)
            n_attn = max(1, self.num_layers // max(1, self.attn_period))
            n += attn + 2 * d  # shared attention block counted once
            del n_attn
        elif self.family == "encdec":
            e = self.encoder
            enc_attn = 4 * e.d_model * e.num_heads * (e.d_model // e.num_heads)
            enc_mlp = 2 * e.d_model * e.d_ff
            n += e.num_layers * (enc_attn + enc_mlp + 2 * e.d_model)
            # decoder: self-attn + cross-attn + mlp (gelu, 2 mats)
            n += L * (2 * attn + 2 * d * self.d_ff + 3 * d)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top_k + shared)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.num_layers
        m = self.moe
        inactive = L * 3 * d * m.expert_d_ff * (m.num_experts - m.top_k)
        return self.param_count() - inactive

    def shapes(self) -> Tuple[ShapeConfig, ...]:
        """Shape cells applicable to this arch (skips recorded elsewhere)."""
        out = []
        for s in LM_SHAPES:
            if s.kind == "decode" and not self.has_decoder:
                continue
            if s.subquadratic_only and not self.subquadratic:
                continue
            out.append(s)
        return tuple(out)

    def skipped_shapes(self) -> Tuple[Tuple[ShapeConfig, str], ...]:
        out = []
        for s in LM_SHAPES:
            if s.kind == "decode" and not self.has_decoder:
                out.append((s, "encoder-only arch has no decode step"))
            elif s.subquadratic_only and not self.subquadratic:
                out.append((s, "pure full-attention arch; long_500k requires sub-quadratic attention"))
        return tuple(out)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_ARCHS: Dict[str, ModelConfig] = {}


def register_arch(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in _ARCHS:
        raise ValueError(f"duplicate arch {cfg.name}")
    _ARCHS[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    from repro import configs  # noqa: F401  (ensure registration ran)
    if name not in _ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCHS)}")
    return _ARCHS[name]


def list_archs():
    from repro import configs  # noqa: F401
    return sorted(_ARCHS)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test-sized config of the same family (tiny dims, same topology)."""
    changes: Dict[str, object] = dict(
        num_layers=min(cfg.num_layers, 2 if cfg.family != "hybrid" else 4),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2),
        head_dim=32,
        d_ff=256,
        vocab_size=512,
    )
    if cfg.moe is not None:
        # capacity_factor high enough that no token is dropped at smoke
        # scale: keeps prefill/decode exactly consistent in tests.
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=min(cfg.moe.num_experts, 8),
            top_k=min(cfg.moe.top_k, 2), expert_d_ff=64,
            shared_d_ff=64 if cfg.moe.num_shared_experts else 0,
            capacity_factor=8.0)
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(cfg.ssm, state_dim=16, chunk=16)
    if cfg.encoder is not None:
        changes["encoder"] = EncoderConfig(
            num_layers=2, d_model=128, num_heads=4, d_ff=256, num_positions=16)
    if cfg.attn_period:
        changes["attn_period"] = 2
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)
