"""internvl2-2b — InternViT (stub frontend) + InternLM2 backbone [arXiv:2404.16821; hf].

The vision tower is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (batch, 256, d_model) projected into the LM space.
"""
from repro.configs.base import EncoderConfig, ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    encoder=EncoderConfig(num_positions=256),   # patch-embedding stub only
    source="arXiv:2404.16821; hf",
))
