"""whisper-base — encoder-decoder, conv frontend STUB [arXiv:2212.04356; unverified].

The conv1d/mel frontend is a stub per the assignment: ``input_specs()``
provides precomputed frame embeddings (batch, 1500, d_model) as the encoder
input. 6 encoder + 6 decoder layers, d_model=512, 8 heads, GELU MLP.
"""
from repro.configs.base import EncoderConfig, ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="whisper-base",
    family="encdec",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    encoder=EncoderConfig(
        num_layers=6, d_model=512, num_heads=8, d_ff=2048, num_positions=1500),
    rope_theta=0.0,   # whisper uses learned/sinusoidal positions, not RoPE
    source="arXiv:2212.04356; unverified",
))
