"""Architecture config registry. Importing this package registers all archs."""
from repro.configs.base import (  # noqa: F401
    LM_SHAPES, SHAPES, EncoderConfig, MoEConfig, ModelConfig, SSMConfig,
    ShapeConfig, get_config, list_archs, reduced, register_arch,
)

# Register every assigned architecture.
from repro.configs import (  # noqa: F401
    deepseek_67b, yi_34b, phi3_medium_14b, starcoder2_7b, rwkv6_7b,
    internvl2_2b, zamba2_7b, whisper_base, moonshot_v1_16b_a3b,
    qwen2_moe_a2_7b,
)

ALL_ARCHS = list_archs()
