"""All-pairs cross-platform transfer matrix (DESIGN.md §2).

The §6.2 transfer sweep (:mod:`repro.campaign.transfer`) measures ONE
ordered platform pair. The matrix engine runs it over **every ordered pair
of registered platforms** and aggregates the per-pair warm-minus-cold
fast_1 uplift into a heat-map — the headline cross-target artifact of the
paper's platform-agnosticism claim.

Work sharing keeps N platforms at N + N·(N−1) campaigns instead of the
naive 3·N·(N−1):

* one **base campaign per platform** doubles as the *source* leg of every
  pair it feeds and the *cold* leg of every pair that targets it (both are
  the same ``use_reference=False`` configuration on that platform);
* one shared :class:`VerificationCache` serves every leg — the platform is
  part of the verification content address, so legs never collide, and a
  candidate two legs both visit is verified once;
* one shared :class:`Scheduler` (worker pool / timeout policy) runs every
  campaign, instead of each leg sizing its own pool;
* warm legs are tagged ``LoopConfig.transfer_from``, so a shared event log
  keeps (A → B) and (C → B) warm results apart and resume works per leg.

A leg that dies (platform misconfiguration, scheduler failure) is isolated
into its :class:`MatrixLeg` ``error`` — the matrix completes and the
heat-map renders the hole instead of crashing.

CLI: ``python -m repro.campaign --matrix [--platforms A B ...]``;
benchmark: ``benchmarks/bench_transfer_matrix.py``.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from repro.campaign.cache import VerificationCache
from repro.campaign.runner import CampaignResult, run_campaign
from repro.campaign.scheduler import Scheduler
from repro.campaign.transfer import (TransferSweepResult, harvest_hints,
                                     reference_sources)
from repro.core.refinement import LoopConfig
from repro.core.synthesis import TemplateSearchBackend
from repro.core.workload import Workload
from repro.platforms import available_platforms, resolve_platform


def all_pairs(platforms: Sequence[str]) -> List[Tuple[str, str]]:
    """Every ordered (source, target) pair of distinct platforms, in
    deterministic (sorted-source, sorted-target) order."""
    names = sorted(platforms)
    return [(a, b) for a in names for b in names if a != b]


@dataclasses.dataclass
class MatrixLeg:
    """One ordered (source → target) cell of the transfer matrix.

    Exactly one of ``sweep`` / ``error`` is set: a completed leg carries the
    full :class:`TransferSweepResult`; a failed one carries the error string
    so the matrix can render around the hole.
    """
    from_platform: str
    to_platform: str
    sweep: Optional[TransferSweepResult] = None
    error: Optional[str] = None

    @property
    def ok(self) -> bool:
        return self.error is None and self.sweep is not None

    @property
    def uplift_fast1(self) -> Optional[float]:
        """Total warm − cold fast_1 of this leg (None on a failed leg)."""
        if not self.ok:
            return None
        return self.sweep.report()["total"]["uplift_fast1"]


@dataclasses.dataclass
class TransferMatrix:
    """All-pairs transfer result: one :class:`MatrixLeg` per ordered pair.

    ``platforms`` is the sorted platform list the matrix ran over; ``legs``
    maps every ordered pair from :func:`all_pairs` to its leg. ``cache`` is
    the single verification cache all legs shared (its hit/miss counters
    are the matrix's work-sharing telemetry).
    """
    platforms: List[str]
    legs: Dict[Tuple[str, str], MatrixLeg]
    cache: VerificationCache
    log_path: Optional[Path] = None

    def leg(self, from_platform: str, to_platform: str) -> MatrixLeg:
        return self.legs[(from_platform, to_platform)]

    def uplift(self, from_platform: str, to_platform: str) -> Optional[float]:
        """fast_1 uplift of one ordered pair (None if that leg failed)."""
        return self.legs[(from_platform, to_platform)].uplift_fast1

    @property
    def n_failed(self) -> int:
        return sum(1 for leg in self.legs.values() if not leg.ok)

    def report(self) -> Dict[str, Any]:
        """Aggregate dict: per-pair leg reports (or errors), the best and
        worst completed pairs by fast_1 uplift, and cache stats."""
        pairs: Dict[str, Any] = {}
        for (src, dst), leg in sorted(self.legs.items()):
            key = f"{src}->{dst}"
            pairs[key] = leg.sweep.report() if leg.ok \
                else {"error": leg.error}
        done = [(k, v["total"]["uplift_fast1"])
                for k, v in pairs.items() if "error" not in v]
        return {
            "platforms": list(self.platforms),
            "n_pairs": len(self.legs),
            "n_failed": self.n_failed,
            "pairs": pairs,
            "best_pair": max(done, key=lambda kv: kv[1])[0] if done else None,
            "worst_pair": min(done, key=lambda kv: kv[1])[0] if done else None,
            "cache": self.cache.stats(),
        }

    # -- heat-map rendering --------------------------------------------------

    def _cell(self, src: str, dst: str) -> str:
        if src == dst:
            return "·"
        leg = self.legs.get((src, dst))
        if leg is None or not leg.ok:
            return "ERR"
        return f"{leg.uplift_fast1:+.3f}"

    def heatmap_text(self) -> str:
        """ASCII heat-map: rows = source platform, columns = target,
        cells = total fast_1 uplift (warm − cold); '·' diagonal, 'ERR' for
        a failed leg."""
        names = list(self.platforms)
        width = max([len("from \\ to")] + [len(n) for n in names])
        cell_w = max(8, max(len(n) for n in names))
        lines = [
            f"transfer matrix — fast_1 uplift (warm − cold), "
            f"{len(names)} platforms, {len(self.legs)} pairs"
            + (f", {self.n_failed} failed" if self.n_failed else ""),
        ]
        header = "from \\ to".ljust(width) + "  " + "  ".join(
            n.rjust(cell_w) for n in names)
        lines.append(header)
        lines.append("-" * len(header))
        for src in names:
            row = src.ljust(width) + "  " + "  ".join(
                self._cell(src, dst).rjust(cell_w) for dst in names)
            lines.append(row)
        return "\n".join(lines)

    def heatmap_markdown(self) -> str:
        """The same heat-map as a GitHub-flavored markdown table."""
        names = list(self.platforms)
        lines = ["| from \\ to | " + " | ".join(names) + " |",
                 "|---" * (len(names) + 1) + "|"]
        for src in names:
            cells = " | ".join(self._cell(src, dst) for dst in names)
            lines.append(f"| **{src}** | {cells} |")
        return "\n".join(lines)


def run_transfer_matrix(workloads: Sequence[Workload],
                        platforms: Optional[Sequence[str]] = None, *,
                        loop: Optional[LoopConfig] = None,
                        cache: Optional[VerificationCache] = None,
                        max_workers: int = 4,
                        timeout_s: Optional[float] = None,
                        log_path: Optional[Union[str, Path]] = None,
                        resume: bool = True) -> TransferMatrix:
    """Run the §6.2 transfer sweep over every ordered platform pair.

    Args:
        workloads: KernelBench workloads, shared by every leg.
        platforms: platform names to cross (≥ 2); defaults to every
            registered platform (:func:`repro.platforms.available_platforms`).
        loop: base loop configuration; ``platform`` / ``use_reference`` /
            ``transfer_from`` are overridden per leg.
        cache: shared verification cache for ALL legs (open a persistent
            one with ``VerificationCache.open`` to share across processes
            and reruns); a fresh in-memory cache when omitted.
        max_workers / timeout_s: sizing of the ONE worker pool every
            campaign leg runs on.
        log_path / resume: one JSONL event log shared by every leg
            (platform- and transfer_from-tagged); resuming skips whatever
            legs already finished.

    Returns:
        A :class:`TransferMatrix` whose ``legs`` cover exactly
        ``all_pairs(platforms)``. Per-leg failures are recorded, never
        raised.

    Base campaigns run first, one per platform — each is reused as the
    source leg of every pair it feeds and the cold leg of every pair that
    targets it — then the N·(N−1) warm legs.
    """
    names = sorted(platforms) if platforms is not None \
        else available_platforms()
    if len(names) < 2:
        raise ValueError(f"transfer matrix needs >= 2 platforms, got {names}")
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate platforms in {names}")
    base = loop or LoopConfig()
    cache = cache if cache is not None else VerificationCache()
    sched = Scheduler(max_workers=max_workers, timeout_s=timeout_s)
    common = dict(cache=cache, max_workers=max_workers, timeout_s=timeout_s,
                  log_path=log_path, resume=resume, scheduler=sched)

    # Phase 1 — one base campaign per platform: source AND cold leg at once.
    campaigns: Dict[str, CampaignResult] = {}
    hints: Dict[str, Dict[str, Dict[str, Any]]] = {}
    refs: Dict[str, Dict[str, Tuple[str, str]]] = {}
    errors: Dict[str, str] = {}
    for name in names:
        try:
            plat = resolve_platform(name)
            result = run_campaign(
                workloads,
                dataclasses.replace(base, platform=plat.name,
                                    use_reference=False, transfer_from=None),
                **common)
            campaigns[name] = result
            hints[name] = harvest_hints(result)
            refs[name] = reference_sources(result, plat.name)
        except Exception as exc:  # noqa: BLE001 — isolate per platform
            errors[name] = f"{type(exc).__name__}: {exc}"

    # Phase 2 — warm legs for every ordered pair.
    legs: Dict[Tuple[str, str], MatrixLeg] = {}
    for src, dst in all_pairs(names):
        fail = errors.get(src) or errors.get(dst)
        if fail:
            legs[(src, dst)] = MatrixLeg(src, dst, error=fail)
            continue
        try:
            dst_plat = resolve_platform(dst)
            warm = run_campaign(
                workloads,
                dataclasses.replace(base, platform=dst_plat.name,
                                    use_reference=True, transfer_from=src),
                agent_factory=lambda: TemplateSearchBackend(
                    platform=dst_plat, reference_hints=hints[src]),
                **common)
            sweep = TransferSweepResult(
                from_platform=src, to_platform=dst, source=campaigns[src],
                cold=campaigns[dst], warm=warm, hints=hints[src],
                references=refs[src],
                log_path=Path(log_path) if log_path else None)
            legs[(src, dst)] = MatrixLeg(src, dst, sweep=sweep)
        except Exception as exc:  # noqa: BLE001 — isolate per leg
            legs[(src, dst)] = MatrixLeg(
                src, dst, error=f"{type(exc).__name__}: {exc}")

    return TransferMatrix(platforms=names, legs=legs, cache=cache,
                          log_path=Path(log_path) if log_path else None)
