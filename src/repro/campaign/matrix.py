"""All-pairs cross-platform transfer matrix as a dependency-aware job graph
(DESIGN.md §2).

The §6.2 transfer sweep (:mod:`repro.campaign.transfer`) measures ONE
ordered platform pair. The matrix engine runs it over **every ordered pair
of registered platforms** and aggregates two warm-minus-cold signals per
pair into heat-maps — fast_1 uplift, and the non-saturating
iterations-to-correct delta — the headline cross-target artifact of the
paper's platform-agnosticism claim.

Execution model: ONE job graph on a two-level scheduler, not two
sequential for-loops. All N base campaigns are submitted at once and run
concurrently; every warm leg is submitted immediately with
``after=(base[src], base[dst])`` edges, so it starts the moment its two
base campaigns resolve — while unrelated bases are still running. Sizing:

* ``matrix_workers`` — how many campaign legs may be in flight at once
  (the graph scheduler's budget);
* ``leg_workers`` — the total workload-verification budget, ONE shared
  :class:`Scheduler` every in-flight leg fans its workloads onto (the
  scheduler's slot semaphore is global to the instance, so concurrent
  campaigns share it instead of each spawning its own pool).

Work sharing keeps N platforms at N + N·(N−1) campaigns instead of the
naive 3·N·(N−1):

* one **base campaign per platform** doubles as the *source* leg of every
  pair it feeds and the *cold* leg of every pair that targets it (both are
  the same ``use_reference=False`` configuration on that platform);
* one shared :class:`VerificationCache` serves every leg — the platform is
  part of the verification content address, so legs never collide, and a
  candidate two legs both visit is verified once;
* warm legs are tagged ``LoopConfig.transfer_from``, so a shared event log
  keeps (A → B) and (C → B) warm results apart and resume works per leg.

Failure isolation: a leg that dies (platform misconfiguration, campaign
crash) is isolated into its :class:`MatrixLeg` ``error``. A warm leg whose
base campaign(s) failed records *which* platform's base failed — both
names when both failed — instead of running on garbage.

Isolation mode: ``isolation="process"`` (CLI ``--isolate``) runs every leg
in a forked child process, so ``timeout_s`` bounds each leg and a hung leg
is actually SIGKILL-ed instead of abandoned. The trade-offs (picklable
results, per-leg cache objects constructed post-fork, file-backed sharing
only) are documented on :class:`repro.campaign.Scheduler`; pass a
*persistent* cache (``--cache-path``) to keep cross-leg verification
sharing through the JSONL file. One more fork caveat: the parent must not
have executed jax computations before the matrix runs — the XLA runtime's
threads and locks do not survive a fork and the children deadlock. The
``--isolate`` CLI path satisfies this by construction (all verification
happens inside the leg children); a long-lived driver process that already
ran jax should shell out instead.

Backends: the default legs run the offline ``TemplateSearchBackend``.
``backend="llm"`` (CLI ``--backend llm``) fans the SAME job graph over
``LLMBackend`` sessions (``repro.llm``): base legs prompt cold, warm legs
inject the source base's rendered references per leg, and all sessions
share one transport / rate limiter / usage meter — a throttled session
yields its verification slot (``Scheduler.yielding``), so LLM pacing never
shrinks the worker budget. See ``docs/llm_backends.md``.

CLI: ``python -m repro.campaign --matrix [--platforms A B ...]
[--matrix-workers N] [--leg-workers N] [--isolate] [--backend llm]``;
benchmark: ``benchmarks/bench_transfer_matrix.py``.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from repro.campaign.cache import VerificationCache
from repro.campaign.runner import CampaignResult, run_campaign
from repro.campaign.scheduler import Scheduler
from repro.campaign.transfer import (TransferSweepResult, harvest_hints,
                                     reference_sources)
from repro.core.evalio import ExecutableCache, WorkloadIOCache
from repro.core.refinement import LoopConfig
from repro.core.synthesis import TemplateSearchBackend
from repro.core.workload import Workload
from repro.platforms import available_platforms, resolve_platform

HEATMAP_METRICS = ("uplift_fast1", "delta_iters")


def all_pairs(platforms: Sequence[str]) -> List[Tuple[str, str]]:
    """Every ordered (source, target) pair of distinct platforms, in
    deterministic (sorted-source, sorted-target) order."""
    names = sorted(platforms)
    return [(a, b) for a in names for b in names if a != b]


@dataclasses.dataclass
class MatrixLeg:
    """One ordered (source → target) cell of the transfer matrix.

    Exactly one of ``sweep`` / ``error`` is set: a completed leg carries the
    full :class:`TransferSweepResult`; a failed one carries the error string
    so the matrix can render around the hole.
    """
    from_platform: str
    to_platform: str
    sweep: Optional[TransferSweepResult] = None
    error: Optional[str] = None

    @property
    def ok(self) -> bool:
        return self.error is None and self.sweep is not None

    @property
    def uplift_fast1(self) -> Optional[float]:
        """Total warm − cold fast_1 of this leg (None on a failed leg)."""
        if not self.ok:
            return None
        return self.sweep.report()["total"]["uplift_fast1"]

    @property
    def delta_iters(self) -> Optional[float]:
        """Mean iterations-to-correct delta (warm − cold) of this leg:
        negative means the transferred reference reached correctness in
        fewer iterations. None on a failed leg or when either leg never
        produced a correct workload."""
        if not self.ok:
            return None
        return self.sweep.report()["total"]["iters_to_correct"]["delta"]


@dataclasses.dataclass
class TransferMatrix:
    """All-pairs transfer result: one :class:`MatrixLeg` per ordered pair.

    ``platforms`` is the sorted platform list the matrix ran over; ``legs``
    maps every ordered pair from :func:`all_pairs` to its leg. ``cache`` is
    the single verification cache all legs shared (its hit/miss counters
    are the matrix's work-sharing telemetry). ``telemetry`` is the job
    graph's execution record: peak concurrent legs plus per-job
    start/finish stamps — what overlap assertions read.
    """
    platforms: List[str]
    legs: Dict[Tuple[str, str], MatrixLeg]
    cache: VerificationCache
    log_path: Optional[Path] = None
    telemetry: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # The fast-path caches every thread-mode leg shared (DESIGN.md §4):
    # io_cache.oracle_computes staying strictly below legs × workloads is
    # the cross-leg-sharing proof. None under process isolation, where each
    # leg builds its own inside the forked child.
    io_cache: Optional[WorkloadIOCache] = None
    exe_cache: Optional[ExecutableCache] = None

    def leg(self, from_platform: str, to_platform: str) -> MatrixLeg:
        return self.legs[(from_platform, to_platform)]

    def uplift(self, from_platform: str, to_platform: str) -> Optional[float]:
        """fast_1 uplift of one ordered pair (None if that leg failed)."""
        return self.legs[(from_platform, to_platform)].uplift_fast1

    @property
    def n_failed(self) -> int:
        return sum(1 for leg in self.legs.values() if not leg.ok)

    def report(self) -> Dict[str, Any]:
        """Aggregate dict: per-pair leg reports (or errors), the best and
        worst completed pairs by fast_1 uplift, cache stats, and the job
        graph telemetry."""
        pairs: Dict[str, Any] = {}
        for (src, dst), leg in sorted(self.legs.items()):
            key = f"{src}->{dst}"
            pairs[key] = leg.sweep.report() if leg.ok \
                else {"error": leg.error}
        done = [(k, v["total"]["uplift_fast1"])
                for k, v in pairs.items() if "error" not in v]
        return {
            "platforms": list(self.platforms),
            "n_pairs": len(self.legs),
            "n_failed": self.n_failed,
            "pairs": pairs,
            "best_pair": max(done, key=lambda kv: kv[1])[0] if done else None,
            "worst_pair": min(done, key=lambda kv: kv[1])[0] if done else None,
            "cache": self.cache.stats(),
            "io_cache": self.io_cache.stats() if self.io_cache else None,
            "exe_cache": self.exe_cache.stats() if self.exe_cache else None,
            "telemetry": self.telemetry,
        }

    # -- heat-map rendering --------------------------------------------------

    def _cell(self, src: str, dst: str,
              metric: str = "uplift_fast1") -> str:
        if src == dst:
            return "·"
        leg = self.legs.get((src, dst))
        if leg is None or not leg.ok:
            return "ERR"
        value = (leg.uplift_fast1 if metric == "uplift_fast1"
                 else leg.delta_iters)
        if value is None:       # metric undefined (e.g. nothing correct)
            return "n/a"
        return f"{value:+.3f}" if metric == "uplift_fast1" \
            else f"{value:+.2f}"

    _TITLES = {
        "uplift_fast1": "fast_1 uplift (warm − cold)",
        "delta_iters": "iterations-to-correct delta (warm − cold)",
    }

    def heatmap_text(self, metric: str = "uplift_fast1") -> str:
        """ASCII heat-map: rows = source platform, columns = target.

        ``metric`` selects the cell value: ``"uplift_fast1"`` (total warm −
        cold fast_1) or ``"delta_iters"`` (mean warm − cold iterations to
        the first correct result — negative is better, and unlike fast_1
        uplift it does not saturate at 0 when both legs eventually
        converge). '·' diagonal, 'ERR' failed leg, 'n/a' undefined metric.
        """
        if metric not in HEATMAP_METRICS:
            raise ValueError(f"metric must be one of {HEATMAP_METRICS}, "
                             f"got {metric!r}")
        names = list(self.platforms)
        width = max([len("from \\ to")] + [len(n) for n in names])
        cell_w = max(8, max(len(n) for n in names))
        lines = [
            f"transfer matrix — {self._TITLES[metric]}, "
            f"{len(names)} platforms, {len(self.legs)} pairs"
            + (f", {self.n_failed} failed" if self.n_failed else ""),
        ]
        header = "from \\ to".ljust(width) + "  " + "  ".join(
            n.rjust(cell_w) for n in names)
        lines.append(header)
        lines.append("-" * len(header))
        for src in names:
            row = src.ljust(width) + "  " + "  ".join(
                self._cell(src, dst, metric).rjust(cell_w) for dst in names)
            lines.append(row)
        return "\n".join(lines)

    def heatmap_markdown(self, metric: str = "uplift_fast1") -> str:
        """The same heat-map as a GitHub-flavored markdown table."""
        if metric not in HEATMAP_METRICS:
            raise ValueError(f"metric must be one of {HEATMAP_METRICS}, "
                             f"got {metric!r}")
        names = list(self.platforms)
        lines = ["| from \\ to | " + " | ".join(names) + " |",
                 "|---" * (len(names) + 1) + "|"]
        for src in names:
            cells = " | ".join(self._cell(src, dst, metric)
                               for dst in names)
            lines.append(f"| **{src}** | {cells} |")
        return "\n".join(lines)


def run_transfer_matrix(workloads: Sequence[Workload],
                        platforms: Optional[Sequence[str]] = None, *,
                        loop: Optional[LoopConfig] = None,
                        cache: Optional[VerificationCache] = None,
                        max_workers: int = 4,
                        matrix_workers: Optional[int] = None,
                        leg_workers: Optional[int] = None,
                        timeout_s: Optional[float] = None,
                        leg_timeout_s: Optional[float] = None,
                        isolation: str = "thread",
                        log_path: Optional[Union[str, Path]] = None,
                        resume: bool = True,
                        backend: str = "template",
                        analysis: str = "rule",
                        llm=None,
                        io_cache: Optional[WorkloadIOCache] = None,
                        exe_cache: Optional[ExecutableCache] = None
                        ) -> TransferMatrix:
    """Run the §6.2 transfer sweep over every ordered platform pair as one
    dependency-aware job graph.

    Args:
        workloads: KernelBench workloads, shared by every leg.
        platforms: platform names to cross (≥ 2); defaults to every
            registered platform (:func:`repro.platforms.available_platforms`).
        loop: base loop configuration; ``platform`` / ``use_reference`` /
            ``transfer_from`` are overridden per leg.
        backend: ``"template"`` (offline deterministic agent, default) or
            ``"llm"``: every leg's workers then run ``LLMBackend`` sessions
            drawn from ``llm`` — base legs prompt cold, each warm leg
            injects its source base's *rendered references*
            (``LLMBackend.reference_sources``), bound per leg the same
            default-arg way the template factories bind hints. Sessions
            share ONE transport, rate limiter, and usage meter across all
            legs, and pace/back off inside ``work_sched.yielding()`` so a
            throttled leg's slot goes to runnable verification work (peak
            concurrency stays within the same budget as the template
            backend). Incompatible with ``isolation="process"`` (transports
            and limiters are in-memory shared state a fork would split).
        llm: a :class:`repro.llm.LLMContext` when ``backend="llm"``; a
            deterministic MockTransport context is built when omitted. Its
            usage snapshot lands in ``TransferMatrix.telemetry["llm_usage"]``
            and on every leg's ``campaign_done`` event.
        cache: shared verification cache for ALL legs (open a persistent
            one with ``VerificationCache.open`` to share across processes
            and reruns); a fresh in-memory cache when omitted. In process
            isolation each leg re-opens the cache's path inside its child
            (lock-bearing objects must be born after the fork), so only a
            persistent cache shares verifications across legs there.
        io_cache / exe_cache: shared fast-path caches for ALL thread-mode
            legs — workload inputs and the reference oracle are
            platform-independent, so one IO entry per (workload, seed)
            serves every leg (``oracle_computes`` < legs × workloads is
            the sharing proof; see ``TransferMatrix.io_cache``). Ignored
            under ``isolation="process"``: locks and compiled executables
            cannot cross a fork, so each leg builds fresh per-campaign
            caches inside its child (sharing still applies within a leg).
        max_workers: default for both pool levels when the explicit knobs
            are not given.
        matrix_workers: how many campaign legs run concurrently (the graph
            scheduler's budget); default ``max_workers``.
        leg_workers: total workload-verification slots, shared by every
            in-flight leg through one scheduler; default ``max_workers``.
            In process isolation a child cannot share the parent's
            semaphore, so the total is preserved by giving each leg
            ``leg_workers // matrix_workers`` slots of its own.
        analysis: ``"rule"`` (deterministic rule-table agent G, default) or
            ``"llm"`` (requires ``backend="llm"``): each leg's workers then
            analyze profiles through :class:`repro.llm.LLMAnalyzer`
            sessions over the SAME shared transport/limiter, metered into
            the same per-leg usage meter as that leg's generation calls —
            so every leg's ``campaign_done.llm_usage`` delta covers both
            agents of the two-agent loop.
        timeout_s: per-workload timeout inside each leg; with
            ``isolation="process"`` it additionally bounds each *leg*,
            whose child process is killed on expiry.
        leg_timeout_s: deadline for each whole leg in THREAD mode — the
            graph scheduler's per-job timeout, stamping the same
            ``job.error="timeout ..."`` the process path produces (the
            leg's thread is abandoned rather than killed). This is how LLM
            matrices — thread-mode only — keep a hung leg from wedging a
            graph slot forever. Ignored under ``isolation="process"``
            (there ``timeout_s`` already bounds the leg).
        isolation: ``"thread"`` (default) or ``"process"`` — forwarded to
            the graph scheduler (see :class:`repro.campaign.Scheduler`).
        log_path / resume: one JSONL event log shared by every leg
            (platform- and transfer_from-tagged); resuming skips whatever
            legs already finished.

    Returns:
        A :class:`TransferMatrix` whose ``legs`` cover exactly
        ``all_pairs(platforms)``. Per-leg failures are recorded, never
        raised.

    Scheduling: the N base campaigns (each reused as the source leg of
    every pair it feeds and the cold leg of every pair targeting it) are
    all submitted up front and run concurrently; each of the N·(N−1) warm
    legs is submitted with ``after`` edges on its two base campaigns and
    starts the moment both resolve — not when every base has finished.
    """
    names = sorted(platforms) if platforms is not None \
        else available_platforms()
    if len(names) < 2:
        raise ValueError(f"transfer matrix needs >= 2 platforms, got {names}")
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate platforms in {names}")
    if backend not in ("template", "llm"):
        raise ValueError(f"backend must be 'template' or 'llm', "
                         f"got {backend!r}")
    if backend == "llm" and isolation == "process":
        raise ValueError(
            "backend='llm' cannot run with isolation='process': the shared "
            "transport, rate limiter, and usage meter are in-memory state a "
            "fork would split per child (and record/replay file writes "
            "would race); run LLM matrices in thread mode")
    if analysis not in ("rule", "llm"):
        raise ValueError(f"analysis must be 'rule' or 'llm', "
                         f"got {analysis!r}")
    if analysis == "llm" and backend != "llm":
        raise ValueError(
            "analysis='llm' requires backend='llm': the LLM analyzer rides "
            "the LLM context's transport sessions; the template backend "
            "has none to offer")
    base = loop or LoopConfig()
    if base.search == "pbt" and backend == "llm":
        raise ValueError(
            "search='pbt' runs on declarative template candidates (tiling "
            "params to exploit-copy and mutate); LLM callable candidates "
            "carry neither — use backend='template' for population sweeps")
    if backend == "llm" and llm is None:
        from repro.llm import build_llm_context
        llm = build_llm_context()
    cache = cache if cache is not None else VerificationCache()
    leg_workers = leg_workers if leg_workers is not None else max_workers
    matrix_workers = matrix_workers if matrix_workers is not None \
        else max_workers
    graph = Scheduler(max_workers=matrix_workers,
                      timeout_s=(timeout_s if isolation == "process"
                                 else leg_timeout_s),
                      isolation=isolation)
    if isolation != "process":
        work_sched = Scheduler(max_workers=leg_workers, timeout_s=timeout_s)
        leg_pool_width = leg_workers
    else:
        # a forked child cannot share the parent's slot semaphore, so keep
        # leg_workers a TOTAL budget by splitting it across the legs that
        # can be in flight at once (each child sizes its own pool)
        work_sched = None
        leg_pool_width = max(1, leg_workers // matrix_workers)
    cache_path = getattr(cache, "path", None)

    def leg_cache() -> VerificationCache:
        # thread mode: the one shared cache object. process mode: a cache
        # constructed INSIDE the leg's forked child — a lock copied from
        # another thread mid-hold would deadlock the child — re-opening the
        # persistent path when there is one (the JSONL file is the shared
        # medium across processes).
        if isolation != "process":
            return cache
        return VerificationCache.open(cache_path) if cache_path \
            else VerificationCache()

    # fast-path caches: one shared pair for every thread-mode leg. Under
    # process isolation they stay None — run_campaign's per-campaign
    # defaults are then born inside each forked child (same fork rule as
    # leg_cache; compiled executables additionally don't pickle, so there
    # is no file-backed sharing medium for them).
    if isolation != "process":
        io_cache = io_cache if io_cache is not None else WorkloadIOCache()
        exe_cache = exe_cache if exe_cache is not None else ExecutableCache()
    else:
        io_cache = exe_cache = None

    common = dict(max_workers=leg_pool_width, timeout_s=timeout_s,
                  log_path=log_path, resume=resume, scheduler=work_sched,
                  io_cache=io_cache, exe_cache=exe_cache)

    # Phase 1 — submit one base campaign per platform, all at once. Each
    # doubles as source AND cold leg of every pair that touches it.
    def leg_analyzer_factory(plat, leg_usage):
        # agent G for one leg: LLM analyzer sessions share the leg's usage
        # meter with its generation sessions, so the leg's campaign_done
        # delta journals BOTH agents' tokens; None keeps the rule table
        if analysis != "llm":
            return None
        return llm.analyzer_factory(platform=plat, scheduler=work_sched,
                                    usage=leg_usage)

    def base_fn(name: str):
        def run() -> Tuple[CampaignResult, Dict, Dict]:
            plat = resolve_platform(name)
            factory, leg_usage = None, None
            if backend == "llm":
                # a per-leg meter (parented on the fleet meter): legs run
                # concurrently, so journaling wall-clock deltas of ONE
                # shared meter would let every leg absorb the others' spend
                leg_usage = llm.leg_meter()
                factory = llm.agent_factory(platform=plat,
                                            scheduler=work_sched,
                                            usage=leg_usage)
            result = run_campaign(
                workloads,
                dataclasses.replace(base, platform=plat.name,
                                    use_reference=False, transfer_from=None),
                agent_factory=factory,
                analyzer_factory=leg_analyzer_factory(plat, leg_usage),
                cache=leg_cache(), usage=leg_usage,
                **common)
            return (result, harvest_hints(result),
                    reference_sources(result, plat.name))
        return run

    base_jobs = {name: graph.submit(f"base[{name}]", base_fn(name))
                 for name in names}

    # Phase 2 — submit every warm leg NOW, gated on its two bases. The
    # factory lambda binds the target platform and source hints via
    # default arguments: legs run concurrently, so closing over loop
    # variables by reference would hand some legs another leg's platform.
    def warm_fn(src: str, dst: str):
        def run() -> CampaignResult:
            failed = [p for p in (src, dst)
                      if base_jobs[p].error is not None]
            if failed:
                raise RuntimeError("; ".join(
                    f"base campaign [{p}] failed: {base_jobs[p].error}"
                    for p in failed))
            dst_plat = resolve_platform(dst)
            leg_usage = None
            if backend == "llm":
                # the LLM warm leg consumes the source base's *rendered*
                # references (LLMBackend.reference_sources); the context
                # factory binds platform + references by value per leg,
                # and a per-leg meter keeps its journal delta its own
                src_refs = base_jobs[src].value[2]
                leg_usage = llm.leg_meter()
                factory = llm.agent_factory(platform=dst_plat,
                                            reference_sources=src_refs,
                                            scheduler=work_sched,
                                            usage=leg_usage)
            else:
                src_hints = base_jobs[src].value[1]
                factory = (lambda p=dst_plat, h=src_hints:
                           TemplateSearchBackend(platform=p,
                                                 reference_hints=h))
            return run_campaign(
                workloads,
                dataclasses.replace(base, platform=dst_plat.name,
                                    use_reference=True, transfer_from=src),
                agent_factory=factory,
                analyzer_factory=leg_analyzer_factory(dst_plat, leg_usage),
                cache=leg_cache(), usage=leg_usage,
                **common)
        return run

    warm_jobs = {
        (src, dst): graph.submit(
            f"warm[{src}->{dst}]", warm_fn(src, dst),
            after=(base_jobs[src], base_jobs[dst]))
        for src, dst in all_pairs(names)}

    graph.wait(list(base_jobs.values()) + list(warm_jobs.values()))

    # Phase 3 — fold handles into legs (in the coordinator: sweeps built
    # here share the base CampaignResult objects, so (A → B).source IS
    # (B → A).cold even in process mode).
    campaigns: Dict[str, CampaignResult] = {}
    hints: Dict[str, Dict[str, Dict[str, Any]]] = {}
    refs: Dict[str, Dict[str, Tuple[str, str]]] = {}
    for name, job in base_jobs.items():
        if job.error is None:
            campaigns[name], hints[name], refs[name] = job.value
            if isolation == "process":
                # fold the child's cache snapshot (it rode back on the
                # CampaignResult) into the parent's telemetry
                cache.absorb(job.value[0].cache)
    legs: Dict[Tuple[str, str], MatrixLeg] = {}
    for (src, dst), job in warm_jobs.items():
        if job.error is not None:
            legs[(src, dst)] = MatrixLeg(src, dst, error=job.error)
            continue
        if isolation == "process":
            cache.absorb(job.value.cache)
        sweep = TransferSweepResult(
            from_platform=src, to_platform=dst, source=campaigns[src],
            cold=campaigns[dst], warm=job.value, hints=hints[src],
            references=refs[src],
            log_path=Path(log_path) if log_path else None)
        legs[(src, dst)] = MatrixLeg(src, dst, sweep=sweep)

    jobs = list(base_jobs.values()) + list(warm_jobs.values())
    telemetry = {
        "matrix_workers": matrix_workers,
        "leg_workers": leg_workers,
        "leg_timeout_s": leg_timeout_s,
        "isolation": isolation,
        "backend": backend,
        "analysis": analysis,
        "io_cache": io_cache.stats() if io_cache is not None else None,
        "exe_cache": exe_cache.stats() if exe_cache is not None else None,
        "llm_usage": llm.usage.snapshot() if llm is not None else None,
        "peak_concurrent_legs": graph.telemetry()["peak_concurrent"],
        "jobs": {job.name: {"started_at": job.started_at,
                            "finished_at": job.finished_at,
                            "duration_s": job.duration_s,
                            "error": job.error}
                 for job in jobs},
        "serial_sum_s": sum(job.duration_s for job in jobs),
        "wall_s": (max((j.finished_at for j in jobs
                        if j.finished_at is not None), default=0.0)
                   - min((j.started_at for j in jobs
                          if j.started_at is not None), default=0.0)),
    }
    return TransferMatrix(platforms=names, legs=legs, cache=cache,
                          log_path=Path(log_path) if log_path else None,
                          telemetry=telemetry,
                          io_cache=io_cache, exe_cache=exe_cache)
