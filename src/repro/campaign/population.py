"""Population-based candidate search (PBT) over the campaign fleet.

The paper's refinement loop (§3) follows ONE candidate lineage per
workload. This module keeps a *population*: K lineages per workload,
evolved over G generations of the classic PBT exploit/explore cycle —
the §6.2 cross-platform transfer insight (copy tiling knowledge between
searches) applied *within* a platform, between members of one search.

One generation is:

  evaluate   one :func:`repro.core.verification.verify_batch` call over
             all K members — shared inputs, shared reference oracle,
             shared compiled executables, content-addressed results.
             When a :class:`repro.campaign.scheduler.Scheduler` is
             available the unique candidates are sharded across its
             slots (re-entrant ``wait``, so a generation fanned out from
             inside a workload job never deadlocks the pool).
  select     truncation selection on ``member_score``: fast_p tier first
             (speedup > 1.5, > 1.0, correct, failed), modeled time as
             the tie-break. The bottom quarter are losers; failed
             members are never winners.
  exploit    each loser copies a winner's tiling params
             (:func:`repro.core.candidates.copy_tiling` — validated
             against ``space_for(op, platform)``, illegal values snap
             legal).
  explore    one mutation on top: the winner's journaled agent-G
             recommendation when it is legal and changes the candidate
             (recommendations propagate with the params they were made
             for), else a seeded draw from the platform-legal mutation
             operators.

Every generation is journaled as a ``generation_done`` event (see
:func:`generation_event`), so a killed PBT campaign resumes mid-search:
restored generations replay from the journal with ZERO re-verification,
and the continuation evolves from the last journaled generation exactly
as the killed run would have. Determinism: all randomness flows from
``random.Random`` seeded by ``(cfg.seed, generation)``; identical seeds
produce identical generation journals.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.campaign.events import result_from_dict, result_to_dict
from repro.core import candidates as cand_mod
from repro.core.analysis import RuleBasedAnalyzer
from repro.core.evalio import ExecutableCache, WorkloadIOCache
from repro.core.refinement import (IterationLog, LoopConfig,
                                   RefinementOutcome)
from repro.core.states import EvalResult, ExecutionState
from repro.core.synthesis import TemplateSearchBackend
from repro.core.verification import (cache_key, io_signature, verify,
                                     verify_batch)
from repro.core.workload import Workload
from repro.platforms import resolve_platform

# score tiers, best first: the fast_p thresholds a member clears. Tier
# index = first threshold it fails; one past the end = not even correct.
SELECTION_TIERS = (1.5, 1.0)
FAILED_TIER = len(SELECTION_TIERS) + 1
# truncation fraction: bottom quarter of the population are losers (and
# symmetrically at most the top quarter — never more than half — are the
# winners they exploit)
TRUNCATION_FRAC = 0.25
# explore draws uniformly from the top-N mutations by predicted modeled
# time (when a ranking is available): greedy enough to hill-climb a
# winner's neighborhood, wide enough to keep the population diverse
EXPLORE_TOP = 3

Score = Tuple[int, float]


@dataclasses.dataclass(frozen=True)
class Member:
    """One lineage of the population. ``lineage`` ids are slot-stable
    ("m0".."m{K-1}"): a loser that exploit-copies a winner keeps its own
    id — the journal tracks where each slot's params came from via
    ``origin``/``exploited_from``/``explored``, not by renaming slots."""
    lineage: str
    candidate: cand_mod.Candidate
    origin: str = "init"                 # init | survivor | exploit | explore
    exploited_from: Optional[str] = None  # winner lineage copied from
    explored: Optional[str] = None        # mutation applied ("param->value")
    # which analyzer produced the adopted recommendation ("rule" | "llm");
    # None when explore drew from the mutation operators instead
    recommendation_source: Optional[str] = None


def member_score(result: EvalResult) -> Score:
    """Selection score, lower is better: (fast_p tier, modeled time).

    Tier 0: correct and speedup > 1.5; tier 1: speedup > 1.0; tier 2:
    correct; tier 3 (``FAILED_TIER``): not correct. Ties inside a tier
    break on modeled kernel time (wall time when the model has none;
    +inf for failures, so a failed member never outranks anything).
    """
    if not result.correct:
        return (FAILED_TIER, float("inf"))
    tier = len(SELECTION_TIERS)
    for t, threshold in enumerate(SELECTION_TIERS):
        if (result.speedup or 0.0) > threshold:
            tier = t
            break
    time_s = result.model_time_s
    if time_s is None:
        time_s = result.wall_time_s
    return (tier, time_s if time_s is not None else float("inf"))


def truncation_split(scores: Sequence[Score],
                     frac: float = TRUNCATION_FRAC
                     ) -> Tuple[List[int], List[int]]:
    """Truncation selection: (winner indices, loser indices), each in
    score order (best winner first, worst loser last).

    The cut is ``max(1, min(int(n * frac), n // 2))`` members off each
    end — PLUS every failed member (``FAILED_TIER``) on the loser side:
    a failing candidate holds nothing worth keeping, so it is always up
    for exploit/explore, not just when it lands in the bottom quarter.
    Failed members are symmetrically excluded from the winner set — a
    generation where everything failed has winners == [] and evolve
    falls back to explore-only (every loser mutates its own params).
    Winners and losers stay disjoint and selection stays monotone (every
    winner's score <= every loser's; failed scores are maximal).
    Populations below 2 have nothing to select over.
    """
    n = len(scores)
    if n < 2:
        return [], []
    order = sorted(range(n), key=lambda i: (scores[i], i))
    cut = max(1, min(int(n * frac), n // 2))
    winners = [i for i in order[:cut] if scores[i][0] < FAILED_TIER]
    loser_set = set(order[n - cut:])
    loser_set.update(i for i in range(n) if scores[i][0] >= FAILED_TIER)
    loser_set.difference_update(winners)
    losers = [i for i in order if i in loser_set]
    return winners, losers


def _derive_rng(seed: int, generation: int) -> random.Random:
    """One deterministic stream per (campaign seed, generation);
    generation -1 is population init."""
    return random.Random((int(seed) & 0xFFFFFFFF) * 1_000_003
                         + generation + 1)


def mutation_ranker(wl: Workload, platform,
                    legal: Optional[Callable] = None
                    ) -> Callable[[cand_mod.Candidate], List[str]]:
    """A ranking closure for :func:`evolve`/:func:`init_population`:
    candidate -> its workload-legal mutation names, best predicted
    modeled time first (deterministic — ties break on name). Mutations
    the performance model cannot score sort last, not out: on a
    workload the model lacks, ranking degrades to name order instead of
    an empty neighborhood."""
    shapes = {name: tuple(dims) for name, dims, _ in io_signature(wl)}

    def rank(cand: cand_mod.Candidate) -> List[str]:
        muts = cand_mod.mutations(cand, platform)
        scored = []
        for name in sorted(muts):
            if legal is not None and not legal(muts[name]):
                continue
            try:
                t = cand_mod.model_time(muts[name], shapes, platform)
            except Exception:  # noqa: BLE001 — op/shape combos it lacks
                t = float("inf")
            if t != t:   # NaN
                t = float("inf")
            scored.append((t, name))
        scored.sort()
        return [name for _, name in scored]

    return rank


def evolve(members: Sequence[Member], results: Sequence[EvalResult], *,
           platform=None, seed: int = 0, generation: int = 0,
           truncation: float = TRUNCATION_FRAC,
           legal: Optional[Callable[[cand_mod.Candidate], bool]] = None,
           recommendations: Optional[Dict[str, Any]] = None,
           rank: Optional[Callable[[cand_mod.Candidate],
                                   List[str]]] = None
           ) -> List[Member]:
    """One exploit/explore step: the next generation's members.

    Non-losers survive with their params unchanged. Each loser
    round-robins over the winners (best first): exploit = copy that
    winner's tiling params (snapped legal), then explore = the winner's
    agent-G recommendation (``recommendations`` maps winner lineage ->
    :class:`repro.core.analysis.Recommendation`) when it is in-space,
    workload-legal and actually changes the candidate — else one seeded
    platform-legal mutation. When every member failed (no winners),
    losers keep their own params and explore only.

    ``rank`` (optional, see :func:`mutation_ranker`) orders a
    candidate's legal mutation names best-predicted first; explore then
    draws among the top ``EXPLORE_TOP`` — hill-climbing the exploited
    winner's neighborhood instead of wandering it. Without it, explore
    draws uniformly over all legal mutations.

    Deterministic: the only randomness is ``random.Random`` seeded from
    ``(seed, generation)``, drawing over deterministically-ordered
    mutation names.
    """
    if len(members) != len(results):
        raise ValueError(f"{len(members)} members vs {len(results)} results")
    plat = resolve_platform(platform)
    scores = [member_score(r) for r in results]
    winners, losers = truncation_split(scores, truncation)
    loser_rank = {idx: rank for rank, idx in enumerate(losers)}
    recommendations = recommendations or {}
    rng = _derive_rng(seed, generation)
    nxt: List[Member] = []
    for i, m in enumerate(members):
        if i not in loser_rank:
            nxt.append(dataclasses.replace(
                m, origin="survivor", exploited_from=None, explored=None,
                recommendation_source=None))
            continue
        rec = None
        if winners:
            w = members[winners[loser_rank[i] % len(winners)]]
            cand = cand_mod.copy_tiling(m.candidate, w.candidate, plat)
            origin, exploited_from = "exploit", w.lineage
            rec = recommendations.get(w.lineage)
        else:
            cand, origin, exploited_from = m.candidate, "explore", None
        explored = rec_source = None
        if rec is not None and getattr(rec, "param", None) is not None:
            adopted = rec.apply(cand)
            if adopted.params != cand.params \
                    and cand_mod.in_space(adopted, plat) \
                    and (legal is None or legal(adopted)):
                cand = adopted
                explored = f"{rec.param}->{rec.value}"
                rec_source = getattr(rec, "source", None)
        if explored is None:
            muts = cand_mod.mutations(cand, plat)
            if rank is not None:
                names = rank(cand)[:EXPLORE_TOP]
            else:
                names = [k for k in sorted(muts)
                         if legal is None or legal(muts[k])]
            if names:
                explored = rng.choice(names)
                cand = muts[explored]
        nxt.append(Member(lineage=m.lineage, candidate=cand, origin=origin,
                          exploited_from=exploited_from, explored=explored,
                          recommendation_source=rec_source))
    return nxt


def init_population(wl: Workload, cfg: LoopConfig, *, agent, platform,
                    legal: Optional[Callable] = None,
                    rank: Optional[Callable] = None
                    ) -> Tuple[Optional[List[Member]], Optional[str]]:
    """Generation-0 members: m0 is the agent's initial candidate (so
    reference hints flow in on warm transfer legs), m1..m{K-1} are its
    workload-legal single-parameter mutations — best predicted first
    when a ``rank`` closure (:func:`mutation_ranker`) is given, name
    order otherwise — cycling when the space is smaller than the
    population (duplicate members are fine — verify_batch dedupes them
    by cache_key).

    Returns ``(members, None)`` or ``(None, error)`` when the agent
    cannot produce a declarative candidate (population search exploits
    and mutates template params; an opaque callable has neither).
    """
    gen = agent.generate(wl, use_reference=cfg.use_reference)
    if gen.failure or gen.candidate is None:
        return None, (gen.failure or
                      "agent produced no declarative candidate — population "
                      "search needs template params to exploit and mutate")
    base = gen.candidate
    members = [Member("m0", base, origin="init")]
    muts = cand_mod.mutations(base, platform)
    if rank is not None:
        names = rank(base)
    else:
        names = [k for k in sorted(muts) if legal is None or legal(muts[k])]
    for i in range(1, cfg.population):
        if names:
            pick = names[(i - 1) % len(names)]
            members.append(Member(f"m{i}", muts[pick], origin="init",
                                  explored=pick))
        else:
            members.append(Member(f"m{i}", base, origin="init"))
    return members, None


def evaluate_generation(cands: Sequence[cand_mod.Candidate], wl: Workload,
                        *, seed: int, cache=None, platform=None,
                        io_cache: Optional[WorkloadIOCache] = None,
                        exe_cache: Optional[ExecutableCache] = None,
                        scheduler=None, label: str = "pbt",
                        direction: str = "fwd") -> List[EvalResult]:
    """Verify one generation; one result per candidate, in order.

    The whole generation is one :func:`verify_batch` (shared inputs,
    oracle, executables). With a scheduler and more than one unique
    candidate, the unique set is sharded across the pool's slots —
    nested ``wait`` yields the caller's slot, so generations fanned out
    from inside a campaign's workload job stay within the existing slot
    budget without deadlocking.

    Fault isolation: if the batch path raises (a candidate poisoning the
    whole batch), every member is re-verified singly and a member whose
    verification still raises is scored ``RUNTIME_ERROR`` — the
    generation always completes with K results, and a faulty member
    simply lands in ``FAILED_TIER``.
    """
    plat = resolve_platform(platform)
    if io_cache is None:
        io_cache = WorkloadIOCache()   # batch path requires one
    try:
        if scheduler is not None and scheduler.max_workers > 1 \
                and len(cands) > 1:
            return _evaluate_sharded(cands, wl, seed=seed, cache=cache,
                                     plat=plat, io_cache=io_cache,
                                     exe_cache=exe_cache,
                                     scheduler=scheduler, label=label,
                                     direction=direction)
        return verify_batch(cands, wl, seed=seed, cache=cache,
                            platform=plat, io_cache=io_cache,
                            exe_cache=exe_cache, direction=direction)
    except Exception:  # noqa: BLE001 — isolate the faulty member below
        results: List[EvalResult] = []
        for c in cands:
            try:
                results.append(verify(c, wl, seed=seed, cache=cache,
                                      platform=plat, io_cache=io_cache,
                                      exe_cache=exe_cache,
                                      direction=direction))
            except Exception as exc:  # noqa: BLE001
                results.append(EvalResult(
                    ExecutionState.RUNTIME_ERROR,
                    error=("verification raised: "
                           f"{type(exc).__name__}: {exc}")))
        return results


def _evaluate_sharded(cands, wl, *, seed, cache, plat, io_cache, exe_cache,
                      scheduler, label,
                      direction: str = "fwd") -> List[EvalResult]:
    """Shard the UNIQUE candidates round-robin over scheduler slots; each
    shard is its own verify_batch against the shared caches. Duplicate
    candidates resolve to their unique result afterwards, exactly like
    verify_batch's own dedupe."""
    uniq_idx: Dict[str, int] = {}
    uniq: List[cand_mod.Candidate] = []
    keys: List[str] = []
    for c in cands:
        k = cache_key(c, wl, seed, plat, direction=direction)
        keys.append(k)
        if k not in uniq_idx:
            uniq_idx[k] = len(uniq)
            uniq.append(c)
    shards = min(scheduler.max_workers, len(uniq))
    jobs = [scheduler.submit(
        f"{label}.shard{i}",
        lambda part=uniq[i::shards]: verify_batch(
            part, wl, seed=seed, cache=cache, platform=plat,
            io_cache=io_cache, exe_cache=exe_cache, direction=direction))
        for i in range(shards)]
    shard_results = scheduler.wait(jobs)
    bad = next((r for r in shard_results if not r.ok), None)
    if bad is not None:
        # surfaces to evaluate_generation's fallback, which isolates the
        # faulty member; the other shards' results are already cached, so
        # the fallback re-verifies them for free
        raise RuntimeError(f"generation shard failed: {bad.error}")
    uniq_results: List[Optional[EvalResult]] = [None] * len(uniq)
    for i, jr in enumerate(shard_results):
        for j, r in enumerate(jr.value):
            uniq_results[i + j * shards] = r
    return [uniq_results[uniq_idx[k]] for k in keys]


def _score_record(s: Score) -> Dict[str, Any]:
    return {"tier": s[0],
            "time_s": None if s[1] == float("inf") else s[1]}


def member_record(m: Member, r: EvalResult, s: Score) -> Dict[str, Any]:
    """One member's journal record. Each member gets its OWN dicts even
    when verify_batch deduped it onto a shared result object — per-member
    lineage attribution (lineage/origin/exploited_from/explored) must stay
    distinct in the journal regardless of result sharing."""
    return {
        "lineage": m.lineage,
        "origin": m.origin,
        "exploited_from": m.exploited_from,
        "explored": m.explored,
        "recommendation_source": m.recommendation_source,
        "params": dict(m.candidate.params),
        "score": _score_record(s),
        "state": r.state.value,
        "result": result_to_dict(r),
    }


def generation_event(wl: Workload, loop: Dict[str, Any], *,
                     generation: int, seed: int, platform: str,
                     members: Sequence[Member],
                     results: Sequence[EvalResult],
                     scores: Sequence[Score],
                     winners: Sequence[int], losers: Sequence[int]
                     ) -> Dict[str, Any]:
    """The ``generation_done`` JSONL event: the full population state of
    one generation — member lineages, params, scores, exploit/explore
    provenance, serialized results (with cache keys — what resume
    pre-warms the verification cache from), and the selection outcome."""
    return {
        "event": "generation_done",
        "workload": wl.name,
        "level": wl.level,
        "platform": platform,
        # journaled top-level (not just inside loop) so log consumers can
        # filter fwd vs fwd_bwd generations without parsing loop configs
        "direction": dict(loop).get("direction", "fwd"),
        "loop": dict(loop),
        "io": io_signature(wl),
        "generation": generation,
        "seed": seed,
        "population": len(members),
        "winners": [members[i].lineage for i in winners],
        "losers": [members[i].lineage for i in losers],
        "members": [member_record(m, r, s)
                    for m, r, s in zip(members, results, scores)],
    }


def _restore(wl: Workload, ev: Dict[str, Any]
             ) -> Tuple[List[Member], List[EvalResult]]:
    """Members + results of one journaled generation — no verification."""
    members = [Member(lineage=mr["lineage"],
                      candidate=cand_mod.Candidate(wl.op,
                                                   dict(mr["params"])),
                      origin=mr.get("origin", "survivor"),
                      exploited_from=mr.get("exploited_from"),
                      explored=mr.get("explored"),
                      recommendation_source=mr.get("recommendation_source"))
               for mr in ev["members"]]
    results = [result_from_dict(mr["result"]) for mr in ev["members"]]
    return members, results


@dataclasses.dataclass
class PBTOutcome(RefinementOutcome):
    """A population search's outcome. ``logs`` carries one per-generation
    IterationLog (phase "pbt", the generation's best member) so campaign
    plumbing built on RefinementOutcome — ``iterations_to_correct``,
    reports, transfer hint harvesting via ``best_candidate`` — works
    unchanged; ``generations`` carries the full per-generation journal
    records (the same dicts written to the EventLog)."""
    generations: List[Dict[str, Any]] = dataclasses.field(
        default_factory=list)


def run_workload_pbt(wl: Workload, cfg: LoopConfig, *,
                     agent=None, analyzer=None, cache=None,
                     on_generation=None, io_cache=None, exe_cache=None,
                     scheduler=None, prior_events=None) -> PBTOutcome:
    """Population-based search for one workload (``cfg.search == "pbt"``).

    ``on_generation`` (optional) receives each ``generation_done`` event
    dict the moment the generation completes — the campaign runner
    journals generations through it, so a run killed mid-search keeps
    every generation it paid for.

    ``prior_events`` (optional) is the journaled ``generation_done``
    prefix of an earlier run of this exact search (see
    :func:`repro.campaign.events.generation_events`): those generations
    are restored — members, scores, best — with zero re-verification,
    and the search continues from the next generation index,
    deterministically identical to the run that was killed.

    ``scheduler`` (optional) fans each generation's unique candidates
    across the pool (see :func:`evaluate_generation`).
    """
    platform = resolve_platform(cfg.platform)
    if cfg.population < 2:
        raise ValueError(f"PBT needs population >= 2, got {cfg.population} "
                         "(one member is just the single-lineage loop)")
    if cfg.generations < 1:
        raise ValueError(
            f"PBT needs generations >= 1, got {cfg.generations}")
    agent = agent or TemplateSearchBackend(platform=platform)
    analyzer = analyzer or RuleBasedAnalyzer(platform=platform)
    loop_dict = dataclasses.asdict(cfg)
    legal_probe = getattr(agent, "_legal", None)
    legal = (None if legal_probe is None
             else (lambda c: legal_probe(c, wl)))
    rank = mutation_ranker(wl, platform, legal)

    logs: List[IterationLog] = []
    records: List[Dict[str, Any]] = []
    best: Optional[EvalResult] = None
    best_cand: Optional[cand_mod.Candidate] = None

    def bookkeep(members, results, scores, g, seed):
        """Per-generation IterationLog (the generation's best member) +
        global best tracking."""
        nonlocal best, best_cand
        top = min(range(len(members)), key=lambda i: (scores[i], i))
        logs.append(IterationLog(
            iteration=g, phase="pbt",
            candidate_desc=members[top].candidate.describe(),
            result=results[top], candidate=members[top].candidate,
            seed=seed))
        r = results[top]
        if r.correct and (best is None or (r.model_time_s or 1e9) <
                          (best.model_time_s or 1e9)):
            best, best_cand = r, members[top].candidate

    def recommend(members, results, winners) -> Dict[str, Any]:
        """Agent-G recommendations for the winners (profiling mode only):
        winner lineage -> Recommendation. These propagate to the losers
        that exploit that winner — the two-agent loop applied to a
        population instead of one candidate."""
        recs: Dict[str, Any] = {}
        if not cfg.use_profiling:
            return recs
        for i in winners:
            r = results[i]
            if r.correct and r.profile:
                try:
                    recs[members[i].lineage] = analyzer.analyze(r.profile)
                except Exception:  # noqa: BLE001 — advice, not a dependency
                    continue
        return recs

    # -- restore journaled generations (resume mid-search) ------------------
    members: Optional[List[Member]] = None
    results: List[EvalResult] = []
    start_gen = 0
    for ev in (prior_events or []):
        members, results = _restore(wl, ev)
        scores = [member_score(r) for r in results]
        bookkeep(members, results, scores, ev["generation"], ev.get("seed"))
        records.append(ev)
        start_gen = ev["generation"] + 1

    if members is None:
        members, err = init_population(wl, cfg, agent=agent,
                                       platform=platform, legal=legal,
                                       rank=rank)
        if err is not None:
            res = EvalResult(ExecutionState.GENERATION_FAILURE, error=err)
            return PBTOutcome(workload=wl.name, best=None,
                              best_candidate=None,
                              logs=[IterationLog(0, "pbt", None, res)],
                              generations=[])
    elif start_gen < cfg.generations:
        # continue the restored search: evolve the last journaled
        # generation exactly as the killed run would have
        scores = [member_score(r) for r in results]
        winners, _ = truncation_split(scores)
        members = evolve(members, results, platform=platform,
                         seed=cfg.seed, generation=start_gen - 1,
                         legal=legal, rank=rank,
                         recommendations=recommend(members, results,
                                                   winners))

    for g in range(start_gen, cfg.generations):
        seed = cfg.seed + g     # fresh inputs per generation (paper §7.3)
        results = evaluate_generation(
            [m.candidate for m in members], wl, seed=seed, cache=cache,
            platform=platform, io_cache=io_cache, exe_cache=exe_cache,
            scheduler=scheduler, label=f"pbt[{wl.name}].g{g}",
            direction=cfg.direction)
        scores = [member_score(r) for r in results]
        winners, losers = truncation_split(scores)
        ev = generation_event(wl, loop_dict, generation=g, seed=seed,
                              platform=platform.name, members=members,
                              results=results, scores=scores,
                              winners=winners, losers=losers)
        records.append(ev)
        if on_generation is not None:
            on_generation(ev)
        bookkeep(members, results, scores, g, seed)
        if g + 1 < cfg.generations:
            members = evolve(members, results, platform=platform,
                             seed=cfg.seed, generation=g, legal=legal,
                             rank=rank,
                             recommendations=recommend(members, results,
                                                       winners))

    return PBTOutcome(workload=wl.name, best=best, best_candidate=best_cand,
                      logs=logs, generations=records)
