"""Concurrent, cached, resumable synthesis campaigns.

A campaign = one refinement loop per workload, fanned out over a
:class:`Scheduler` worker pool, every verification memoized in a shared
:class:`VerificationCache`, and every iteration appended to a JSONL
:class:`EventLog`. Restarting a campaign with the same log path skips
workloads that already reached a terminal event and pre-warms the cache
from the logged iterations, so only unfinished work runs — and what runs
re-verifies nothing the previous run already paid for.

This is the substrate the benchmark harness (bench_fastp_levels,
bench_correctness, bench_profiling_impact) runs on, and what future
multi-backend / LLM-backend sweeps should extend.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

from repro.campaign import events as ev_mod
from repro.campaign.cache import VerificationCache
from repro.campaign.events import EventLog
from repro.campaign.report import format_report, report_from_events
from repro.campaign.scheduler import JobResult, Scheduler
from repro.core import verification as verif_mod
from repro.core.analysis import RuleBasedAnalyzer
from repro.core.evalio import ExecutableCache, WorkloadIOCache
from repro.core.refinement import LoopConfig, RefinementOutcome, run_workload
from repro.core.states import EvalResult, ExecutionState
from repro.core.synthesis import TemplateSearchBackend
from repro.core.workload import Workload


def _same_io(logged, current) -> bool:
    """Compare a JSON-round-tripped io signature (lists) against a live one
    (tuples). A log without an io stamp never matches — better to re-run a
    workload than to pass foreign-shape results off as this campaign's."""
    if logged is None:
        return False
    return json.dumps(logged) == json.dumps(current)


@dataclasses.dataclass
class CampaignConfig:
    loop: LoopConfig = dataclasses.field(default_factory=LoopConfig)
    max_workers: int = 4
    timeout_s: Optional[float] = None      # per-workload
    log_path: Optional[Union[str, Path]] = None
    resume: bool = True
    label: str = "campaign"


@dataclasses.dataclass
class WorkloadRun:
    """Terminal record for one workload of the campaign."""
    workload: str
    level: int
    outcome: Optional[RefinementOutcome] = None   # None on error/skip
    final: Optional[EvalResult] = None
    error: Optional[str] = None
    skipped: bool = False                          # resumed from the log
    duration_s: float = 0.0
    # refinement iterations until the first CORRECT verification (1 = the
    # initial candidate was already correct; None = never correct). Survives
    # resume via the workload_done event — the transfer sweep's
    # iterations-to-correct delta is computed from this.
    iters_to_correct: Optional[int] = None


@dataclasses.dataclass
class CampaignResult:
    runs: List[WorkloadRun]
    cache: VerificationCache
    log_path: Optional[Path] = None
    # THIS campaign's token/request accounting: the delta of the shared
    # repro.llm.UsageMeter across the run (None for offline backends) —
    # also journaled on the campaign_done event, where deltas from several
    # campaigns (sweep legs, resumed processes) sum to the log's total
    llm_usage: Optional[Dict[str, Any]] = None

    def finals(self) -> List[EvalResult]:
        """One terminal EvalResult per workload (errors/timeouts map to
        GENERATION_FAILURE so fast_p keeps its per-problem denominator)."""
        out = []
        for run in self.runs:
            if run.final is not None:
                out.append(run.final)
            else:
                out.append(EvalResult(ExecutionState.GENERATION_FAILURE,
                                      error=run.error or "no result"))
        return out

    @property
    def n_skipped(self) -> int:
        return sum(1 for r in self.runs if r.skipped)

    @property
    def n_failed(self) -> int:
        return sum(1 for r in self.runs if r.error is not None)


class Campaign:
    """Coordinate one synthesis campaign over a set of workloads.

    ``agent_factory`` / ``analyzer_factory`` build per-workload agents so
    stateful backends (an LLM session, say) are never shared across worker
    threads; the defaults are the stateless offline backends.
    """

    def __init__(self, workloads: Sequence[Workload], cfg: CampaignConfig,
                 *, cache: Optional[VerificationCache] = None,
                 agent_factory: Optional[Callable[[], Any]] = None,
                 analyzer_factory: Optional[Callable[[], Any]] = None,
                 scheduler: Optional[Scheduler] = None,
                 usage: Optional[Any] = None,
                 io_cache: Optional[WorkloadIOCache] = None,
                 exe_cache: Optional[ExecutableCache] = None):
        self.workloads = list(workloads)
        self.cfg = cfg
        self.cache = cache if cache is not None else VerificationCache()
        # fast-path cache layers (DESIGN.md §4): shared workload inputs +
        # reference oracle per seed, and compiled-executable reuse. Inject
        # shared instances to pool across campaigns (sweep/matrix legs in
        # thread mode); the per-campaign defaults still pool across this
        # campaign's workers and iterations.
        self.io_cache = io_cache if io_cache is not None else WorkloadIOCache()
        self.exe_cache = exe_cache if exe_cache is not None \
            else ExecutableCache()
        # an injected scheduler lets several campaigns (e.g. every leg of a
        # transfer matrix) share one worker-pool/timeout policy
        self.scheduler = scheduler
        # LLM token/request accounting (repro.llm.UsageMeter) shared with
        # the agent factory's sessions: journaled on campaign_done and
        # surfaced in report()/CampaignResult.llm_usage
        self.usage = usage
        plat = cfg.loop.platform
        self.agent_factory = agent_factory or (
            lambda: TemplateSearchBackend(platform=plat))
        self.analyzer_factory = analyzer_factory or (
            lambda: RuleBasedAnalyzer(platform=plat))
        self.log = EventLog(cfg.log_path) if cfg.log_path else None
        # raw replayed events (set by _load_previous): what PBT workloads
        # restore their journaled generation prefix from
        self._prior_events: List[Dict[str, Any]] = []
        # the scheduler run() is currently executing on — PBT workloads fan
        # their generations across it (re-entrant wait, same slot budget)
        self._active_sched: Optional[Scheduler] = None

    # -- resume ------------------------------------------------------------

    def _load_previous(self) -> Dict[str, Dict]:
        """Replay the log: returns terminal events by workload name and
        pre-warms the verification cache from logged iterations.

        Terminal events are filtered to this campaign's loop config up
        front (a log may interleave runs of several configs — e.g. the
        three legs of a transfer sweep — and the latest event for a name
        may belong to another leg) and re-checked per event in ``run``.
        The cache is warmed unconditionally: its keys are config-independent
        (candidate + workload io + platform + seed).
        """
        if self.log is None or not self.cfg.resume:
            return {}
        events = self.log.events()
        if not events:
            return {}
        self._prior_events = events
        ev_mod.warm_cache(self.cache, events)
        return ev_mod.completed_workloads(
            events, loop=dataclasses.asdict(self.cfg.loop))

    # -- one workload ------------------------------------------------------

    def _run_one(self, wl: Workload) -> RefinementOutcome:
        if self.cfg.loop.search == "pbt":
            return self._run_one_pbt(wl)
        on_iteration = None
        if self.log is not None:
            # journal each iteration the moment it completes: a campaign
            # killed mid-workload keeps the verifications it already paid
            # for (resume pre-warms the cache from these events).
            def on_iteration(it):
                self.log.append(ev_mod.iteration_event(
                    wl.name, wl.level, it, platform=self.cfg.loop.platform))
        return run_workload(
            wl, self.cfg.loop, agent=self.agent_factory(),
            analyzer=self.analyzer_factory(), cache=self.cache,
            on_iteration=on_iteration, io_cache=self.io_cache,
            exe_cache=self.exe_cache)

    def _run_one_pbt(self, wl: Workload) -> RefinementOutcome:
        """Population search for one workload: journal each generation as
        it completes (so a killed campaign keeps its paid-for
        generations), restore the journaled generation prefix on resume,
        and fan generations across the campaign's own scheduler."""
        from repro.campaign import population as pop_mod
        prior = None
        if self.cfg.resume and self._prior_events:
            prior = ev_mod.generation_events(
                self._prior_events, wl.name,
                loop=dataclasses.asdict(self.cfg.loop),
                io=verif_mod.io_signature(wl))
        on_generation = self.log.append if self.log is not None else None
        # generation fan-out shares the campaign's own thread pool
        # (re-entrant wait). Under process isolation the workload job runs
        # in a forked child where the scheduler is a mid-run copy —
        # verify the generation in-process there instead.
        sched = self._active_sched
        if sched is not None and \
                getattr(sched, "isolation", "thread") != "thread":
            sched = None
        return pop_mod.run_workload_pbt(
            wl, self.cfg.loop, agent=self.agent_factory(),
            analyzer=self.analyzer_factory(), cache=self.cache,
            on_generation=on_generation, io_cache=self.io_cache,
            exe_cache=self.exe_cache, scheduler=sched,
            prior_events=prior)

    # -- campaign ----------------------------------------------------------

    def run(self) -> CampaignResult:
        """Execute the campaign: resume-skip finished workloads, fan the
        rest over the worker pool, journal every iteration and terminal
        event. Returns a CampaignResult with one WorkloadRun per workload
        in input order."""
        done = self._load_previous()
        by_name = {wl.name: wl for wl in self.workloads}
        runs: Dict[str, WorkloadRun] = {}
        # usage meters are shared (all legs of a sweep/matrix) and only ever
        # grow; journal this campaign's DELTA so campaign_done events from
        # several campaigns — or from a resumed log's separate processes —
        # sum to the true total instead of double- or under-counting
        usage_start = self.usage.snapshot() if self.usage is not None \
            else None

        loop_dict = dataclasses.asdict(self.cfg.loop)
        for name, ev in done.items():
            # only cleanly-finished workloads are skipped; errored or
            # timed-out ones are retried (their verified iterations replay
            # from the pre-warmed cache, so retries are cheap). The event's
            # own loop config and io signature must both match: a log may
            # interleave runs of several configs, and the small/full suites
            # share workload names — neither may masquerade as this
            # campaign's results.
            if name not in by_name or ev.get("event") != "workload_done":
                continue
            if ev_mod.normalize_loop(ev.get("loop")) != \
                    ev_mod.normalize_loop(loop_dict):
                continue
            if not _same_io(ev.get("io"), verif_mod.io_signature(
                    by_name[name])):
                continue
            runs[name] = WorkloadRun(
                workload=name, level=by_name[name].level,
                final=ev_mod.result_from_dict(ev["final"]), skipped=True,
                iters_to_correct=ev.get("iters_to_correct"))

        todo = [wl for wl in self.workloads if wl.name not in runs]
        if self.log is not None:
            self.log.append({
                "event": "campaign_start", "label": self.cfg.label,
                "n_workloads": len(self.workloads), "n_skipped": len(runs),
                "platform": self.cfg.loop.platform,
                "loop": dataclasses.asdict(self.cfg.loop),
            })

        def record(job: JobResult) -> None:
            wl = by_name[job.name]
            if job.ok:
                outcome: RefinementOutcome = job.value
                final = outcome.final
                itc = ev_mod.iterations_to_correct(outcome.logs)
                runs[job.name] = WorkloadRun(
                    workload=job.name, level=wl.level, outcome=outcome,
                    final=final, duration_s=job.duration_s,
                    iters_to_correct=itc)
                if self.log is not None:
                    self.log.append({
                        "event": "workload_done", "workload": job.name,
                        "level": wl.level, "duration_s": job.duration_s,
                        "iterations": len(outcome.logs),
                        "iters_to_correct": itc,
                        "io": verif_mod.io_signature(wl),
                        "platform": self.cfg.loop.platform,
                        # top-level (duplicating loop.direction) so log
                        # consumers filter fwd vs fwd_bwd terminals without
                        # parsing loop configs
                        "direction": self.cfg.loop.direction,
                        "loop": dataclasses.asdict(self.cfg.loop),
                        "final": ev_mod.result_to_dict(final),
                    })
            else:
                runs[job.name] = WorkloadRun(
                    workload=job.name, level=wl.level, error=job.error,
                    duration_s=job.duration_s)
                if self.log is not None:
                    self.log.append({
                        "event": "workload_error", "workload": job.name,
                        "level": wl.level, "error": job.error,
                        "duration_s": job.duration_s,
                        "platform": self.cfg.loop.platform,
                        "loop": dataclasses.asdict(self.cfg.loop),
                    })

        if todo:
            sched = self.scheduler or Scheduler(
                max_workers=self.cfg.max_workers,
                timeout_s=self.cfg.timeout_s)
            self._active_sched = sched
            sched.run([(wl.name, (lambda wl=wl: self._run_one(wl)))
                       for wl in todo], on_result=record)

        usage = None
        if self.usage is not None:
            end = self.usage.snapshot()
            usage = {k: round(v - usage_start.get(k, 0), 6)
                     if isinstance(v, float) else v - usage_start.get(k, 0)
                     for k, v in end.items()}
        if self.log is not None:
            # io_cache / exe_cache stats ride along so fast-path cache
            # effectiveness is auditable from the event log alone; like
            # `cache`, these are snapshots of possibly-shared objects (the
            # report keeps the latest per log)
            done = {"event": "campaign_done", "cache": self.cache.stats(),
                    "io_cache": self.io_cache.stats(),
                    "exe_cache": self.exe_cache.stats()}
            if usage is not None:
                done["llm_usage"] = usage
            self.log.append(done)
        ordered = [runs[wl.name] for wl in self.workloads if wl.name in runs]
        return CampaignResult(runs=ordered, cache=self.cache,
                              log_path=self.log.path if self.log else None,
                              llm_usage=usage)

    # -- reporting ---------------------------------------------------------

    def report(self) -> Dict[str, Any]:
        """Aggregate the JSONL log (this campaign must have a log path),
        restricted to terminal events of this campaign's loop config."""
        if self.log is None:
            raise ValueError("campaign has no event log to report from")
        return report_from_events(self.log.events(),
                                  loop=dataclasses.asdict(self.cfg.loop))

    def report_text(self) -> str:
        return format_report(self.report())


def run_campaign(workloads: Sequence[Workload],
                 loop: Optional[LoopConfig] = None, *,
                 cache: Optional[VerificationCache] = None,
                 max_workers: int = 4,
                 timeout_s: Optional[float] = None,
                 log_path: Optional[Union[str, Path]] = None,
                 resume: bool = True,
                 agent_factory: Optional[Callable[[], Any]] = None,
                 analyzer_factory: Optional[Callable[[], Any]] = None,
                 scheduler: Optional[Scheduler] = None,
                 usage: Optional[Any] = None,
                 io_cache: Optional[WorkloadIOCache] = None,
                 exe_cache: Optional[ExecutableCache] = None
                 ) -> CampaignResult:
    """One-call campaign: the concurrent, cached replacement for
    ``run_suite`` that benchmarks and examples build on.

    Args:
        workloads: the KernelBench workloads to synthesize for.
        loop: refinement-loop configuration (platform, iterations, seed);
            defaults to ``LoopConfig()``.
        cache: shared :class:`VerificationCache`; a fresh in-memory one per
            call when omitted.
        max_workers / timeout_s: worker-pool width and per-workload timeout
            (ignored when ``scheduler`` is injected).
        log_path: JSONL event-log path; enables journaling and resume.
        resume: skip workloads whose terminal event (same loop config and io
            signature) is already in the log.
        agent_factory / analyzer_factory: per-workload builders for agent F
            and agent G; defaults are the offline platform-aware backends.
        scheduler: an existing :class:`Scheduler` to run on — lets several
            campaigns share one worker-pool policy (transfer matrix).
        usage: a shared :class:`repro.llm.UsageMeter` when ``agent_factory``
            builds LLM backends; its snapshot is journaled on the
            ``campaign_done`` event and returned as
            ``CampaignResult.llm_usage``.
        io_cache / exe_cache: shared fast-path caches
            (:class:`repro.core.evalio.WorkloadIOCache` /
            :class:`repro.core.evalio.ExecutableCache`); fresh per-campaign
            instances when omitted. Pass one of each across several
            campaigns (sweep/matrix legs) so they share generated inputs,
            oracle outputs, and compiled executables.

    Returns:
        A :class:`CampaignResult` with one :class:`WorkloadRun` per
        workload, in input order.
    """
    cfg = CampaignConfig(loop=loop or LoopConfig(), max_workers=max_workers,
                         timeout_s=timeout_s, log_path=log_path,
                         resume=resume)
    return Campaign(workloads, cfg, cache=cache, agent_factory=agent_factory,
                    analyzer_factory=analyzer_factory,
                    scheduler=scheduler, usage=usage,
                    io_cache=io_cache, exe_cache=exe_cache).run()
