"""Content-addressed verification cache.

Keys are produced by :func:`repro.core.verification.cache_key` — a sha256
over (op, sorted candidate params, kernel input shapes/dtypes, tolerance,
seed, platform) — so equal keys imply byte-identical verification work on
the same hardware target. The cache is shared by every worker of a
campaign (and, in the benchmark harness, across configs, levels, and both
legs of a cross-platform transfer sweep), so a candidate the search
revisits is verified exactly once per input seed per platform.

``VerificationCache.open(path)`` returns the persistent variant: every
entry is also appended to a JSONL file, and re-opening the same path
pre-loads all previously verified results — the cache survives across
processes (ROADMAP item).

Thread-safe; hit/miss counters are the campaign's cache-effectiveness
telemetry and what the resume/acceptance tests assert on.
"""
from __future__ import annotations

import json
import threading
from pathlib import Path
from typing import Dict, Optional, Union


from repro.core.states import EvalResult


def format_cache_stats(stats: Dict[str, int]) -> str:
    """One-line human-readable rendering of :meth:`VerificationCache.stats`
    ('X hits / Y misses (Z entries, R% hit rate)') — the single format every
    CLI branch and benchmark prints."""
    total = stats["hits"] + stats["misses"]
    rate = 100.0 * stats["hits"] / total if total else 0.0
    return (f"{stats['hits']} hits / {stats['misses']} misses "
            f"({stats['entries']} entries, {rate:.1f}% hit rate)")


class VerificationCache:
    """In-memory EvalResult memo keyed by verification content address."""

    def __init__(self) -> None:
        self._store: Dict[str, EvalResult] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    @classmethod
    def open(cls, path: Union[str, Path]) -> "PersistentVerificationCache":
        """A cache backed by a JSONL file at ``path`` (created if missing);
        entries survive across processes."""
        return PersistentVerificationCache(path)

    def get(self, key: str) -> Optional[EvalResult]:
        """Look up one verification by content address; returns the cached
        EvalResult or None, updating the hit/miss counters."""
        with self._lock:
            result = self._store.get(key)
            if result is None:
                self.misses += 1
            else:
                self.hits += 1
            return result

    def put(self, key: str, result: EvalResult) -> None:
        """Store (or overwrite) the EvalResult for one content address."""
        with self._lock:
            self._store[key] = result

    def warm(self, key: str, result: EvalResult) -> None:
        """Pre-load an entry (e.g. from a JSONL event log) without touching
        the hit/miss counters."""
        with self._lock:
            self._store.setdefault(key, result)

    def __len__(self) -> int:
        with self._lock:
            return len(self._store)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._store

    def stats(self) -> Dict[str, int]:
        """Snapshot of {entries, hits, misses} — the campaign's
        cache-effectiveness telemetry."""
        with self._lock:
            return {"entries": len(self._store), "hits": self.hits,
                    "misses": self.misses}

    def absorb(self, other: "VerificationCache") -> None:
        """Merge another cache's entries and hit/miss counters into this
        one, in memory only — no persistence side effects even on a
        persistent cache (the matrix uses this to fold the cache snapshots
        process-isolated legs send back into the parent's telemetry; a
        persistent leg cache already appended its entries to the shared
        JSONL file itself)."""
        with other._lock:
            entries = dict(other._store)
            hits, misses = other.hits, other.misses
        with self._lock:
            for key, result in entries.items():
                self._store.setdefault(key, result)
            self.hits += hits
            self.misses += misses

    # Locks don't pickle; campaign results (which carry their cache) must
    # cross the process-isolation pipe, so drop the lock on the way out and
    # mint a fresh one on the way in. The unpickled copy is a snapshot —
    # mutating it does not feed back into the parent's cache.
    def __getstate__(self) -> Dict[str, object]:
        with self._lock:
            state = self.__dict__.copy()
        del state["_lock"]
        return state

    def __setstate__(self, state: Dict[str, object]) -> None:
        self.__dict__.update(state)
        self._lock = threading.Lock()


class PersistentVerificationCache(VerificationCache):
    """On-disk (JSONL, append-only) verification cache.

    One ``{"key": ..., "result": ...}`` object per line; later lines win on
    load, so a measure_wall-upgraded entry replaces its wall-less
    predecessor. A torn final line from a killed process is skipped.
    Construct via :meth:`VerificationCache.open`.
    """

    def __init__(self, path: Union[str, Path]) -> None:
        super().__init__()
        # serialization helpers live in events.py (events does not import us)
        from repro.campaign import events as _ev
        self._to_dict = _ev.result_to_dict
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._io_lock = threading.Lock()
        if self.path.exists():
            with self.path.open() as fh:
                for line in fh:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        rec = json.loads(line)
                        self._store[rec["key"]] = _ev.result_from_dict(
                            rec["result"])
                    except (json.JSONDecodeError, KeyError):
                        continue  # torn tail write from a killed run

    def _append(self, key: str, result: EvalResult) -> None:
        line = json.dumps({"key": key, "result": self._to_dict(result)},
                          sort_keys=True, default=str)
        with self._io_lock:
            with self.path.open("a") as fh:
                fh.write(line + "\n")

    def put(self, key: str, result: EvalResult) -> None:
        with self._lock:
            prev = self._store.get(key)
            self._store[key] = result
        if prev is not result:
            self._append(key, result)

    def warm(self, key: str, result: EvalResult) -> None:
        with self._lock:
            if key in self._store:
                return
            self._store[key] = result
        self._append(key, result)

    def __getstate__(self) -> Dict[str, object]:
        state = super().__getstate__()
        del state["_io_lock"]
        return state

    def __setstate__(self, state: Dict[str, object]) -> None:
        super().__setstate__(state)
        self._io_lock = threading.Lock()
