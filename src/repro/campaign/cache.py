"""Content-addressed verification cache.

Keys are produced by :func:`repro.core.verification.cache_key` — a sha256
over (op, sorted candidate params, kernel input shapes/dtypes, tolerance,
seed) — so equal keys imply byte-identical verification work. The cache is
shared by every worker of a campaign (and, in the benchmark harness, across
configs and levels), so a candidate the search revisits is verified exactly
once per input seed.

Thread-safe; hit/miss counters are the campaign's cache-effectiveness
telemetry and what the resume/acceptance tests assert on.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional

from repro.core.states import EvalResult


class VerificationCache:
    """In-memory EvalResult memo keyed by verification content address."""

    def __init__(self) -> None:
        self._store: Dict[str, EvalResult] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: str) -> Optional[EvalResult]:
        with self._lock:
            result = self._store.get(key)
            if result is None:
                self.misses += 1
            else:
                self.hits += 1
            return result

    def put(self, key: str, result: EvalResult) -> None:
        with self._lock:
            self._store[key] = result

    def warm(self, key: str, result: EvalResult) -> None:
        """Pre-load an entry (e.g. from a JSONL event log) without touching
        the hit/miss counters."""
        with self._lock:
            self._store.setdefault(key, result)

    def __len__(self) -> int:
        with self._lock:
            return len(self._store)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._store

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"entries": len(self._store), "hits": self.hits,
                    "misses": self.misses}
