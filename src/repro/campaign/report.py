"""Campaign report: fast_p curves and execution-state histograms per level,
aggregated from the JSONL event log (so a report never requires re-running
anything — ``python -m repro.campaign --report-only`` works on any log).
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional

from repro.campaign.events import normalize_loop, result_from_dict
from repro.core.metrics import fast_p_curve, state_histogram
from repro.core.states import EvalResult, ExecutionState

FAST_P_THRESHOLDS = (0.0, 1.0, 1.5, 2.0)


def distinct_loop_configs(events: Iterable[Dict[str, Any]]
                          ) -> List[Dict[str, Any]]:
    """The distinct loop configs that produced terminal events in a log."""
    seen: Dict[str, Dict[str, Any]] = {}
    for ev in events:
        if ev.get("event") in ("workload_done", "workload_error") \
                and ev.get("loop") is not None:
            loop = normalize_loop(ev["loop"])
            seen.setdefault(json.dumps(loop, sort_keys=True), loop)
    return list(seen.values())


def report_from_events(events: Iterable[Dict[str, Any]],
                       thresholds=FAST_P_THRESHOLDS,
                       loop: Optional[Dict[str, Any]] = None
                       ) -> Dict[str, Any]:
    """Aggregate the terminal per-workload results by KernelBench level.

    A resumed/retried log can hold several terminal events for one workload
    (e.g. ``workload_error`` in run 1, ``workload_done`` after the retry);
    only the latest one counts, so fast_p denominators stay per-problem.

    ``loop`` (optional) restricts ``workload_done`` events to those written
    under that loop config, so a log that interleaves runs of several
    configurations is never blended into one fast_p curve — pass
    :func:`distinct_loop_configs` output to report each separately.
    """
    terminal: Dict[str, Dict[str, Any]] = {}
    cache_stats = None
    io_cache_stats = None
    exe_cache_stats = None
    llm_usage = None
    for ev in events:
        if ev.get("event") in ("workload_done", "workload_error"):
            if loop is None or \
                    normalize_loop(ev.get("loop")) == normalize_loop(loop):
                terminal[ev["workload"]] = ev
        elif ev.get("event") == "campaign_done":
            cache_stats = ev.get("cache")
            # fast-path caches are shared objects like the verification
            # cache: each campaign_done snapshots the cumulative counters,
            # so the latest event carries the log's totals
            io_cache_stats = ev.get("io_cache", io_cache_stats)
            exe_cache_stats = ev.get("exe_cache", exe_cache_stats)
            # each campaign_done journals its own usage DELTA, so summing
            # them totals the log — across sweep legs sharing one meter
            # and across the separate processes of a resumed run alike
            ev_usage = ev.get("llm_usage")
            if ev_usage:
                llm_usage = llm_usage or {}
                for k, v in ev_usage.items():
                    llm_usage[k] = round(llm_usage.get(k, 0) + v, 6)
    finals: Dict[int, List[EvalResult]] = {}
    names: Dict[int, List[str]] = {}
    iters: Dict[int, List[int]] = {}
    for name, ev in terminal.items():
        level = int(ev.get("level", 0))
        if ev["event"] == "workload_done":
            result = result_from_dict(ev["final"])
            if ev.get("iters_to_correct") is not None:
                iters.setdefault(level, []).append(ev["iters_to_correct"])
        else:
            result = EvalResult(state=ExecutionState.GENERATION_FAILURE,
                                error=ev.get("error"))
        finals.setdefault(level, []).append(result)
        names.setdefault(level, []).append(name)
    levels = {}
    for level in sorted(finals):
        rs = finals[level]
        it = iters.get(level, [])
        levels[level] = {
            "n": len(rs),
            "workloads": names[level],
            "fast_p": {f"{p:g}": v
                       for p, v in fast_p_curve(rs, thresholds).items()},
            "states": state_histogram(rs),
            "mean_best_model_time_us": _mean_time_us(rs),
            # mean refinement iterations until the first CORRECT result
            # (over workloads that got there) — the transfer matrix's
            # warm-vs-cold delta signal, here per single campaign
            "mean_iters_to_correct": sum(it) / len(it) if it else None,
        }
    all_rs = [r for rs in finals.values() for r in rs]
    return {
        "levels": levels,
        "total": {
            "n": len(all_rs),
            "fast_p": {f"{p:g}": v
                       for p, v in fast_p_curve(all_rs, thresholds).items()},
            "states": state_histogram(all_rs),
        },
        "cache": cache_stats,
        # fast-path cache effectiveness (DESIGN.md §4): shared-input/oracle
        # and compiled-executable reuse, from the latest campaign_done
        "io_cache": io_cache_stats,
        "exe_cache": exe_cache_stats,
        # token/request accounting of LLM-backed runs (None for the
        # offline template backend): the campaign_done llm_usage snapshot
        "llm_usage": llm_usage,
    }


def _mean_time_us(results: List[EvalResult]) -> float:
    times = [r.model_time_s for r in results if r.correct and r.model_time_s]
    return sum(times) / len(times) * 1e6 if times else 0.0


def format_report(report: Dict[str, Any]) -> str:
    """Human-readable rendering of :func:`report_from_events`."""
    lines = ["campaign report", "==============="]
    for level, stats in sorted(report["levels"].items()):
        lines.append(f"level {level}  (n={stats['n']})")
        fp = "  ".join(f"fast_{p}={v:.3f}"
                       for p, v in stats["fast_p"].items())
        lines.append(f"  {fp}")
        st = ", ".join(f"{k}={v}" for k, v in stats["states"].items())
        lines.append(f"  states: {st}")
        if stats["mean_best_model_time_us"]:
            lines.append("  mean best model time: "
                         f"{stats['mean_best_model_time_us']:.2f} us")
        if stats.get("mean_iters_to_correct") is not None:
            lines.append("  mean iters to correct: "
                         f"{stats['mean_iters_to_correct']:.2f}")
    tot = report["total"]
    fp = "  ".join(f"fast_{p}={v:.3f}" for p, v in tot["fast_p"].items())
    lines.append(f"total  (n={tot['n']})")
    lines.append(f"  {fp}")
    if report.get("cache"):
        c = report["cache"]
        lines.append(f"  cache: {c.get('hits', 0)} hits / "
                     f"{c.get('misses', 0)} misses "
                     f"({c.get('entries', 0)} entries)")
    if report.get("io_cache"):
        c = report["io_cache"]
        line = (f"  io cache: {c.get('hits', 0)} hits / "
                f"{c.get('misses', 0)} misses "
                f"({c.get('oracle_computes', 0)} oracle computes")
        if c.get("grad_oracle_computes"):
            line += f", {c['grad_oracle_computes']} grad oracle computes"
        line += ")"
        # nonzero = io_signature's abstract eval_shape path regressed and
        # real inputs were generated just to read shapes — a perf bug
        if c.get("io_sig_fallbacks"):
            line += (f"  [WARNING: {c['io_sig_fallbacks']} io-signature "
                     "concrete fallbacks]")
        lines.append(line)
    if report.get("exe_cache"):
        c = report["exe_cache"]
        lines.append(f"  exe cache: {c.get('hits', 0)} hits / "
                     f"{c.get('misses', 0)} misses "
                     f"({c.get('entries', 0)} compiled)")
    if report.get("llm_usage"):
        from repro.llm import format_usage
        lines.append(f"  llm: {format_usage(report['llm_usage'])}")
    return "\n".join(lines)
