"""Campaign report: fast_p curves and execution-state histograms per level,
aggregated from the JSONL event log (so a report never requires re-running
anything — ``python -m repro.campaign --report-only`` works on any log).
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional

from repro.campaign.events import normalize_loop, result_from_dict
from repro.core.metrics import fast_p_curve, state_histogram
from repro.core.states import EvalResult, ExecutionState

FAST_P_THRESHOLDS = (0.0, 1.0, 1.5, 2.0)


def distinct_loop_configs(events: Iterable[Dict[str, Any]]
                          ) -> List[Dict[str, Any]]:
    """The distinct loop configs that produced terminal events in a log."""
    seen: Dict[str, Dict[str, Any]] = {}
    for ev in events:
        if ev.get("event") in ("workload_done", "workload_error") \
                and ev.get("loop") is not None:
            loop = normalize_loop(ev["loop"])
            seen.setdefault(json.dumps(loop, sort_keys=True), loop)
    return list(seen.values())


def report_from_events(events: Iterable[Dict[str, Any]],
                       thresholds=FAST_P_THRESHOLDS,
                       loop: Optional[Dict[str, Any]] = None
                       ) -> Dict[str, Any]:
    """Aggregate the terminal per-workload results by KernelBench level.

    A resumed/retried log can hold several terminal events for one workload
    (e.g. ``workload_error`` in run 1, ``workload_done`` after the retry);
    only the latest one counts, so fast_p denominators stay per-problem.

    ``loop`` (optional) restricts ``workload_done`` events to those written
    under that loop config, so a log that interleaves runs of several
    configurations is never blended into one fast_p curve — pass
    :func:`distinct_loop_configs` output to report each separately.
    """
    terminal: Dict[str, Dict[str, Any]] = {}
    cache_stats = None
    io_cache_stats = None
    exe_cache_stats = None
    llm_usage = None
    requests: List[Dict[str, Any]] = []
    for ev in events:
        if ev.get("event") in ("workload_done", "workload_error"):
            if loop is None or \
                    normalize_loop(ev.get("loop")) == normalize_loop(loop):
                terminal[ev["workload"]] = ev
        elif ev.get("event") == "campaign_done":
            cache_stats = ev.get("cache")
            # fast-path caches are shared objects like the verification
            # cache: each campaign_done snapshots the cumulative counters,
            # so the latest event carries the log's totals
            io_cache_stats = ev.get("io_cache", io_cache_stats)
            exe_cache_stats = ev.get("exe_cache", exe_cache_stats)
            # each campaign_done journals its own usage DELTA, so summing
            # them totals the log — across sweep legs sharing one meter
            # and across the separate processes of a resumed run alike
            ev_usage = ev.get("llm_usage")
            if ev_usage:
                llm_usage = llm_usage or {}
                for k, v in ev_usage.items():
                    llm_usage[k] = round(llm_usage.get(k, 0) + v, 6)
        elif ev.get("event") == "request_done":
            # service-daemon journals (repro.service): each request_done
            # carries cumulative shared-cache snapshots — the latest one
            # is the log's running total, exactly like campaign_done
            requests.append(ev)
            cache_stats = ev.get("cache", cache_stats)
            io_cache_stats = ev.get("io_cache", io_cache_stats)
            exe_cache_stats = ev.get("exe_cache", exe_cache_stats)
            ev_usage = ev.get("llm_usage")
            if ev_usage:
                llm_usage = llm_usage or {}
                for k, v in ev_usage.items():
                    llm_usage[k] = round(llm_usage.get(k, 0) + v, 6)
        elif ev.get("event") == "service_stop":
            # the daemon's terminal event snapshots the final cache totals
            # (same role campaign_done plays for batch runs)
            cache_stats = ev.get("cache", cache_stats)
            io_cache_stats = ev.get("io_cache", io_cache_stats)
            exe_cache_stats = ev.get("exe_cache", exe_cache_stats)
    finals: Dict[int, List[EvalResult]] = {}
    names: Dict[int, List[str]] = {}
    iters: Dict[int, List[int]] = {}
    for name, ev in terminal.items():
        level = int(ev.get("level", 0))
        if ev["event"] == "workload_done":
            result = result_from_dict(ev["final"])
            if ev.get("iters_to_correct") is not None:
                iters.setdefault(level, []).append(ev["iters_to_correct"])
        else:
            result = EvalResult(state=ExecutionState.GENERATION_FAILURE,
                                error=ev.get("error"))
        finals.setdefault(level, []).append(result)
        names.setdefault(level, []).append(name)
    levels = {}
    for level in sorted(finals):
        rs = finals[level]
        it = iters.get(level, [])
        levels[level] = {
            "n": len(rs),
            "workloads": names[level],
            "fast_p": {f"{p:g}": v
                       for p, v in fast_p_curve(rs, thresholds).items()},
            "states": state_histogram(rs),
            "mean_best_model_time_us": _mean_time_us(rs),
            # mean refinement iterations until the first CORRECT result
            # (over workloads that got there) — the transfer matrix's
            # warm-vs-cold delta signal, here per single campaign
            "mean_iters_to_correct": sum(it) / len(it) if it else None,
        }
    all_rs = [r for rs in finals.values() for r in rs]
    return {
        "levels": levels,
        "total": {
            "n": len(all_rs),
            "fast_p": {f"{p:g}": v
                       for p, v in fast_p_curve(all_rs, thresholds).items()},
            "states": state_histogram(all_rs),
        },
        "cache": cache_stats,
        # fast-path cache effectiveness (DESIGN.md §4): shared-input/oracle
        # and compiled-executable reuse, from the latest campaign_done
        "io_cache": io_cache_stats,
        "exe_cache": exe_cache_stats,
        # token/request accounting of LLM-backed runs (None for the
        # offline template backend): the campaign_done llm_usage snapshot
        "llm_usage": llm_usage,
        # multi-tenant daemon traffic (None for batch-campaign logs)
        "service": _service_section(requests),
    }


def _service_section(requests: List[Dict[str, Any]]
                     ) -> Optional[Dict[str, Any]]:
    """Aggregate a service journal's ``request_done`` events: per-tenant
    counts + attributed LLM spend, dedupe ratio, and queue/wall latency
    percentiles. ``None`` when the log holds no daemon traffic."""
    if not requests:
        return None
    tenants: Dict[str, Dict[str, Any]] = {}
    served: Dict[str, int] = {}
    for ev in requests:
        t = tenants.setdefault(ev.get("tenant", "anon"),
                               {"requests": 0, "ok": 0, "deduped": 0,
                                "llm_usage": None})
        t["requests"] += 1
        if ev.get("ok"):
            t["ok"] += 1
        frm = ev.get("served_from") or "run"
        served[frm] = served.get(frm, 0) + 1
        if frm in ("memo", "coalesced"):
            t["deduped"] += 1
        usage = ev.get("llm_usage")
        if usage:
            t["llm_usage"] = t["llm_usage"] or {}
            for k, v in usage.items():
                t["llm_usage"][k] = round(t["llm_usage"].get(k, 0) + v, 6)
    queue = sorted(ev.get("queue_s") for ev in requests
                   if ev.get("queue_s") is not None)
    wall = sorted(ev.get("wall_s") for ev in requests
                  if ev.get("wall_s") is not None)
    n = len(requests)
    deduped = sum(v for k, v in served.items() if k != "run")
    return {
        "requests": n,
        "ok": sum(bool(ev.get("ok")) for ev in requests),
        "deduped": deduped,
        "served_from": served,
        "tenants": tenants,
        "queue_p50_s": _percentile(queue, 0.50),
        "queue_p95_s": _percentile(queue, 0.95),
        "wall_p50_s": _percentile(wall, 0.50),
        "wall_p95_s": _percentile(wall, 0.95),
    }


def _percentile(sorted_vals: List[float], q: float) -> Optional[float]:
    """Nearest-rank percentile over an already-sorted sample."""
    if not sorted_vals:
        return None
    idx = min(len(sorted_vals) - 1,
              max(0, int(round(q * len(sorted_vals) + 0.5)) - 1))
    return sorted_vals[idx]


def _mean_time_us(results: List[EvalResult]) -> float:
    times = [r.model_time_s for r in results if r.correct and r.model_time_s]
    return sum(times) / len(times) * 1e6 if times else 0.0


def format_report(report: Dict[str, Any]) -> str:
    """Human-readable rendering of :func:`report_from_events`."""
    lines = ["campaign report", "==============="]
    for level, stats in sorted(report["levels"].items()):
        lines.append(f"level {level}  (n={stats['n']})")
        fp = "  ".join(f"fast_{p}={v:.3f}"
                       for p, v in stats["fast_p"].items())
        lines.append(f"  {fp}")
        st = ", ".join(f"{k}={v}" for k, v in stats["states"].items())
        lines.append(f"  states: {st}")
        if stats["mean_best_model_time_us"]:
            lines.append("  mean best model time: "
                         f"{stats['mean_best_model_time_us']:.2f} us")
        if stats.get("mean_iters_to_correct") is not None:
            lines.append("  mean iters to correct: "
                         f"{stats['mean_iters_to_correct']:.2f}")
    tot = report["total"]
    fp = "  ".join(f"fast_{p}={v:.3f}" for p, v in tot["fast_p"].items())
    lines.append(f"total  (n={tot['n']})")
    lines.append(f"  {fp}")
    if report.get("cache"):
        c = report["cache"]
        lines.append(f"  cache: {c.get('hits', 0)} hits / "
                     f"{c.get('misses', 0)} misses "
                     f"({c.get('entries', 0)} entries)")
    if report.get("io_cache"):
        c = report["io_cache"]
        line = (f"  io cache: {c.get('hits', 0)} hits / "
                f"{c.get('misses', 0)} misses "
                f"({c.get('oracle_computes', 0)} oracle computes")
        if c.get("grad_oracle_computes"):
            line += f", {c['grad_oracle_computes']} grad oracle computes"
        line += ")"
        # nonzero = io_signature's abstract eval_shape path regressed and
        # real inputs were generated just to read shapes — a perf bug
        if c.get("io_sig_fallbacks"):
            line += (f"  [WARNING: {c['io_sig_fallbacks']} io-signature "
                     "concrete fallbacks]")
        lines.append(line)
    if report.get("exe_cache"):
        c = report["exe_cache"]
        lines.append(f"  exe cache: {c.get('hits', 0)} hits / "
                     f"{c.get('misses', 0)} misses "
                     f"({c.get('entries', 0)} compiled)")
    if report.get("llm_usage"):
        from repro.llm import format_usage
        lines.append(f"  llm: {format_usage(report['llm_usage'])}")
    svc = report.get("service")
    if svc:
        lines.append(f"service  ({svc['requests']} requests, "
                     f"{svc['ok']} ok, {svc['deduped']} deduped)")
        frm = ", ".join(f"{k}={v}"
                        for k, v in sorted(svc["served_from"].items()))
        lines.append(f"  served from: {frm}")
        if svc.get("queue_p50_s") is not None:
            lines.append(f"  queue latency: p50={svc['queue_p50_s']*1e3:.1f}"
                         f" ms  p95={svc['queue_p95_s']*1e3:.1f} ms")
        if svc.get("wall_p50_s") is not None:
            lines.append(f"  request wall: p50={svc['wall_p50_s']*1e3:.1f}"
                         f" ms  p95={svc['wall_p95_s']*1e3:.1f} ms")
        for tenant, t in sorted(svc["tenants"].items()):
            line = (f"  tenant {tenant}: {t['requests']} requests, "
                    f"{t['ok']} ok, {t['deduped']} deduped")
            if t.get("llm_usage"):
                from repro.llm import format_usage
                line += f", llm {format_usage(t['llm_usage'])}"
            lines.append(line)
    return "\n".join(lines)
