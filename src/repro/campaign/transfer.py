"""Cross-platform transfer sweep (paper §6.2).

The paper's §6.2 experiment: a correct kernel from platform A, injected as
a reference, improves synthesis on platform B. The campaign-level version:

  1. run a full campaign on the *source* platform;
  2. harvest each workload's best verified candidate and reduce it to its
     platform-portable strategy hints (``core.transfer.strategy_hints`` —
     online-softmax, fusion, recurrence form; tiling stays behind);
  3. run the *target* platform twice — cold (no reference) and warm (the
     harvested hints injected through the agent's reference path) — and
     report the per-level fast_p uplift.

All three campaigns share one verification cache (platform is part of the
content address, so legs never collide) and journal into one JSONL event
log, platform-tagged, so ``--report-only`` can still split them by config.

CLI: ``python -m repro.campaign --platform gpu_sim --transfer-from tpu_v5e``.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from repro.campaign.cache import VerificationCache
from repro.campaign.runner import CampaignResult, run_campaign
from repro.campaign.scheduler import Scheduler
from repro.core import transfer as core_transfer
from repro.core.evalio import ExecutableCache, WorkloadIOCache
from repro.core.metrics import fast_p
from repro.core.refinement import LoopConfig
from repro.core.states import EvalResult
from repro.core.synthesis import TemplateSearchBackend
from repro.core.workload import Workload
from repro.platforms import resolve_platform

TRANSFER_THRESHOLDS = (0.0, 1.0, 1.5)


def harvest_hints(result: CampaignResult) -> Dict[str, Dict[str, Any]]:
    """Workload name -> portable strategy hints of the best verified
    candidate of a finished campaign (skipped/resumed workloads fall back
    to the params recorded in their journaled profile)."""
    hints: Dict[str, Dict[str, Any]] = {}
    for run in result.runs:
        params = None
        if run.outcome is not None and run.outcome.best_candidate is not None:
            params = run.outcome.best_candidate.params
        elif run.final is not None and run.final.correct and run.final.profile:
            params = run.final.profile.get("params")
        if params:
            hints[run.workload] = core_transfer.strategy_hints(params)
    return hints


def reference_sources(result: CampaignResult, from_platform: str
                      ) -> Dict[str, Tuple[str, str]]:
    """Workload name -> (source platform, rendered reference text) for LLM
    backends (``LLMBackend.reference_sources``); the offline template
    backend consumes :func:`harvest_hints` instead."""
    out: Dict[str, Tuple[str, str]] = {}
    for run in result.runs:
        profile = run.final.profile if run.final is not None else None
        if run.outcome is not None and run.outcome.best_candidate is not None:
            op = run.outcome.best_candidate.op
            params = run.outcome.best_candidate.params
        elif run.final is not None and run.final.correct and profile:
            op, params = profile.get("op"), profile.get("params")
        else:
            continue
        if not op or params is None:
            continue
        out[run.workload] = (from_platform,
                             core_transfer.candidate_reference_source(
                                 op, params, from_platform))
    return out


@dataclasses.dataclass
class TransferSweepResult:
    from_platform: str
    to_platform: str
    source: CampaignResult
    cold: CampaignResult
    warm: CampaignResult
    hints: Dict[str, Dict[str, Any]]
    # workload -> (source platform, rendered reference text): ready to pass
    # as LLMBackend(reference_sources=...) for a production warm leg
    references: Dict[str, Tuple[str, str]] = \
        dataclasses.field(default_factory=dict)
    log_path: Optional[Path] = None

    def _by_level(self, result: CampaignResult) -> Dict[int, List[EvalResult]]:
        by: Dict[int, List[EvalResult]] = {}
        for run, final in zip(result.runs, result.finals()):
            by.setdefault(run.level, []).append(final)
        return by

    def _iters_by_level(self, result: CampaignResult
                        ) -> Dict[int, Dict[str, int]]:
        """Level -> {workload: iterations-to-correct} of the workloads
        that reached CORRECT (never-correct workloads contribute
        nothing)."""
        by: Dict[int, Dict[str, int]] = {}
        for run in result.runs:
            if run.iters_to_correct is not None:
                by.setdefault(run.level, {})[run.workload] = \
                    run.iters_to_correct
        return by

    @staticmethod
    def _iters_stats(cold: Dict[str, int],
                     warm: Dict[str, int]) -> Dict[str, Any]:
        """Mean iterations-to-correct per leg plus the warm − cold delta —
        the non-saturating transfer signal (negative = the transferred
        reference reached correctness in fewer iterations).

        The delta is *paired*: averaged over workloads correct in BOTH
        legs, so the two means cover the same population. Leg means over
        mismatched populations can flip the sign — a workload only the
        warm leg rescued (the strongest transfer win) would otherwise drag
        the warm mean up and read as a regression. ``n_paired`` says how
        many workloads the delta is over; None when there are none (or a
        leg mean when that leg had no correct workload).
        """
        c = sum(cold.values()) / len(cold) if cold else None
        w = sum(warm.values()) / len(warm) if warm else None
        paired = sorted(set(cold) & set(warm))
        delta = (sum(warm[k] - cold[k] for k in paired) / len(paired)
                 if paired else None)
        return {"cold": c, "warm": w, "delta": delta,
                "n_paired": len(paired)}

    def report(self, thresholds=TRANSFER_THRESHOLDS) -> Dict[str, Any]:
        cold_lv, warm_lv = self._by_level(self.cold), self._by_level(self.warm)
        cold_it, warm_it = (self._iters_by_level(self.cold),
                            self._iters_by_level(self.warm))
        levels: Dict[int, Dict[str, Any]] = {}
        for level in sorted(set(cold_lv) | set(warm_lv)):
            c, w = cold_lv.get(level, []), warm_lv.get(level, [])
            levels[level] = {
                "n": max(len(c), len(w)),
                "cold": {f"{p:g}": fast_p(c, p) for p in thresholds},
                "warm": {f"{p:g}": fast_p(w, p) for p in thresholds},
                "uplift_fast1": fast_p(w, 1.0) - fast_p(c, 1.0),
                "iters_to_correct": self._iters_stats(
                    cold_it.get(level, {}), warm_it.get(level, {})),
            }
        cold_all = [r for rs in cold_lv.values() for r in rs]
        warm_all = [r for rs in warm_lv.values() for r in rs]
        return {
            "from": self.from_platform,
            "to": self.to_platform,
            "n_references": len(self.hints),
            "levels": levels,
            "total": {
                "n": max(len(cold_all), len(warm_all)),
                "cold": {f"{p:g}": fast_p(cold_all, p) for p in thresholds},
                "warm": {f"{p:g}": fast_p(warm_all, p) for p in thresholds},
                "uplift_fast1": (fast_p(warm_all, 1.0)
                                 - fast_p(cold_all, 1.0)),
                "iters_to_correct": self._iters_stats(
                    {k: v for it in cold_it.values()
                     for k, v in it.items()},
                    {k: v for it in warm_it.values()
                     for k, v in it.items()}),
            },
        }

    @staticmethod
    def _iters_line(stats: Dict[str, Any]) -> str:
        it = stats["iters_to_correct"]
        fmt = (lambda v: "n/a" if v is None else f"{v:.2f}")
        delta = "n/a" if it["delta"] is None else f"{it['delta']:+.2f}"
        return (f"  iters-to-correct: cold={fmt(it['cold'])} "
                f"warm={fmt(it['warm'])} (delta {delta})")

    def report_text(self) -> str:
        rep = self.report()
        lines = [
            f"transfer sweep: {rep['from']} -> {rep['to']} "
            f"({rep['n_references']} harvested references)",
            "=" * 60,
        ]
        for level, stats in sorted(rep["levels"].items()):
            lines.append(f"level {level}  (n={stats['n']})")
            for leg in ("cold", "warm"):
                fp = "  ".join(f"fast_{p}={v:.3f}"
                               for p, v in stats[leg].items())
                lines.append(f"  {leg:4s}: {fp}")
            lines.append(f"  fast_1 uplift: {stats['uplift_fast1']:+.3f}")
            lines.append(self._iters_line(stats))
        tot = rep["total"]
        lines.append(f"total  (n={tot['n']})")
        for leg in ("cold", "warm"):
            fp = "  ".join(f"fast_{p}={v:.3f}" for p, v in tot[leg].items())
            lines.append(f"  {leg:4s}: {fp}")
        lines.append(f"  fast_1 uplift: {tot['uplift_fast1']:+.3f}")
        lines.append(self._iters_line(tot))
        return "\n".join(lines)


def run_transfer_sweep(workloads: Sequence[Workload], *,
                       from_platform, to_platform,
                       loop: Optional[LoopConfig] = None,
                       cache: Optional[VerificationCache] = None,
                       max_workers: int = 4,
                       timeout_s: Optional[float] = None,
                       log_path: Optional[Union[str, Path]] = None,
                       resume: bool = True,
                       scheduler: Optional[Scheduler] = None,
                       backend: str = "template",
                       analysis: str = "rule",
                       llm=None,
                       io_cache: Optional[WorkloadIOCache] = None,
                       exe_cache: Optional[ExecutableCache] = None
                       ) -> TransferSweepResult:
    """Run the §6.2 transfer experiment between two registered platforms.

    Args:
        workloads: KernelBench workloads, shared by all three legs.
        from_platform / to_platform: source and target (name or Platform);
            they must be distinct — transferring a platform's own references
            back onto itself is a degenerate experiment (the "warm" leg
            would re-measure the source campaign), so it raises ValueError.
        loop: base configuration (iterations, profiling, seed); its
            ``platform``/``use_reference``/``transfer_from`` fields are
            overridden per leg.
        cache / scheduler: shared verification cache and (optional) shared
            worker pool — one of each serves all three campaigns.
        max_workers / timeout_s / log_path / resume: as in
            :func:`repro.campaign.run_campaign`; all three legs journal
            into ONE event log, and resuming an interrupted sweep skips
            whatever legs already finished.
        backend: ``"template"`` (offline deterministic agent, default) or
            ``"llm"`` — every leg then runs ``LLMBackend`` sessions from
            ``llm``, and the warm leg injects the source campaign's
            *rendered references* (``LLMBackend.reference_sources``)
            instead of structured hints.
        analysis: ``"rule"`` (deterministic rule-table agent G, default) or
            ``"llm"`` (requires ``backend="llm"``): every leg then analyzes
            profiles through :class:`repro.llm.LLMAnalyzer` sessions over
            the same shared transport — analysis tokens land in the same
            usage meter (and ``campaign_done.llm_usage`` deltas) as
            generation tokens.
        llm: a :class:`repro.llm.LLMContext` (transport + rate limiter +
            usage meter) when ``backend="llm"``; a MockTransport-backed
            context is built when omitted.
        io_cache / exe_cache: fast-path caches shared by all three legs
            (fresh shared instances when omitted). Workload inputs and the
            reference oracle are platform-independent, so the cold and warm
            target legs — and the source leg, where seeds coincide — reuse
            the same IO entries instead of regenerating per leg.

    Returns:
        A :class:`TransferSweepResult` (source/cold/warm campaigns, the
        harvested hints and rendered references, per-level uplift report).
    """
    src = resolve_platform(from_platform)
    dst = resolve_platform(to_platform)
    if src.name == dst.name:
        raise ValueError(
            f"transfer sweep needs two distinct platforms, got {src.name!r} "
            "as both source and target — a same-platform sweep would just "
            "re-run the source campaign and report zero uplift. Pick a "
            "different --transfer-from/--platform pair (see "
            "repro.platforms.available_platforms()).")
    if backend not in ("template", "llm"):
        raise ValueError(f"backend must be 'template' or 'llm', "
                         f"got {backend!r}")
    if analysis not in ("rule", "llm"):
        raise ValueError(f"analysis must be 'rule' or 'llm', "
                         f"got {analysis!r}")
    if analysis == "llm" and backend != "llm":
        raise ValueError(
            "analysis='llm' requires backend='llm': the LLM analyzer rides "
            "the LLM context's transport sessions; the template backend "
            "has none to offer")
    base = loop or LoopConfig()
    if base.search == "pbt" and backend == "llm":
        raise ValueError(
            "search='pbt' runs on declarative template candidates (tiling "
            "params to exploit-copy and mutate); LLM callable candidates "
            "carry neither — use backend='template' for population sweeps")
    if backend == "llm" and llm is None:
        from repro.llm import build_llm_context
        llm = build_llm_context()
    cache = cache if cache is not None else VerificationCache()
    io_cache = io_cache if io_cache is not None else WorkloadIOCache()
    exe_cache = exe_cache if exe_cache is not None else ExecutableCache()
    common = dict(cache=cache, max_workers=max_workers, timeout_s=timeout_s,
                  log_path=log_path, resume=resume, scheduler=scheduler,
                  io_cache=io_cache, exe_cache=exe_cache)
    if llm is not None:
        common["usage"] = llm.usage

    def leg_factory(platform, references=None, hints=None):
        """Per-leg agent factory, everything bound by value at call time:
        template search with the warm leg's structured hints, or LLM
        sessions with the leg's platform and rendered references."""
        if backend == "llm":
            return llm.agent_factory(platform=platform,
                                     reference_sources=references,
                                     scheduler=scheduler)
        if hints is not None:
            return lambda p=platform, h=hints: TemplateSearchBackend(
                platform=p, reference_hints=h)
        return None                     # run_campaign's platform default

    def leg_analyzer(platform):
        """Per-leg agent-G factory: LLM analyzer sessions over the shared
        transport (metered into the same ``llm.usage`` as generation), or
        None for the default rule table on the leg's platform."""
        if analysis == "llm":
            return llm.analyzer_factory(platform=platform,
                                        scheduler=scheduler)
        return None

    # Leg 1: source-platform campaign (the reference-producing run).
    source = run_campaign(
        workloads,
        dataclasses.replace(base, platform=src.name, transfer_from=None),
        agent_factory=leg_factory(src), analyzer_factory=leg_analyzer(src),
        **common)
    hints = harvest_hints(source)
    references = reference_sources(source, src.name)

    # Leg 2: cold target run — no reference of any kind.
    cold = run_campaign(
        workloads,
        dataclasses.replace(base, platform=dst.name, use_reference=False,
                            transfer_from=None),
        agent_factory=leg_factory(dst), analyzer_factory=leg_analyzer(dst),
        **common)

    # Leg 3: warm target run — the source campaign's harvest injected
    # through the agent's reference path: structured strategy hints for the
    # template backend (REFERENCE_HINTS extended per workload), rendered
    # reference kernels (LLMBackend.reference_sources) for LLM sessions.
    # transfer_from tags the loop config so warm legs fed from different
    # sources stay distinguishable in a shared event log (matrix runs).
    warm = run_campaign(
        workloads,
        dataclasses.replace(base, platform=dst.name, use_reference=True,
                            transfer_from=src.name),
        agent_factory=leg_factory(dst, references=references, hints=hints),
        analyzer_factory=leg_analyzer(dst), **common)

    return TransferSweepResult(
        from_platform=src.name, to_platform=dst.name, source=source,
        cold=cold, warm=warm, hints=hints, references=references,
        log_path=Path(log_path) if log_path else None)
