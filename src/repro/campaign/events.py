"""JSONL campaign event log — the durable record that makes campaigns
resumable (paper §3.3: "we save detailed logs for each workload").

One JSON object per line. Event kinds:

  campaign_start   {suite, n_workloads, platform, loop: {...}}
  iteration        one per refinement iteration, mirroring ``IterationLog``
                   (workload, iteration, phase, candidate, state, timing,
                   cache_key, recommendation, recommendation_source,
                   platform)
  generation_done  one per PBT generation (``--search pbt``): the full
                   population state — member lineage ids, params, scores,
                   exploit/explore provenance, serialized results — plus
                   the selection outcome (winner/loser lineages). Written
                   by :mod:`repro.campaign.population`; resume replays
                   the journaled generation prefix with zero
                   re-verification
  workload_done    terminal per-workload record with the serialized final
                   EvalResult and ``iters_to_correct`` (how many refinement
                   iterations ran before the first CORRECT verification —
                   the transfer matrix's non-saturating warm-vs-cold
                   signal) — resume skips these workloads
  workload_error   scheduler-isolated failure (exception or timeout)
  campaign_done    end-of-run marker with the verification-cache stats,
                   the fast-path cache stats (``io_cache`` — shared
                   input/oracle reuse incl. ``oracle_computes`` — and
                   ``exe_cache`` — compiled-executable reuse; DESIGN.md
                   §4) and, for LLM-backed campaigns, ``llm_usage`` —
                   THIS campaign's token/request delta of the shared
                   repro.llm.UsageMeter; report_from_events sums the
                   deltas of every campaign_done in a log

Every event carries the hardware platform it ran against (also embedded in
``loop``), so one log can interleave multi-platform runs — e.g. both legs
of a transfer sweep — and still aggregate per-config reports.

On restart the runner replays the log: ``workload_done``/``workload_error``
names are skipped, and every ``iteration`` event carrying a cache key
pre-warms the verification cache, so even interrupted workloads resume
without re-verifying the iterations they already paid for.
"""
from __future__ import annotations

import dataclasses
import json
import threading
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional, Union

from repro.core.refinement import IterationLog, LoopConfig
from repro.core.states import EvalResult, ExecutionState


def normalize_loop(loop: Optional[Dict[str, Any]]
                   ) -> Optional[Dict[str, Any]]:
    """Fill LoopConfig fields absent from a logged loop dict with their
    defaults. Logs written before a config field existed (e.g.
    ``transfer_from``) must keep resuming and reporting under the grown
    config — the same tolerant-loading promise :func:`result_from_dict`
    makes for results. Always compare loop configs through this."""
    if loop is None:
        return None
    out = dataclasses.asdict(LoopConfig())
    out.update(loop)
    return out


def result_to_dict(r: EvalResult) -> Dict[str, Any]:
    """JSON-serializable form of an EvalResult (inverse:
    :func:`result_from_dict`); shared by the event log and the persistent
    verification cache."""
    return {
        "state": r.state.value,
        "error": r.error,
        "wall_time_s": r.wall_time_s,
        "model_time_s": r.model_time_s,
        "baseline_model_time_s": r.baseline_model_time_s,
        "max_abs_err": r.max_abs_err,
        "profile": r.profile,
        "cache_key": r.cache_key,
    }


def result_from_dict(d: Dict[str, Any]) -> EvalResult:
    """Rebuild an EvalResult from :func:`result_to_dict` output; absent
    keys default to None, so older logs stay loadable."""
    return EvalResult(
        state=ExecutionState(d["state"]),
        error=d.get("error"),
        wall_time_s=d.get("wall_time_s"),
        model_time_s=d.get("model_time_s"),
        baseline_model_time_s=d.get("baseline_model_time_s"),
        max_abs_err=d.get("max_abs_err"),
        profile=d.get("profile"),
        cache_key=d.get("cache_key"),
    )


def iterations_to_correct(logs: Iterable[IterationLog]) -> Optional[int]:
    """How many refinement iterations ran before (and including) the first
    CORRECT verification — 1 means the initial candidate was already
    correct; None means the workload never got there.

    This is the transfer matrix's second heat-map metric: the deterministic
    backend usually converges cold too given enough iterations, so final
    fast_1 uplift saturates at 0 — but a transferred reference still shows
    up as *fewer iterations spent* reaching correctness (warm − cold < 0).
    """
    for n, log in enumerate(logs, 1):
        if log.result.correct:
            return n
    return None


def iteration_event(workload: str, level: int, log: IterationLog,
                    platform: Optional[str] = None) -> Dict[str, Any]:
    """The JSONL event for one refinement iteration: candidate, phase,
    serialized result (with cache_key — what resume pre-warms the
    verification cache from), and the platform it ran against."""
    return {
        "event": "iteration",
        "workload": workload,
        "level": level,
        "platform": platform,
        "iteration": log.iteration,
        "phase": log.phase,
        "candidate": log.candidate_desc,
        "params": dict(log.candidate.params) if log.candidate else None,
        "seed": log.seed,
        "recommendation": log.recommendation,
        # which analyzer produced the recommendation ("rule" | "llm"; None
        # when none was made) — the audit trail for two-agent campaigns
        "recommendation_source": log.recommendation_source,
        "result": result_to_dict(log.result),
    }


class EventLog:
    """Append-only, thread-safe JSONL writer/reader.

    Each ``append`` is one ``write`` of a full line on a line-buffered
    handle, so concurrent workers interleave whole events, never bytes; a
    truncated final line from a killed process is tolerated on read.
    """

    def __init__(self, path: Union[str, Path]) -> None:
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._lock = threading.Lock()

    def append(self, event: Dict[str, Any]) -> None:
        line = json.dumps(event, sort_keys=True, default=str)
        with self._lock:
            with self.path.open("a") as fh:
                fh.write(line + "\n")

    def events(self) -> List[Dict[str, Any]]:
        if not self.path.exists():
            return []
        out: List[Dict[str, Any]] = []
        with self.path.open() as fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                try:
                    out.append(json.loads(line))
                except json.JSONDecodeError:
                    continue  # torn tail write from a killed run
        return out


def completed_workloads(events: Iterable[Dict[str, Any]],
                        loop: Optional[Dict[str, Any]] = None
                        ) -> Dict[str, Dict]:
    """name -> latest terminal event, for every workload the log finished.

    ``loop`` (optional) restricts terminal events to that loop config. A
    log may interleave runs of several configs — e.g. the transfer sweep's
    three legs in one file — and the *latest* event for a name can belong
    to a different leg; without the filter an earlier leg's finished work
    would be shadowed and needlessly re-run on resume.
    """
    done: Dict[str, Dict] = {}
    for ev in events:
        if ev.get("event") not in ("workload_done", "workload_error"):
            continue
        if loop is not None and \
                normalize_loop(ev.get("loop")) != normalize_loop(loop):
            continue
        done[ev["workload"]] = ev
    return done


def warm_cache(cache, events: Iterable[Dict[str, Any]]) -> int:
    """Pre-load a VerificationCache from logged verification results —
    ``iteration`` events and every member of ``generation_done`` events;
    returns the number of entries loaded."""
    n = 0
    for ev in events:
        kind = ev.get("event")
        if kind == "iteration":
            result_dicts = [ev.get("result")]
        elif kind == "generation_done":
            result_dicts = [m.get("result")
                            for m in ev.get("members", [])]
        else:
            continue
        for rd in result_dicts:
            key: Optional[str] = (rd or {}).get("cache_key")
            if not key:
                continue
            cache.warm(key, result_from_dict(rd))
            n += 1
    return n


def generation_events(events: Iterable[Dict[str, Any]], workload: str,
                      loop: Optional[Dict[str, Any]] = None,
                      io: Any = None) -> List[Dict[str, Any]]:
    """The journaled ``generation_done`` prefix of one workload's PBT
    search: generations 0..n in order, from the LATEST run in the log.

    A retried workload restarts at generation 0, so a fresh prefix
    supersedes any earlier (possibly torn) one; a log is only resumable
    up to its last *contiguous* generation index — anything after a gap
    (torn tail) is discarded and re-run.

    ``loop`` restricts to one loop config (compared through
    :func:`normalize_loop`, like terminal events) and ``io`` to one io
    signature — pass the live ``io_signature(wl)`` so the small/full
    suites' shared workload names never masquerade as each other.
    """
    loop_n = normalize_loop(loop) if loop is not None else None
    io_blob = json.dumps(io) if io is not None else None
    prefix: List[Dict[str, Any]] = []
    for ev in events:
        if ev.get("event") != "generation_done" \
                or ev.get("workload") != workload:
            continue
        if loop_n is not None \
                and normalize_loop(ev.get("loop")) != loop_n:
            continue
        if io_blob is not None and json.dumps(ev.get("io")) != io_blob:
            continue
        g = ev.get("generation")
        if g == 0:
            prefix = [ev]
        elif g == len(prefix):
            prefix.append(ev)
    return prefix
