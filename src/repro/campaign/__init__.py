"""Concurrent synthesis-campaign runner (the KForge "fleet" substrate).

``run_suite`` evaluates workloads one by one in-process; a *campaign* runs
the same refinement loops concurrently over a worker pool, memoizes every
verification in a content-addressed cache, journals every iteration to a
JSONL event log, and can resume an interrupted run from that log. See
``python -m repro.campaign --help`` for the CLI.
"""
from repro.campaign.cache import (  # noqa: F401
    PersistentVerificationCache, VerificationCache,
)
from repro.core.evalio import (  # noqa: F401 — fast-path cache layers
    ExecutableCache, WorkloadIOCache,
)
from repro.campaign.events import (  # noqa: F401
    EventLog, completed_workloads, generation_events, iteration_event,
    result_from_dict, result_to_dict, warm_cache,
)
from repro.campaign.population import (  # noqa: F401
    Member, PBTOutcome, evaluate_generation, evolve, generation_event,
    init_population, member_score, run_workload_pbt, truncation_split,
)
from repro.campaign.report import (  # noqa: F401
    FAST_P_THRESHOLDS, distinct_loop_configs, format_report,
    report_from_events,
)
from repro.campaign.runner import (  # noqa: F401
    Campaign, CampaignConfig, CampaignResult, WorkloadRun, run_campaign,
)
from repro.campaign.scheduler import JobResult, Scheduler  # noqa: F401
from repro.campaign.transfer import (  # noqa: F401
    TransferSweepResult, harvest_hints, reference_sources,
    run_transfer_sweep,
)
from repro.campaign.matrix import (  # noqa: F401
    MatrixLeg, TransferMatrix, all_pairs, run_transfer_matrix,
)
