"""Dependency-aware worker-pool scheduler with per-job timeout, failure
isolation, and an opt-in process-isolation mode.

Two layers of API:

* ``run(jobs)`` — the original flat interface: fan a list of named thunks
  over the pool, collect ``JobResult``s in submission order.
* ``submit(name, fn, after=...)`` / ``wait(handles)`` — dependency-aware
  submission. A job submitted with ``after=(a, b)`` starts the moment BOTH
  ``a`` and ``b`` resolve (success *or* failure — dependents read their
  dependencies' ``value``/``error`` off the handle and decide for
  themselves), not when the caller gets around to waiting. The transfer
  matrix uses this to launch every warm leg as soon as its two base
  campaigns finish, while unrelated base campaigns are still running.

Concurrency budget. One ``Scheduler`` instance holds ONE slot semaphore
(``max_workers`` wide) shared by every ``run``/``submit`` call on it, from
any thread — so several campaigns fanning workloads onto a shared scheduler
get ``max_workers`` slots *total*, not each. The pool is re-entrant: a job
that calls ``run``/``wait`` on its own scheduler releases its slot while it
blocks and re-acquires afterwards, so the budget counts only jobs actually
computing and nested fan-out cannot deadlock the pool. ``telemetry()``
reports the high-water mark of concurrently running jobs.

Thread mode (default). Workers are *daemon* threads — one per job, gated
by the slot semaphore. A queued job parks its (cheap, mostly-unmapped)
thread on a 0.25 s semaphore poll; that is the right trade at campaign
scale (tens to low hundreds of jobs, each seconds long). A graph of many
thousands of short jobs would want a dispatcher feeding a fixed pool
instead — extend here if campaigns ever reach that shape. Daemon threads
rather than a ``ThreadPoolExecutor``: the executor
joins its non-daemon workers at interpreter shutdown, so one genuinely hung
kernel would block process exit forever even after its timeout fired.
Verification time is dominated by jax trace/compile/execute, which release
the GIL, and candidate programs close over unpicklable jax callables — so
threads are the right default substrate. The trade-off: a timed-out job's
thread cannot be force-killed; it is abandoned (it dies with the process),
its slot permanently occupied, which the result's error documents. The
deadline itself is enforced by a per-job watchdog timer, so a hung job
resolves (``error="timeout ..."``, done set) at ``timeout_s`` even when no
waiter or dependent happens to be observing it — LLM matrix legs, which
are thread-mode only, rely on this to never wedge a graph slot forever. A job
starved of a slot because the whole pool is wedged on hung jobs is
cancelled (it never runs) and reported as such; a job still waiting on its
``after`` dependencies is *not* starved and never cancelled this way.

Process mode (``isolation="process"``). Each job's thunk runs in a forked
child process, so a timed-out job is actually ``SIGKILL``-ed instead of
abandoned and its slot comes back (ROADMAP open item). The cost: the job's
return value must be picklable (an unpicklable result is reported as the
job's error), and in-memory side effects — shared caches, dicts mutated by
the thunk — die with the child; only file-backed state (JSONL event logs,
persistent verification caches) survives. Fork-only: objects captured by
the thunk are inherited by the child, never pickled. Locks copied mid-hold
from *other* threads are the classic fork hazard — construct lock-bearing
state (caches, event logs) inside the thunk, as the matrix does.

One exploding or hung job never takes down the campaign — its error (or a
timeout marker) is recorded in its :class:`JobResult` and every other job
completes normally. Timeouts are measured from when a job actually starts
executing, so K simultaneously hung jobs are all flagged ~timeout_s after
they hang rather than serially K×timeout_s later.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, \
    Tuple

ISOLATION_MODES = ("thread", "process")


@dataclasses.dataclass
class JobResult:
    name: str
    value: Any = None
    error: Optional[str] = None
    duration_s: float = 0.0
    # perf_counter stamps (None for a job that never started): what overlap
    # tests and the matrix telemetry read.
    started_at: Optional[float] = None
    finished_at: Optional[float] = None

    @property
    def ok(self) -> bool:
        return self.error is None


class _Job:
    """One unit of work plus its completion state — also the handle
    ``submit`` returns. After ``done`` is set, ``value``/``error`` are
    final and safe to read from any thread (dependents do)."""

    def __init__(self, name: str, fn: Callable[[], Any],
                 after: Tuple["_Job", ...] = ()) -> None:
        self.name = name
        self.fn = fn
        self.after = after
        self.done = threading.Event()
        self.value: Any = None
        self.error: Optional[str] = None
        self.duration_s = 0.0
        self.started_at: Optional[float] = None
        self.finished_at: Optional[float] = None
        self.cancelled = False
        self._lock = threading.Lock()

    def try_cancel(self, reason: str = "cancelled") -> bool:
        """Cancel iff the job has not started; a cancelled job never runs.

        Stamps ``error`` so EVERY resolution path — the generic
        ``done.wait()`` path included — agrees the job failed; without the
        stamp a cancelled job would resolve as ``ok=True, value=None``.
        """
        with self._lock:
            if self.started_at is None and not self.done.is_set():
                self.cancelled = True
                self.error = reason
                self.done.set()
                return True
            return False


JobHandle = _Job


class Scheduler:
    """Fan named jobs out over a bounded worker pool; see module docstring.

    Args:
        max_workers: slot budget shared by every job submitted to this
            instance, across all threads and nested fan-out.
        timeout_s: per-job timeout measured from job start. Thread mode
            abandons the worker thread on expiry; process mode kills the
            child process and frees the slot.
        isolation: ``"thread"`` (default) or ``"process"`` (fork per job;
            timeout-killable, picklable results required).
    """

    def __init__(self, max_workers: int = 4,
                 timeout_s: Optional[float] = None,
                 isolation: str = "thread") -> None:
        if isolation not in ISOLATION_MODES:
            raise ValueError(
                f"isolation must be one of {ISOLATION_MODES}, "
                f"got {isolation!r}")
        self.max_workers = max(1, int(max_workers))
        self.timeout_s = timeout_s
        self.isolation = isolation
        self._slots = threading.Semaphore(self.max_workers)
        self._local = threading.local()      # .holds_slot on worker threads
        # last observed pool activity (job submitted/started/finished):
        # what the wedged-pool cancellation path measures staleness against
        self._progress = {"t": time.perf_counter()}
        self._meter_lock = threading.Lock()
        self._running = 0
        self._peak = 0
        self._completed = 0

    # -- submission ----------------------------------------------------------

    def submit(self, name: str, fn: Callable[[], Any], *,
               after: Sequence["_Job"] = ()) -> _Job:
        """Submit one job; returns its handle immediately.

        ``after``: handles this job must wait for. Dependencies are
        *ordering only* — the job runs even if a dependency failed; read
        ``dep.error``/``dep.value`` inside ``fn`` to react (the matrix
        turns failed-base errors into attributed leg errors this way).
        """
        job = _Job(name, fn, after=tuple(after))
        self._progress["t"] = time.perf_counter()
        threading.Thread(target=self._worker, args=(job,),
                         daemon=True).start()
        return job

    @contextlib.contextmanager
    def yielding(self) -> Iterator[None]:
        """Release the calling job's slot for the duration of the block.

        The slot-yield primitive behind the pool's re-entrancy: a job that
        blocks — waiting on nested sub-jobs (``wait``), or pacing out an
        LLM rate limit (:class:`repro.llm.LLMSession`) — wraps the blocking
        region in ``with scheduler.yielding():`` and its slot goes to a
        runnable job instead of idling; the slot is re-acquired on exit.
        Called from a thread that holds no slot (the coordinator, a nested
        yield), it is a no-op — safe to use unconditionally.
        """
        held = getattr(self._local, "holds_slot", False)
        if held:
            self._local.holds_slot = False
            self._slots.release()
        try:
            yield
        finally:
            if held:
                self._slots.acquire()
                self._local.holds_slot = True

    def wait(self, jobs: Sequence[_Job],
             on_result: Optional[Callable[[JobResult], None]] = None
             ) -> List[JobResult]:
        """Block until every handle resolves; results in ``jobs`` order.

        Re-entrant: when called from inside a job of this same scheduler,
        the caller's slot is released for the duration of the wait (and
        re-acquired after, via :meth:`yielding`), so nested fan-out cannot
        deadlock the pool. ``on_result`` is invoked from the waiting thread
        as each job resolves, in ``jobs`` order.

        With thread-mode timeouts and ``after`` edges, wait on every job
        of the graph (as the matrix does), not just the sinks: a job
        queued behind a wedged pool is cancelled by *its* waiter's
        starvation check, and a multi-hop chain whose head hangs needs
        each link observed to propagate the timeout.
        """
        with self.yielding():
            results: List[JobResult] = []
            for job in jobs:
                res = self._await(job)
                results.append(res)
                if on_result is not None:
                    on_result(res)
            return results

    def run(self, jobs: Sequence[Tuple[str, Callable[[], Any]]],
            on_result: Optional[Callable[[JobResult], None]] = None
            ) -> List[JobResult]:
        """Execute all (name, thunk) jobs; results in submission order."""
        return self.wait([self.submit(name, fn) for name, fn in jobs],
                         on_result=on_result)

    def telemetry(self) -> Dict[str, int]:
        """Pool-utilization snapshot: ``running`` jobs now,
        ``peak_concurrent`` high-water mark, ``completed`` total. A job
        blocked in a nested ``wait`` still counts as running (it is
        in flight) even though it holds no slot."""
        with self._meter_lock:
            return {"max_workers": self.max_workers,
                    "running": self._running,
                    "peak_concurrent": self._peak,
                    "completed": self._completed}

    # -- worker --------------------------------------------------------------

    def _worker(self, job: _Job) -> None:
        for dep in job.after:
            while not dep.done.wait(timeout=0.25):
                # thread mode cannot kill a hung dependency, but it must
                # not strand dependents either: once the dependency blows
                # its timeout, flag it resolved-as-failed so this job (and
                # every waiter) proceeds. Without this, a hung dependency's
                # done event never fires and wait() deadlocks.
                if self.timeout_s is not None \
                        and self.isolation != "process" \
                        and dep.started_at is not None \
                        and time.perf_counter() - dep.started_at \
                        >= self.timeout_s:
                    # (process mode never needs this: the dependency's own
                    # worker kills the child and sets done itself)
                    self._flag_timeout(dep)
        # acquire in short slices so a job cancelled while queued neither
        # runs nor leaks a thread blocked on the semaphore forever
        while not self._slots.acquire(timeout=0.25):
            if job.done.is_set():
                return
        if job.done.is_set():               # cancelled between poll & acquire
            self._slots.release()
            return
        self._local.holds_slot = True
        watchdog: Optional[threading.Timer] = None
        try:
            with job._lock:
                if job.cancelled:
                    return
                job.started_at = self._progress["t"] = time.perf_counter()
            with self._meter_lock:
                self._running += 1
                self._peak = max(self._peak, self._running)
            if self.timeout_s is not None and self.isolation != "process":
                # thread-mode deadline even when NOBODY is observing the
                # job: a waiter-side check alone (``_await``/dependency
                # polls) leaves a fire-and-wait-later job hanging its
                # waiter until it happens to look. The watchdog stamps the
                # same ``timeout ... abandoned`` error the observers do, so
                # e.g. a matrix leg wedged on one graph job resolves at the
                # deadline no matter how it is awaited.
                watchdog = threading.Timer(self.timeout_s,
                                           self._flag_timeout, args=(job,))
                watchdog.daemon = True
                watchdog.start()
            try:
                if self.isolation == "process":
                    job.value = self._run_in_child(job)
                else:
                    job.value = job.fn()
            except BaseException as exc:  # noqa: BLE001 — isolate
                job.error = f"{type(exc).__name__}: {exc}"
            now = self._progress["t"] = time.perf_counter()
            with self._meter_lock:
                self._running -= 1
                self._completed += 1
            with job._lock:
                if job.done.is_set():
                    # the watchdog (or an observer) already resolved this
                    # job as timed out; keep that verdict — the late value
                    # must not resurrect a job every waiter saw fail
                    return
                job.duration_s = now - job.started_at
                job.finished_at = now
            job.done.set()
        finally:
            if watchdog is not None:
                watchdog.cancel()
            self._local.holds_slot = False
            self._slots.release()

    def _run_in_child(self, job: _Job) -> Any:
        """Run ``job.fn`` in a forked child; kill it on timeout.

        The child sends ``("ok", value)`` or ``("error", message)`` over a
        pipe. The parent polls the pipe *while* the child runs (receiving
        before join, so a large result can never deadlock the pipe buffer)
        and SIGKILLs the child when ``timeout_s`` expires.
        """
        import multiprocessing
        ctx = multiprocessing.get_context("fork")
        recv, send = ctx.Pipe(duplex=False)

        def child() -> None:
            try:
                value = job.fn()
                try:
                    send.send(("ok", value))
                except Exception as exc:  # unpicklable result
                    send.send(("error",
                               f"result not picklable: "
                               f"{type(exc).__name__}: {exc}"))
            except BaseException as exc:  # noqa: BLE001 — isolate
                try:
                    send.send(("error", f"{type(exc).__name__}: {exc}"))
                except Exception:
                    pass
            finally:
                send.close()

        proc = ctx.Process(target=child, daemon=True)
        proc.start()
        send.close()
        deadline = (None if self.timeout_s is None
                    else time.perf_counter() + self.timeout_s)
        msg = None
        while msg is None:
            step = 0.1
            if deadline is not None:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    break
                step = min(step, remaining)
            if recv.poll(step):
                try:
                    msg = recv.recv()
                except EOFError:
                    break
                continue
            if not proc.is_alive():
                if recv.poll(0):        # drain a result buffered at exit
                    try:
                        msg = recv.recv()
                    except EOFError:
                        pass
                break
        if msg is None and proc.is_alive():
            pid = proc.pid
            proc.kill()
            proc.join(10.0)
            job.error = (f"timeout after {self.timeout_s:.0f}s "
                         f"(worker process pid={pid} killed)")
            return None
        proc.join(10.0)
        if msg is None:
            job.error = (f"worker process died without a result "
                         f"(exit code {proc.exitcode})")
            return None
        tag, payload = msg
        if tag == "ok":
            return payload
        job.error = payload
        return None

    # -- resolution ----------------------------------------------------------

    def _flag_timeout(self, job: _Job) -> None:
        """Mark a started-but-hung job resolved as a timeout failure.

        The worker thread itself is abandoned (it cannot be killed and
        still holds its slot); stamping error + done here makes every
        observer — waiters and dependents alike — agree the job failed,
        instead of each waiter privately timing out while dependents hang
        forever on a done event nobody will ever set. If the abandoned
        thread eventually finishes anyway, ``error`` stays set, so the job
        still resolves as failed everywhere.
        """
        with job._lock:
            if job.done.is_set():
                return
            job.error = (f"timeout after {self.timeout_s:.0f}s "
                         "(worker thread abandoned)")
            job.finished_at = time.perf_counter()
            job.duration_s = job.finished_at - (job.started_at
                                                or job.finished_at)
            job.done.set()

    def _await(self, job: _Job) -> JobResult:
        if self.timeout_s is None or self.isolation == "process":
            # process mode enforces the timeout in the worker (the child is
            # killed and the slot freed), so the waiter just waits
            job.done.wait()
            return self._resolve(job)
        while True:
            started = job.started_at
            if started is not None:
                remaining = self.timeout_s - (time.perf_counter() - started)
                if job.done.wait(timeout=max(0.0, remaining)):
                    return self._resolve(job)
                self._flag_timeout(job)
                return self._resolve(job)
            # queued: wait a quantum for a worker slot; give up only once
            # the pool has shown no progress (no job submitted, starting or
            # finishing) for a full timeout — i.e. every worker is wedged.
            # A job still waiting on `after` dependencies is not starved:
            # it is not competing for a slot yet, so it is never cancelled
            # here (its dependencies either finish — progress — or are hung
            # jobs that get flagged themselves).
            if job.done.wait(timeout=min(1.0, self.timeout_s)):
                return self._resolve(job)
            if job.started_at is None \
                    and all(dep.done.is_set() for dep in job.after) \
                    and time.perf_counter() - self._progress["t"] \
                    >= self.timeout_s \
                    and job.try_cancel(
                        f"never started within {self.timeout_s:.0f}s of "
                        "last pool progress (workers wedged); cancelled"):
                return self._resolve(job)

    def _resolve(self, job: _Job) -> JobResult:
        if job.error is not None:
            return JobResult(job.name, error=job.error,
                             duration_s=job.duration_s,
                             started_at=job.started_at,
                             finished_at=job.finished_at)
        return JobResult(job.name, value=job.value,
                         duration_s=job.duration_s,
                         started_at=job.started_at,
                         finished_at=job.finished_at)
