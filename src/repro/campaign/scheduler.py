"""Worker-pool scheduler with per-job timeout and failure isolation.

Threads are the right substrate here: verification time is dominated by jax
trace/compile/execute, which release the GIL, and candidate programs close
over unpicklable jax callables, so processes would buy latency, not
throughput. The pool is hand-rolled on *daemon* threads rather than
``ThreadPoolExecutor`` deliberately: the executor joins its non-daemon
workers at interpreter shutdown, so one genuinely hung kernel would block
process exit forever even after its timeout fired. Daemon workers let the
process exit the moment the campaign is done.

One exploding or hung job never takes down the campaign — its error (or a
timeout marker) is recorded in its :class:`JobResult` and every other job
completes normally. Timeouts are measured from when a job actually starts
executing, not from when the coordinator happens to look at it, so K
simultaneously hung jobs are all flagged ~timeout_s after they hang rather
than serially K×timeout_s later. A timed-out job's thread cannot be
force-killed; it is abandoned (and dies with the process), which is the
standard thread trade-off and is documented in the result's error. A job
starved of a worker slot because the whole pool is wedged on hung jobs is
cancelled (it never runs) and reported as such.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class JobResult:
    name: str
    value: Any = None
    error: Optional[str] = None
    duration_s: float = 0.0

    @property
    def ok(self) -> bool:
        return self.error is None


class _Job:
    """One unit of work plus its completion state."""

    def __init__(self, name: str, fn: Callable[[], Any]) -> None:
        self.name = name
        self.fn = fn
        self.done = threading.Event()
        self.value: Any = None
        self.error: Optional[str] = None
        self.duration_s = 0.0
        self.started_at: Optional[float] = None
        self.cancelled = False
        self._lock = threading.Lock()

    def try_cancel(self) -> bool:
        """Cancel iff the job has not started; a cancelled job never runs."""
        with self._lock:
            if self.started_at is None and not self.done.is_set():
                self.cancelled = True
                self.done.set()
                return True
            return False


class Scheduler:
    """Fan a list of named jobs out over a daemon-thread worker pool."""

    def __init__(self, max_workers: int = 4,
                 timeout_s: Optional[float] = None) -> None:
        self.max_workers = max(1, int(max_workers))
        self.timeout_s = timeout_s

    def run(self, jobs: Sequence[Tuple[str, Callable[[], Any]]],
            on_result: Optional[Callable[[JobResult], None]] = None
            ) -> List[JobResult]:
        """Execute all jobs; returns results in submission order.

        ``on_result`` (optional) is invoked from the coordinating thread as
        each job resolves — the campaign uses it for progress events.
        """
        progress = {"t": time.perf_counter()}   # last start or finish seen
        work: "queue.SimpleQueue[Optional[_Job]]" = queue.SimpleQueue()
        job_list = [_Job(name, fn) for name, fn in jobs]
        for job in job_list:
            work.put(job)
        for _ in range(self.max_workers):
            work.put(None)                      # one shutdown token each

        def worker() -> None:
            while True:
                job = work.get()
                if job is None:
                    return
                with job._lock:
                    if job.cancelled:
                        continue
                    job.started_at = progress["t"] = time.perf_counter()
                try:
                    job.value = job.fn()
                except BaseException as exc:  # noqa: BLE001 — isolate
                    job.error = f"{type(exc).__name__}: {exc}"
                now = progress["t"] = time.perf_counter()
                job.duration_s = now - job.started_at
                job.done.set()

        for _ in range(min(self.max_workers, len(job_list))):
            threading.Thread(target=worker, daemon=True).start()

        results: List[JobResult] = []
        for job in job_list:
            res = self._await(job, progress)
            results.append(res)
            if on_result is not None:
                on_result(res)
        return results

    def _await(self, job: _Job, progress: Dict[str, float]) -> JobResult:
        if self.timeout_s is None:
            job.done.wait()
            return self._resolve(job)
        while True:
            started = job.started_at
            if started is not None:
                remaining = self.timeout_s - (time.perf_counter() - started)
                if job.done.wait(timeout=max(0.0, remaining)):
                    return self._resolve(job)
                return JobResult(
                    job.name,
                    error=(f"timeout after {self.timeout_s:.0f}s "
                           "(worker thread abandoned)"),
                    duration_s=time.perf_counter() - started)
            # queued: wait a quantum for a worker slot; give up only once
            # the pool has shown no progress (no job starting or finishing)
            # for a full timeout — i.e. every worker is wedged.
            if job.done.wait(timeout=min(1.0, self.timeout_s)):
                return self._resolve(job)
            if job.started_at is None \
                    and time.perf_counter() - progress["t"] >= self.timeout_s \
                    and job.try_cancel():
                return JobResult(
                    job.name, error=(f"never started within "
                                     f"{self.timeout_s:.0f}s of last pool "
                                     "progress (workers wedged); cancelled"))

    def _resolve(self, job: _Job) -> JobResult:
        if job.error is not None:
            return JobResult(job.name, error=job.error,
                             duration_s=job.duration_s)
        return JobResult(job.name, value=job.value,
                         duration_s=job.duration_s)
