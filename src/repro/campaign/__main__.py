"""``python -m repro.campaign`` — run a synthesis campaign over KernelBench
and print the fast_p report aggregated from its JSONL event log.

Examples::

  python -m repro.campaign --suite small
  python -m repro.campaign --suite small --level 2 --workers 8 --iters 5
  python -m repro.campaign --search pbt --population 6 --generations 5
  python -m repro.campaign --suite small --platform gpu_sim
  python -m repro.campaign --suite small --platform gpu_sim \
      --transfer-from tpu_v5e                 # §6.2 transfer sweep
  python -m repro.campaign --matrix           # every ordered platform pair
  python -m repro.campaign --matrix --platforms tpu_v5e metal_m2
  python -m repro.campaign --matrix --matrix-workers 4 --leg-workers 8
  python -m repro.campaign --matrix --isolate --timeout 600
  python -m repro.campaign --log runs/c1.jsonl           # resumable
  python -m repro.campaign --log runs/c1.jsonl --report-only
  python -m repro.campaign --cache-path runs/verify.jsonl  # cross-process
  python -m repro.campaign --backend llm --record runs/s1.jsonl
  python -m repro.campaign --backend llm --replay runs/s1.jsonl \
      --platform metal_m2                 # deterministic, 0 live calls
  python -m repro.campaign --backend llm --analysis llm --use-profiling \
      --replay runs/s1.jsonl              # two-agent loop, 0 live calls
  python -m repro.campaign --matrix --backend llm --rpm 60 --tpm 200000
  python -m repro.campaign --matrix --backend llm --leg-timeout 900
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from repro.campaign.cache import VerificationCache, format_cache_stats
from repro.core.evalio import ExecutableCache, WorkloadIOCache
from repro.campaign.events import EventLog
from repro.campaign.report import (distinct_loop_configs, format_report,
                                   report_from_events)
from repro.campaign.matrix import run_transfer_matrix
from repro.campaign.runner import Campaign, CampaignConfig
from repro.campaign.scheduler import Scheduler
from repro.campaign.transfer import run_transfer_sweep
from repro.core import kernelbench
from repro.core.refinement import LoopConfig
from repro.platforms import DEFAULT_PLATFORM, available_platforms


def build_parser() -> argparse.ArgumentParser:
    """The ``python -m repro.campaign`` argument parser (kept separate so
    tests and docs can introspect the flags)."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.campaign",
        description="concurrent, cached, resumable KForge synthesis campaign")
    ap.add_argument("--suite", choices=("small", "full"), default="small",
                    help="KernelBench-JAX suite size (default: small)")
    ap.add_argument("--level", type=int, choices=(1, 2, 3), default=None,
                    help="restrict to one KernelBench level")
    ap.add_argument("--iters", type=int, default=5,
                    help="refinement iterations per workload (default: 5)")
    ap.add_argument("--single-shot", action="store_true",
                    help="one generation per workload, no refinement")
    ap.add_argument("--reference", action="store_true",
                    help="cross-platform reference configuration (§6.2)")
    ap.add_argument("--profiling", "--use-profiling", action="store_true",
                    help="enable the performance-analysis agent (§5.2); "
                         "--use-profiling is an alias matching the "
                         "LoopConfig field name")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fanout", type=int, default=1, metavar="K",
                    help="verify the agent's proposal plus the top K-1 "
                         "predicted mutations per optimization iteration "
                         "as one batch sharing inputs and the reference "
                         "oracle (default: 1 = classic loop)")
    ap.add_argument("--search", choices=("lineage", "pbt"),
                    default="lineage",
                    help="candidate search mode: the single-lineage "
                         "refinement loop (default) or population-based "
                         "search — K lineages per workload evolved by "
                         "truncation selection + exploit/explore "
                         "(repro.campaign.population)")
    ap.add_argument("--population", type=int, default=None, metavar="K",
                    help="(--search pbt) lineages per workload "
                         "(default: 4)")
    ap.add_argument("--generations", type=int, default=None, metavar="G",
                    help="(--search pbt) generations of the exploit/"
                         "explore loop per workload (default: 4)")
    ap.add_argument("--direction", choices=("fwd", "fwd_bwd"),
                    default="fwd",
                    help="verification direction: forward output only "
                         "(default) or forward plus input gradients "
                         "against the jax.vjp oracle; fwd_bwd restricts "
                         "the suite to differentiable workloads")
    ap.add_argument("--platform", choices=available_platforms(),
                    default=DEFAULT_PLATFORM,
                    help="hardware target to synthesize for "
                         f"(default: {DEFAULT_PLATFORM})")
    ap.add_argument("--transfer-from", choices=available_platforms(),
                    default=None, metavar="PLATFORM",
                    help="run the §6.2 transfer sweep: campaign on this "
                         "source platform first, then --platform cold and "
                         "with the harvested references")
    ap.add_argument("--matrix", action="store_true",
                    help="run the transfer sweep over EVERY ordered "
                         "platform pair and print the uplift heat-map "
                         "(all registered platforms, or --platforms)")
    ap.add_argument("--platforms", nargs="+", default=None,
                    metavar="PLATFORM",
                    help="restrict --matrix to these platforms (>= 2)")
    ap.add_argument("--matrix-workers", type=int, default=None,
                    help="how many --matrix campaign legs run concurrently "
                         "(default: --workers)")
    ap.add_argument("--leg-workers", type=int, default=None,
                    help="total workload-verification worker budget shared "
                         "by every in-flight --matrix leg "
                         "(default: --workers)")
    ap.add_argument("--isolate", action="store_true",
                    help="run each --matrix leg in a forked child process "
                         "so --timeout bounds the whole leg and a hung leg "
                         "is killed instead of abandoned")
    ap.add_argument("--backend", choices=("template", "llm"),
                    default="template",
                    help="generation agent: the offline template search "
                         "(default) or LLM sessions over the repro.llm "
                         "transport layer (MockTransport unless "
                         "KFORGE_LLM_ENDPOINT or --replay selects another)")
    ap.add_argument("--analysis", choices=("rule", "llm"), default="rule",
                    help="performance-analysis agent G: the deterministic "
                         "rule table (default) or LLM analysis sessions "
                         "over the same transport as --backend llm "
                         "(requires --backend llm; meaningful with "
                         "--profiling, which enables agent G at all)")
    ap.add_argument("--leg-timeout", type=float, default=None,
                    metavar="SECONDS",
                    help="(--matrix, thread mode) deadline for each whole "
                         "campaign leg: a hung leg resolves as a timeout "
                         "error instead of wedging a graph slot forever "
                         "(LLM matrices are thread-mode only; with "
                         "--isolate, --timeout already bounds each leg)")
    ap.add_argument("--record", default=None, metavar="SESSION",
                    help="(--backend llm) record every prompt->completion "
                         "pair into this JSONL session file (resume-safe: "
                         "recorded keys are never re-spent)")
    ap.add_argument("--replay", default=None, metavar="SESSION",
                    help="(--backend llm) replay a recorded session "
                         "byte-for-byte with ZERO live calls")
    ap.add_argument("--rpm", type=float, default=None,
                    help="(--backend llm) shared requests-per-minute "
                         "budget across all workers/legs")
    ap.add_argument("--tpm", type=float, default=None,
                    help="(--backend llm) shared tokens-per-minute budget "
                         "across all workers/legs")
    ap.add_argument("--cache-path", default=None,
                    help="persistent JSONL verification cache shared "
                         "across processes (and across both sweep legs)")
    ap.add_argument("--workers", type=int, default=4,
                    help="worker threads (default: 4)")
    ap.add_argument("--timeout", type=float, default=None,
                    help="per-workload timeout in seconds")
    ap.add_argument("--log", default=None,
                    help="JSONL event log path (default: "
                         "campaign-<suite>.jsonl)")
    ap.add_argument("--no-resume", action="store_true",
                    help="ignore terminal events in an existing log")
    ap.add_argument("--report-only", action="store_true",
                    help="skip running; aggregate the existing log")
    return ap


def _print_fastpath_stats(io_cache, exe_cache) -> None:
    """The fast-path cache-effectiveness lines every CLI branch prints
    under the verification-cache line (None = leg-local caches, e.g.
    --isolate, nothing meaningful to print in the parent)."""
    if io_cache is not None:
        s = io_cache.stats()
        line = (f"io cache: {format_cache_stats(s)}, "
                f"{s['oracle_computes']} oracle computes")
        if s.get("grad_oracle_computes"):
            line += f", {s['grad_oracle_computes']} grad oracle computes"
        if s.get("io_sig_fallbacks"):
            line += (f"  [WARNING: {s['io_sig_fallbacks']} io-signature "
                     "concrete fallbacks]")
        print(line)
    if exe_cache is not None:
        print(f"executable cache: "
              f"{format_cache_stats(exe_cache.stats())}")


def main(argv: Optional[List[str]] = None) -> int:
    """CLI entry point; returns a process exit code (0 on success, 1 on
    empty --report-only logs or failed matrix legs, 2 on usage errors)."""
    ap = build_parser()
    args = ap.parse_args(argv)
    if args.transfer_from is not None and args.transfer_from == args.platform:
        ap.error(f"--transfer-from {args.transfer_from} --platform "
                 f"{args.platform}: source and target platform must differ "
                 "(a same-platform sweep would just re-run the source "
                 "campaign and report zero uplift); available: "
                 + ", ".join(available_platforms()))
    if args.matrix and args.transfer_from:
        ap.error("--matrix already runs every ordered platform pair; "
                 "it cannot be combined with --transfer-from")
    if args.matrix and args.platform != DEFAULT_PLATFORM:
        ap.error("--platform does not scope --matrix; use "
                 "--platforms A B ... to restrict the platform set")
    if args.platforms is not None and not args.matrix:
        ap.error("--platforms only applies to --matrix")
    for flag, value in (("--matrix-workers", args.matrix_workers),
                        ("--leg-workers", args.leg_workers),
                        ("--isolate", args.isolate or None)):
        if value is not None and not args.matrix:
            ap.error(f"{flag} only applies to --matrix")
    for flag, value in (("--record", args.record), ("--replay", args.replay),
                        ("--rpm", args.rpm), ("--tpm", args.tpm)):
        if value is not None and args.backend != "llm":
            ap.error(f"{flag} only applies to --backend llm")
    if args.analysis == "llm" and args.backend != "llm":
        ap.error("--analysis llm requires --backend llm: the LLM analyzer "
                 "rides the same transport sessions as LLM generation")
    if args.leg_timeout is not None and not args.matrix:
        ap.error("--leg-timeout only applies to --matrix")
    if args.leg_timeout is not None and args.isolate:
        ap.error("--leg-timeout only applies to thread-mode --matrix; with "
                 "--isolate, --timeout already bounds each leg (the child "
                 "process is killed on expiry)")
    if args.fanout < 1:
        ap.error(f"--fanout must be >= 1, got {args.fanout} (1 = the "
                 "classic single-candidate loop)")
    for flag, value in (("--population", args.population),
                        ("--generations", args.generations)):
        if value is not None and args.search != "pbt":
            ap.error(f"{flag} only applies to --search pbt")
    if args.search == "pbt":
        if args.backend == "llm":
            ap.error("--search pbt requires --backend template: population "
                     "search exploit-copies and mutates declarative tiling "
                     "params, which LLM callable candidates do not carry")
        if args.single_shot:
            ap.error("--search pbt cannot run --single-shot (a population "
                     "generation is already one batch; use --generations 1 "
                     "for a single generation)")
        if args.fanout != 1:
            ap.error("--fanout is the single-lineage loop's batch knob; "
                     "--search pbt already verifies whole generations as "
                     "batches")
        if args.population is not None and args.population < 2:
            ap.error(f"--population must be >= 2, got {args.population} "
                     "(one member is just the single-lineage loop)")
        if args.generations is not None and args.generations < 1:
            ap.error(f"--generations must be >= 1, got {args.generations}")
    if args.record and args.replay:
        ap.error("--record and --replay are mutually exclusive (a replayed "
                 "session makes no live calls to record)")
    if args.backend == "llm" and args.isolate:
        ap.error("--backend llm cannot run with --isolate: the shared "
                 "transport/rate-limiter state does not survive per-leg "
                 "forks; drop --isolate for LLM matrices")
    if args.platforms is not None:
        unknown = sorted(set(args.platforms) - set(available_platforms()))
        if unknown:
            ap.error(f"unknown platform(s) {', '.join(unknown)}; available: "
                     + ", ".join(available_platforms()))
        if len(set(args.platforms)) < 2:
            ap.error("--matrix needs at least 2 distinct platforms")
    log_path = args.log or f"campaign-{args.suite}.jsonl"

    if args.report_only:
        events = EventLog(log_path).events()
        if not events:
            print(f"no events in {log_path}", file=sys.stderr)
            return 1
        loops = distinct_loop_configs(events)
        if len(loops) <= 1:
            print(format_report(report_from_events(events)))
        else:
            # the log interleaves runs of several configs: report each
            # separately rather than blending them into one fast_p curve
            for loop in loops:
                desc = " ".join(f"{k}={v}" for k, v in sorted(loop.items()))
                print(f"--- loop config: {desc}")
                print(format_report(report_from_events(events, loop=loop)))
                print()
        return 0

    suite_kw = {}
    if args.direction == "fwd_bwd":
        suite_kw["differentiable"] = True
    workloads = kernelbench.suite(
        args.level, small=args.suite == "small", **suite_kw)
    if not workloads:
        ap.error(f"--direction {args.direction} with --suite {args.suite}"
                 + (f" --level {args.level}" if args.level else "")
                 + ": no differentiable workloads in that selection "
                 "(fwd_bwd verification needs a jax.vjp-compatible oracle)")
    pbt_kw = {}
    if args.population is not None:
        pbt_kw["population"] = args.population
    if args.generations is not None:
        pbt_kw["generations"] = args.generations
    loop = LoopConfig(num_iterations=args.iters,
                      single_shot=args.single_shot,
                      use_reference=args.reference,
                      use_profiling=args.profiling, seed=args.seed,
                      platform=args.platform, fanout=args.fanout,
                      search=args.search, direction=args.direction,
                      **pbt_kw)
    cache = (VerificationCache.open(args.cache_path)
             if args.cache_path else VerificationCache())
    # fast-path caches (DESIGN.md §4), shared by every leg of whatever runs
    # below (the matrix swaps them for per-leg instances under --isolate)
    io_cache = WorkloadIOCache()
    exe_cache = ExecutableCache()

    llm_ctx = None
    if args.backend == "llm":
        from repro.llm import TransportError, build_llm_context
        try:
            llm_ctx = build_llm_context(record=args.record,
                                        replay=args.replay,
                                        rpm=args.rpm, tpm=args.tpm)
        except (TransportError, ValueError) as exc:
            # ValueError: e.g. --rpm 0 / --tpm 0 (budgets must be positive)
            ap.error(str(exc))

    if args.matrix:
        # No default event log for the matrix: with only --cache-path, a
        # rerun re-verifies every leg against the persistent cache (100%
        # hits) instead of skipping legs via log resume. Pass --log to get
        # journaling + resume on top.
        matrix = run_transfer_matrix(
            workloads, args.platforms, loop=loop, cache=cache,
            max_workers=args.workers,
            matrix_workers=args.matrix_workers,
            leg_workers=args.leg_workers,
            isolation="process" if args.isolate else "thread",
            timeout_s=args.timeout, leg_timeout_s=args.leg_timeout,
            log_path=args.log, resume=not args.no_resume,
            backend=args.backend, analysis=args.analysis, llm=llm_ctx,
            io_cache=io_cache, exe_cache=exe_cache)
        tele = matrix.telemetry
        print(f"transfer matrix: {len(workloads)} workloads x "
              f"{len(matrix.legs)} ordered pairs over "
              f"{len(matrix.platforms)} platforms "
              f"({tele['backend']} backend)"
              + (f" -> {args.log}" if args.log else ""))
        print(f"job graph: peak {tele['peak_concurrent_legs']} concurrent "
              f"legs (matrix_workers={tele['matrix_workers']}, "
              f"leg_workers={tele['leg_workers']}, "
              f"isolation={tele['isolation']}); "
              f"wall {tele['wall_s']:.1f}s vs "
              f"{tele['serial_sum_s']:.1f}s serial leg-time")
        print(f"verification cache: {format_cache_stats(cache.stats())}")
        _print_fastpath_stats(matrix.io_cache, matrix.exe_cache)
        if tele.get("llm_usage"):
            from repro.llm import format_usage
            print(f"llm usage: {format_usage(tele['llm_usage'])}")
        print()
        print(matrix.heatmap_text())
        print()
        print(matrix.heatmap_text(metric="delta_iters"))
        for (src, dst), leg in sorted(matrix.legs.items()):
            if not leg.ok:
                print(f"FAILED {src}->{dst}: {leg.error}", file=sys.stderr)
        return 1 if matrix.n_failed else 0

    if args.transfer_from:
        # LLM sweeps get an explicit shared scheduler so throttled sessions
        # can yield their slot (the sweep's agent factories receive it)
        sweep_sched = Scheduler(max_workers=args.workers,
                                timeout_s=args.timeout) \
            if llm_ctx is not None else None
        sweep = run_transfer_sweep(
            workloads, from_platform=args.transfer_from,
            to_platform=args.platform, loop=loop, cache=cache,
            max_workers=args.workers, timeout_s=args.timeout,
            log_path=log_path, resume=not args.no_resume,
            backend=args.backend, analysis=args.analysis, llm=llm_ctx,
            scheduler=sweep_sched, io_cache=io_cache, exe_cache=exe_cache)
        print(f"transfer sweep: {len(workloads)} workloads x 3 legs "
              f"({args.backend} backend) -> {log_path}")
        print(f"verification cache: {format_cache_stats(cache.stats())}")
        _print_fastpath_stats(io_cache, exe_cache)
        if llm_ctx is not None:
            from repro.llm import format_usage
            print(f"llm usage: {format_usage(llm_ctx.usage.snapshot())}")
        print()
        print(sweep.report_text())
        return 0

    cfg = CampaignConfig(loop=loop, max_workers=args.workers,
                         timeout_s=args.timeout, log_path=log_path,
                         resume=not args.no_resume)
    if llm_ctx is not None:
        # an explicit scheduler so the sessions' pacing sleeps can yield
        # their worker slot back to runnable verification jobs
        sched = Scheduler(max_workers=args.workers, timeout_s=args.timeout)
        campaign = Campaign(
            workloads, cfg, cache=cache, scheduler=sched,
            agent_factory=llm_ctx.agent_factory(platform=args.platform,
                                                scheduler=sched),
            analyzer_factory=(llm_ctx.analyzer_factory(
                platform=args.platform, scheduler=sched)
                if args.analysis == "llm" else None),
            usage=llm_ctx.usage, io_cache=io_cache, exe_cache=exe_cache)
    else:
        campaign = Campaign(workloads, cfg, cache=cache,
                            io_cache=io_cache, exe_cache=exe_cache)
    result = campaign.run()

    done = sum(1 for r in result.runs if r.error is None and not r.skipped)
    print(f"campaign[{args.platform}]: {len(result.runs)} workloads "
          f"({result.n_skipped} resumed, {result.n_failed} failed, "
          f"{done} ran ok) -> {result.log_path}")
    print(f"verification cache: "
          f"{format_cache_stats(result.cache.stats())}")
    _print_fastpath_stats(io_cache, exe_cache)
    if result.llm_usage is not None:
        from repro.llm import format_usage
        print(f"llm usage: {format_usage(result.llm_usage)}")
    print()
    print(campaign.report_text())
    return 0


if __name__ == "__main__":
    sys.exit(main())
