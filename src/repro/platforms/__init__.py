"""Hardware-target registry: the platform abstraction KForge retargets over.

``Platform`` bundles everything one accelerator target needs — roofline
constants for the performance model, tile-alignment/legality rules, the
prompt descriptor + one-shot example, a compiler-params hook, and
per-platform reference-transfer hints. ``resolve_platform`` is the one
entry point call sites use (name | Platform | None).

Import-leaf package: must not import from ``repro.core`` / ``repro.roofline``
(they import us).
"""
from repro.platforms.base import Platform, PlatformLike  # noqa: F401
from repro.platforms.registry import (  # noqa: F401
    DEFAULT_PLATFORM, available_platforms, get_platform, register_platform,
    resolve_platform,
)
from repro.platforms import examples  # noqa: F401

# The old module constant, now derived from the registry; only this package
# may export it (no module outside repro/platforms imports HW_V5E directly).
HW_V5E = get_platform("tpu_v5e").hw
